//! Golden-model service: owns the (non-`Send`) PJRT runtime on a
//! dedicated thread and serves batched class-sum requests over channels.
//!
//! This is the coordinator's "functional path": requests routed to the
//! golden model are batched by the dynamic batcher and executed as one
//! XLA call on the AOT-compiled artifact whose batch size fits (inputs
//! are padded up; padding rows are discarded).

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};

/// A batched execution request for one model family.
pub struct GoldenRequest {
    /// `"multiclass_tm"` or `"cotm"`.
    pub family: String,
    /// Row-major (n × F) features in {0,1}.
    pub features: Vec<Vec<f32>>,
    /// Reply channel: per-row (class sums, argmax).
    pub reply: mpsc::Sender<Result<Vec<(Vec<f32>, usize)>>>,
}

enum Msg {
    Run(GoldenRequest),
    Shutdown,
}

/// Handle to the golden-model thread.
pub struct GoldenService {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

/// A cloneable, `Send` client to the golden-model thread (the service
/// handle itself owns the join handle; clients just carry a sender).
#[derive(Clone)]
pub struct GoldenClient {
    tx: mpsc::Sender<Msg>,
}

impl GoldenClient {
    /// Submit a batch and wait for the reply.
    pub fn infer_batch(
        &self,
        family: &str,
        features: Vec<Vec<f32>>,
    ) -> Result<Vec<(Vec<f32>, usize)>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Run(GoldenRequest {
                family: family.to_string(),
                features,
                reply: reply_tx,
            }))
            .map_err(|_| Error::coordinator("golden service stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| Error::coordinator("golden service dropped reply"))?
    }
}

/// Model parameters the service needs (flattened, f32).
#[derive(Debug, Clone)]
pub struct GoldenModels {
    /// Multi-class include masks (K·C × 2F), or empty to disable.
    pub multiclass_include: Vec<f32>,
    /// CoTM include masks (C × 2F), or empty to disable.
    pub cotm_include: Vec<f32>,
    /// CoTM weights (K × C).
    pub cotm_weights: Vec<f32>,
}

impl GoldenService {
    /// Spawn the service thread: loads + compiles artifacts inside the
    /// thread (the runtime is not `Send`), then serves requests.
    pub fn spawn(artifacts_dir: String, models: GoldenModels) -> Result<GoldenService> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("golden-pjrt".into())
            .spawn(move || {
                let rt = match super::Runtime::load(&artifacts_dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(Msg::Run(req)) = rx.recv() {
                    let result = run_batch(&rt, &models, &req);
                    let _ = req.reply.send(result);
                }
            })
            .map_err(|e| Error::coordinator(format!("spawn golden thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::coordinator("golden thread died during load"))??;
        Ok(GoldenService { tx, handle: Some(handle) })
    }

    /// A cloneable `Send` client for use from other threads.
    pub fn client(&self) -> GoldenClient {
        GoldenClient { tx: self.tx.clone() }
    }

    /// Submit a batch and wait for the reply.
    pub fn infer_batch(
        &self,
        family: &str,
        features: Vec<Vec<f32>>,
    ) -> Result<Vec<(Vec<f32>, usize)>> {
        self.client().infer_batch(family, features)
    }
}

impl Drop for GoldenService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn run_batch(
    rt: &super::Runtime,
    models: &GoldenModels,
    req: &GoldenRequest,
) -> Result<Vec<(Vec<f32>, usize)>> {
    let n = req.features.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let meta = rt.manifest.artifact_for_batch(&req.family, n)?;
    let b = meta.batch();
    let f = rt.manifest.features;
    let mut out = Vec::with_capacity(n);
    // Chunk the request into artifact-sized batches, padding the last.
    for chunk in req.features.chunks(b) {
        let mut flat = Vec::with_capacity(b * f);
        for row in chunk {
            if row.len() != f {
                return Err(Error::runtime(format!(
                    "feature row width {} != {f}",
                    row.len()
                )));
            }
            flat.extend_from_slice(row);
        }
        flat.resize(b * f, 0.0); // pad rows with zeros
        let inputs: Vec<Vec<f32>> = match req.family.as_str() {
            "multiclass_tm" => vec![flat, models.multiclass_include.clone()],
            "cotm" => vec![flat, models.cotm_include.clone(), models.cotm_weights.clone()],
            other => return Err(Error::runtime(format!("unknown family {other:?}"))),
        };
        let (rows, preds) = rt.execute_class_sums(&meta.name, &inputs)?;
        for (row, pred) in rows.into_iter().zip(preds).take(chunk.len()) {
            out.push((row, pred));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};

    fn service() -> Option<(GoldenService, data::Dataset, crate::tm::MultiClassTmModel, crate::tm::CoTmModel)>
    {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return None;
        }
        let d = data::iris().unwrap();
        let (tr, _) = d.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 30, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 30, 3).unwrap();
        let svc = GoldenService::spawn(
            "artifacts".into(),
            GoldenModels {
                multiclass_include: m.include_f32(),
                cotm_include: cm.include_f32(),
                cotm_weights: cm.weights_f32(),
            },
        )
        .unwrap();
        Some((svc, d, m, cm))
    }

    #[test]
    fn golden_matches_rust_reference_multiclass() {
        let Some((svc, d, m, _)) = service() else { return };
        let rows: Vec<Vec<f32>> = d.features[..20]
            .iter()
            .map(|r| r.iter().map(|&b| b as u8 as f32).collect())
            .collect();
        let out = svc.infer_batch("multiclass_tm", rows).unwrap();
        for (i, (sums, pred)) in out.iter().enumerate() {
            let want = crate::tm::infer::multiclass_class_sums(&m, &d.features[i]);
            let got: Vec<i32> = sums.iter().map(|&x| x as i32).collect();
            assert_eq!(got, want, "row {i}");
            assert_eq!(*pred, crate::tm::infer::predict_argmax(&want), "row {i}");
        }
    }

    #[test]
    fn golden_matches_rust_reference_cotm_with_padding() {
        let Some((svc, d, _, cm)) = service() else { return };
        // 5 rows forces the b16 artifact with 11 pad rows.
        let rows: Vec<Vec<f32>> = d.features[..5]
            .iter()
            .map(|r| r.iter().map(|&b| b as u8 as f32).collect())
            .collect();
        let out = svc.infer_batch("cotm", rows).unwrap();
        assert_eq!(out.len(), 5);
        for (i, (sums, _)) in out.iter().enumerate() {
            let want = crate::tm::infer::cotm_class_sums(&cm, &d.features[i]);
            let got: Vec<i32> = sums.iter().map(|&x| x as i32).collect();
            assert_eq!(got, want, "row {i}");
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let Some((svc, _, _, _)) = service() else { return };
        assert!(svc.infer_batch("cotm", vec![]).unwrap().is_empty());
    }

    #[test]
    fn unknown_family_is_error() {
        let Some((svc, d, _, _)) = service() else { return };
        let row: Vec<f32> = d.features[0].iter().map(|&b| b as u8 as f32).collect();
        assert!(svc.infer_batch("nope", vec![row]).is_err());
    }
}
