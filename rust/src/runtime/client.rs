//! PJRT CPU client wrapper: compile HLO-text artifacts once, execute
//! many times. Adapted from /opt/xla-example/load_hlo (HLO *text* is the
//! interchange format — xla_extension 0.5.1 rejects jax≥0.5 serialized
//! protos with 64-bit instruction ids).

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArtifactMeta, Manifest};

/// A loaded runtime: PJRT client + one compiled executable per artifact.
/// NOT `Send` — own it on a dedicated thread (see [`super::golden`]).
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load every artifact in the manifest and compile it.
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for (name, meta) in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                meta.file
                    .to_str()
                    .ok_or_else(|| Error::artifact("non-UTF-8 artifact path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(name.clone(), exe);
        }
        Ok(Runtime { manifest, client, executables })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .artifacts
            .get(name)
            .ok_or_else(|| Error::artifact(format!("unknown artifact {name:?}")))
    }

    /// Execute `name` with f32 row-major inputs matching the manifest
    /// shapes; returns the flattened f32 output.
    pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let meta = self.artifact(name)?;
        if inputs.len() != meta.args.len() {
            return Err(Error::runtime(format!(
                "{name}: got {} inputs, expected {}",
                inputs.len(),
                meta.args.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&meta.args) {
            let expected: usize = shape.iter().product();
            if data.len() != expected {
                return Err(Error::runtime(format!(
                    "{name}: input size {} != shape {:?} ({expected})",
                    data.len(),
                    shape
                )));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| Error::artifact(format!("artifact {name:?} not compiled")))?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute and reshape the (B, K) class-sum output into per-row
    /// argmax predictions alongside the raw sums.
    pub fn execute_class_sums(
        &self,
        name: &str,
        inputs: &[Vec<f32>],
    ) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
        let meta = self.artifact(name)?;
        let flat = self.execute(name, inputs)?;
        let k = *meta.out.last().unwrap_or(&1);
        let rows: Vec<Vec<f32>> = flat.chunks(k).map(|c| c.to_vec()).collect();
        let preds = rows
            .iter()
            .map(|r| {
                r.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect();
        Ok((rows, preds))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These run only when `make artifacts` has produced real outputs
    /// (always the case under `make test`).
    fn runtime() -> Option<Runtime> {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            Some(Runtime::load("artifacts").expect("runtime load"))
        } else {
            None
        }
    }

    #[test]
    fn loads_and_reports_platform() {
        let Some(rt) = runtime() else { return };
        assert!(rt.platform().to_lowercase().contains("cpu"));
        assert!(rt.manifest.artifacts.len() >= 6);
    }

    #[test]
    fn cotm_artifact_matches_rust_reference() {
        let Some(rt) = runtime() else { return };
        // Tiny deterministic CoTM; batch-1 artifact.
        let m = rt.manifest.clone();
        let (f, c, k) = (m.features, m.clauses, m.classes);
        let mut rng = crate::util::SplitMix64::new(9);
        let features: Vec<f32> = (0..f).map(|_| (rng.next_bool() as u8) as f32).collect();
        let include: Vec<f32> = (0..c * 2 * f).map(|_| (rng.chance(0.2) as u8) as f32).collect();
        let weights: Vec<f32> = (0..k * c).map(|_| (rng.next_below(15) as i64 - 7) as f32).collect();
        let (sums, _) = rt
            .execute_class_sums("cotm_b1", &[features.clone(), include.clone(), weights.clone()])
            .unwrap();
        // Rust reference.
        let feats: Vec<bool> = features.iter().map(|&x| x == 1.0).collect();
        let mut model = crate::tm::CoTmModel::zeroed(crate::tm::TmParams {
            features: f,
            clauses: c,
            classes: k,
            ..crate::tm::TmParams::iris_paper()
        });
        for j in 0..c {
            for l in 0..2 * f {
                model.clauses[j].include[l] = include[j * 2 * f + l] == 1.0;
            }
        }
        for kk in 0..k {
            for j in 0..c {
                model.weights[kk][j] = weights[kk * c + j] as i32;
            }
        }
        let want = crate::tm::infer::cotm_class_sums(&model, &feats);
        let got: Vec<i32> = sums[0].iter().map(|&x| x as i32).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn rejects_wrong_input_arity_and_shape() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("cotm_b1", &[vec![0.0; 16]]).is_err());
        let bad = vec![vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]];
        assert!(rt.execute("cotm_b1", &bad).is_err());
        assert!(rt.execute("nope", &[]).is_err());
    }
}
