//! `artifacts/manifest.json` — shapes and filenames of the AOT outputs,
//! written by `python/compile/aot.py` alongside the HLO text files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::Json;
use crate::error::{Error, Result};

/// One artifact's metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    /// Entry argument shapes, in order.
    pub args: Vec<Vec<usize>>,
    /// Output shape.
    pub out: Vec<usize>,
}

impl ArtifactMeta {
    /// Batch size (first dim of the first argument).
    pub fn batch(&self) -> usize {
        self.args.first().and_then(|s| s.first()).copied().unwrap_or(1)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub features: usize,
    pub clauses: usize,
    pub classes: usize,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.get("version")?.as_usize()?;
        if version != 1 {
            return Err(Error::artifact(format!("unsupported manifest version {version}")));
        }
        let mut artifacts = BTreeMap::new();
        for (name, meta) in j.get("artifacts")?.as_object()? {
            let args = meta
                .get("args")?
                .as_array()?
                .iter()
                .map(|shape| {
                    shape
                        .as_array()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<usize>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            let out = meta
                .get("out")?
                .as_array()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<usize>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: dir.join(meta.get("file")?.as_str()?),
                    args,
                    out,
                },
            );
        }
        Ok(Manifest {
            features: j.get("features")?.as_usize()?,
            clauses: j.get("clauses")?.as_usize()?,
            classes: j.get("classes")?.as_usize()?,
            artifacts,
        })
    }

    /// Available batch sizes for a model family (e.g. `"cotm"`),
    /// ascending.
    pub fn batches_for(&self, family: &str) -> Vec<usize> {
        let prefix = format!("{family}_b");
        let mut v: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|name| name.strip_prefix(&prefix)?.parse().ok())
            .collect();
        v.sort_unstable();
        v
    }

    /// Pick the smallest artifact batch ≥ `n` (or the largest if none).
    pub fn artifact_for_batch(&self, family: &str, n: usize) -> Result<&ArtifactMeta> {
        let batches = self.batches_for(family);
        if batches.is_empty() {
            return Err(Error::artifact(format!("no artifacts for family {family:?}")));
        }
        let b = batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or(*batches.last().unwrap());
        self.artifacts
            .get(&format!("{family}_b{b}"))
            .ok_or_else(|| Error::artifact(format!("missing artifact {family}_b{b}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1, "literal_order": "interleaved",
        "features": 16, "clauses": 12, "classes": 3,
        "artifacts": {
            "cotm_b1":  {"file": "cotm_b1.hlo.txt",  "args": [[1,16],[12,32],[3,12]], "out": [1,3]},
            "cotm_b16": {"file": "cotm_b16.hlo.txt", "args": [[16,16],[12,32],[3,12]], "out": [16,3]},
            "multiclass_tm_b1": {"file": "m.hlo.txt", "args": [[1,16],[3,12,32]], "out": [1,3]}
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.features, 16);
        assert_eq!(m.artifacts.len(), 3);
        let a = &m.artifacts["cotm_b16"];
        assert_eq!(a.batch(), 16);
        assert_eq!(a.file, PathBuf::from("/art/cotm_b16.hlo.txt"));
    }

    #[test]
    fn batch_selection() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.batches_for("cotm"), vec![1, 16]);
        assert_eq!(m.artifact_for_batch("cotm", 1).unwrap().batch(), 1);
        assert_eq!(m.artifact_for_batch("cotm", 5).unwrap().batch(), 16);
        // Larger than any: falls back to the largest.
        assert_eq!(m.artifact_for_batch("cotm", 99).unwrap().batch(), 16);
        assert!(m.artifact_for_batch("nonexistent", 1).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        // Integration check against the actual build output when it
        // exists (CI runs `make artifacts` first).
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let m = Manifest::load("artifacts").unwrap();
            assert_eq!(m.features, 16);
            assert!(!m.batches_for("multiclass_tm").is_empty());
            assert!(!m.batches_for("cotm").is_empty());
        }
    }
}
