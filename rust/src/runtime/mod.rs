//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client.
//!
//! This is the only place the crate touches XLA; Python never runs on
//! the request path — `make artifacts` runs it once at build time.
//!
//! Threading: the `xla` crate's handles wrap raw pointers and are not
//! `Send`, so [`golden::GoldenService`] owns the whole runtime on one
//! dedicated thread and serves requests over channels.
//!
//! Offline builds: the `xla` bindings crate cannot be vendored into the
//! offline CI image, so the real client is gated behind the `xla`
//! feature. Without it, [`client_stub`] provides the same API and
//! `Runtime::load` fails with a clear error — the coordinator's golden
//! backends then report "golden path disabled" while the simulated and
//! bit-parallel backends keep serving.

#[cfg(feature = "xla")]
pub mod client;
#[cfg(not(feature = "xla"))]
#[path = "client_stub.rs"]
pub mod client;
pub mod golden;
pub mod manifest;

pub use client::Runtime;
pub use golden::GoldenService;
pub use manifest::{ArtifactMeta, Manifest};
