//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client.
//!
//! This is the only place the crate touches XLA; Python never runs on
//! the request path — `make artifacts` runs it once at build time.
//!
//! Threading: the `xla` crate's handles wrap raw pointers and are not
//! `Send`, so [`golden::GoldenService`] owns the whole runtime on one
//! dedicated thread and serves requests over channels.

pub mod client;
pub mod golden;
pub mod manifest;

pub use client::Runtime;
pub use golden::GoldenService;
pub use manifest::{ArtifactMeta, Manifest};
