//! Stub PJRT client used when the crate is built without the `xla`
//! feature (the bindings crate is unavailable offline). Mirrors the API
//! of the real [`Runtime`](crate::runtime::client) so `golden.rs` and
//! the coordinator compile unchanged; `load` always fails, so the stub
//! is never actually constructed and every golden request surfaces a
//! clean "runtime unavailable" error instead of a link failure.

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArtifactMeta, Manifest};

/// Stand-in for the PJRT runtime. Cannot be constructed (no public
/// constructor besides the always-failing [`Runtime::load`]).
pub struct Runtime {
    pub manifest: Manifest,
}

fn unavailable() -> Error {
    Error::runtime(
        "built without the `xla` feature: the PJRT golden path needs the \
         xla bindings crate (see Cargo.toml); simulated and bit-parallel \
         backends remain available",
    )
}

impl Runtime {
    /// Always fails: there is no PJRT client in this build.
    pub fn load(_artifacts_dir: &str) -> Result<Runtime> {
        Err(unavailable())
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        "stub (no xla feature)".into()
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .artifacts
            .get(name)
            .ok_or_else(|| Error::artifact(format!("unknown artifact {name:?}")))
    }

    /// Execute `name` — always fails in the stub.
    pub fn execute(&self, _name: &str, _inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        Err(unavailable())
    }

    /// Execute and split class sums — always fails in the stub.
    pub fn execute_class_sums(
        &self,
        _name: &str,
        _inputs: &[Vec<f32>],
    ) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = Runtime::load("artifacts").unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
