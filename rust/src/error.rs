//! Crate-wide error type (hand-rolled `Display`/`Error` impls —
//! `thiserror` is unavailable offline).

use std::fmt;

/// Unified error for every subsystem of the crate.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / value errors (parser in [`crate::config`]).
    Config(String),

    /// Simulator invariant violations (e.g. event scheduled in the past).
    Sim(String),

    /// Netlist construction errors (dangling pins, double drivers, ...).
    Netlist(String),

    /// TM model shape / parameter errors.
    Model(String),

    /// AOT artifact loading / manifest errors.
    Artifact(String),

    /// PJRT runtime failures (compile / execute / literal marshalling).
    Runtime(String),

    /// Coordinator / serving failures (queue closed, worker died, ...).
    Coordinator(String),

    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Netlist(m) => write!(f, "netlist error: {m}"),
            Error::Model(m) => write!(f, "model error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

impl Error {
    /// Shorthand constructors used throughout the crate.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn sim(msg: impl Into<String>) -> Self {
        Error::Sim(msg.into())
    }
    pub fn netlist(msg: impl Into<String>) -> Self {
        Error::Netlist(msg.into())
    }
    pub fn model(msg: impl Into<String>) -> Self {
        Error::Model(msg.into())
    }
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_subsystem_prefix() {
        assert_eq!(Error::config("x").to_string(), "config error: x");
        assert_eq!(Error::coordinator("q").to_string(), "coordinator error: q");
    }

    #[test]
    fn io_errors_convert_and_expose_source() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
