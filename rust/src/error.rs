//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every subsystem of the crate.
#[derive(Error, Debug)]
pub enum Error {
    /// Configuration file / value errors (parser in [`crate::config`]).
    #[error("config error: {0}")]
    Config(String),

    /// Simulator invariant violations (e.g. event scheduled in the past).
    #[error("simulation error: {0}")]
    Sim(String),

    /// Netlist construction errors (dangling pins, double drivers, ...).
    #[error("netlist error: {0}")]
    Netlist(String),

    /// TM model shape / parameter errors.
    #[error("model error: {0}")]
    Model(String),

    /// AOT artifact loading / manifest errors.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT runtime failures (compile / execute / literal marshalling).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator / serving failures (queue closed, worker died, ...).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

impl Error {
    /// Shorthand constructors used throughout the crate.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn sim(msg: impl Into<String>) -> Self {
        Error::Sim(msg.into())
    }
    pub fn netlist(msg: impl Into<String>) -> Self {
        Error::Netlist(msg.into())
    }
    pub fn model(msg: impl Into<String>) -> Self {
        Error::Model(msg.into())
    }
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
}
