//! Descriptive statistics for benchmark / serving reports.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` on an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            count: n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
        })
    }
}

/// Nearest-rank percentile on a pre-sorted slice.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let rank = (q * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Online mean/variance accumulator (Welford) — used by the simulator's
/// energy accounting where sample vectors would be too large to retain.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_sample_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[5.0]).unwrap();
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - 1.5811388).abs() < 1e-6);
    }

    #[test]
    fn percentiles_monotone() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs).unwrap();
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
    }
}
