//! Deterministic PRNG (SplitMix64) — the crate's only randomness source.
//!
//! `rand` is not available offline; SplitMix64 is tiny, fast, passes
//! BigCrush when used as a 64-bit generator, and — critically for a
//! simulator — makes every experiment exactly reproducible from a seed.

/// SplitMix64 PRNG (Steele, Lea, Flood 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform u64 in `[0, bound)` (Lemire's multiply-shift rejection).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Random boolean.
    pub fn next_bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box–Muller (used for PVT jitter injection).
    pub fn next_gaussian(&mut self) -> f64 {
        // Avoid log(0) by nudging u1 away from zero.
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent child stream (for per-worker determinism).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn next_below_in_range() {
        let mut r = SplitMix64::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..50 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments_roughly_standard() {
        let mut r = SplitMix64::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
