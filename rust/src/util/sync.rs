//! Poison-tolerant locking, shared by every coordinator mutex user.
//!
//! A thread that panics while holding a `Mutex` poisons it; a bare
//! `.lock().unwrap()` on the next acquire then *re-raises* the panic in
//! every other thread touching the lock, cascading one contained worker
//! failure into a full-coordinator outage. Everything this crate guards
//! with a mutex (stats sample rings, the worker-pool job channel) holds
//! data whose every intermediate state is valid — samples are plain
//! `f64`s, the channel is externally synchronized — so the right
//! recovery is always to take the guard anyway.
//!
//! This helper is the only sanctioned way to acquire those locks: lint
//! rule R1 (`python/analysis/rules/r1_lock_discipline.py`) rejects bare
//! `.lock().unwrap()` / `.lock().expect(..)` everywhere in the tree.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard from a poisoned mutex instead of
/// propagating the poisoning panic.
///
/// Use this only where the protected data stays structurally valid
/// across a mid-update panic (true for all current users: sample rings
/// and channel receivers). If a future critical section can leave torn
/// state, repair it at the call site after taking the guard.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Mutex};

    fn poison(m: &Arc<Mutex<Vec<f64>>>) {
        let m2 = Arc::clone(m);
        let result = catch_unwind(AssertUnwindSafe(move || {
            let _guard = lock_unpoisoned(&m2);
            panic!("poison the mutex while holding the guard");
        }));
        assert!(result.is_err(), "the poisoning closure must panic");
        assert!(m.is_poisoned(), "the mutex must actually be poisoned");
    }

    #[test]
    fn recovers_guard_from_poisoned_mutex() {
        let m = Arc::new(Mutex::new(vec![1.0, 2.0]));
        poison(&m);
        // A bare .lock().unwrap() here would cascade the panic; the
        // helper hands back the guard with the data intact.
        let guard = lock_unpoisoned(&m);
        assert_eq!(*guard, vec![1.0, 2.0]);
    }

    #[test]
    fn poisoned_mutex_stays_writable() {
        let m = Arc::new(Mutex::new(Vec::new()));
        poison(&m);
        lock_unpoisoned(&m).push(7.0);
        lock_unpoisoned(&m).push(9.0);
        assert_eq!(*lock_unpoisoned(&m), vec![7.0, 9.0]);
    }
}
