//! Plain-text table rendering for benchmark output (paper Tables I/III/IV).

/// A simple left-aligned text table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push(' ');
                line.push_str(&cells[i]);
                line.push_str(&" ".repeat(widths[i] - cells[i].len()));
                line.push_str(" |");
            }
            line.push('\n');
            line
        };
        let rule: String = {
            let mut r = String::from("|");
            for w in &widths {
                r.push_str(&"-".repeat(w + 2));
                r.push('|');
            }
            r.push('\n');
            r
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str(&rule);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format a float with engineering-style precision for table cells.
pub fn fmt_eng(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{:.0}", x)
    } else if x.abs() >= 10.0 {
        format!("{:.1}", x)
    } else {
        format!("{:.3}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["a", "long-header"]);
        t.row(vec!["1", "2"]);
        t.row(vec!["wide-cell", "3"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines equal width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn fmt_eng_ranges() {
        assert_eq!(fmt_eng(0.0), "0");
        assert_eq!(fmt_eng(3329.0), "3329");
        assert_eq!(fmt_eng(12.34), "12.3");
        assert_eq!(fmt_eng(1.2345), "1.234");
    }
}
