//! Small shared utilities: deterministic PRNG, descriptive statistics,
//! poison-tolerant locking, and plain-text table rendering (no external
//! deps are available offline).

pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;

pub use rng::SplitMix64;
pub use stats::Summary;
pub use sync::lock_unpoisoned;
pub use table::Table;
