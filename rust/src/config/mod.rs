//! Configuration: a minimal TOML-subset parser (no external crates
//! offline) plus typed configuration structures for the serving
//! coordinator and experiment harnesses.

pub mod json;
pub mod toml;

pub use json::Json;
pub use toml::TomlDoc;

use crate::error::Result;
use crate::tm::async_train::TrainerChoice;
use crate::tm::compile::CompileMode;
use crate::tm::simd::SimdChoice;
use crate::wta::WtaKind;

/// Serving coordinator configuration (`tmtd serve --config <file>`).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Coordinator shards behind the consistent-hash front door
    /// (`coordinator::shard`). Each shard owns its own worker pool,
    /// batchers and engines; 1 = a single unsharded coordinator.
    pub shards: usize,
    /// Worker threads for hardware-simulation backends (per shard).
    pub workers: usize,
    /// Dynamic batcher: max batch (must be one of the AOT batch sizes).
    pub max_batch: usize,
    /// Dynamic batcher: flush timeout in microseconds.
    pub batch_timeout_us: u64,
    /// Bounded request queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Artifacts directory (AOT outputs).
    pub artifacts_dir: String,
    /// WTA topology for the proposed architectures.
    pub wta: WtaKind,
    /// `auto-*` backend crossover: models whose included-literal
    /// density is at or below this threshold serve through the
    /// event-driven inverted-index engines; denser models through the
    /// packed bit-parallel engines. Must be in [0, 1]; the default is
    /// [`crate::tm::index::PACKED_VS_INDEXED_DENSITY`].
    pub indexed_density_threshold: f64,
    /// Upper edge of the three-way `auto-*` crossover: models denser
    /// than `indexed_density_threshold` but at or below this threshold
    /// serve through the compressed include-list engines (ETHEREAL
    /// tier); denser models through the packed bit-parallel engines.
    /// Must be in [0, 1]; the default is
    /// [`crate::tm::compressed::PACKED_VS_COMPRESSED_DENSITY`].
    pub compressed_density_threshold: f64,
    /// SIMD lane width the packed engines evaluate through
    /// (`simd = "auto" | "scalar" | "portable" | "neon" | "avx2" |
    /// "avx512"`).
    /// `auto` (the default) picks the widest level detected at server
    /// build time; forcing an unavailable level fails the build
    /// cleanly. A speed decision only — the class sums are invariant
    /// under dispatch.
    pub simd: SimdChoice,
    /// Model-compile pass applied once at server build, feeding every
    /// engine family (`compile = "off" | "prune" | "full"`). `prune`
    /// (the default) removes dead clauses — exact, outputs are
    /// bit-identical; `full` additionally reorders clauses by fire
    /// probability over a deterministic synthetic calibration batch
    /// (also output-invariant); `off` serves the model byte-for-byte.
    pub compile: CompileMode,
    /// Remote shard addresses (`host:port`, comma-separated in TOML /
    /// on the CLI). Non-empty switches `tmtd serve` from in-process
    /// shards to the networked router (`coordinator::net`): requests
    /// route over TCP to `tmtd shard` processes on these addresses.
    pub remote_shards: Vec<String>,
    /// Listen address for `tmtd shard` (`host:port`; empty = not a
    /// shard process). Also settable with `tmtd shard --listen`.
    pub listen: String,
    /// Trainer tier `tmtd train` (and the in-process demo training in
    /// `serve`/`shard` without pinned models) runs
    /// (`trainer = "packed" | "reference" | "async" | "async-indexed"`).
    /// `packed`/`reference` are the deterministic bit-exact tiers;
    /// `async`/`async-indexed` are the clause-parallel stale-vote tiers
    /// (`tm::async_train`), nondeterministic under threading and held
    /// to a statistical accuracy-parity bar instead. Also settable with
    /// `tmtd train --trainer`.
    pub trainer: TrainerChoice,
    /// Worker threads for the async trainer tiers (clause partitions).
    /// Must be >= 1; ignored by the deterministic tiers. Also settable
    /// with `tmtd train --threads`.
    pub train_threads: usize,
    /// TCP connections pooled per remote shard (request parallelism
    /// toward one shard process). Must be >= 1.
    pub net_connections: usize,
    /// Heartbeat period in milliseconds for remote-shard health
    /// tracking; a shard that misses a heartbeat is routed around
    /// until it acks again. Must be >= 1.
    pub net_heartbeat_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 1,
            workers: 4,
            max_batch: 16,
            batch_timeout_us: 200,
            queue_depth: 1024,
            artifacts_dir: "artifacts".into(),
            wta: WtaKind::Tba,
            indexed_density_threshold: crate::tm::index::PACKED_VS_INDEXED_DENSITY,
            compressed_density_threshold:
                crate::tm::compressed::PACKED_VS_COMPRESSED_DENSITY,
            simd: SimdChoice::Auto,
            compile: CompileMode::default(),
            remote_shards: Vec::new(),
            listen: String::new(),
            trainer: TrainerChoice::default(),
            train_threads: 4,
            net_connections: 2,
            net_heartbeat_ms: 500,
        }
    }
}

impl ServeConfig {
    /// Parse from a TOML document:
    ///
    /// ```toml
    /// [coordinator]
    /// shards = 1
    /// workers = 4
    /// max_batch = 16
    /// batch_timeout_us = 200
    /// queue_depth = 1024
    /// artifacts_dir = "artifacts"
    /// wta = "tba"
    /// indexed_density_threshold = 0.05
    /// compressed_density_threshold = 0.2
    /// simd = "auto"
    /// compile = "prune"
    /// remote_shards = "127.0.0.1:7401,127.0.0.1:7402"
    /// listen = ""
    /// trainer = "packed"
    /// train_threads = 4
    /// net_connections = 2
    /// net_heartbeat_ms = 500
    /// ```
    pub fn from_toml(doc: &TomlDoc) -> Result<ServeConfig> {
        // Counts must reject negative values rather than `as`-casting
        // them into huge unsigned numbers that slip past validate().
        fn non_negative<T: TryFrom<i64>>(v: &toml::TomlValue, key: &str) -> Result<T> {
            T::try_from(v.as_int()?)
                .map_err(|_| crate::Error::config(format!("{key} must be >= 0")))
        }
        let mut cfg = ServeConfig::default();
        if let Some(v) = doc.get("coordinator", "shards") {
            cfg.shards = non_negative(v, "shards")?;
        }
        if let Some(v) = doc.get("coordinator", "workers") {
            cfg.workers = non_negative(v, "workers")?;
        }
        if let Some(v) = doc.get("coordinator", "max_batch") {
            cfg.max_batch = non_negative(v, "max_batch")?;
        }
        if let Some(v) = doc.get("coordinator", "batch_timeout_us") {
            cfg.batch_timeout_us = non_negative(v, "batch_timeout_us")?;
        }
        if let Some(v) = doc.get("coordinator", "queue_depth") {
            cfg.queue_depth = non_negative(v, "queue_depth")?;
        }
        if let Some(v) = doc.get("coordinator", "artifacts_dir") {
            cfg.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("coordinator", "indexed_density_threshold") {
            cfg.indexed_density_threshold = v.as_float()?;
        }
        if let Some(v) = doc.get("coordinator", "compressed_density_threshold") {
            cfg.compressed_density_threshold = v.as_float()?;
        }
        if let Some(v) = doc.get("coordinator", "simd") {
            let name = v.as_str()?;
            cfg.simd = SimdChoice::parse(name).ok_or_else(|| {
                crate::Error::config(format!(
                    "unknown simd level {name:?} (expected auto|scalar|portable|neon|avx2|avx512)"
                ))
            })?;
        }
        if let Some(v) = doc.get("coordinator", "compile") {
            let name = v.as_str()?;
            cfg.compile = CompileMode::parse(name).ok_or_else(|| {
                crate::Error::config(format!(
                    "unknown compile mode {name:?} (expected off|prune|full)"
                ))
            })?;
        }
        if let Some(v) = doc.get("coordinator", "remote_shards") {
            cfg.remote_shards = parse_remote_shards(v.as_str()?)?;
        }
        if let Some(v) = doc.get("coordinator", "listen") {
            cfg.listen = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("coordinator", "trainer") {
            let name = v.as_str()?;
            cfg.trainer = TrainerChoice::parse(name).ok_or_else(|| {
                crate::Error::config(format!(
                    "unknown trainer {name:?} (expected packed|reference|async|async-indexed)"
                ))
            })?;
        }
        if let Some(v) = doc.get("coordinator", "train_threads") {
            cfg.train_threads = non_negative(v, "train_threads")?;
        }
        if let Some(v) = doc.get("coordinator", "net_connections") {
            cfg.net_connections = non_negative(v, "net_connections")?;
        }
        if let Some(v) = doc.get("coordinator", "net_heartbeat_ms") {
            cfg.net_heartbeat_ms = non_negative(v, "net_heartbeat_ms")?;
        }
        if let Some(v) = doc.get("coordinator", "wta") {
            cfg.wta = match v.as_str()? {
                "tba" => WtaKind::Tba,
                "mesh" => WtaKind::Mesh,
                other => {
                    return Err(crate::Error::config(format!(
                        "unknown wta kind {other:?} (expected tba|mesh)"
                    )))
                }
            };
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&TomlDoc::parse(&text)?)
    }

    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(crate::Error::config("shards must be >= 1"));
        }
        if self.workers == 0 {
            return Err(crate::Error::config("workers must be >= 1"));
        }
        if self.max_batch == 0 {
            return Err(crate::Error::config("max_batch must be >= 1"));
        }
        if self.queue_depth < self.max_batch {
            return Err(crate::Error::config(
                "queue_depth must be >= max_batch (backpressure would deadlock)",
            ));
        }
        if !(0.0..=1.0).contains(&self.indexed_density_threshold) {
            // NaN fails the range test too: the auto-select comparison
            // must be total.
            return Err(crate::Error::config(
                "indexed_density_threshold must be in [0, 1]",
            ));
        }
        if !(0.0..=1.0).contains(&self.compressed_density_threshold) {
            // NaN fails the range test too: the three-way auto-select
            // comparison must be total.
            return Err(crate::Error::config(
                "compressed_density_threshold must be in [0, 1]",
            ));
        }
        if self.remote_shards.iter().any(|a| a.is_empty()) {
            return Err(crate::Error::config(
                "remote_shards entries must be non-empty host:port addresses",
            ));
        }
        if self.train_threads == 0 {
            return Err(crate::Error::config("train_threads must be >= 1"));
        }
        if self.net_connections == 0 {
            return Err(crate::Error::config("net_connections must be >= 1"));
        }
        if self.net_heartbeat_ms == 0 {
            return Err(crate::Error::config("net_heartbeat_ms must be >= 1"));
        }
        Ok(())
    }
}

/// Split a comma-separated `host:port` list, trimming whitespace and
/// dropping empty segments from trailing commas; fully-empty input
/// yields no shards (local serving).
pub fn parse_remote_shards(text: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    for part in text.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if !part.contains(':') {
            return Err(crate::Error::config(format!(
                "remote shard address {part:?} is not host:port"
            )));
        }
        out.push(part.to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(ServeConfig::default().validate().is_ok());
    }

    #[test]
    fn parses_full_config() {
        let doc = TomlDoc::parse(
            r#"
            [coordinator]
            shards = 3
            workers = 8
            max_batch = 64
            batch_timeout_us = 500
            queue_depth = 2048
            artifacts_dir = "custom/artifacts"
            wta = "mesh"
            indexed_density_threshold = 0.12
            compressed_density_threshold = 0.33
            simd = "portable"
            compile = "full"
            remote_shards = "127.0.0.1:7401, 127.0.0.1:7402"
            listen = "0.0.0.0:7400"
            net_connections = 3
            net_heartbeat_ms = 250
            "#,
        )
        .unwrap();
        let cfg = ServeConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.compile, CompileMode::Full);
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.max_batch, 64);
        assert_eq!(cfg.wta, WtaKind::Mesh);
        assert_eq!(cfg.artifacts_dir, "custom/artifacts");
        assert_eq!(cfg.remote_shards, vec!["127.0.0.1:7401", "127.0.0.1:7402"]);
        assert_eq!(cfg.listen, "0.0.0.0:7400");
        assert_eq!(cfg.net_connections, 3);
        assert_eq!(cfg.net_heartbeat_ms, 250);
        assert_eq!(cfg.indexed_density_threshold, 0.12);
        assert_eq!(cfg.compressed_density_threshold, 0.33);
        assert_eq!(
            cfg.simd,
            SimdChoice::Forced(crate::tm::simd::SimdLevel::Portable)
        );
    }

    #[test]
    fn parses_simd_levels_and_rejects_unknown_names() {
        use crate::tm::simd::SimdLevel;
        for (name, want) in [
            ("auto", SimdChoice::Auto),
            ("scalar", SimdChoice::Forced(SimdLevel::Scalar)),
            ("portable", SimdChoice::Forced(SimdLevel::Portable)),
            ("neon", SimdChoice::Forced(SimdLevel::Neon)),
            ("avx2", SimdChoice::Forced(SimdLevel::Avx2)),
            ("avx512", SimdChoice::Forced(SimdLevel::Avx512)),
        ] {
            let doc =
                TomlDoc::parse(&format!("[coordinator]\nsimd = \"{name}\"\n")).unwrap();
            assert_eq!(ServeConfig::from_toml(&doc).unwrap().simd, want, "{name}");
        }
        let doc = TomlDoc::parse("[coordinator]\nsimd = \"sve\"\n").unwrap();
        let err = ServeConfig::from_toml(&doc).unwrap_err();
        assert!(err.to_string().contains("unknown simd level"), "{err}");
        // Default stays auto-dispatch.
        assert_eq!(ServeConfig::default().simd, SimdChoice::Auto);
    }

    #[test]
    fn parses_compile_modes_and_rejects_unknown_names() {
        for (name, want) in [
            ("off", CompileMode::Off),
            ("prune", CompileMode::Prune),
            ("full", CompileMode::Full),
        ] {
            let doc =
                TomlDoc::parse(&format!("[coordinator]\ncompile = \"{name}\"\n")).unwrap();
            assert_eq!(ServeConfig::from_toml(&doc).unwrap().compile, want, "{name}");
        }
        let doc = TomlDoc::parse("[coordinator]\ncompile = \"aggressive\"\n").unwrap();
        let err = ServeConfig::from_toml(&doc).unwrap_err();
        assert!(err.to_string().contains("unknown compile mode"), "{err}");
        // Pruning is exact, so it is the default; reordering needs a
        // calibration batch and stays opt-in.
        assert_eq!(ServeConfig::default().compile, CompileMode::Prune);
    }

    #[test]
    fn default_density_threshold_matches_engine_crossover() {
        assert_eq!(
            ServeConfig::default().indexed_density_threshold,
            crate::tm::index::PACKED_VS_INDEXED_DENSITY
        );
        assert_eq!(
            ServeConfig::default().compressed_density_threshold,
            crate::tm::compressed::PACKED_VS_COMPRESSED_DENSITY
        );
    }

    #[test]
    fn rejects_out_of_range_compressed_threshold() {
        // Regression (the new knob must get the same total-comparison
        // guard as the indexed one): NaN and out-of-range values must
        // fail validation, not silently skew the three-way auto select.
        for t in ["-0.1", "1.5"] {
            let doc = TomlDoc::parse(&format!(
                "[coordinator]\ncompressed_density_threshold = {t}\n"
            ))
            .unwrap();
            let err = ServeConfig::from_toml(&doc).unwrap_err();
            assert!(
                err.to_string().contains("compressed_density_threshold"),
                "{t}: {err}"
            );
        }
        // "nan" no longer reaches from_toml at all — the TOML layer
        // rejects non-finite literals — but the validate() guard stays
        // for programmatic construction.
        assert!(TomlDoc::parse("[coordinator]\ncompressed_density_threshold = nan\n").is_err());
        let cfg = ServeConfig {
            compressed_density_threshold: f64::NAN,
            ..ServeConfig::default()
        };
        assert!(cfg.validate().is_err());
        // Integer 0 and 1 coerce to float and are valid boundaries, and
        // the two knobs validate independently (inverted pairs are
        // legal — selection stays total).
        for t in ["0", "1", "0.5"] {
            let doc = TomlDoc::parse(&format!(
                "[coordinator]\ncompressed_density_threshold = {t}\n"
            ))
            .unwrap();
            assert!(ServeConfig::from_toml(&doc).is_ok(), "{t}");
        }
        let doc = TomlDoc::parse(
            "[coordinator]\nindexed_density_threshold = 0.9\ncompressed_density_threshold = 0.1\n",
        )
        .unwrap();
        assert!(ServeConfig::from_toml(&doc).is_ok());
    }

    #[test]
    fn rejects_out_of_range_density_threshold() {
        for t in ["-0.1", "1.5"] {
            let doc = TomlDoc::parse(&format!(
                "[coordinator]\nindexed_density_threshold = {t}\n"
            ))
            .unwrap();
            assert!(ServeConfig::from_toml(&doc).is_err(), "{t}");
        }
        // Non-finite literals are now a TOML-layer parse error; the
        // validate() range guard still covers programmatic NaN.
        assert!(TomlDoc::parse("[coordinator]\nindexed_density_threshold = nan\n").is_err());
        let cfg = ServeConfig { indexed_density_threshold: f64::NAN, ..ServeConfig::default() };
        assert!(cfg.validate().is_err());
        // Integer 0 and 1 coerce to float and are valid boundaries.
        for t in ["0", "1", "0.5"] {
            let doc = TomlDoc::parse(&format!(
                "[coordinator]\nindexed_density_threshold = {t}\n"
            ))
            .unwrap();
            assert!(ServeConfig::from_toml(&doc).is_ok(), "{t}");
        }
    }

    #[test]
    fn rejects_zero_shards() {
        let doc = TomlDoc::parse("[coordinator]\nshards = 0\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn rejects_negative_counts_instead_of_wrapping() {
        // Regression: `as usize` wrapped -2 to a huge shard count that
        // passed the non-zero validation.
        for key in ["shards", "workers", "max_batch", "batch_timeout_us", "queue_depth"] {
            let doc = TomlDoc::parse(&format!("[coordinator]\n{key} = -2\n")).unwrap();
            let err = ServeConfig::from_toml(&doc).unwrap_err();
            assert!(err.to_string().contains(">= 0"), "{key}: {err}");
        }
    }

    #[test]
    fn rejects_bad_wta() {
        let doc = TomlDoc::parse("[coordinator]\nwta = \"ring\"\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn rejects_queue_smaller_than_batch() {
        let doc =
            TomlDoc::parse("[coordinator]\nmax_batch = 64\nqueue_depth = 8\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn parses_remote_shard_lists() {
        // Trailing commas and whitespace are tolerated; empty input
        // means local in-process serving.
        assert_eq!(
            parse_remote_shards(" a:1, b:2 ,").unwrap(),
            vec!["a:1", "b:2"]
        );
        assert_eq!(parse_remote_shards("").unwrap(), Vec::<String>::new());
        // A segment without a port is a config error, not a late
        // connect failure.
        let err = parse_remote_shards("a:1,nocolon").unwrap_err();
        assert!(err.to_string().contains("host:port"), "{err}");
    }

    #[test]
    fn parses_trainer_choices_and_rejects_unknown_names() {
        for (name, want) in [
            ("packed", TrainerChoice::Packed),
            ("reference", TrainerChoice::Reference),
            ("async", TrainerChoice::Async),
            ("async-indexed", TrainerChoice::AsyncIndexed),
        ] {
            let doc =
                TomlDoc::parse(&format!("[coordinator]\ntrainer = \"{name}\"\n")).unwrap();
            assert_eq!(ServeConfig::from_toml(&doc).unwrap().trainer, want, "{name}");
        }
        let doc = TomlDoc::parse("[coordinator]\ntrainer = \"gpu\"\n").unwrap();
        let err = ServeConfig::from_toml(&doc).unwrap_err();
        assert!(err.to_string().contains("unknown trainer"), "{err}");
        // The deterministic packed tier stays the default: async is a
        // throughput opt-in, not a semantics change by surprise.
        assert_eq!(ServeConfig::default().trainer, TrainerChoice::Packed);
    }

    #[test]
    fn rejects_bad_train_threads() {
        let doc = TomlDoc::parse("[coordinator]\ntrain_threads = 0\n").unwrap();
        let err = ServeConfig::from_toml(&doc).unwrap_err();
        assert!(err.to_string().contains("train_threads"), "{err}");
        let doc = TomlDoc::parse("[coordinator]\ntrain_threads = -3\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[coordinator]\ntrain_threads = 8\n").unwrap();
        assert_eq!(ServeConfig::from_toml(&doc).unwrap().train_threads, 8);
        assert!(ServeConfig::default().train_threads >= 1);
    }

    #[test]
    fn rejects_bad_net_knobs() {
        let doc = TomlDoc::parse("[coordinator]\nnet_connections = 0\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[coordinator]\nnet_heartbeat_ms = 0\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[coordinator]\nremote_shards = \"a:1,b\"\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
        // An empty remote_shards string is the local-serving default.
        let doc = TomlDoc::parse("[coordinator]\nremote_shards = \"\"\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).unwrap().remote_shards.is_empty());
    }
}
