//! Configuration: a minimal TOML-subset parser (no external crates
//! offline) plus typed configuration structures for the serving
//! coordinator and experiment harnesses.

pub mod json;
pub mod toml;

pub use json::Json;
pub use toml::TomlDoc;

use crate::error::Result;
use crate::wta::WtaKind;

/// Serving coordinator configuration (`tmtd serve --config <file>`).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Coordinator shards behind the consistent-hash front door
    /// (`coordinator::shard`). Each shard owns its own worker pool,
    /// batchers and engines; 1 = a single unsharded coordinator.
    pub shards: usize,
    /// Worker threads for hardware-simulation backends (per shard).
    pub workers: usize,
    /// Dynamic batcher: max batch (must be one of the AOT batch sizes).
    pub max_batch: usize,
    /// Dynamic batcher: flush timeout in microseconds.
    pub batch_timeout_us: u64,
    /// Bounded request queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Artifacts directory (AOT outputs).
    pub artifacts_dir: String,
    /// WTA topology for the proposed architectures.
    pub wta: WtaKind,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 1,
            workers: 4,
            max_batch: 16,
            batch_timeout_us: 200,
            queue_depth: 1024,
            artifacts_dir: "artifacts".into(),
            wta: WtaKind::Tba,
        }
    }
}

impl ServeConfig {
    /// Parse from a TOML document:
    ///
    /// ```toml
    /// [coordinator]
    /// shards = 1
    /// workers = 4
    /// max_batch = 16
    /// batch_timeout_us = 200
    /// queue_depth = 1024
    /// artifacts_dir = "artifacts"
    /// wta = "tba"
    /// ```
    pub fn from_toml(doc: &TomlDoc) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        if let Some(v) = doc.get("coordinator", "shards") {
            cfg.shards = v.as_int()? as usize;
        }
        if let Some(v) = doc.get("coordinator", "workers") {
            cfg.workers = v.as_int()? as usize;
        }
        if let Some(v) = doc.get("coordinator", "max_batch") {
            cfg.max_batch = v.as_int()? as usize;
        }
        if let Some(v) = doc.get("coordinator", "batch_timeout_us") {
            cfg.batch_timeout_us = v.as_int()? as u64;
        }
        if let Some(v) = doc.get("coordinator", "queue_depth") {
            cfg.queue_depth = v.as_int()? as usize;
        }
        if let Some(v) = doc.get("coordinator", "artifacts_dir") {
            cfg.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("coordinator", "wta") {
            cfg.wta = match v.as_str()? {
                "tba" => WtaKind::Tba,
                "mesh" => WtaKind::Mesh,
                other => {
                    return Err(crate::Error::config(format!(
                        "unknown wta kind {other:?} (expected tba|mesh)"
                    )))
                }
            };
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&TomlDoc::parse(&text)?)
    }

    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(crate::Error::config("shards must be >= 1"));
        }
        if self.workers == 0 {
            return Err(crate::Error::config("workers must be >= 1"));
        }
        if self.max_batch == 0 {
            return Err(crate::Error::config("max_batch must be >= 1"));
        }
        if self.queue_depth < self.max_batch {
            return Err(crate::Error::config(
                "queue_depth must be >= max_batch (backpressure would deadlock)",
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(ServeConfig::default().validate().is_ok());
    }

    #[test]
    fn parses_full_config() {
        let doc = TomlDoc::parse(
            r#"
            [coordinator]
            shards = 3
            workers = 8
            max_batch = 64
            batch_timeout_us = 500
            queue_depth = 2048
            artifacts_dir = "custom/artifacts"
            wta = "mesh"
            "#,
        )
        .unwrap();
        let cfg = ServeConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.max_batch, 64);
        assert_eq!(cfg.wta, WtaKind::Mesh);
        assert_eq!(cfg.artifacts_dir, "custom/artifacts");
    }

    #[test]
    fn rejects_zero_shards() {
        let doc = TomlDoc::parse("[coordinator]\nshards = 0\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn rejects_bad_wta() {
        let doc = TomlDoc::parse("[coordinator]\nwta = \"ring\"\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn rejects_queue_smaller_than_batch() {
        let doc =
            TomlDoc::parse("[coordinator]\nmax_batch = 64\nqueue_depth = 8\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
    }
}
