//! Minimal TOML-subset parser: `[section]` headers and
//! `key = value` lines where value is a string, integer, float or bool.
//! Comments (`#`) and blank lines are ignored. No nested tables, arrays
//! or multi-line strings — the config surface deliberately stays small.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A scalar TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    String(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::String(s) => Ok(s),
            other => Err(Error::config(format!("expected string, got {other:?}"))),
        }
    }
    pub fn as_int(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => Err(Error::config(format!("expected integer, got {other:?}"))),
        }
    }
    pub fn as_float(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => Err(Error::config(format!("expected float, got {other:?}"))),
        }
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(Error::config(format!("expected bool, got {other:?}"))),
        }
    }
}

/// A parsed document: section → key → value. Keys before any section
/// header land in the `""` section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        let mut declared: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| Error::config(format!("line {}: unterminated section", lineno + 1)))?;
                section = name.trim().to_string();
                if !declared.insert(section.clone()) {
                    return Err(Error::config(format!(
                        "line {}: section [{section}] reopened (TOML forbids redefining a table)",
                        lineno + 1
                    )));
                }
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let value = parse_value(value.trim())
                .map_err(|e| Error::config(format!("line {}: {e}", lineno + 1)))?;
            let key = key.trim().to_string();
            let entry = doc.sections.entry(section.clone()).or_default();
            if entry.contains_key(&key) {
                return Err(Error::config(format!(
                    "line {}: duplicate key {key:?} in section [{section}]",
                    lineno + 1
                )));
            }
            entry.insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment, and an
    // escaped '\"' inside a string does not close it (a naive
    // quote-toggle would truncate `path = "say \"hi\" # tag"` at the
    // '#' between the escaped quotes).
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
        } else if c == '"' {
            in_str = true;
        } else if c == '#' {
            return &line[..i];
        }
    }
    line
}

fn parse_value(text: &str) -> std::result::Result<TomlValue, String> {
    if let Some(rest) = text.strip_prefix('"') {
        // Escape-aware scan: `strip_suffix('"')` would treat the
        // escaped quote in `"ends with \""` as the terminator and
        // mangle the value.
        let mut out = String::new();
        let mut chars = rest.chars();
        loop {
            match chars.next() {
                None => return Err("unterminated string".to_string()),
                Some('"') => break,
                Some('\\') => match chars.next() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some(other) => return Err(format!("unsupported escape \\{other}")),
                    None => return Err("unterminated string (escape at end of line)".to_string()),
                },
                Some(c) => out.push(c),
            }
        }
        let trailing = chars.as_str();
        if !trailing.is_empty() {
            return Err(format!("trailing characters {trailing:?} after string"));
        }
        return Ok(TomlValue::String(out));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        // TOML has no inf/nan literals, and `f64::from_str` happily
        // accepts "inf", "nan" and overflowing forms like "1e999";
        // letting them through would dodge every downstream range
        // check that compares with `<`/`>`.
        if !f.is_finite() {
            return Err(format!("non-finite float {text:?} (TOML forbids inf/nan)"));
        }
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {text:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(
            r#"
            top = 1
            [a]
            s = "hello"   # trailing comment
            n = 42
            f = 2.5
            b = true
            [b]
            s = "wor#ld"
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("a", "s").unwrap().as_str().unwrap(), "hello");
        assert_eq!(doc.get("a", "n").unwrap().as_int().unwrap(), 42);
        assert_eq!(doc.get("a", "f").unwrap().as_float().unwrap(), 2.5);
        assert!(doc.get("a", "b").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("b", "s").unwrap().as_str().unwrap(), "wor#ld");
    }

    #[test]
    fn int_coerces_to_float_not_vice_versa() {
        let doc = TomlDoc::parse("x = 3\ny = 3.5\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float().unwrap(), 3.0);
        assert!(doc.get("", "y").unwrap().as_int().is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("x = \"open\n").is_err());
    }

    #[test]
    fn escaped_quotes_do_not_open_comments_or_close_strings() {
        let doc = TomlDoc::parse(
            "p = \"say \\\"hi\\\" # tag\"   # real comment\n\
             q = \"ends with \\\"\"\n\
             r = \"back\\\\slash\"\n\
             t = \"trailing backslash\\\\\"\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "p").unwrap().as_str().unwrap(), "say \"hi\" # tag");
        assert_eq!(doc.get("", "q").unwrap().as_str().unwrap(), "ends with \"");
        assert_eq!(doc.get("", "r").unwrap().as_str().unwrap(), "back\\slash");
        assert_eq!(doc.get("", "t").unwrap().as_str().unwrap(), "trailing backslash\\");
    }

    #[test]
    fn rejects_bad_strings_with_reasons() {
        // Escape at end of line leaves the string unterminated.
        let err = TomlDoc::parse("x = \"dangling\\").unwrap_err().to_string();
        assert!(err.contains("unterminated"), "{err}");
        // Junk after the closing quote is not silently dropped.
        let err = TomlDoc::parse("x = \"a\" b\n").unwrap_err().to_string();
        assert!(err.contains("trailing characters"), "{err}");
        // Unknown escapes are an error, not a pass-through.
        let err = TomlDoc::parse("x = \"\\q\"\n").unwrap_err().to_string();
        assert!(err.contains("unsupported escape"), "{err}");
    }

    #[test]
    fn rejects_duplicate_keys_and_reopened_sections() {
        let err = TomlDoc::parse("[a]\nx = 1\nx = 2\n").unwrap_err().to_string();
        assert!(err.contains("line 3") && err.contains("duplicate key"), "{err}");
        let err = TomlDoc::parse("x = 1\nx = 2\n").unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("duplicate key"), "{err}");
        let err = TomlDoc::parse("[a]\nx = 1\n[b]\ny = 2\n[a]\nz = 3\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 5") && err.contains("reopened"), "{err}");
        // Same key in different sections stays legal.
        let doc = TomlDoc::parse("[a]\nx = 1\n[b]\nx = 2\n").unwrap();
        assert_eq!(doc.get("a", "x").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("b", "x").unwrap().as_int().unwrap(), 2);
    }

    #[test]
    fn rejects_non_finite_floats() {
        for text in ["inf", "-inf", "+inf", "infinity", "nan", "NaN", "1e999", "-1e999"] {
            let err = TomlDoc::parse(&format!("x = {text}\n")).unwrap_err().to_string();
            assert!(
                err.contains("line 1")
                    && (err.contains("non-finite") || err.contains("cannot parse")),
                "{text}: {err}"
            );
        }
        // Ordinary floats (incl. exponents within range) still parse.
        let doc = TomlDoc::parse("x = 1e10\ny = -2.5e-3\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float().unwrap(), 1e10);
        assert_eq!(doc.get("", "y").unwrap().as_float().unwrap(), -2.5e-3);
    }

    #[test]
    fn missing_returns_none() {
        let doc = TomlDoc::parse("[a]\nx = 1\n").unwrap();
        assert!(doc.get("a", "y").is_none());
        assert!(doc.get("z", "x").is_none());
    }
}
