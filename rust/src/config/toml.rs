//! Minimal TOML-subset parser: `[section]` headers and
//! `key = value` lines where value is a string, integer, float or bool.
//! Comments (`#`) and blank lines are ignored. No nested tables, arrays
//! or multi-line strings — the config surface deliberately stays small.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A scalar TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    String(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::String(s) => Ok(s),
            other => Err(Error::config(format!("expected string, got {other:?}"))),
        }
    }
    pub fn as_int(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => Err(Error::config(format!("expected integer, got {other:?}"))),
        }
    }
    pub fn as_float(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => Err(Error::config(format!("expected float, got {other:?}"))),
        }
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(Error::config(format!("expected bool, got {other:?}"))),
        }
    }
}

/// A parsed document: section → key → value. Keys before any section
/// header land in the `""` section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| Error::config(format!("line {}: unterminated section", lineno + 1)))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let value = parse_value(value.trim())
                .map_err(|e| Error::config(format!("line {}: {e}", lineno + 1)))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> std::result::Result<TomlValue, String> {
    if let Some(stripped) = text.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::String(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {text:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(
            r#"
            top = 1
            [a]
            s = "hello"   # trailing comment
            n = 42
            f = 2.5
            b = true
            [b]
            s = "wor#ld"
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("a", "s").unwrap().as_str().unwrap(), "hello");
        assert_eq!(doc.get("a", "n").unwrap().as_int().unwrap(), 42);
        assert_eq!(doc.get("a", "f").unwrap().as_float().unwrap(), 2.5);
        assert!(doc.get("a", "b").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("b", "s").unwrap().as_str().unwrap(), "wor#ld");
    }

    #[test]
    fn int_coerces_to_float_not_vice_versa() {
        let doc = TomlDoc::parse("x = 3\ny = 3.5\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float().unwrap(), 3.0);
        assert!(doc.get("", "y").unwrap().as_int().is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("x = \"open\n").is_err());
    }

    #[test]
    fn missing_returns_none() {
        let doc = TomlDoc::parse("[a]\nx = 1\n").unwrap();
        assert!(doc.get("a", "y").is_none());
        assert!(doc.get("z", "x").is_none());
    }
}
