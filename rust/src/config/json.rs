//! Minimal JSON parser (no serde offline) — reads `artifacts/manifest.json`.
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null); enough for manifests and configs,
//! not a streaming parser.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::config(format!(
                "trailing JSON content at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    pub fn as_object(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Ok(m),
            other => Err(Error::config(format!("expected object, got {other:?}"))),
        }
    }

    pub fn as_array(&self) -> Result<&[Json]> {
        match self {
            Json::Array(a) => Ok(a),
            other => Err(Error::config(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::String(s) => Ok(s),
            other => Err(Error::config(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Number(n) => Ok(*n),
            other => Err(Error::config(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::config(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    /// `obj["key"]` with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_object()?
            .get(key)
            .ok_or_else(|| Error::config(format!("missing key {key:?}")))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| Error::config("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            return Err(Error::config(format!(
                "expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos - 1,
                got as char
            )));
        }
        Ok(())
    }

    fn literal(&mut self, text: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(value)
        } else {
            Err(Error::config(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::config(format!("unexpected {other:?} in JSON"))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Object(map)),
                other => {
                    return Err(Error::config(format!(
                        "expected ',' or '}}', got {:?}",
                        other as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Array(items)),
                other => {
                    return Err(Error::config(format!(
                        "expected ',' or ']', got {:?}",
                        other as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| {
                                    Error::config("bad \\u escape")
                                })?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => {
                        return Err(Error::config(format!(
                            "bad escape \\{}",
                            other as char
                        )))
                    }
                },
                b if b < 0x20 => return Err(Error::config("control char in string")),
                b => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    for _ in 1..len {
                        self.bump()?;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| Error::config("invalid UTF-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| Error::config(format!("bad number {text:?}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"version": 1, "artifacts": {"a": {"args": [[1, 16]], "out": [1, 3]}}}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize().unwrap(), 1);
        let a = j.get("artifacts").unwrap().get("a").unwrap();
        let args = a.get("args").unwrap().as_array().unwrap();
        assert_eq!(args[0].as_array().unwrap()[1].as_usize().unwrap(), 16);
    }

    #[test]
    fn parses_strings_with_escapes() {
        let j = Json::parse(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\"b\"A");
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(Json::parse("0").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn parses_nested_arrays_and_bools() {
        let j = Json::parse("[true, [false, null], 2]").unwrap();
        let a = j.as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0], Json::Bool(true));
        assert_eq!(a[1].as_array().unwrap()[1], Json::Null);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn rejects_fractional_usize() {
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-2").unwrap().as_usize().is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo → ok\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → ok");
    }
}
