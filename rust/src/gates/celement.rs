//! Muller C-element (paper Table II) — the state-holding rendezvous
//! element of asynchronous design: output rises only when *all* inputs
//! are 1, falls only when all are 0, holds otherwise.

use crate::sim::energy::{EnergyKind, GateKind};
use crate::sim::{Component, Ctx, Logic, NetId, Time};

/// N-input Muller C-element. Pins: the N inputs in order.
pub struct CElement {
    name: String,
    inputs: Vec<NetId>,
    output: NetId,
    delay: Time,
    energy_fj: f64,
    energy_kind: EnergyKind,
    state: Logic,
}

impl CElement {
    pub fn new(
        name: impl Into<String>,
        inputs: Vec<NetId>,
        output: NetId,
        tech: &crate::sim::TechParams,
    ) -> CElement {
        assert!(inputs.len() >= 2, "C-element needs >= 2 inputs");
        CElement {
            name: name.into(),
            inputs,
            output,
            delay: tech.gate_delay(GateKind::CElement),
            energy_fj: tech.gate_energy_fj(GateKind::CElement),
            energy_kind: EnergyKind::Handshake,
            state: Logic::Zero,
        }
    }

    pub fn with_energy_kind(mut self, kind: EnergyKind) -> CElement {
        self.energy_kind = kind;
        self
    }

    /// Set the power-on state (defaults to 0).
    pub fn with_initial(mut self, v: Logic) -> CElement {
        self.state = v;
        self
    }
}

impl Component for CElement {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        ctx.schedule(self.output, self.state, Time::ZERO);
    }

    fn on_input(&mut self, _pin: usize, ctx: &mut Ctx) {
        let all_one = self.inputs.iter().all(|n| ctx.get(*n) == Logic::One);
        let all_zero = self.inputs.iter().all(|n| ctx.get(*n) == Logic::Zero);
        let next = if all_one {
            Logic::One
        } else if all_zero {
            Logic::Zero
        } else {
            self.state // hold (Table II: c_prev)
        };
        if next != self.state {
            self.state = next;
            ctx.spend(self.energy_kind, self.energy_fj);
            ctx.schedule(self.output, next, self.delay);
        }
    }

    fn gate_equivalents(&self) -> f64 {
        3.0 + 0.5 * (self.inputs.len().saturating_sub(2)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::Circuit;

    fn fixture() -> (Circuit, NetId, NetId, NetId) {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let a = c.net_init("a", Logic::Zero);
        let b = c.net_init("b", Logic::Zero);
        let o = c.net("c");
        let t = c.tech.clone();
        c.add(
            Box::new(CElement::new("ce", vec![a, b], o, &t)),
            vec![a, b],
        );
        c.init_components();
        c.run_to_quiescence().unwrap();
        (c, a, b, o)
    }

    #[test]
    fn truth_table_ii() {
        let (mut c, a, b, o) = fixture();
        assert_eq!(c.value(o), Logic::Zero);
        // 0,1 -> holds 0
        c.drive(b, Logic::One, Time::ps(1));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(o), Logic::Zero);
        // 1,1 -> 1
        c.drive(a, Logic::One, Time::ps(1));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(o), Logic::One);
        // 1,0 -> holds 1
        c.drive(b, Logic::Zero, Time::ps(1));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(o), Logic::One);
        // 0,0 -> 0
        c.drive(a, Logic::Zero, Time::ps(1));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(o), Logic::Zero);
    }

    #[test]
    fn three_input_rendezvous() {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let ins: Vec<NetId> = (0..3).map(|i| c.net_init(format!("i{i}"), Logic::Zero)).collect();
        let o = c.net("c");
        let t = c.tech.clone();
        c.add(
            Box::new(CElement::new("ce3", ins.clone(), o, &t)),
            ins.clone(),
        );
        c.init_components();
        c.run_to_quiescence().unwrap();
        for (k, n) in ins.iter().enumerate() {
            c.drive(*n, Logic::One, Time::ps(1));
            c.run_to_quiescence().unwrap();
            let want = if k == 2 { Logic::One } else { Logic::Zero };
            assert_eq!(c.value(o), want, "after raising input {k}");
        }
    }

    #[test]
    fn energy_charged_only_on_state_change() {
        let (mut c, a, b, _o) = fixture();
        let e0 = c.energy.dynamic_fj(EnergyKind::Handshake);
        // a toggles alone: state holds, no energy.
        c.drive(a, Logic::One, Time::ps(1));
        c.drive(a, Logic::Zero, Time::ps(100));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.energy.dynamic_fj(EnergyKind::Handshake), e0);
        // full rendezvous: one rise = one charge.
        c.drive(a, Logic::One, Time::ps(1));
        c.drive(b, Logic::One, Time::ps(2));
        c.run_to_quiescence().unwrap();
        assert!(c.energy.dynamic_fj(EnergyKind::Handshake) > e0);
    }
}
