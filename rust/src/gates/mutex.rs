//! Mutual-exclusion element (paper Fig. 5): a cross-coupled-NAND SR latch
//! with a metastability filter. Grants exactly one of two competing
//! requests; on near-simultaneous arrivals the latch dwells in
//! metastability for `t_res = τ_m · ln(Δ₀ / Δt)` before resolving —
//! the standard analytic model (DESIGN.md §Substitutions).
//!
//! Two models are provided:
//! * [`Mutex`] — behavioural primitive used inside WTA arbiters. The
//!   decision is *deferred*: the first arrival schedules a grant after
//!   the latch's nominal set time; a competitor arriving inside that
//!   vulnerability window re-opens the decision and adds the
//!   metastability dwell, so close races genuinely slow the grant — the
//!   behaviour Table I's latency column and [19] describe.
//! * [`build_gate_level`] — the literal Fig. 5 netlist (cross-coupled
//!   NANDs + filter) for functional cross-validation on well-separated
//!   inputs (an exact-tie would oscillate at gate level, which is exactly
//!   why real Mutexes need the analogue filter the behavioural model
//!   captures).

use crate::sim::energy::{EnergyKind, GateKind};
use crate::sim::{Circuit, Component, Ctx, Logic, NetId, Time};

use super::basic::{Gate, GateOp};

/// Behavioural Mutex. Pins: `[r1, r2, tick]` where `tick` is a private
/// self-scheduling net (created by [`Mutex::build`]); outputs `[g1, g2]`.
///
/// Four-phase protocol: a grant is issued while its request is high and
/// the other grant is low; dropping the request releases the grant.
pub struct Mutex {
    name: String,
    r1: NetId,
    r2: NetId,
    g1: NetId,
    g2: NetId,
    tick: NetId,
    base_delay: Time,
    energy_fj: f64,
    /// Metastability time constant τ_m.
    tau_m: Time,
    /// Δ₀: arrival-gap scale below which the penalty applies. Also the
    /// decision window during which a competitor re-opens the race.
    window: Time,
    arrival1: Option<Time>,
    arrival2: Option<Time>,
    /// Side tentatively or definitely owning the grant (0 = none).
    owner: u8,
    /// Whether the owner's grant output has been driven high.
    granted: bool,
    /// Extra metastability dwell to insert before granting.
    extra: Time,
    /// Count of metastable resolutions (observability for tests/benches).
    pub metastable_events: u64,
}

/// Shared handle for observing mutex internals after boxing.
pub type MutexStats = std::rc::Rc<std::cell::Cell<u64>>;

impl Mutex {
    /// Instantiate a Mutex in `c`: creates the grant outputs and the
    /// private tick net, wires the pins, returns `(g1, g2)`.
    pub fn build(c: &mut Circuit, name: &str, r1: NetId, r2: NetId) -> (NetId, NetId) {
        let g1 = c.net(format!("{name}.g1"));
        let g2 = c.net(format!("{name}.g2"));
        let tick = c.net_init(format!("{name}.tick"), Logic::Zero);
        let tech = c.tech.clone();
        let m = Mutex::new(name, r1, r2, g1, g2, tick, &tech);
        c.add(Box::new(m), vec![r1, r2, tick]);
        (g1, g2)
    }

    pub fn new(
        name: impl Into<String>,
        r1: NetId,
        r2: NetId,
        g1: NetId,
        g2: NetId,
        tick: NetId,
        tech: &crate::sim::TechParams,
    ) -> Mutex {
        Mutex {
            name: name.into(),
            r1,
            r2,
            g1,
            g2,
            tick,
            // Nominal grant latency: SR latch (NAND) + filter stage.
            base_delay: tech.gate_delay(GateKind::Nand) + tech.gate_delay(GateKind::Inv),
            energy_fj: 2.0 * tech.gate_energy_fj(GateKind::Nand)
                + 2.0 * tech.gate_energy_fj(GateKind::Inv),
            tau_m: Time::from_ps_f64(tech.mutex_tau_ps * tech.dscale()),
            window: Time::from_ps_f64(4.0 * tech.mutex_tau_ps),
            arrival1: None,
            arrival2: None,
            owner: 0,
            granted: false,
            extra: Time::ZERO,
            metastable_events: 0,
        }
    }

    /// Metastability penalty for an arrival gap `dt`:
    /// `τ_m · ln(Δ₀/Δt)`, zero outside the window.
    ///
    /// An *exact* tie at femtosecond resolution is a quantisation
    /// artefact of the nominal-corner simulator (integer-coded delay
    /// chains produce identical nominal delays); in silicon the two
    /// paths always differ by ~ps of device mismatch. Exact ties are
    /// therefore charged the dwell expected for a ~1 ps arrival spread,
    /// `τ_m · ln(Δ₀ / 1ps)`, rather than an unbounded value.
    fn meta_penalty(&self, dt: Time) -> Time {
        if dt >= self.window {
            return Time::ZERO;
        }
        let dt_eff = dt.max(Time::PS); // silicon mismatch floor
        let ratio = self.window.as_fs() as f64 / dt_eff.as_fs() as f64;
        self.tau_m.scale(ratio.ln().max(0.0))
    }

    /// Schedule a decision tick as a 1 fs *pulse* rather than a toggle:
    /// multiple pending ticks may land out of order (a handover tick can
    /// be due before an earlier-scheduled dwell tick), and a toggle
    /// scheme would then produce a same-value event that the simulator
    /// rightly suppresses — silently wedging the decision. Pulses always
    /// produce edges; the decision handler is idempotent, so a collapsed
    /// double-rise costs nothing.
    fn schedule_tick(&mut self, ctx: &mut Ctx, delay: Time) {
        ctx.schedule(self.tick, Logic::One, delay);
        ctx.schedule(self.tick, Logic::Zero, delay + Time::FS);
    }

    /// Begin (or restart) a decision for `side`.
    fn open_decision(&mut self, ctx: &mut Ctx, side: u8) {
        self.owner = side;
        self.granted = false;
        self.extra = Time::ZERO;
        self.schedule_tick(ctx, self.base_delay);
    }

    fn grant_net(&self, side: u8) -> NetId {
        if side == 1 {
            self.g1
        } else {
            self.g2
        }
    }
}

impl Component for Mutex {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        ctx.schedule(self.g1, Logic::Zero, Time::ZERO);
        ctx.schedule(self.g2, Logic::Zero, Time::ZERO);
    }

    fn on_input(&mut self, pin: usize, ctx: &mut Ctx) {
        match pin {
            0 | 1 => {
                let side = pin as u8 + 1;
                let (req, other_arrival) = if pin == 0 {
                    (ctx.get(self.r1), self.arrival2)
                } else {
                    (ctx.get(self.r2), self.arrival1)
                };
                match req {
                    Logic::One => {
                        let now = ctx.now;
                        if pin == 0 {
                            self.arrival1 = Some(now);
                        } else {
                            self.arrival2 = Some(now);
                        }
                        if self.owner == 0 {
                            // Uncontended (so far): tentative decision.
                            self.open_decision(ctx, side);
                        } else if !self.granted {
                            // Competitor inside the decision window:
                            // metastability dwell proportional to the gap.
                            let dt = now.since(other_arrival.unwrap_or(now));
                            let p = self.meta_penalty(dt);
                            if p > Time::ZERO {
                                self.metastable_events += 1;
                                self.extra = self.extra.max(p);
                            }
                        }
                        // If already granted to the other side, this
                        // request simply queues (arrival recorded).
                    }
                    _ => {
                        if pin == 0 {
                            self.arrival1 = None;
                        } else {
                            self.arrival2 = None;
                        }
                        if self.owner == side {
                            // Four-phase release.
                            let was_granted = self.granted;
                            self.owner = 0;
                            self.granted = false;
                            if was_granted {
                                ctx.spend(EnergyKind::Arbiter, self.energy_fj * 0.5);
                                ctx.schedule(
                                    self.grant_net(side),
                                    Logic::Zero,
                                    self.base_delay,
                                );
                            }
                            // Hand over to a waiting competitor.
                            let waiter = if side == 1 { self.arrival2 } else { self.arrival1 };
                            if waiter.is_some() {
                                self.open_decision(ctx, 3 - side);
                            }
                        }
                    }
                }
            }
            2 => {
                // Decision tick: act on the rising edge only.
                if ctx.get(self.tick) != Logic::One {
                    return;
                }
                if self.owner == 0 || self.granted {
                    return;
                }
                if self.extra > Time::ZERO {
                    // Consume the metastability dwell, then re-tick.
                    let dwell = self.extra;
                    self.extra = Time::ZERO;
                    self.schedule_tick(ctx, dwell);
                    return;
                }
                // Verify the owner still requests (may have withdrawn).
                let still = match self.owner {
                    1 => self.arrival1.is_some(),
                    _ => self.arrival2.is_some(),
                };
                if !still {
                    let other_waiting = match self.owner {
                        1 => self.arrival2.is_some(),
                        _ => self.arrival1.is_some(),
                    };
                    let other = 3 - self.owner;
                    self.owner = 0;
                    if other_waiting {
                        self.open_decision(ctx, other);
                    }
                    return;
                }
                self.granted = true;
                ctx.spend(EnergyKind::Arbiter, self.energy_fj);
                ctx.schedule(self.grant_net(self.owner), Logic::One, Time::ZERO);
            }
            _ => {}
        }
    }

    fn gate_equivalents(&self) -> f64 {
        4.0
    }
}

/// Nets exposed by the gate-level Fig. 5 Mutex.
pub struct GateLevelMutex {
    pub r1: NetId,
    pub r2: NetId,
    pub g1: NetId,
    pub g2: NetId,
}

/// Build the literal Fig. 5 netlist: cross-coupled NANDs + an
/// inverter/AND metastability-filter stage. The caller must pulse both
/// requests to 0 once at start-up to settle the latch out of X.
pub fn build_gate_level(c: &mut Circuit, prefix: &str) -> GateLevelMutex {
    let tech = c.tech.clone();
    let r1 = c.net(format!("{prefix}.r1"));
    let r2 = c.net(format!("{prefix}.r2"));
    let q1 = c.net(format!("{prefix}.q1"));
    let q2 = c.net(format!("{prefix}.q2"));
    let g1 = c.net(format!("{prefix}.g1"));
    let g2 = c.net(format!("{prefix}.g2"));
    // SR latch: q1 = NAND(r1, q2); q2 = NAND(r2, q1).
    c.add(
        Box::new(
            Gate::new(format!("{prefix}.nand1"), GateOp::Nand, vec![r1, q2], q1, &tech)
                .with_energy_kind(EnergyKind::Arbiter),
        ),
        vec![r1, q2],
    );
    c.add(
        Box::new(
            Gate::new(format!("{prefix}.nand2"), GateOp::Nand, vec![r2, q1], q2, &tech)
                .with_energy_kind(EnergyKind::Arbiter),
        ),
        vec![r2, q1],
    );
    // Filter: grant_i = NOT q_i AND q_other.
    let q1n = c.net(format!("{prefix}.q1n"));
    let q2n = c.net(format!("{prefix}.q2n"));
    c.add(
        Box::new(
            Gate::new(format!("{prefix}.inv1"), GateOp::Inv, vec![q1], q1n, &tech)
                .with_energy_kind(EnergyKind::Arbiter),
        ),
        vec![q1],
    );
    c.add(
        Box::new(
            Gate::new(format!("{prefix}.inv2"), GateOp::Inv, vec![q2], q2n, &tech)
                .with_energy_kind(EnergyKind::Arbiter),
        ),
        vec![q2],
    );
    c.add(
        Box::new(
            Gate::new(format!("{prefix}.and1"), GateOp::And, vec![q1n, q2], g1, &tech)
                .with_energy_kind(EnergyKind::Arbiter),
        ),
        vec![q1n, q2],
    );
    c.add(
        Box::new(
            Gate::new(format!("{prefix}.and2"), GateOp::And, vec![q2n, q1], g2, &tech)
                .with_energy_kind(EnergyKind::Arbiter),
        ),
        vec![q2n, q1],
    );
    GateLevelMutex { r1, r2, g1, g2 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;

    fn behavioural() -> (Circuit, NetId, NetId, NetId, NetId) {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let r1 = c.net_init("r1", Logic::Zero);
        let r2 = c.net_init("r2", Logic::Zero);
        let (g1, g2) = Mutex::build(&mut c, "mx", r1, r2);
        c.init_components();
        c.run_to_quiescence().unwrap();
        (c, r1, r2, g1, g2)
    }

    #[test]
    fn first_arrival_wins() {
        let (mut c, r1, r2, g1, g2) = behavioural();
        c.drive(r1, Logic::One, Time::ps(10));
        c.drive(r2, Logic::One, Time::ps(500)); // well separated
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(g1), Logic::One);
        assert_eq!(c.value(g2), Logic::Zero);
    }

    #[test]
    fn release_hands_over_to_waiter() {
        let (mut c, r1, r2, g1, g2) = behavioural();
        c.drive(r1, Logic::One, Time::ps(10));
        c.drive(r2, Logic::One, Time::ps(500));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(g1), Logic::One);
        assert_eq!(c.value(g2), Logic::Zero);
        // r1 releases; r2 pending -> g2 granted.
        c.drive(r1, Logic::Zero, Time::ps(10));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(g1), Logic::Zero);
        assert_eq!(c.value(g2), Logic::One);
    }

    #[test]
    fn close_arrivals_pay_metastability_penalty() {
        // Gap of 1 ps inside the 48 ps window -> extra resolution delay.
        let (mut c, r1, r2, g1, _g2) = behavioural();
        c.drive(r1, Logic::One, Time::ps(100));
        c.drive(r2, Logic::One, Time::ps(101));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(g1), Logic::One);
        let grant_time = c.now();
        // Nominal latency = d_nand + d_inv = 40 ps; dwell must add
        // τ_m·ln(48/1) ≈ 46 ps on top.
        assert!(
            grant_time > Time::ps(100) + Time::ps(40) + Time::ps(20),
            "grant at {grant_time}, expected metastability dwell"
        );
    }

    #[test]
    fn distant_arrivals_have_no_penalty() {
        let (mut c, r1, r2, g1, _g2) = behavioural();
        c.drive(r1, Logic::One, Time::ps(100));
        c.drive(r2, Logic::One, Time::ps(300)); // outside 48 ps window
        c.run_while(Time::ps(500), |c| c.value(g1) == Logic::One)
            .unwrap();
        // Grant exactly at nominal latency.
        assert_eq!(c.now(), Time::ps(140));
    }

    #[test]
    fn exact_tie_resolves_deterministically() {
        let (mut c, r1, r2, g1, g2) = behavioural();
        c.drive(r1, Logic::One, Time::ps(100));
        c.drive(r2, Logic::One, Time::ps(100));
        c.run_to_quiescence().unwrap();
        // Exactly one grant; side 1 (first scheduled) wins the model tie.
        assert_eq!(c.value(g1), Logic::One);
        assert_eq!(c.value(g2), Logic::Zero);
    }

    #[test]
    fn never_both_granted() {
        for gap in [0u64, 1, 5, 20, 100, 1000] {
            let (mut c, r1, r2, g1, g2) = behavioural();
            c.drive(r1, Logic::One, Time::ps(50));
            c.drive(r2, Logic::One, Time::ps(50 + gap));
            c.run_to_quiescence().unwrap();
            let both = c.value(g1) == Logic::One && c.value(g2) == Logic::One;
            assert!(!both, "mutual exclusion violated at gap {gap}ps");
            // And exactly one granted (requests held high):
            let any = c.value(g1) == Logic::One || c.value(g2) == Logic::One;
            assert!(any, "no grant at gap {gap}ps");
        }
    }

    #[test]
    fn withdrawn_request_before_grant_passes_to_other() {
        let (mut c, r1, r2, g1, g2) = behavioural();
        // r1 arrives, then withdraws 10 ps later (before the 40 ps set
        // time elapses); r2 arrives during the gap.
        c.drive(r1, Logic::One, Time::ps(100));
        c.drive(r2, Logic::One, Time::ps(105));
        c.drive(r1, Logic::Zero, Time::ps(110));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(g1), Logic::Zero);
        assert_eq!(c.value(g2), Logic::One);
    }

    #[test]
    fn gate_level_matches_behavioural_when_separated() {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let m = build_gate_level(&mut c, "mx");
        // Settle the latch out of X.
        c.drive(m.r1, Logic::Zero, Time::ps(1));
        c.drive(m.r2, Logic::Zero, Time::ps(1));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(m.g1), Logic::Zero);
        assert_eq!(c.value(m.g2), Logic::Zero);
        // Request 1 wins.
        c.drive(m.r1, Logic::One, Time::ps(300));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(m.g1), Logic::One);
        assert_eq!(c.value(m.g2), Logic::Zero);
        // Second request queues; release hands over.
        c.drive(m.r2, Logic::One, Time::ps(10));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(m.g2), Logic::Zero);
        c.drive(m.r1, Logic::Zero, Time::ps(10));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(m.g1), Logic::Zero);
        assert_eq!(c.value(m.g2), Logic::One);
    }
}
