//! Time-domain delay primitives: fixed delay elements and the
//! digitally-controlled delay element (DCDE) of §II-C.3.
//!
//! These are the paper's "weak-capacitance" nodes: an event traversing a
//! delay stage costs `e_delay_stage_fj` — an order of magnitude below a
//! std-cell transition — which is the physical basis of the architecture's
//! energy advantage.

use std::cell::Cell;
use std::rc::Rc;

use crate::sim::energy::EnergyKind;
use crate::sim::{Component, Ctx, NetId, Time};

/// Fixed-delay element: output follows input after `delay`; energy is
/// charged per *stage* traversed (delay / τ stages, ≥ 1).
pub struct DelayElement {
    name: String,
    input: NetId,
    output: NetId,
    delay: Time,
    stages: f64,
    stage_energy_fj: f64,
    /// Gaussian PVT jitter σ as fraction of nominal (0 disables).
    jitter_sigma: f64,
    jitter_rng: Option<crate::util::SplitMix64>,
}

impl DelayElement {
    pub fn new(
        name: impl Into<String>,
        input: NetId,
        output: NetId,
        delay: Time,
        tech: &crate::sim::TechParams,
    ) -> DelayElement {
        let stages = (delay.as_ps_f64() / tech.tau_ps).max(1.0);
        DelayElement {
            name: name.into(),
            input,
            output,
            delay,
            stages,
            stage_energy_fj: tech.e_delay_stage_fj * tech.vscale(),
            jitter_sigma: tech.pvt_sigma,
            jitter_rng: if tech.pvt_sigma > 0.0 {
                Some(crate::util::SplitMix64::new(0xD31A))
            } else {
                None
            },
        }
    }

    /// Reseed the PVT jitter stream (per-instance decorrelation).
    pub fn with_jitter_seed(mut self, seed: u64) -> DelayElement {
        if self.jitter_sigma > 0.0 {
            self.jitter_rng = Some(crate::util::SplitMix64::new(seed));
        }
        self
    }

    fn effective_delay(&mut self) -> Time {
        match (&mut self.jitter_rng, self.jitter_sigma) {
            (Some(rng), s) if s > 0.0 => {
                let factor = (1.0 + s * rng.next_gaussian()).max(0.05);
                self.delay.scale(factor)
            }
            _ => self.delay,
        }
    }
}

impl Component for DelayElement {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_input(&mut self, _pin: usize, ctx: &mut Ctx) {
        let v = ctx.get(self.input);
        ctx.spend(EnergyKind::DelayLine, self.stage_energy_fj * self.stages);
        let d = self.effective_delay();
        ctx.schedule(self.output, v, d);
    }

    fn gate_equivalents(&self) -> f64 {
        0.3 * self.stages
    }
}

/// Shared, runtime-writable delay code — the interface between the
/// Vernier TDC (writer) and the DCDE (reader) in the CoTM path.
pub type DelayCode = Rc<Cell<u64>>;

/// Digitally-controlled delay element: delay = `base + code × step`,
/// where `code` is written at runtime by an upstream component (TDC).
///
/// Implementations in silicon are multiplexed delay segments or
/// current-starved inverters ([12], [15]–[17]); energetically it is a
/// delay line of `code` unit stages.
pub struct Dcde {
    name: String,
    input: NetId,
    output: NetId,
    code: DelayCode,
    base: Time,
    step: Time,
    stage_energy_fj: f64,
}

impl Dcde {
    pub fn new(
        name: impl Into<String>,
        input: NetId,
        output: NetId,
        code: DelayCode,
        base: Time,
        step: Time,
        tech: &crate::sim::TechParams,
    ) -> Dcde {
        Dcde {
            name: name.into(),
            input,
            output,
            code,
            base,
            step,
            stage_energy_fj: tech.e_delay_stage_fj * tech.vscale(),
        }
    }
}

impl Component for Dcde {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_input(&mut self, _pin: usize, ctx: &mut Ctx) {
        let v = ctx.get(self.input);
        let code = self.code.get();
        let delay = self.base + self.step.scale(code as f64);
        // Energy ∝ traversed stages (code), plus the base stage.
        ctx.spend(
            EnergyKind::DelayLine,
            self.stage_energy_fj * (1.0 + code as f64),
        );
        ctx.schedule(self.output, v, delay);
    }

    fn gate_equivalents(&self) -> f64 {
        2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::{Circuit, Logic};

    #[test]
    fn delays_by_nominal() {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let i = c.net_init("i", Logic::Zero);
        let o = c.net("o");
        let t = c.tech.clone();
        c.add(
            Box::new(DelayElement::new("d", i, o, Time::ps(250), &t)),
            vec![i],
        );
        c.drive(i, Logic::One, Time::ps(10));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(o), Logic::One);
        assert_eq!(c.now(), Time::ps(260));
    }

    #[test]
    fn energy_scales_with_stage_count() {
        let t = TechParams::tsmc65_digital();
        let mut c = Circuit::new(t.clone());
        let i = c.net_init("i", Logic::Zero);
        let o1 = c.net("o1");
        let o2 = c.net("o2");
        c.add(Box::new(DelayElement::new("d1", i, o1, Time::ps(100), &t)), vec![i]);
        c.add(Box::new(DelayElement::new("d4", i, o2, Time::ps(400), &t)), vec![i]);
        c.drive(i, Logic::One, Time::ps(1));
        c.run_to_quiescence().unwrap();
        let e = c.energy.dynamic_fj(EnergyKind::DelayLine);
        // 1 stage + 4 stages = 5 × 0.08 fJ
        assert!((e - 5.0 * 0.08).abs() < 1e-9, "e={e}");
    }

    #[test]
    fn dcde_tracks_runtime_code() {
        let t = TechParams::tsmc65_digital();
        let mut c = Circuit::new(t.clone());
        let i = c.net_init("i", Logic::Zero);
        let o = c.net("o");
        let code: DelayCode = Rc::new(Cell::new(0));
        c.add(
            Box::new(Dcde::new("dc", i, o, code.clone(), Time::ps(50), Time::ps(10), &t)),
            vec![i],
        );
        code.set(7);
        c.drive(i, Logic::One, Time::ps(0));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.now(), Time::ps(120)); // 50 + 7×10

        code.set(2);
        c.drive(i, Logic::Zero, Time::ps(0));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.now(), Time::ps(190)); // 120 + 50 + 2×10
    }

    #[test]
    fn jitter_perturbs_but_stays_positive() {
        let mut t = TechParams::tsmc65_digital();
        t.pvt_sigma = 0.1;
        let mut c = Circuit::new(t.clone());
        let i = c.net_init("i", Logic::Zero);
        let o = c.net("o");
        c.add(
            Box::new(DelayElement::new("d", i, o, Time::ps(100), &t).with_jitter_seed(99)),
            vec![i],
        );
        c.drive(i, Logic::One, Time::ps(0));
        c.run_to_quiescence().unwrap();
        let arr = c.now();
        assert!(arr > Time::ps(50) && arr < Time::ps(150), "arr={arr}");
        assert_ne!(arr, Time::ps(100)); // jitter actually applied
    }
}
