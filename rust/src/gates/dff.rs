//! Sequential primitives: D flip-flop and T flip-flop.

use crate::sim::energy::{EnergyKind, GateKind};
use crate::sim::{Component, Ctx, Logic, NetId, Time};

/// Rising-edge D flip-flop with async active-high reset.
/// Pins: `[d, clk, rst]`.
pub struct Dff {
    name: String,
    d: NetId,
    clk: NetId,
    rst: NetId,
    q: NetId,
    delay: Time,
    energy_fj: f64,
    energy_kind: EnergyKind,
    last_clk: Logic,
}

impl Dff {
    pub fn new(
        name: impl Into<String>,
        d: NetId,
        clk: NetId,
        rst: NetId,
        q: NetId,
        tech: &crate::sim::TechParams,
    ) -> Dff {
        Dff {
            name: name.into(),
            d,
            clk,
            rst,
            q,
            delay: tech.gate_delay(GateKind::Dff),
            energy_fj: tech.gate_energy_fj(GateKind::Dff),
            energy_kind: EnergyKind::Sequential,
            last_clk: Logic::X,
        }
    }

    pub fn with_energy_kind(mut self, kind: EnergyKind) -> Dff {
        self.energy_kind = kind;
        self
    }
}

impl Component for Dff {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        // Latch the power-on clock level so the first real edge is seen.
        self.last_clk = ctx.get(self.clk);
    }

    fn on_input(&mut self, pin: usize, ctx: &mut Ctx) {
        // pin 2 = rst
        if pin == 2 || ctx.get(self.rst) == Logic::One {
            if ctx.get(self.rst) == Logic::One && ctx.get(self.q) != Logic::Zero {
                ctx.spend(self.energy_kind, self.energy_fj * 0.5);
                ctx.schedule(self.q, Logic::Zero, self.delay);
            }
            self.last_clk = ctx.get(self.clk);
            return;
        }
        if pin == 1 {
            let clk = ctx.get(self.clk);
            let rising = self.last_clk == Logic::Zero && clk == Logic::One;
            self.last_clk = clk;
            if rising {
                let d = ctx.get(self.d);
                // Clock pin toggles cost energy even without a Q change
                // (internal master latch) — half the captured-edge cost.
                ctx.spend(self.energy_kind, self.energy_fj * 0.5);
                if d != ctx.get(self.q) && d.is_defined() {
                    ctx.spend(self.energy_kind, self.energy_fj * 0.5);
                    ctx.schedule(self.q, d, self.delay);
                }
            }
        }
        // pin 0 (d) changes don't propagate until a clock edge.
    }

    fn gate_equivalents(&self) -> f64 {
        6.0
    }
}

/// Toggle flip-flop with async reset: output inverts on every rising edge
/// of `t`. Pins: `[t, rst]`. Used as the paper's four-to-two phase
/// interface element (§II-C.5).
pub struct Tff {
    name: String,
    t: NetId,
    rst: NetId,
    q: NetId,
    delay: Time,
    energy_fj: f64,
    energy_kind: EnergyKind,
    last_t: Logic,
    state: Logic,
}

impl Tff {
    pub fn new(
        name: impl Into<String>,
        t: NetId,
        rst: NetId,
        q: NetId,
        tech: &crate::sim::TechParams,
    ) -> Tff {
        Tff {
            name: name.into(),
            t,
            rst,
            q,
            delay: tech.gate_delay(GateKind::Tff),
            energy_fj: tech.gate_energy_fj(GateKind::Tff),
            energy_kind: EnergyKind::Sequential,
            last_t: Logic::X,
            state: Logic::Zero,
        }
    }

    pub fn with_energy_kind(mut self, kind: EnergyKind) -> Tff {
        self.energy_kind = kind;
        self
    }
}

impl Component for Tff {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        self.last_t = ctx.get(self.t);
        ctx.schedule(self.q, Logic::Zero, Time::ZERO);
    }

    fn on_input(&mut self, pin: usize, ctx: &mut Ctx) {
        if pin == 1 || ctx.get(self.rst) == Logic::One {
            if self.state != Logic::Zero {
                self.state = Logic::Zero;
                ctx.spend(self.energy_kind, self.energy_fj * 0.5);
                ctx.schedule(self.q, Logic::Zero, self.delay);
            }
            self.last_t = ctx.get(self.t);
            return;
        }
        let t = ctx.get(self.t);
        let rising = self.last_t == Logic::Zero && t == Logic::One;
        self.last_t = t;
        if rising {
            self.state = self.state.not();
            if self.state == Logic::X {
                self.state = Logic::One; // from reset state it's defined
            }
            ctx.spend(self.energy_kind, self.energy_fj);
            ctx.schedule(self.q, self.state, self.delay);
        }
    }

    fn gate_equivalents(&self) -> f64 {
        6.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::Circuit;

    fn dff_fixture() -> (Circuit, NetId, NetId, NetId, NetId) {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let d = c.net_init("d", Logic::Zero);
        let clk = c.net_init("clk", Logic::Zero);
        let rst = c.net_init("rst", Logic::Zero);
        let q = c.net("q");
        let t = c.tech.clone();
        c.add(
            Box::new(Dff::new("ff", d, clk, rst, q, &t)),
            vec![d, clk, rst],
        );
        c.init_components();
        c.run_to_quiescence().unwrap();
        (c, d, clk, rst, q)
    }

    #[test]
    fn captures_on_rising_edge_only() {
        let (mut c, d, clk, _rst, q) = dff_fixture();
        c.drive(d, Logic::One, Time::ps(1));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(q), Logic::X); // no edge yet
        c.drive(clk, Logic::One, Time::ps(1)); // rising edge
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(q), Logic::One);
        c.drive(d, Logic::Zero, Time::ps(1));
        c.drive(clk, Logic::Zero, Time::ps(2)); // falling edge: no capture
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(q), Logic::One);
    }

    #[test]
    fn reset_clears_q() {
        let (mut c, d, clk, rst, q) = dff_fixture();
        c.drive(d, Logic::One, Time::ps(1));
        c.drive(clk, Logic::One, Time::ps(5));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(q), Logic::One);
        c.drive(rst, Logic::One, Time::ps(1));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(q), Logic::Zero);
    }

    #[test]
    fn tff_toggles_per_rising_edge() {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let t = c.net_init("t", Logic::Zero);
        let rst = c.net_init("rst", Logic::Zero);
        let q = c.net("q");
        let tech = c.tech.clone();
        c.add(Box::new(Tff::new("tff", t, rst, q, &tech)), vec![t, rst]);
        c.init_components();
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(q), Logic::Zero);
        for i in 0..4u64 {
            c.drive(t, Logic::One, Time::ps(1));
            c.drive(t, Logic::Zero, Time::ps(50));
            c.run_to_quiescence().unwrap();
            let expect = if i % 2 == 0 { Logic::One } else { Logic::Zero };
            assert_eq!(c.value(q), expect, "toggle {i}");
        }
    }
}
