//! Clock generator for the synchronous baselines.
//!
//! The clock is an ordinary net in the event-driven simulator; the
//! synchronous architecture's defining cost — the tree toggling every
//! cycle whether or not data moved — is charged here per edge pair,
//! scaled by the number of leaf flops served.

use crate::sim::energy::EnergyKind;
use crate::sim::{Component, Ctx, Logic, NetId, Time};

/// Free-running clock: drives `clk` with a 50% duty cycle.
pub struct ClockGen {
    name: String,
    clk: NetId,
    half_period: Time,
    /// Leaf flops served by the tree; tree energy = leaves × e_clktree per cycle.
    leaves: usize,
    e_tree_per_cycle_fj: f64,
    running: bool,
    /// Stop after this absolute time (simulation horizon).
    pub stop_at: Time,
}

impl ClockGen {
    pub fn new(
        name: impl Into<String>,
        clk: NetId,
        period: Time,
        leaves: usize,
        tech: &crate::sim::TechParams,
    ) -> ClockGen {
        assert!(period.as_fs() >= 2, "period too small");
        ClockGen {
            name: name.into(),
            clk,
            half_period: Time::fs(period.as_fs() / 2),
            leaves,
            e_tree_per_cycle_fj: tech.e_clktree_fj * tech.vscale(),
            running: false,
            stop_at: Time::ns(1_000_000),
        }
    }

    pub fn with_stop_at(mut self, t: Time) -> ClockGen {
        self.stop_at = t;
        self
    }
}

impl Component for ClockGen {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        self.running = true;
        ctx.schedule(self.clk, Logic::Zero, Time::ZERO);
        ctx.schedule(self.clk, Logic::One, self.half_period);
    }

    /// Self-retriggering: the generator is wired with its own output as
    /// pin 0, so each edge schedules the next.
    fn on_input(&mut self, _pin: usize, ctx: &mut Ctx) {
        if !self.running || ctx.now >= self.stop_at {
            return;
        }
        let cur = ctx.get(self.clk);
        // Tree energy: charge half per edge (rising+falling = one cycle).
        ctx.spend(
            EnergyKind::ClockTree,
            0.5 * self.e_tree_per_cycle_fj * self.leaves as f64,
        );
        ctx.schedule(self.clk, cur.not(), self.half_period);
    }

    fn gate_equivalents(&self) -> f64 {
        // Clock buffers: ~1 GE per 4 leaves plus the oscillator.
        4.0 + self.leaves as f64 * 0.25
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::Circuit;

    #[test]
    fn toggles_at_period() {
        let t = TechParams::tsmc65_digital();
        let mut c = Circuit::new(t.clone());
        let clk = c.net("clk");
        let g = ClockGen::new("ck", clk, Time::ns(1), 8, &t).with_stop_at(Time::ns(10));
        c.add(Box::new(g), vec![clk]);
        c.init_components();
        c.run_until(Time::ns(10)).unwrap();
        // 10 ns at 1 ns period = ~20 edges.
        let n = c.transitions(clk);
        assert!((18..=22).contains(&n), "transitions={n}");
    }

    #[test]
    fn tree_energy_scales_with_leaves() {
        let t = TechParams::tsmc65_digital();
        let run = |leaves: usize| {
            let mut c = Circuit::new(t.clone());
            let clk = c.net("clk");
            let g = ClockGen::new("ck", clk, Time::ns(1), leaves, &t)
                .with_stop_at(Time::ns(5));
            c.add(Box::new(g), vec![clk]);
            c.init_components();
            c.run_until(Time::ns(5)).unwrap();
            c.energy.dynamic_fj(EnergyKind::ClockTree)
        };
        let e8 = run(8);
        let e16 = run(16);
        assert!((e16 / e8 - 2.0).abs() < 0.01, "e8={e8} e16={e16}");
    }

    #[test]
    fn stops_at_horizon() {
        let t = TechParams::tsmc65_digital();
        let mut c = Circuit::new(t.clone());
        let clk = c.net("clk");
        let g = ClockGen::new("ck", clk, Time::ns(1), 1, &t).with_stop_at(Time::ns(3));
        c.add(Box::new(g), vec![clk]);
        c.init_components();
        c.run_to_quiescence().unwrap();
        assert!(c.now() <= Time::ns(4));
    }
}
