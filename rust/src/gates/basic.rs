//! Combinational primitives: INV/BUF/AND/OR/NAND/NOR/XOR/XNOR/MUX2.

use crate::sim::energy::{EnergyKind, GateKind};
use crate::sim::{Component, Ctx, Logic, NetId, Time};

/// Boolean function selector for [`Gate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateOp {
    Inv,
    Buf,
    And,
    Or,
    Nand,
    Nor,
    Xor,
    Xnor,
    /// `output = sel ? b : a`; pins are `[a, b, sel]`.
    Mux2,
}

impl GateOp {
    pub fn gate_kind(self) -> GateKind {
        match self {
            GateOp::Inv => GateKind::Inv,
            GateOp::Buf => GateKind::Buf,
            GateOp::And => GateKind::And,
            GateOp::Or => GateKind::Or,
            GateOp::Nand => GateKind::Nand,
            GateOp::Nor => GateKind::Nor,
            GateOp::Xor => GateKind::Xor,
            GateOp::Xnor => GateKind::Xnor,
            GateOp::Mux2 => GateKind::Mux2,
        }
    }

    /// Evaluate over three-valued inputs.
    pub fn eval(self, ins: &[Logic]) -> Logic {
        match self {
            GateOp::Inv => ins[0].not(),
            GateOp::Buf => ins[0],
            GateOp::And => ins.iter().copied().fold(Logic::One, Logic::and),
            GateOp::Nand => ins.iter().copied().fold(Logic::One, Logic::and).not(),
            GateOp::Or => ins.iter().copied().fold(Logic::Zero, Logic::or),
            GateOp::Nor => ins.iter().copied().fold(Logic::Zero, Logic::or).not(),
            GateOp::Xor => ins.iter().copied().fold(Logic::Zero, Logic::xor),
            GateOp::Xnor => ins.iter().copied().fold(Logic::Zero, Logic::xor).not(),
            GateOp::Mux2 => match ins[2] {
                Logic::Zero => ins[0],
                Logic::One => ins[1],
                Logic::X => {
                    // If both data inputs agree the output is defined.
                    if ins[0] == ins[1] {
                        ins[0]
                    } else {
                        Logic::X
                    }
                }
            },
        }
    }
}

/// A combinational gate instance.
pub struct Gate {
    name: String,
    op: GateOp,
    inputs: Vec<NetId>,
    output: NetId,
    delay: Time,
    energy_fj: f64,
    energy_kind: EnergyKind,
}

impl Gate {
    /// Create with delay/energy from the tech parameters.
    pub fn new(
        name: impl Into<String>,
        op: GateOp,
        inputs: Vec<NetId>,
        output: NetId,
        tech: &crate::sim::TechParams,
    ) -> Gate {
        if op == GateOp::Mux2 {
            assert_eq!(inputs.len(), 3, "mux2 needs [a, b, sel]");
        }
        if matches!(op, GateOp::Inv | GateOp::Buf) {
            assert_eq!(inputs.len(), 1);
        }
        Gate {
            name: name.into(),
            op,
            inputs,
            output,
            delay: tech.gate_delay(op.gate_kind()),
            energy_fj: tech.gate_energy_fj(op.gate_kind()),
            energy_kind: EnergyKind::Logic,
        }
    }

    /// Attribute this gate's switching to a non-default energy category
    /// (e.g. handshake logic inside a click element).
    pub fn with_energy_kind(mut self, kind: EnergyKind) -> Gate {
        self.energy_kind = kind;
        self
    }
}

impl Component for Gate {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_input(&mut self, _pin: usize, ctx: &mut Ctx) {
        let ins: Vec<Logic> = self.inputs.iter().map(|n| ctx.get(*n)).collect();
        let v = self.op.eval(&ins);
        if ctx.get(self.output) != v {
            ctx.spend(self.energy_kind, self.energy_fj);
            ctx.schedule(self.output, v, self.delay);
        }
    }

    fn gate_equivalents(&self) -> f64 {
        match self.op {
            GateOp::Inv | GateOp::Buf => 0.5,
            GateOp::Xor | GateOp::Xnor => 2.2,
            GateOp::Mux2 => 1.4,
            _ => 1.0 + 0.5 * (self.inputs.len().saturating_sub(2)) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::Circuit;

    fn two_input_truth(op: GateOp, table: [(bool, bool, bool); 4]) {
        for (a, b, want) in table {
            let mut c = Circuit::new(TechParams::tsmc65_digital());
            let na = c.net_init("a", Logic::from_bool(a));
            let nb = c.net_init("b", Logic::from_bool(b));
            let no = c.net("o");
            c.add(
                Box::new(Gate::new("g", op, vec![na, nb], no, &c.tech.clone())),
                vec![na, nb],
            );
            // Re-drive `a` to its value's complement then back, to trigger
            // evaluation deterministically from a defined state.
            c.drive(na, Logic::from_bool(!a), Time::ps(1));
            c.drive(na, Logic::from_bool(a), Time::ps(50));
            c.run_to_quiescence().unwrap();
            assert_eq!(
                c.value(no),
                Logic::from_bool(want),
                "{op:?}({a},{b}) != {want}"
            );
        }
    }

    #[test]
    fn and_truth() {
        two_input_truth(
            GateOp::And,
            [(false, false, false), (false, true, false), (true, false, false), (true, true, true)],
        );
    }

    #[test]
    fn nand_truth() {
        two_input_truth(
            GateOp::Nand,
            [(false, false, true), (false, true, true), (true, false, true), (true, true, false)],
        );
    }

    #[test]
    fn xor_truth() {
        two_input_truth(
            GateOp::Xor,
            [(false, false, false), (false, true, true), (true, false, true), (true, true, false)],
        );
    }

    #[test]
    fn mux_selects() {
        let tech = TechParams::tsmc65_digital();
        let mut c = Circuit::new(tech);
        let a = c.net_init("a", Logic::Zero);
        let b = c.net_init("b", Logic::One);
        let s = c.net_init("s", Logic::Zero);
        let o = c.net("o");
        let t = c.tech.clone();
        c.add(
            Box::new(Gate::new("m", GateOp::Mux2, vec![a, b, s], o, &t)),
            vec![a, b, s],
        );
        c.drive(s, Logic::One, Time::ps(1));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(o), Logic::One);
        c.drive(s, Logic::Zero, Time::ps(1));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(o), Logic::Zero);
    }

    #[test]
    fn x_propagation_through_and() {
        // One input X, other 1 -> X out; other 0 -> 0 out (controlling).
        assert_eq!(GateOp::And.eval(&[Logic::X, Logic::One]), Logic::X);
        assert_eq!(GateOp::And.eval(&[Logic::X, Logic::Zero]), Logic::Zero);
    }
}
