//! Gate-level component library.
//!
//! Primitives carry their own nominal delay and per-transition switching
//! energy from [`crate::sim::TechParams`]; composite cells (Mutex,
//! C-element, click) are the paper's asynchronous building blocks.
//!
//! Energy attribution note: a gate spends its switching energy when it
//! *schedules* an output transition that differs from the output net's
//! present value. If a later input change re-schedules the opposite value
//! before the first arrives, both count — which is faithful: glitches
//! charge real CMOS nodes too, and glitch power is precisely one of the
//! costs the paper's time-domain approach avoids.

pub mod basic;
pub mod celement;
pub mod clock;
pub mod delay;
pub mod dff;
pub mod mutex;

pub use basic::{Gate, GateOp};
pub use celement::CElement;
pub use clock::ClockGen;
pub use delay::{Dcde, DelayElement};
pub use dff::{Dff, Tff};
pub use mutex::Mutex;
