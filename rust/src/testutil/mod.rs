//! Mini property-based testing framework (proptest is unavailable
//! offline). Deterministic: every case derives from a seeded
//! [`SplitMix64`] stream, and failures report the case index + seed so
//! they can be replayed exactly.
//!
//! ```no_run
//! # // no_run: doctest executables do not inherit the crate's
//! # // xla_extension rpath and fail to load libstdc++ offline.
//! use tsetlin_td::testutil::{prop, Gen};
//! prop("reverse twice is identity", 100, |g| {
//!     let xs = g.vec(0..20, |g| g.u64(0..1000));
//!     let mut twice = xs.clone();
//!     twice.reverse();
//!     twice.reverse();
//!     assert_eq!(xs, twice);
//! });
//! ```

use crate::util::SplitMix64;

/// Random-case generator handed to property bodies.
pub struct Gen {
    rng: SplitMix64,
    /// Human-readable log of drawn values (printed on failure).
    pub draws: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: SplitMix64::new(seed), draws: Vec::new() }
    }

    fn note(&mut self, label: &str, v: impl std::fmt::Debug) {
        if self.draws.len() < 64 {
            self.draws.push(format!("{label}={v:?}"));
        }
    }

    /// u64 in `[range.start, range.end)`.
    pub fn u64(&mut self, range: std::ops::Range<u64>) -> u64 {
        assert!(range.end > range.start);
        let v = range.start + self.rng.next_below(range.end - range.start);
        self.note("u64", v);
        v
    }

    /// usize in `[range.start, range.end)`.
    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    /// i64 in `[range.start, range.end)`.
    pub fn i64(&mut self, range: std::ops::Range<i64>) -> i64 {
        assert!(range.end > range.start);
        let span = (range.end - range.start) as u64;
        let v = range.start + self.rng.next_below(span) as i64;
        self.note("i64", v);
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_bool();
        self.note("bool", v);
        v
    }

    /// f64 in `[0, 1)`.
    pub fn f64_unit(&mut self) -> f64 {
        let v = self.rng.next_f64();
        self.note("f64", v);
        v
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// A vector with random length in `len` of generated elements.
    pub fn vec<T>(
        &mut self,
        len: std::ops::Range<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize(len);
        (0..n).map(|_| f(self)).collect()
    }

    /// A boolean vector of exactly `n` elements.
    pub fn bools(&mut self, n: usize) -> Vec<bool> {
        (0..n).map(|_| self.rng.next_bool()).collect()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }
}

/// Run `cases` random cases of a property. Panics (with seed and draw
/// log) on the first failing case.
pub fn prop(name: &str, cases: u64, mut body: impl FnMut(&mut Gen)) {
    prop_seeded(name, cases, 0x7E57_CA5E, &mut body)
}

/// Like [`prop`] with an explicit base seed (replay a failure by pasting
/// the seed from the panic message).
pub fn prop_seeded(name: &str, cases: u64, base_seed: u64, body: &mut impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}):\n  {msg}\n  draws: [{}]",
                g.draws.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop("addition commutes", 50, |g| {
            let a = g.u64(0..1000);
            let b = g.u64(0..1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_failure_with_seed() {
        let r = std::panic::catch_unwind(|| {
            prop("always fails above 5", 100, |g| {
                let x = g.u64(0..100);
                assert!(x <= 5, "x={x}");
            });
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>().unwrap());
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("draws"), "{msg}");
    }

    #[test]
    fn deterministic_replay() {
        let mut first: Vec<u64> = Vec::new();
        prop_seeded("record", 5, 42, &mut |g| {
            first.push(g.u64(0..1_000_000));
        });
        let mut second: Vec<u64> = Vec::new();
        prop_seeded("record", 5, 42, &mut |g| {
            second.push(g.u64(0..1_000_000));
        });
        assert_eq!(first, second);
    }

    #[test]
    fn generators_respect_ranges() {
        prop("ranges", 200, |g| {
            let u = g.u64(10..20);
            assert!((10..20).contains(&u));
            let i = g.i64(-5..5);
            assert!((-5..5).contains(&i));
            let v = g.vec(0..4, |g| g.bool());
            assert!(v.len() < 4);
            let f = g.f64_unit();
            assert!((0.0..1.0).contains(&f));
        });
    }
}
