//! Time-domain computing blocks (paper §II-C):
//!
//! * [`lod`] — leading-ones-detector coarse/fine delay compression
//!   (Algorithm 4): exponential delay range → logarithmic path length.
//! * [`hamming`] — the multi-class TM Hamming-distance delay encoding
//!   ([12]): linear, exact-argmax delay mapping.
//! * [`delay_path`] — the differential delay path of Fig. 4 (S/M rails).
//! * [`vernier`] — Vernier time-to-digital converter ([14]) digitising
//!   the rail interval into a compact delay code `dc`.
//! * [`race`] — the race control unit tying the CoTM classification
//!   together: LOD → differential paths → TDC → DCDE single-rail race.

pub mod delay_path;
pub mod hamming;
pub mod lod;
pub mod race;
pub mod vernier;

pub use delay_path::DiffDelayPath;
pub use hamming::{hamming_delay_units, hamming_score};
pub use lod::{lod_delay, lod_delay_units, lod_extract, LodCode};
pub use race::CotmRaceUnit;
pub use vernier::VernierTdc;
