//! CoTM race control unit (paper Fig. 3, §II-C).
//!
//! Per class: a differential delay path (LOD-programmed S/M rails), a
//! Vernier TDC digitising the rail interval into `dc`, and a DCDE
//! replaying `dc` on the single-rail (SR) path. A Muller C-element
//! rendezvous launches the SR race only when *every* class's TDC has
//! converted (QDI completion); the WTA grants the first SR arrival.
//!
//! Ordering invariant: the SR arrival time is
//! `t_i = base + dc_i·res`, with
//! `dc_i = round((g(S_i) − g(M_i) + offset)/res)` and `g` the monotone
//! LOD delay map — so the grant goes to `argmax_i (g(M_i) − g(S_i))`,
//! the time-domain analogue of `argmax (M−S)`. Exact up to LOD/TDC
//! quantisation, which `tests/equivalence.rs` and the ablation benches
//! measure.

use crate::gates::celement::CElement;
use crate::gates::delay::{Dcde, DelayCode};
use crate::sim::{Circuit, Logic, NetId, Time};
use crate::wta::{self, WtaKind};

use super::delay_path::DiffDelayPath;
use super::vernier::VernierTdc;

/// The assembled CoTM classification back-end.
pub struct CotmRaceUnit {
    /// Four-phase launch (raceDR): drive ↑ to classify, ↓ to recover.
    pub launch: NetId,
    /// One-hot grant outputs.
    pub grants: Vec<NetId>,
    /// SR-race go signal (C-element output; observability/tracing).
    pub sr_go: NetId,
    paths: Vec<DiffDelayPath>,
    /// Retained for observability in debugging sessions.
    #[allow(dead_code)]
    sr_codes: Vec<DelayCode>,
    pub tdc_dones: Vec<NetId>,
}

impl CotmRaceUnit {
    /// Build for `classes` competitors. `max_sum` bounds the S/M sums
    /// (e.g. clauses × max_weight) and sizes the TDC offset so negative
    /// intervals remain representable.
    pub fn build(
        c: &mut Circuit,
        name: &str,
        classes: usize,
        max_sum: u64,
        wta_kind: WtaKind,
    ) -> CotmRaceUnit {
        assert!(classes >= 2);
        // The race unit runs on the short-segment corner (cotm_tau_ps):
        // rails traverse up to k_max coarse segments per classification,
        // so segment length directly bounds the race cycle.
        let tech = c.tech.cotm_race_corner();
        let launch = c.net_init(format!("{name}.raceDR"), Logic::Zero);
        // Offset: the largest possible |g(S) − g(M)| is bounded by the
        // LOD delay of max_sum plus one coarse segment.
        let kmax = 64 - max_sum.max(1).leading_zeros() as u64;
        let offset = tech.tau().scale((kmax + 2) as f64);
        // Guaranteed minimum raw TDC code: the offset minus the largest
        // possible rail delay, in resolution ticks. Subtracting it from
        // every conversion (a shared constant — ordering unchanged)
        // keeps the single-rail paths short, which is the point of the
        // LOD compression.
        let g_max = crate::timedomain::lod::lod_delay(max_sum, tech.fine_bits, tech.tau());
        let floor_code =
            offset.since(g_max).as_fs() / Time::from_ps_f64(tech.tdc_res_ps).as_fs();

        let mut paths = Vec::with_capacity(classes);
        let mut sr_codes = Vec::with_capacity(classes);
        let mut tdc_dones = Vec::with_capacity(classes);
        let mut sr_races = Vec::with_capacity(classes);

        for i in 0..classes {
            let pname = format!("{name}.cls{i}");
            let path = DiffDelayPath::build_with_tech(c, &pname, launch, &tech);
            let done = c.net(format!("{pname}.tdc_done"));
            let dc: DelayCode = DelayCode::default();
            let tdc = VernierTdc::new(
                format!("{pname}.tdc"),
                path.race_s,
                path.race_m,
                done,
                dc.clone(),
                offset,
                &tech,
            )
            .with_floor_code(floor_code);
            c.add(Box::new(tdc), vec![path.race_s, path.race_m]);
            tdc_dones.push(done);
            paths.push(path);
            sr_codes.push(dc);
        }

        // QDI completion: SR race launches when all TDCs have converted.
        let sr_go = c.net(format!("{name}.sr_go"));
        c.add(
            Box::new(CElement::new(format!("{name}.celem"), tdc_dones.clone(), sr_go, &tech)),
            tdc_dones.clone(),
        );

        // SR DCDE per class: base + dc × sr_step. The segment length is
        // decoupled from the TDC resolution — dc *indexes* segments, it
        // does not replay the interval at full scale, which is what keeps
        // the SR path "only a short length" (§II-C.3).
        let res = Time::from_ps_f64(tech.sr_step_ps);
        for (i, dc) in sr_codes.iter().enumerate() {
            let race = c.net(format!("{name}.sr_race{i}"));
            c.add(
                Box::new(Dcde::new(
                    format!("{name}.sr_dcde{i}"),
                    sr_go,
                    race,
                    dc.clone(),
                    tech.tau(),
                    res,
                    &tech,
                )),
                vec![sr_go],
            );
            sr_races.push(race);
        }

        let arb = wta::build(c, wta_kind, &format!("{name}.wta"), &sr_races);
        CotmRaceUnit {
            launch,
            grants: arb.grants,
            sr_go,
            paths,
            sr_codes,
            tdc_dones,
        }
    }

    /// Program every class's differential path from its digitally
    /// pre-computed (S, M) sums.
    pub fn program(&self, sums: &[(u64, u64)]) {
        assert_eq!(sums.len(), self.paths.len());
        for (path, &(s, m)) in self.paths.iter().zip(sums) {
            path.program(s, m);
        }
    }

    /// The winner currently granted (if any).
    pub fn winner(&self, c: &Circuit) -> Option<usize> {
        let mut w = None;
        for (i, g) in self.grants.iter().enumerate() {
            if c.value(*g) == Logic::One {
                if w.is_some() {
                    return None; // not one-hot (transient)
                }
                w = Some(i);
            }
        }
        w
    }

    /// One full four-phase classification: program, launch, wait for the
    /// grant, recover. Returns (winner, decision latency).
    pub fn classify(
        &self,
        c: &mut Circuit,
        sums: &[(u64, u64)],
    ) -> crate::Result<(usize, Time)> {
        self.program(sums);
        let t0 = c.now();
        c.drive(self.launch, Logic::One, Time::ZERO);
        let deadline = t0 + Time::ns(10_000);
        let decided = c.run_while(deadline, |cc| {
            self.grants.iter().any(|g| cc.value(*g) == Logic::One)
        })?;
        if !decided {
            return Err(crate::Error::sim("race never resolved"));
        }
        let winner = self
            .winner(c)
            .ok_or_else(|| crate::Error::sim("grant not one-hot"))?;
        let latency = c.now().since(t0);
        // Four-phase recovery: drop launch, let everything RTZ.
        c.drive(self.launch, Logic::Zero, Time::ZERO);
        c.run_to_quiescence()?;
        Ok((winner, latency))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;

    fn unit(classes: usize) -> (Circuit, CotmRaceUnit) {
        let t = TechParams::tsmc65_proposed();
        let mut c = Circuit::new(t);
        let u = CotmRaceUnit::build(&mut c, "race", classes, 84, WtaKind::Tba);
        c.init_components();
        c.run_to_quiescence().unwrap();
        (c, u)
    }

    #[test]
    fn picks_largest_signed_sum() {
        let (mut c, u) = unit(3);
        // class sums M−S: 10−2=8, 3−0=3, 7−7=0 -> winner 0.
        let (w, _) = u.classify(&mut c, &[(2, 10), (0, 3), (7, 7)]).unwrap();
        assert_eq!(w, 0);
    }

    #[test]
    fn negative_sums_lose_to_positive() {
        let (mut c, u) = unit(3);
        // sums: −5, +1, −2 -> winner 1.
        let (w, _) = u.classify(&mut c, &[(6, 1), (0, 1), (3, 1)]).unwrap();
        assert_eq!(w, 1);
    }

    #[test]
    fn all_negative_picks_least_negative() {
        let (mut c, u) = unit(3);
        // sums: −8, −2, −20 -> winner 1.
        let (w, _) = u.classify(&mut c, &[(9, 1), (3, 1), (21, 1)]).unwrap();
        assert_eq!(w, 1);
    }

    #[test]
    fn reusable_across_classifications() {
        let (mut c, u) = unit(3);
        let cases: &[(&[(u64, u64)], usize)] = &[
            (&[(0, 9), (0, 1), (0, 3)], 0),
            (&[(0, 1), (0, 9), (0, 3)], 1),
            (&[(5, 1), (9, 1), (0, 4)], 2),
            (&[(0, 2), (0, 2), (0, 8)], 2),
        ];
        for (sums, want) in cases {
            let (w, _) = u.classify(&mut c, sums).unwrap();
            assert_eq!(w, *want, "sums={sums:?}");
        }
    }

    /// Expected winner under the paper's *log-domain* objective: the SR
    /// arrival minimises `dc = round((g(S) − g(M) + offset)/res)` with
    /// `g` the LOD delay map — this is what the hardware computes. Note
    /// it is NOT always `argmax(M−S)`: LOD compression reorders sums of
    /// very different magnitude scales (quantified by `ablation_lod`).
    fn log_domain_codes(sums: &[(u64, u64)], tech: &TechParams) -> Vec<i128> {
        let e = tech.fine_bits;
        let fine_fs = tech.fine_step().as_fs() as i128;
        let res_fs = crate::sim::Time::from_ps_f64(tech.tdc_res_ps).as_fs() as i128;
        sums.iter()
            .map(|&(s, m)| {
                let gs = crate::timedomain::lod::lod_delay_units(s, e) as i128 * fine_fs;
                let gm = crate::timedomain::lod::lod_delay_units(m, e) as i128 * fine_fs;
                // offset cancels across classes; clamp not reached here.
                let interval = gs - gm;
                (interval + res_fs / 2).div_euclid(res_fs)
            })
            .collect()
    }

    fn log_domain_winner(sums: &[(u64, u64)], tech: &TechParams) -> Vec<usize> {
        let dcs = log_domain_codes(sums, tech);
        let min = *dcs.iter().min().unwrap();
        (0..sums.len()).filter(|&i| dcs[i] == min).collect()
    }

    #[test]
    fn winner_matches_log_domain_objective() {
        // Expectations must be computed at the race unit's own corner
        // (short cotm segments), not the generic τ.
        let tech = TechParams::tsmc65_proposed().cotm_race_corner();
        let (mut c, u) = unit(4);
        let mut rng = crate::util::SplitMix64::new(42);
        for trial in 0..50 {
            let sums: Vec<(u64, u64)> =
                (0..4).map(|_| (rng.next_below(40), rng.next_below(40))).collect();
            let expect = log_domain_winner(&sums, &tech);
            let (w, _) = u.classify(&mut c, &sums).unwrap();
            // Arbitration slack: a 1-code gap (one sr_step) is within the
            // Mutex metastability regime and may legitimately invert.
            let dcs = log_domain_codes(&sums, &tech);
            let min = *dcs.iter().min().unwrap();
            assert!(
                expect.contains(&w) || dcs[w] <= min + 1,
                "trial {trial}: sums={sums:?} w={w} dcs={dcs:?} expected {expect:?}"
            );
        }
    }

    #[test]
    fn latency_scales_with_code_magnitude() {
        let (mut c, u) = unit(2);
        let (_, fast) = u.classify(&mut c, &[(0, 80), (0, 1)]).unwrap();
        let (_, slow) = u.classify(&mut c, &[(80, 1), (79, 1)]).unwrap();
        // Strongly negative sums sit at large dc -> later SR arrivals.
        assert!(slow > fast, "slow={slow} fast={fast}");
    }
}
