//! Hamming-distance time-domain encoding for the multi-class TM ([12],
//! §II-C: "comparing Hamming distances among different classes, where
//! contributions from ones in positive clauses and zeros in negative
//! clauses are considered equivalent").
//!
//! The per-class score counts agreeing clause outputs; the race delay is
//! *linear* in the distance `C − score`, so the class with the highest
//! class sum launches the earliest pulse and the WTA argmax is **exact**
//! (unlike the CoTM's LOD-compressed path, which is monotone but
//! quantised — see `timedomain::lod`).

use crate::sim::Time;

/// Per-class agreement score from clause outputs with alternating
/// polarity (+ even, − odd): ones in positive clauses plus zeros in
/// negative clauses. Range `0..=C`.
pub fn hamming_score(clause_outputs: &[bool]) -> u32 {
    clause_outputs
        .iter()
        .enumerate()
        .map(|(j, &out)| {
            let positive = j % 2 == 0;
            (out == positive) as u32
        })
        .sum()
}

/// Class sum (Eq. 1) recovered from the score. With C/2 clauses of each
/// polarity: `score = pos_fired + (C/2 − neg_fired) = sum + C/2`, hence
/// `sum = score − C/2`. Monotone in the score, so racing on scores is
/// racing on sums.
pub fn score_to_class_sum(score: u32, clauses: u32) -> i32 {
    score as i32 - (clauses / 2) as i32
}

/// Race delay in unit steps: distance `C − score` (highest score ⇒
/// shortest delay ⇒ first arrival at the WTA).
pub fn hamming_delay_units(score: u32, clauses: u32) -> u32 {
    debug_assert!(score <= clauses);
    clauses - score
}

/// Race delay as simulated time with unit step `step`.
pub fn hamming_delay(score: u32, clauses: u32, step: Time) -> Time {
    step.scale(hamming_delay_units(score, clauses) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_counts_agreements() {
        // pos fired, neg silent, pos silent, neg fired -> 1+1+0+0 = 2.
        assert_eq!(hamming_score(&[true, false, false, true]), 2);
        // all agree.
        assert_eq!(hamming_score(&[true, false, true, false]), 4);
        // all disagree.
        assert_eq!(hamming_score(&[false, true, false, true]), 0);
    }

    #[test]
    fn score_recovers_class_sum() {
        let mut rng = crate::util::SplitMix64::new(5);
        for _ in 0..200 {
            let c = 12usize;
            let outs: Vec<bool> = (0..c).map(|_| rng.next_bool()).collect();
            let direct: i32 = outs
                .iter()
                .enumerate()
                .map(|(j, &o)| if j % 2 == 0 { o as i32 } else { -(o as i32) })
                .sum();
            let score = hamming_score(&outs);
            assert_eq!(score_to_class_sum(score, c as u32), direct);
        }
    }

    #[test]
    fn higher_sum_means_shorter_delay() {
        let c = 12;
        let step = Time::ps(50);
        let mut last = Time::ps(10_000);
        for score in 0..=c {
            let d = hamming_delay(score, c, step);
            assert!(d < last, "delay must strictly decrease with score");
            last = d;
        }
        assert_eq!(hamming_delay(c, c, step), Time::ZERO);
        assert_eq!(hamming_delay(0, c, step), Time::ps(600));
    }

    #[test]
    fn argmax_exactness_over_random_outputs() {
        // Racing on scores must agree with argmax of Eq. 1 sums.
        let mut rng = crate::util::SplitMix64::new(77);
        for _ in 0..500 {
            let c = 12usize;
            let k = 3usize;
            let outs: Vec<Vec<bool>> = (0..k)
                .map(|_| (0..c).map(|_| rng.next_bool()).collect())
                .collect();
            let sums: Vec<i32> = outs
                .iter()
                .map(|o| {
                    o.iter()
                        .enumerate()
                        .map(|(j, &b)| if j % 2 == 0 { b as i32 } else { -(b as i32) })
                        .sum()
                })
                .collect();
            let scores: Vec<u32> = outs.iter().map(|o| hamming_score(o)).collect();
            // argmax over sums == argmax over scores (incl. tie-break).
            let am_sum = crate::tm::infer::predict_argmax(&sums);
            let am_score = crate::tm::infer::predict_argmax(
                &scores.iter().map(|&s| s as i32).collect::<Vec<_>>(),
            );
            assert_eq!(am_sum, am_score);
        }
    }
}
