//! Leading-ones-detector coarse/fine delay extraction (Algorithm 4).
//!
//! An N-bit class sum would need an O(2ᴺ)-stage linear delay line; the
//! LOD compresses it to a coarse index `k = ⌊log₂ v⌋` (one delay segment
//! per octave) plus an `e`-bit normalised fine remainder `f`, so the path
//! grows *logarithmically* with the sum range while keeping τ/2ᵉ
//! resolution inside each octave.
//!
//! The resulting delay `k·τ + f·τ/2ᵉ` is monotone non-decreasing in `v`
//! (proved by the property test below) with one known collision: v = 0
//! and v = 1 both map to zero delay — an inherent quantisation artefact
//! of Algorithm 4 that the ablation bench (`ablation_fine_res`)
//! quantifies.

use crate::sim::Time;

/// Coarse/fine delay code produced by the LOD.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LodCode {
    /// Coarse index: position of the leading one (0 for v ∈ {0, 1}).
    pub k: u32,
    /// Fine remainder, normalised to `e` bits.
    pub f: u32,
}

/// Algorithm 4: extract `(k, f)` from `sum_value` with `e` fine bits.
pub fn lod_extract(sum_value: u64, e: u32) -> LodCode {
    if sum_value == 0 {
        return LodCode { k: 0, f: 0 };
    }
    let k = 63 - sum_value.leading_zeros(); // leading-one position
    let mask = (1u64 << k) - 1;
    let mut f = sum_value & mask;
    if k >= e {
        f >>= k - e;
    } else {
        f <<= e - k;
    }
    LodCode { k, f: f as u32 }
}

/// Total delay in *fine units* (τ/2ᵉ): `k·2ᵉ + f`. This is the DCDE code
/// the differential path programs.
pub fn lod_delay_units(sum_value: u64, e: u32) -> u64 {
    let code = lod_extract(sum_value, e);
    (code.k as u64) << e | code.f as u64
}

/// Total delay as simulated time: `k·τ + f·τ/2ᵉ`.
pub fn lod_delay(sum_value: u64, e: u32, tau: Time) -> Time {
    let units = lod_delay_units(sum_value, e);
    Time::fs(units * tau.as_fs() / (1u64 << e))
}

/// Linear (no-LOD) delay in fine units — the ablation baseline showing
/// the exponential path growth the LOD removes: `v · 2ᵉ` fine units
/// (i.e. v coarse segments).
pub fn linear_delay_units(sum_value: u64, e: u32) -> u64 {
    sum_value << e
}

/// Number of delay-line *stages* the code traverses (hardware cost):
/// LOD path has `k` coarse + e fine stages; linear path has `v` stages.
pub fn lod_stage_count(sum_value: u64, e: u32) -> u64 {
    lod_extract(sum_value, e).k as u64 + e as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm4_worked_examples() {
        // v=1: k=0, f=0.
        assert_eq!(lod_extract(1, 4), LodCode { k: 0, f: 0 });
        // v=2 (10b): k=1, remainder 0, f = 0 << (4-1) = 0.
        assert_eq!(lod_extract(2, 4), LodCode { k: 1, f: 0 });
        // v=3 (11b): k=1, remainder 1, f = 1 << 3 = 8.
        assert_eq!(lod_extract(3, 4), LodCode { k: 1, f: 8 });
        // v=77 (1001101b): k=6, remainder 13, k>e: f = 13 >> 2 = 3.
        assert_eq!(lod_extract(77, 4), LodCode { k: 6, f: 3 });
        // v=0: defined as (0,0).
        assert_eq!(lod_extract(0, 4), LodCode { k: 0, f: 0 });
    }

    #[test]
    fn delay_units_monotone_nondecreasing() {
        for e in [2u32, 4, 6] {
            let mut prev = 0u64;
            for v in 0..=4096u64 {
                let d = lod_delay_units(v, e);
                assert!(
                    d >= prev,
                    "non-monotone at v={v}, e={e}: {d} < {prev}"
                );
                prev = d;
            }
        }
    }

    #[test]
    fn only_zero_one_collide_for_large_e() {
        // For e >= needed resolution, distinct octave members separate.
        let e = 6;
        let d1 = lod_delay_units(1, e);
        let d0 = lod_delay_units(0, e);
        assert_eq!(d0, d1, "v=0 and v=1 are the known collision");
        for v in 1..200u64 {
            let a = lod_delay_units(v, e);
            let b = lod_delay_units(v + 1, e);
            if a == b {
                // collisions allowed only when quantisation truncates
                // inside an octave with span > 2^e
                let k = 63 - (v + 1).leading_zeros();
                assert!(k > e, "unexpected collision at v={v} (k={k})");
            }
        }
    }

    #[test]
    fn logarithmic_compression_vs_linear() {
        // Paper's claim: exponential path space -> logarithmic.
        let e = 4;
        let lod_stages = lod_stage_count(1 << 12, e);
        let linear_stages = 1u64 << 12;
        assert!(lod_stages <= 16);
        assert!(linear_stages / lod_stages > 200);
    }

    #[test]
    fn delay_matches_units_times_fine_step() {
        let tau = Time::ps(100);
        let e = 4;
        for v in [0u64, 1, 3, 7, 42, 100] {
            let d = lod_delay(v, e, tau);
            let units = lod_delay_units(v, e);
            assert_eq!(d.as_fs(), units * tau.as_fs() / 16);
        }
    }

    #[test]
    fn fine_resolution_bounds_relative_error() {
        // Within an octave, quantised delay error < one fine step of the
        // octave's scale: |delay(v)/τ − log-ish(v)| bounded by 2^-e · 2.
        let e = 4;
        let tau = Time::ps(100);
        for v in 2..500u64 {
            let k = 63 - v.leading_zeros();
            let exact = k as f64 + (v as f64 / (1u64 << k) as f64 - 1.0);
            let got = lod_delay(v, e, tau).as_ps_f64() / 100.0;
            assert!(
                (got - exact).abs() <= 1.0 / (1 << e) as f64 + 1e-9,
                "v={v}: got {got}, exact {exact}"
            );
        }
    }
}
