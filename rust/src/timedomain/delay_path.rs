//! Differential delay path (paper Fig. 4).
//!
//! Two rails — `raceS` (sign/negative contributions) and `raceM`
//! (magnitude/positive contributions) — are launched by a common
//! `raceDR` event and arrive after LOD-compressed delays
//! `k·τ + f·τ/2ᵉ`. The arrival *interval* encodes the signed class sum.
//!
//! Each rail is a [`Dcde`] whose code (in fine units, τ/2ᵉ) is written at
//! classification time by the digital front-end; the path structure —
//! coarse segments `s^k, m^k` plus an e-bit fine vernier — is what the
//! energy model charges for.

use crate::gates::delay::{Dcde, DelayCode};
use crate::sim::{Circuit, NetId, Time};
use crate::timedomain::lod;

/// One class's differential delay path: shared launch, two coded rails.
pub struct DiffDelayPath {
    /// Launch input (raceDR).
    pub launch: NetId,
    /// Sign-rail output (raceS).
    pub race_s: NetId,
    /// Magnitude-rail output (raceM).
    pub race_m: NetId,
    code_s: DelayCode,
    code_m: DelayCode,
    fine_bits: u32,
}

impl DiffDelayPath {
    /// Instantiate the path in `c` with `c.tech`'s τ/e parameters.
    pub fn build(c: &mut Circuit, name: &str, launch: NetId) -> DiffDelayPath {
        let tech = c.tech.clone();
        Self::build_with_tech(c, name, launch, &tech)
    }

    /// Instantiate with an explicit corner (the CoTM race unit passes its
    /// short-segment `cotm_race_corner`).
    pub fn build_with_tech(
        c: &mut Circuit,
        name: &str,
        launch: NetId,
        tech: &crate::sim::TechParams,
    ) -> DiffDelayPath {
        let tech = tech.clone();
        let race_s = c.net(format!("{name}.raceS"));
        let race_m = c.net(format!("{name}.raceM"));
        let code_s: DelayCode = DelayCode::default();
        let code_m: DelayCode = DelayCode::default();
        let fine = tech.fine_step();
        // Base delay: one coarse segment so even code 0 has a defined
        // launch-to-arrival time (the s⁰/m⁰ segment in Fig. 4).
        let base = tech.tau();
        c.add(
            Box::new(Dcde::new(
                format!("{name}.dcde_s"),
                launch,
                race_s,
                code_s.clone(),
                base,
                fine,
                &tech,
            )),
            vec![launch],
        );
        c.add(
            Box::new(Dcde::new(
                format!("{name}.dcde_m"),
                launch,
                race_m,
                code_m.clone(),
                base,
                fine,
                &tech,
            )),
            vec![launch],
        );
        DiffDelayPath {
            launch,
            race_s,
            race_m,
            code_s,
            code_m,
            fine_bits: tech.fine_bits,
        }
    }

    /// Program the rails from the digitally pre-computed S (negative
    /// magnitude) and M (positive magnitude) sums, applying the LOD
    /// compression (Algorithm 4).
    pub fn program(&self, s_sum: u64, m_sum: u64) {
        self.code_s.set(lod::lod_delay_units(s_sum, self.fine_bits));
        self.code_m.set(lod::lod_delay_units(m_sum, self.fine_bits));
    }

    /// The rails' programmed delays (for assertions / analysis).
    pub fn programmed_delays(&self, tech: &crate::sim::TechParams) -> (Time, Time) {
        let fine = tech.fine_step().as_fs();
        let base = tech.tau();
        (
            base + Time::fs(self.code_s.get() * fine),
            base + Time::fs(self.code_m.get() * fine),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::Logic;

    #[test]
    fn rails_arrive_at_lod_delays() {
        let t = TechParams::tsmc65_digital();
        let mut c = Circuit::new(t.clone());
        let launch = c.net_init("raceDR", Logic::Zero);
        let p = DiffDelayPath::build(&mut c, "cls0", launch);
        p.program(3, 10); // S=3: k=1,f=8 -> 24 units; M=10: k=3,f=4 -> 52
        c.drive(launch, Logic::One, Time::ZERO);
        let mut t_s = Time::ZERO;
        let mut t_m = Time::ZERO;
        // run and capture arrival times
        loop {
            let before_s = c.value(p.race_s);
            let before_m = c.value(p.race_m);
            if !c.run_while(Time::ns(100), |cc| {
                (before_s != cc.value(p.race_s)) || (before_m != cc.value(p.race_m))
            }).unwrap() {
                break;
            }
            if c.value(p.race_s) == Logic::One && t_s == Time::ZERO {
                t_s = c.now();
            }
            if c.value(p.race_m) == Logic::One && t_m == Time::ZERO {
                t_m = c.now();
            }
            if t_s != Time::ZERO && t_m != Time::ZERO {
                break;
            }
        }
        // base 100 ps + units × 6.25 ps
        assert_eq!(t_s, Time::from_ps_f64(100.0 + 24.0 * 6.25));
        assert_eq!(t_m, Time::from_ps_f64(100.0 + 52.0 * 6.25));
        // Interval encodes the sum difference direction: M > S ⇒ the M
        // rail arrives later here (bigger delay = bigger magnitude).
        assert!(t_m > t_s);
    }

    #[test]
    fn equal_sums_arrive_together() {
        let t = TechParams::tsmc65_digital();
        let mut c = Circuit::new(t.clone());
        let launch = c.net_init("raceDR", Logic::Zero);
        let p = DiffDelayPath::build(&mut c, "cls", launch);
        p.program(5, 5);
        let (ds, dm) = p.programmed_delays(&t);
        assert_eq!(ds, dm);
    }
}
