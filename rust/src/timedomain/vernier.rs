//! Vernier time-to-digital converter ([14], §II-C.3).
//!
//! Digitises the arrival interval of the differential rails into the
//! compact delay code `dc` that programs the single-rail DCDE:
//!
//! `dc = round((t_S − t_M + offset) / resolution)`, clamped at ≥ 0.
//!
//! A larger class sum (M ≫ S) makes the M rail arrive *later* relative
//! to S, giving a smaller `dc` and therefore an earlier single-rail
//! arrival at the WTA — first arrival = argmax.
//!
//! The `offset` covers the most negative interval (all-sign, no
//! magnitude) so `dc` is always representable; it cancels across classes
//! because every class's SR path shares it.

use std::cell::Cell;
use std::rc::Rc;

use crate::gates::delay::DelayCode;
use crate::sim::energy::{EnergyKind, GateKind};
use crate::sim::{Component, Ctx, Logic, NetId, Time};

/// Vernier TDC component. Pins: `[race_s, race_m]`. Writes `dc` into the
/// shared [`DelayCode`] and raises `done` once both rails arrived;
/// returns `done` to zero when both rails return to zero (four-phase).
pub struct VernierTdc {
    name: String,
    race_s: NetId,
    race_m: NetId,
    done: NetId,
    dc_out: DelayCode,
    offset: Time,
    resolution: Time,
    /// Build-time code floor: the guaranteed minimum raw code given the
    /// offset and the maximum rail delay; subtracted from every
    /// conversion so the SR paths stay short (ordering is unaffected —
    /// it is a common constant). The race controller computes it.
    floor_code: u64,
    max_code: u64,
    t_s: Option<Time>,
    t_m: Option<Time>,
    e_sample_fj: f64,
    e_stage_fj: f64,
    decision_delay: Time,
    /// Observability: last emitted code.
    pub last_code: Rc<Cell<u64>>,
    pub conversions: u64,
}

impl VernierTdc {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: impl Into<String>,
        race_s: NetId,
        race_m: NetId,
        done: NetId,
        dc_out: DelayCode,
        offset: Time,
        tech: &crate::sim::TechParams,
    ) -> VernierTdc {
        VernierTdc {
            name: name.into(),
            race_s,
            race_m,
            done,
            dc_out,
            offset,
            resolution: Time::from_ps_f64(tech.tdc_res_ps),
            floor_code: 0,
            // Vernier chain length bound: code saturates (paper's "short
            // length" claim relies on LOD compression keeping this small).
            max_code: 4096,
            t_s: None,
            t_m: None,
            e_sample_fj: 2.0 * tech.gate_energy_fj(GateKind::Dff),
            e_stage_fj: 2.0 * tech.e_delay_stage_fj * tech.vscale(),
            decision_delay: tech.gate_delay(GateKind::CElement),
            last_code: Rc::new(Cell::new(0)),
            conversions: 0,
        }
    }

    /// Set the common floor code (see field docs).
    pub fn with_floor_code(mut self, floor: u64) -> VernierTdc {
        self.floor_code = floor;
        self
    }

    fn convert(&mut self, ctx: &mut Ctx) {
        let (ts, tm) = match (self.t_s, self.t_m) {
            (Some(a), Some(b)) => (a, b),
            _ => return,
        };
        // interval = t_S − t_M + offset (may clamp at zero).
        let shifted = (ts + self.offset).since(tm);
        let code = (shifted.as_fs() + self.resolution.as_fs() / 2)
            / self.resolution.as_fs();
        let code = code.saturating_sub(self.floor_code).min(self.max_code);
        self.dc_out.set(code);
        self.last_code.set(code);
        self.conversions += 1;
        // Energy: two sampling flops + the vernier stages consumed.
        ctx.spend(EnergyKind::Tdc, self.e_sample_fj + self.e_stage_fj * code as f64);
        ctx.schedule(self.done, Logic::One, self.decision_delay);
    }
}

impl Component for VernierTdc {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        ctx.schedule(self.done, Logic::Zero, Time::ZERO);
    }

    fn on_input(&mut self, pin: usize, ctx: &mut Ctx) {
        let (net, slot) = if pin == 0 {
            (self.race_s, 0)
        } else {
            (self.race_m, 1)
        };
        match ctx.get(net) {
            Logic::One => {
                let t = ctx.now;
                let was_complete = self.t_s.is_some() && self.t_m.is_some();
                if slot == 0 {
                    self.t_s.get_or_insert(t);
                } else {
                    self.t_m.get_or_insert(t);
                }
                if !was_complete && self.t_s.is_some() && self.t_m.is_some() {
                    self.convert(ctx);
                }
            }
            Logic::Zero => {
                // Four-phase RTZ: when both rails are back to zero the
                // converter re-arms and drops `done`.
                if slot == 0 {
                    self.t_s = None;
                } else {
                    self.t_m = None;
                }
                if self.t_s.is_none() && self.t_m.is_none() {
                    ctx.schedule_if_changed(self.done, Logic::Zero, self.decision_delay);
                }
            }
            Logic::X => {}
        }
    }

    fn gate_equivalents(&self) -> f64 {
        // Two sampling flops + arbiter + ~32 vernier stage pairs.
        30.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::Circuit;

    fn fixture(offset_ps: u64) -> (Circuit, NetId, NetId, NetId, DelayCode) {
        let t = TechParams::tsmc65_digital();
        let mut c = Circuit::new(t.clone());
        let rs = c.net_init("raceS", Logic::Zero);
        let rm = c.net_init("raceM", Logic::Zero);
        let done = c.net("done");
        let dc: DelayCode = DelayCode::default();
        let tdc = VernierTdc::new("tdc", rs, rm, done, dc.clone(), Time::ps(offset_ps), &t);
        c.add(Box::new(tdc), vec![rs, rm]);
        c.init_components();
        c.run_to_quiescence().unwrap();
        (c, rs, rm, done, dc)
    }

    #[test]
    fn digitises_positive_interval() {
        let (mut c, rs, rm, done, dc) = fixture(0);
        // S arrives 100 ps after M -> dc = 100/5 = 20.
        c.drive(rm, Logic::One, Time::ps(50));
        c.drive(rs, Logic::One, Time::ps(150));
        c.run_to_quiescence().unwrap();
        assert_eq!(dc.get(), 20);
        assert_eq!(c.value(done), Logic::One);
    }

    #[test]
    fn clamps_negative_interval_to_zero() {
        let (mut c, rs, rm, _done, dc) = fixture(0);
        // M arrives after S and no offset -> clamped to 0.
        c.drive(rs, Logic::One, Time::ps(50));
        c.drive(rm, Logic::One, Time::ps(500));
        c.run_to_quiescence().unwrap();
        assert_eq!(dc.get(), 0);
    }

    #[test]
    fn offset_shifts_code() {
        let (mut c, rs, rm, _done, dc) = fixture(200);
        // t_S − t_M = −100 ps; +200 offset = 100 ps -> 20 ticks.
        c.drive(rs, Logic::One, Time::ps(50));
        c.drive(rm, Logic::One, Time::ps(150));
        c.run_to_quiescence().unwrap();
        assert_eq!(dc.get(), 20);
    }

    #[test]
    fn rtz_rearms_for_next_conversion() {
        let (mut c, rs, rm, done, dc) = fixture(0);
        c.drive(rm, Logic::One, Time::ps(10));
        c.drive(rs, Logic::One, Time::ps(60));
        c.run_to_quiescence().unwrap();
        assert_eq!(dc.get(), 10);
        // Return to zero.
        c.drive(rs, Logic::Zero, Time::ps(10));
        c.drive(rm, Logic::Zero, Time::ps(12));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(done), Logic::Zero);
        // Second conversion with a different interval.
        c.drive(rm, Logic::One, Time::ps(10));
        c.drive(rs, Logic::One, Time::ps(35));
        c.run_to_quiescence().unwrap();
        assert_eq!(dc.get(), 5);
        assert_eq!(c.value(done), Logic::One);
    }

    #[test]
    fn quantisation_rounds_to_nearest() {
        let (mut c, rs, rm, _done, dc) = fixture(0);
        // 13 ps at 5 ps resolution -> round(2.6) = 3.
        c.drive(rm, Logic::One, Time::ps(10));
        c.drive(rs, Logic::One, Time::ps(23));
        c.run_to_quiescence().unwrap();
        assert_eq!(dc.get(), 3);
    }
}
