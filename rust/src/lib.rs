//! # tsetlin-td — Event-Driven Digital-Time-Domain Tsetlin Machine Inference
//!
//! A full software reproduction of *"Event-Driven Digital-Time-Domain
//! Inference Architectures for Tsetlin Machines"* (Lan, Shafik, Yakovlev,
//! 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: an event-driven
//!   (picosecond-resolution, discrete-event) hardware simulator with
//!   per-transition energy accounting, the asynchronous control fabric
//!   (click elements, C-elements, Mutexes), the time-domain classification
//!   machinery (LOD coarse/fine delay compression, differential delay
//!   paths, Vernier TDC, Winner-Takes-All arbiters), six complete
//!   inference architectures ({multi-class TM, CoTM} × {synchronous,
//!   asynchronous bundled-data, proposed digital-time-domain}), a TM/CoTM
//!   training substrate, and a serving coordinator that routes requests to
//!   either the functional XLA path or any hardware model.
//! * **L2/L1 (python/, build-time only)** — JAX + Pallas functional golden
//!   model, AOT-lowered to `artifacts/*.hlo.txt` and executed here through
//!   the PJRT CPU client ([`runtime`]); Python is never on the request path.
//!
//! Start with [`arch::Architecture`] for the hardware models,
//! [`tm`] for the ML substrate, and [`coordinator`] for serving.

// Crate-wide panic-safety bar (see docs/INVARIANTS.md): unsafe code is
// denied everywhere except the audited `#[target_feature]` kernels in
// `tm/simd.rs`, which opts back in at module level. The same contract
// is enforced toolchain-less by lint rule R4 and natively by the
// `[lints.rust]` table in Cargo.toml.
#![deny(unsafe_code)]

pub mod arch;
pub mod async_ctrl;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod gates;
pub mod runtime;
pub mod sim;
pub mod testutil;
pub mod timedomain;
pub mod tm;
pub mod util;
pub mod wta;

/// Evaluation metrics (Eq. 3/4 and Table IV evaluation) — alias of
/// [`arch::metrics`].
pub use arch::metrics;

pub use error::{Error, Result};
