//! Proposed CoTM architecture: hybrid digital-time-domain (paper Fig. 3).
//!
//! Digital front-end (1.0 V, click-controlled): literals, shared clause
//! pool, binary multiplication (weight-mux) matrix, and the *split*
//! accumulation — all negative clause contributions into `S`, all
//! positive into `M` — as two parallel unsigned trees (cheaper and
//! shallower than the baseline's signed tree), then the LOD priority
//! encoders.
//!
//! Time-domain back-end (event-simulated): per class a differential
//! delay path programmed with the LOD codes of (S, M), a Vernier TDC
//! digitising the rail interval to `dc`, a C-element completion
//! rendezvous, per-class DCDE single-rail replay, and WTA arbitration —
//! [`crate::timedomain::CotmRaceUnit`].
//!
//! Pipelining note: the rails/TDC phase of sample *n* overlaps the
//! digital S/M computation of sample *n+1* (paper Fig. 3's fire1/fire2
//! split), so the initiation interval is `max(digital stage, race
//! latency)` with RTZ recovery hidden behind the digital stage — unlike
//! the multi-class design where a single fully-time-domain
//! classification path exposes its recovery. This is why the paper's
//! CoTM gains throughput (+20% vs BD) while the multi-class variant
//! trades some (−21%).

use crate::arch::datapath::{toggles, Blocks};
use crate::arch::{Architecture, InferenceReport};
use crate::sim::energy::GateKind;
use crate::sim::{Circuit, TechParams, Time};
use crate::timedomain::CotmRaceUnit;
use crate::tm::infer::cotm_clause_outputs;
use crate::tm::CoTmModel;
use crate::util::stats::Welford;
use crate::wta::WtaKind;

/// The proposed hybrid DT-domain CoTM.
pub struct ProposedCotm {
    model: CoTmModel,
    blocks: Blocks,
    circuit: Circuit,
    race: CotmRaceUnit,
    digital_stage: Time,
    gate_equivalents: f64,
    weight_bits: usize,
    prev_features: Option<Vec<bool>>,
    prev_clauses: Option<Vec<bool>>,
    race_latency: Welford,
    race_cycle: Welford,
}

impl ProposedCotm {
    pub fn new(model: CoTmModel, wta_kind: WtaKind) -> crate::Result<Self> {
        Self::with_tech(model, wta_kind, TechParams::tsmc65_proposed())
    }

    pub fn with_tech(
        model: CoTmModel,
        wta_kind: WtaKind,
        tech: TechParams,
    ) -> crate::Result<Self> {
        model.validate()?;
        let p = model.params.clone();
        let blocks = Blocks::new(tech.clone());
        let mut circuit = Circuit::new(tech.clone());
        let max_sum = (p.clauses as u64) * (p.max_weight as u64);
        let race = CotmRaceUnit::build(&mut circuit, "cotm", p.classes, max_sum, wta_kind);
        circuit.init_components();
        circuit.run_to_quiescence()?;

        let weight_bits =
            (64 - (p.max_weight as u64).max(1).leading_zeros()) as usize + 1;
        let sum_bits = (64 - max_sum.max(1).leading_zeros()) as usize;
        let max_includes = model
            .clauses
            .iter()
            .map(|cl| cl.included_count())
            .max()
            .unwrap_or(1)
            .max(2);
        // Digital stage: the deeper of (S1 literals+clauses) and (S2
        // weight-mux + unsigned S/M trees + LOD) bounds the BD matched
        // delay of the front-end pipeline.
        let s1 = blocks.literal_gen(0).delay + blocks.clause_stage_delay(max_includes);
        let s2 = blocks.weight_mux(0, p.classes, weight_bits).delay
            + blocks.unsigned_adder_tree(p.clauses, weight_bits, 0).delay
            + blocks.lod_encoder(sum_bits, 0).delay;
        let click = tech.gate_delay(GateKind::Xor)
            + tech.gate_delay(GateKind::And)
            + tech.gate_delay(GateKind::Dff);
        let digital_stage = s1.max(s2).scale(1.0 + tech.bd_margin) + click;

        let ge = blocks.literal_gen_ge(p.features)
            + model
                .clauses
                .iter()
                .map(|cl| blocks.clause_plane_ge(cl.included_count().max(1)))
                .sum::<f64>()
            + (p.clauses * p.classes * weight_bits) as f64 * 1.4 // weight mux
            + 2.0 * (p.classes * p.clauses * weight_bits) as f64 * 1.75 // S/M trees
            + (p.classes * sum_bits) as f64 * 2.0 // LOD encoders
            + circuit.energy.gate_equivalents
            + 17.4 * 2.0 // clicks
            + 10.0; // 4→2 interface

        Ok(ProposedCotm {
            model,
            blocks,
            circuit,
            race,
            digital_stage,
            gate_equivalents: ge,
            weight_bits,
            prev_features: None,
            prev_clauses: None,
            race_latency: Welford::default(),
            race_cycle: Welford::default(),
        })
    }

    /// Split clause contributions into (S, M) per class (the paper's
    /// "sign contributions into S, magnitude contributions into M").
    fn split_sums(&self, clause_outs: &[bool]) -> Vec<(u64, u64)> {
        self.model
            .weights
            .iter()
            .map(|row| {
                let mut s = 0u64;
                let mut m = 0u64;
                for (&w, &fired) in row.iter().zip(clause_outs) {
                    if fired {
                        if w >= 0 {
                            m += w as u64;
                        } else {
                            s += (-w) as u64;
                        }
                    }
                }
                (s, m)
            })
            .collect()
    }
}

impl Architecture for ProposedCotm {
    fn name(&self) -> &'static str {
        "cotm-proposed"
    }

    fn infer(&mut self, features: &[bool]) -> crate::Result<InferenceReport> {
        let p = self.model.params.clone();
        if features.len() != p.features {
            return Err(crate::Error::model("feature width mismatch"));
        }
        let b = &self.blocks;
        let feat_tog = self
            .prev_features
            .as_deref()
            .map_or(features.len(), |prev| toggles(prev, features));

        // ---- digital front-end (analytic, 1.0 V) ----
        let mut energy = b.literal_gen(feat_tog).energy_fj;
        let lits_tog = 2 * feat_tog;
        for cl in &self.model.clauses {
            let inc = cl.included_count();
            let plane_tog = (lits_tog * inc) / (2 * p.features).max(1);
            energy += b.clause_plane(inc.max(1), plane_tog).energy_fj;
        }
        energy += b.memory_read(p.clauses * 2 * p.features);
        energy += b.memory_read(p.classes * p.clauses * self.weight_bits);

        let clause_outs = cotm_clause_outputs(&self.model, features);
        let clause_tog = self
            .prev_clauses
            .as_deref()
            .map_or(clause_outs.len(), |prev| toggles(prev, &clause_outs));
        energy += b.weight_mux(clause_tog, p.classes, self.weight_bits).energy_fj;
        let max_sum = (p.clauses as i64) * (p.max_weight as i64);
        let sum_bits = (64 - (max_sum as u64).max(1).leading_zeros()) as usize;
        for _ in 0..p.classes {
            // Two parallel unsigned trees (S and M): each sees ~half the
            // clause activity.
            energy += 2.0
                * b.unsigned_adder_tree(p.clauses, self.weight_bits, clause_tog.div_ceil(2))
                    .energy_fj;
            energy += b.lod_encoder(sum_bits, clause_tog.min(sum_bits)).energy_fj;
        }
        // Click controllers + 4→2 interface per token.
        energy += 2.0
            * (2.0 * b.tech.gate_energy_fj(GateKind::Xor)
                + b.tech.gate_energy_fj(GateKind::And)
                + 2.0 * b.tech.gate_energy_fj(GateKind::Dff));
        energy += b.tech.gate_energy_fj(GateKind::CElement)
            + b.tech.gate_energy_fj(GateKind::Tff);

        // ---- time-domain back-end (event simulation) ----
        let sums = self.split_sums(&clause_outs);
        let e_before = self.circuit.energy.total_dynamic_fj();
        let ev_before = self.circuit.events_processed();
        let t0 = self.circuit.now();
        let (winner, race_latency) = self.race.classify(&mut self.circuit, &sums)?;
        let race_cycle = self.circuit.now().since(t0);
        energy += self.circuit.energy.total_dynamic_fj() - e_before;
        let sim_events = self.circuit.events_processed() - ev_before;

        self.race_latency.push(race_latency.as_ps_f64());
        self.race_cycle.push(race_cycle.as_ps_f64());
        self.prev_features = Some(features.to_vec());
        self.prev_clauses = Some(clause_outs);

        let class_sums: Vec<i32> = sums.iter().map(|&(s, m)| m as i32 - s as i32).collect();
        Ok(InferenceReport {
            predicted: winner,
            class_sums,
            latency: self.digital_stage + race_latency,
            energy_fj: energy,
            sim_events,
        })
    }

    fn cycle_time(&self) -> Time {
        // fire1/fire2 overlap: rails+TDC of sample n run while the
        // digital stage computes n+1; RTZ recovery hides likewise. The
        // initiation interval is the slower of the digital stage and the
        // mean race *decision* latency.
        let race = if self.race_latency.count() > 0 {
            Time::from_ps_f64(self.race_latency.mean())
        } else {
            let t = &self.blocks.tech;
            // Estimate: rails (~kmax segments) + TDC + SR + WTA.
            t.tau().scale(8.0 * t.dscale())
        };
        self.digital_stage.max(race)
    }

    fn tech(&self) -> &TechParams {
        &self.blocks.tech
    }

    fn gate_equivalents(&self) -> f64 {
        self.gate_equivalents
    }

    fn shape(&self) -> (usize, usize, usize) {
        let p = &self.model.params;
        (p.features, p.clauses, p.classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::EnergyKind;
    use crate::tm::data;
    use crate::tm::infer::{cotm_class_sums, predict_argmax};
    use crate::tm::{cotm_train::train_cotm, TmParams};

    fn model() -> (CoTmModel, data::Dataset) {
        let d = data::iris().unwrap();
        let (tr, _) = d.split(0.8, 42);
        let m = train_cotm(TmParams::iris_paper(), &tr, 60, 3).unwrap();
        (m, d)
    }

    #[test]
    fn class_sums_match_software_reference() {
        let (m, d) = model();
        let mut arch = ProposedCotm::new(m.clone(), WtaKind::Tba).unwrap();
        for x in d.features.iter().take(20) {
            let r = arch.infer(x).unwrap();
            assert_eq!(r.class_sums, cotm_class_sums(&m, x));
        }
    }

    #[test]
    fn prediction_agreement_with_exact_argmax_on_iris() {
        // LOD compression is monotone but log-scaled; on a trained model
        // the winner margin is usually large. Require high agreement and
        // that any disagreement is a near-tie in the exact sums.
        let (m, d) = model();
        let mut arch = ProposedCotm::new(m.clone(), WtaKind::Tba).unwrap();
        let mut agree = 0usize;
        let n = 80usize;
        for x in d.features.iter().take(n) {
            let r = arch.infer(x).unwrap();
            let sums = cotm_class_sums(&m, x);
            let exact = predict_argmax(&sums);
            if r.predicted == exact {
                agree += 1;
            } else {
                let margin = sums[exact] - sums[r.predicted];
                assert!(
                    margin <= 3,
                    "large-margin disagreement: sums={sums:?} got={} exact={exact}",
                    r.predicted
                );
            }
        }
        assert!(agree * 100 >= n * 90, "agreement {agree}/{n}");
    }

    #[test]
    fn race_energy_is_time_domain() {
        let (m, d) = model();
        let mut arch = ProposedCotm::new(m, WtaKind::Tba).unwrap();
        for x in d.features.iter().take(5) {
            arch.infer(x).unwrap();
        }
        let led = &arch.circuit.energy;
        assert!(led.dynamic_fj(EnergyKind::DelayLine) > 0.0);
        assert!(led.dynamic_fj(EnergyKind::Tdc) > 0.0);
        assert!(led.dynamic_fj(EnergyKind::Arbiter) > 0.0);
        assert!(led.dynamic_fj(EnergyKind::Handshake) > 0.0); // C-element
        assert_eq!(led.dynamic_fj(EnergyKind::ClockTree), 0.0);
    }

    #[test]
    fn split_sums_reconstruct_signed_sum() {
        let (m, d) = model();
        let arch = ProposedCotm::new(m.clone(), WtaKind::Tba).unwrap();
        for x in d.features.iter().take(30) {
            let outs = cotm_clause_outputs(&m, x);
            let split = arch.split_sums(&outs);
            let exact = cotm_class_sums(&m, x);
            for (k, &(s, mm)) in split.iter().enumerate() {
                assert_eq!(mm as i32 - s as i32, exact[k]);
            }
        }
    }

    #[test]
    fn repeated_inferences_reuse_the_unit() {
        let (m, d) = model();
        let mut arch = ProposedCotm::new(m, WtaKind::Mesh).unwrap();
        let a = arch.infer(&d.features[0]).unwrap();
        let b = arch.infer(&d.features[0]).unwrap();
        // Same input -> same prediction; the second costs less digital
        // energy (no datapath toggles) though race energy recurs.
        assert_eq!(a.predicted, b.predicted);
        assert!(b.energy_fj < a.energy_fj);
    }
}
