//! Evaluation metrics (paper §III-B, Eqs. 3–4) and batch evaluation
//! producing Table IV rows.

use crate::arch::Architecture;
use crate::sim::Time;
use crate::util::stats::Summary;

/// Eq. 3: `Throughput = 2·F·C·K·f_infer`, in GOp/s.
pub fn throughput_gops(features: usize, clauses: usize, classes: usize, f_infer_hz: f64) -> f64 {
    2.0 * features as f64 * clauses as f64 * classes as f64 * f_infer_hz / 1e9
}

/// Eq. 4: `EnergyEfficiency = Throughput / (1000·P)`, in TOp/J, with
/// throughput in GOp/s and `P` in watts.
pub fn energy_efficiency_tops_per_j(throughput_gops: f64, power_w: f64) -> f64 {
    throughput_gops / (1000.0 * power_w)
}

/// A measured Table IV row.
#[derive(Debug, Clone)]
pub struct PerfRow {
    pub implementation: String,
    /// Mean per-inference cycle (initiation interval).
    pub cycle: Time,
    pub f_infer_mhz: f64,
    pub throughput_gops: f64,
    /// Mean dynamic+leakage power (µW).
    pub power_uw: f64,
    pub energy_eff_tops_per_j: f64,
    /// Mean per-inference energy (fJ).
    pub energy_per_inference_fj: f64,
    pub accuracy: f64,
    pub latency: Summary,
}

/// Run `arch` over a dataset and compute its Table IV row.
pub fn evaluate(
    arch: &mut dyn Architecture,
    xs: &[Vec<bool>],
    ys: &[usize],
) -> crate::Result<PerfRow> {
    assert_eq!(xs.len(), ys.len());
    let mut energy_fj = 0.0;
    let mut correct = 0usize;
    let mut latencies = Vec::with_capacity(xs.len());
    for (x, &y) in xs.iter().zip(ys) {
        let r = arch.infer(x)?;
        energy_fj += r.energy_fj;
        latencies.push(r.latency.as_ps_f64());
        if r.predicted == y {
            correct += 1;
        }
    }
    let n = xs.len() as f64;
    let cycle = arch.cycle_time();
    let f_infer_hz = 1.0 / cycle.as_secs_f64();
    let (f, c, k) = arch.shape();
    let tp = throughput_gops(f, c, k, f_infer_hz);

    // Power: dynamic energy per inference over the cycle, plus leakage.
    let e_dyn_j = energy_fj * 1e-15 / n;
    let p_dyn_w = e_dyn_j / cycle.as_secs_f64();
    let p_leak_w = arch.leakage_power_nw() * 1e-9;
    let p_w = p_dyn_w + p_leak_w;

    Ok(PerfRow {
        implementation: arch.name().to_string(),
        cycle,
        f_infer_mhz: f_infer_hz / 1e6,
        throughput_gops: tp,
        power_uw: p_w * 1e6,
        energy_eff_tops_per_j: energy_efficiency_tops_per_j(tp, p_w),
        energy_per_inference_fj: energy_fj / n,
        accuracy: correct as f64 / n,
        latency: Summary::of(&latencies).unwrap(),
    })
}

/// Render rows as the paper's Table IV.
pub fn render_table_iv(rows: &[PerfRow]) -> String {
    let mut t = crate::util::Table::new(vec![
        "Implementation",
        "Cycle (ps)",
        "f_infer (MHz)",
        "Throughput (GOp/s)",
        "Power (uW)",
        "Energy Eff. (TOp/J)",
        "E/inf (fJ)",
        "Accuracy",
    ]);
    for r in rows {
        t.row(vec![
            r.implementation.clone(),
            format!("{:.0}", r.cycle.as_ps_f64()),
            format!("{:.1}", r.f_infer_mhz),
            format!("{:.1}", r.throughput_gops),
            format!("{:.1}", r.power_uw),
            format!("{:.1}", r.energy_eff_tops_per_j),
            format!("{:.0}", r.energy_per_inference_fj),
            format!("{:.3}", r.accuracy),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_worked_example() {
        // F=16, C=12, K=3 at 330 MHz: 2·16·12·3 = 1152 ops/inference;
        // 1152 × 330e6 = 380 GOp/s — the paper's sync multi-class row.
        let tp = throughput_gops(16, 12, 3, 330e6);
        assert!((tp - 380.16).abs() < 0.01, "tp={tp}");
    }

    #[test]
    fn eq4_worked_example() {
        // 380 GOp/s at 400 µW -> 380/(1000·4e-4) = 950 TOp/J (the paper's
        // 948.61 with their exact power).
        let ee = energy_efficiency_tops_per_j(380.0, 400.6e-6);
        assert!((ee - 948.6).abs() < 1.0, "ee={ee}");
    }

    #[test]
    fn table_renders() {
        let rows = vec![PerfRow {
            implementation: "test".into(),
            cycle: Time::ps(500),
            f_infer_mhz: 2000.0,
            throughput_gops: 100.0,
            power_uw: 50.0,
            energy_eff_tops_per_j: 2000.0,
            energy_per_inference_fj: 25.0,
            accuracy: 0.95,
            latency: Summary::of(&[1.0, 2.0]).unwrap(),
        }];
        let s = render_table_iv(&rows);
        assert!(s.contains("test"));
        assert!(s.contains("Energy Eff."));
    }
}
