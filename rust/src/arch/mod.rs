//! The six inference architectures of the paper's evaluation:
//!
//! | | multi-class TM | CoTM |
//! |---|---|---|
//! | synchronous digital | [`digital::SyncMulticlass`] | [`digital::SyncCotm`] |
//! | asynchronous BD digital | [`digital::AsyncBdMulticlass`] | [`digital::AsyncBdCotm`] |
//! | proposed (digital-time-domain) | [`proposed_tm::ProposedMulticlass`] | [`proposed_cotm::ProposedCotm`] |
//!
//! Modelling split (DESIGN.md §3): the *datapath* blocks (literal
//! generation, clause planes, adder trees, comparators, weight muxes)
//! use analytic switching-activity timing/energy models
//! ([`datapath`]); the *control fabric and time-domain classification* —
//! clicks, C-elements, delay rails, TDC, DCDE, Mutex/WTA races — run in
//! the discrete-event simulator, because that is where the paper's
//! contribution (and all the interesting dynamics: races, metastability,
//! RTZ recovery) lives.

pub mod datapath;
pub mod digital;
pub mod metrics;
pub mod proposed_cotm;
pub mod proposed_tm;
pub mod waveforms;

use crate::sim::{TechParams, Time};

/// Outcome of one inference through a hardware model.
#[derive(Debug, Clone)]
pub struct InferenceReport {
    pub predicted: usize,
    /// Class sums as the architecture's datapath sees them (digital
    /// architectures: exact Eq. 1/2 sums; proposed: derived pre-race sums
    /// for observability).
    pub class_sums: Vec<i32>,
    /// Input-accept → decision latency.
    pub latency: Time,
    /// Dynamic energy consumed by this inference (fJ), incl. control.
    pub energy_fj: f64,
    /// Simulator events processed (0 for fully analytic paths).
    pub sim_events: u64,
}

/// A complete inference architecture with hardware cost semantics.
pub trait Architecture {
    /// Short identifier, e.g. `"multiclass-sync"`.
    fn name(&self) -> &'static str;

    /// Run one inference.
    fn infer(&mut self, features: &[bool]) -> crate::Result<InferenceReport>;

    /// Minimum initiation interval (pipeline cycle) — the steady-state
    /// per-inference period that Eq. 3's `f_infer` is the reciprocal of.
    fn cycle_time(&self) -> Time;

    /// Technology corner this architecture is implemented in.
    fn tech(&self) -> &TechParams;

    /// Total gate-equivalents (leakage accounting + area reporting).
    fn gate_equivalents(&self) -> f64;

    /// Static leakage power in nW at the operating corner.
    fn leakage_power_nw(&self) -> f64 {
        let t = self.tech();
        self.gate_equivalents() * t.leak_nw_per_ge * (t.voltage / t.vref)
    }

    /// Model shape: (features, clauses, classes) for Eq. 3.
    fn shape(&self) -> (usize, usize, usize);
}
