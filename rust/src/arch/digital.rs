//! Pure-digital baseline architectures (paper §III-A): functionally
//! identical synchronous and asynchronous bundled-data pipelines for the
//! multi-class TM and the CoTM, following Algorithms 1–3.
//!
//! Pipeline (paper Fig. 1): three stages —
//!   S1 literal generation + clause evaluation (fire0)
//!   S2 class-sum arithmetic (fire1)
//!   S3 argmax comparison (fire2)
//!
//! Synchronous: one global clock at `T = worst_stage × (1+sync_margin) +
//! skew + t_dff`; the clock tree toggles every flop every cycle whether
//! or not data moved. Asynchronous BD: per-stage click controllers with
//! matched delays `stage × (1+bd_margin)`; idle stages burn nothing.
//! These cost differences — not the datapath, which is identical — are
//! exactly the comparison Table IV draws.

use crate::arch::datapath::{toggles, Blocks};
use crate::arch::{Architecture, InferenceReport};
use crate::sim::energy::GateKind;
use crate::sim::{TechParams, Time};
use crate::tm::infer::{
    cotm_class_sums, cotm_clause_outputs, multiclass_clause_outputs, predict_argmax,
};
use crate::tm::{CoTmModel, MultiClassTmModel};

/// Control style of a digital pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlStyle {
    Synchronous,
    AsyncBundledData,
}

/// Bit width needed for a signed magnitude `max_abs`.
fn signed_bits(max_abs: i64) -> usize {
    (64 - (max_abs.unsigned_abs().max(1)).leading_zeros()) as usize + 1
}

/// Per-token click-element control energy (2×XOR + AND + 2×DFF).
fn click_energy_fj(tech: &TechParams) -> f64 {
    2.0 * tech.gate_energy_fj(GateKind::Xor)
        + tech.gate_energy_fj(GateKind::And)
        + 2.0 * tech.gate_energy_fj(GateKind::Dff)
}

/// Click control latency overhead per stage (decision + phase register).
fn click_overhead(tech: &TechParams) -> Time {
    tech.gate_delay(GateKind::Xor) + tech.gate_delay(GateKind::And) + tech.gate_delay(GateKind::Dff)
}

/// Shared scaffolding for the four digital architectures.
struct DigitalCore {
    blocks: Blocks,
    style: ControlStyle,
    /// Worst-case per-stage combinational delays [S1, S2, S3].
    stage_delays: [Time; 3],
    /// Pipeline flop count (clock-tree leaves).
    flops: usize,
    gate_equivalents: f64,
    prev_features: Option<Vec<bool>>,
    prev_clauses: Option<Vec<bool>>,
    prev_sums: Option<Vec<i32>>,
}

impl DigitalCore {
    fn clock_period(&self) -> Time {
        let tech = &self.blocks.tech;
        let worst = self.stage_delays.iter().copied().max().unwrap();
        worst.scale(1.0 + tech.sync_margin)
            + Time::from_ps_f64(tech.clock_skew_ps)
            + tech.gate_delay(GateKind::Dff)
    }

    fn bd_cycle(&self) -> Time {
        let tech = &self.blocks.tech;
        let worst = self.stage_delays.iter().copied().max().unwrap();
        worst.scale(1.0 + tech.bd_margin) + click_overhead(tech)
    }

    fn cycle_time(&self) -> Time {
        match self.style {
            ControlStyle::Synchronous => self.clock_period(),
            ControlStyle::AsyncBundledData => self.bd_cycle(),
        }
    }

    /// Latency of one token through the 3-stage pipeline.
    fn pipeline_latency(&self) -> Time {
        match self.style {
            ControlStyle::Synchronous => self.clock_period().scale(3.0),
            ControlStyle::AsyncBundledData => {
                let tech = &self.blocks.tech;
                let mut t = Time::ZERO;
                for d in self.stage_delays {
                    t += d.scale(1.0 + tech.bd_margin) + click_overhead(tech);
                }
                t
            }
        }
    }

    /// Control + register energy for moving one token through the
    /// pipeline (3 stage boundaries), given per-bank data toggles.
    fn control_energy(&self, bank_bits: &[usize], bank_toggles: &[usize]) -> f64 {
        let tech = &self.blocks.tech;
        let mut e = 0.0;
        for (bits, tog) in bank_bits.iter().zip(bank_toggles) {
            e += self.blocks.register_bank(*bits, *tog).energy_fj;
        }
        match self.style {
            ControlStyle::Synchronous => {
                // Steady state: one clock cycle charged per inference,
                // over ALL flops (activity-independent — the sync tax).
                e += self.blocks.clock_tree_cycle(self.flops);
            }
            ControlStyle::AsyncBundledData => {
                // Three click elements fire once per token.
                e += 3.0 * click_energy_fj(tech);
            }
        }
        e
    }
}

// ====================================================== multi-class TM

/// Digital multi-class TM pipeline (sync or async BD).
pub struct DigitalMulticlass {
    model: MultiClassTmModel,
    core: DigitalCore,
    name: &'static str,
}

impl DigitalMulticlass {
    pub fn new(model: MultiClassTmModel, style: ControlStyle, tech: TechParams) -> Self {
        let blocks = Blocks::new(tech);
        let p = &model.params;
        let (f, c, k) = (p.features, p.clauses, p.classes);
        let sum_bits = signed_bits((c / 2) as i64);

        let max_includes = model
            .clauses
            .iter()
            .flatten()
            .map(|cl| cl.included_count())
            .max()
            .unwrap_or(1)
            .max(2);
        // S1: literals + clause planes.
        let s1 = blocks.literal_gen(0).delay + blocks.clause_stage_delay(max_includes);
        // S2: two popcounts (parallel) + subtract, per class (parallel).
        let s2 = blocks.popcount(c / 2, 0).delay + blocks.ripple_add(sum_bits, 0).delay;
        // S3: argmax comparator tree.
        let s3 = blocks.argmax_tree(k, sum_bits, 0).delay;

        let flops = 2 * f + k * c + k * sum_bits + (k.next_power_of_two().trailing_zeros() as usize).max(1);
        let ge = blocks.literal_gen_ge(f)
            + model
                .clauses
                .iter()
                .flatten()
                .map(|cl| blocks.clause_plane_ge(cl.included_count().max(1)))
                .sum::<f64>()
            + (k * c) as f64 * 2.5          // popcount trees
            + (k * sum_bits) as f64 * 2.5   // subtractors
            + (k - 1) as f64 * sum_bits as f64 * 2.0 // comparators
            + flops as f64 * 6.0;
        DigitalMulticlass {
            name: match style {
                ControlStyle::Synchronous => "multiclass-sync",
                ControlStyle::AsyncBundledData => "multiclass-async-bd",
            },
            model,
            core: DigitalCore {
                blocks,
                style,
                stage_delays: [s1, s2, s3],
                flops,
                gate_equivalents: ge,
                prev_features: None,
                prev_clauses: None,
                prev_sums: None,
            },
        }
    }
}

impl Architecture for DigitalMulticlass {
    fn name(&self) -> &'static str {
        self.name
    }

    fn infer(&mut self, features: &[bool]) -> crate::Result<InferenceReport> {
        let p = &self.model.params;
        if features.len() != p.features {
            return Err(crate::Error::model(format!(
                "feature width {} != {}",
                features.len(),
                p.features
            )));
        }
        let b = &self.core.blocks;
        let feat_tog = self
            .core
            .prev_features
            .as_deref()
            .map_or(features.len(), |prev| toggles(prev, features));

        // S1: literals + clause planes.
        let mut energy = b.literal_gen(feat_tog).energy_fj;
        let clause_out_2d = multiclass_clause_outputs(&self.model, features);
        let clause_out: Vec<bool> = clause_out_2d.iter().flatten().copied().collect();
        // Activity: toggled included literals per plane ≈ include-masked
        // feature toggles; approximate with per-plane fraction.
        let lits_tog = 2 * feat_tog;
        for class in &self.model.clauses {
            for cl in class {
                let inc = cl.included_count();
                let plane_tog = (lits_tog * inc) / (2 * p.features).max(1);
                energy += b.clause_plane(inc.max(1), plane_tog).energy_fj;
            }
        }
        // TA-state memory read (include masks).
        energy += b.memory_read(p.classes * p.clauses * 2 * p.features);

        let clause_tog = self
            .core
            .prev_clauses
            .as_deref()
            .map_or(clause_out.len(), |prev| toggles(prev, &clause_out));

        // S2: popcount + subtract per class.
        let sums: Vec<i32> = crate::tm::infer::multiclass_class_sums(&self.model, features);
        let sum_bits = signed_bits((p.clauses / 2) as i64);
        let per_class_tog = clause_tog.div_ceil(p.classes);
        for _ in 0..p.classes {
            energy += b.popcount(p.clauses / 2, per_class_tog).energy_fj * 2.0;
            energy += b.ripple_add(sum_bits, per_class_tog.min(sum_bits)).energy_fj;
        }

        // S3: argmax.
        let sum_tog: usize = self.core.prev_sums.as_ref().map_or(p.classes * sum_bits, |prev| {
            prev.iter()
                .zip(&sums)
                .map(|(a, b)| (a ^ b).count_ones() as usize)
                .sum()
        });
        energy += b.argmax_tree(p.classes, sum_bits, sum_tog).energy_fj;

        // Control + registers.
        let bank_bits = [
            p.classes * p.clauses,
            p.classes * sum_bits,
            (p.classes.next_power_of_two().trailing_zeros() as usize).max(1),
        ];
        let bank_tog = [clause_tog, sum_tog, 1];
        energy += self.core.control_energy(&bank_bits, &bank_tog);

        let predicted = predict_argmax(&sums);
        self.core.prev_features = Some(features.to_vec());
        self.core.prev_clauses = Some(clause_out);
        self.core.prev_sums = Some(sums.clone());
        Ok(InferenceReport {
            predicted,
            class_sums: sums,
            latency: self.core.pipeline_latency(),
            energy_fj: energy,
            sim_events: 0,
        })
    }

    fn cycle_time(&self) -> Time {
        self.core.cycle_time()
    }

    fn tech(&self) -> &TechParams {
        &self.core.blocks.tech
    }

    fn gate_equivalents(&self) -> f64 {
        self.core.gate_equivalents
    }

    fn shape(&self) -> (usize, usize, usize) {
        let p = &self.model.params;
        (p.features, p.clauses, p.classes)
    }
}

// =============================================================== CoTM

/// Digital CoTM pipeline (sync or async BD).
pub struct DigitalCotm {
    model: CoTmModel,
    core: DigitalCore,
    name: &'static str,
    weight_bits: usize,
    sum_bits: usize,
}

impl DigitalCotm {
    pub fn new(model: CoTmModel, style: ControlStyle, tech: TechParams) -> Self {
        let blocks = Blocks::new(tech);
        let p = &model.params;
        let (f, c, k) = (p.features, p.clauses, p.classes);
        let weight_bits = signed_bits(p.max_weight as i64);
        let sum_bits = signed_bits((p.max_weight as i64) * c as i64);

        let max_includes = model
            .clauses
            .iter()
            .map(|cl| cl.included_count())
            .max()
            .unwrap_or(1)
            .max(2);
        let s1 = blocks.literal_gen(0).delay + blocks.clause_stage_delay(max_includes);
        // S2: weight mux + signed weighted adder tree.
        let s2 = blocks.weight_mux(0, k, weight_bits).delay
            + blocks.signed_adder_tree(c, weight_bits, 0).delay;
        let s3 = blocks.argmax_tree(k, sum_bits, 0).delay;

        let flops = 2 * f + c + k * sum_bits + (k.next_power_of_two().trailing_zeros() as usize).max(1);
        let ge = blocks.literal_gen_ge(f)
            + model
                .clauses
                .iter()
                .map(|cl| blocks.clause_plane_ge(cl.included_count().max(1)))
                .sum::<f64>()
            + (c * k * weight_bits) as f64 * 1.4      // weight mux matrix
            + (k * c * weight_bits) as f64 * 2.5      // adder trees
            + (k - 1) as f64 * sum_bits as f64 * 2.0  // comparators
            + flops as f64 * 6.0;
        DigitalCotm {
            name: match style {
                ControlStyle::Synchronous => "cotm-sync",
                ControlStyle::AsyncBundledData => "cotm-async-bd",
            },
            model,
            core: DigitalCore {
                blocks,
                style,
                stage_delays: [s1, s2, s3],
                flops,
                gate_equivalents: ge,
                prev_features: None,
                prev_clauses: None,
                prev_sums: None,
            },
            weight_bits,
            sum_bits,
        }
    }
}

impl Architecture for DigitalCotm {
    fn name(&self) -> &'static str {
        self.name
    }

    fn infer(&mut self, features: &[bool]) -> crate::Result<InferenceReport> {
        let p = &self.model.params;
        if features.len() != p.features {
            return Err(crate::Error::model(format!(
                "feature width {} != {}",
                features.len(),
                p.features
            )));
        }
        let b = &self.core.blocks;
        let feat_tog = self
            .core
            .prev_features
            .as_deref()
            .map_or(features.len(), |prev| toggles(prev, features));
        let mut energy = b.literal_gen(feat_tog).energy_fj;

        let clause_out = cotm_clause_outputs(&self.model, features);
        let lits_tog = 2 * feat_tog;
        for cl in &self.model.clauses {
            let inc = cl.included_count();
            let plane_tog = (lits_tog * inc) / (2 * p.features).max(1);
            energy += b.clause_plane(inc.max(1), plane_tog).energy_fj;
        }
        energy += b.memory_read(p.clauses * 2 * p.features); // include masks
        energy += b.memory_read(p.classes * p.clauses * self.weight_bits); // weights

        let clause_tog = self
            .core
            .prev_clauses
            .as_deref()
            .map_or(clause_out.len(), |prev| toggles(prev, &clause_out));

        // S2: weight mux + signed adder tree per class.
        energy += b.weight_mux(clause_tog, p.classes, self.weight_bits).energy_fj;
        for _ in 0..p.classes {
            energy += b
                .signed_adder_tree(p.clauses, self.weight_bits, clause_tog)
                .energy_fj;
        }

        let sums = cotm_class_sums(&self.model, features);
        let sum_tog: usize = self
            .core
            .prev_sums
            .as_ref()
            .map_or(p.classes * self.sum_bits, |prev| {
                prev.iter()
                    .zip(&sums)
                    .map(|(a, b)| (a ^ b).count_ones() as usize)
                    .sum()
            });
        energy += b.argmax_tree(p.classes, self.sum_bits, sum_tog).energy_fj;

        let bank_bits = [
            p.clauses,
            p.classes * self.sum_bits,
            (p.classes.next_power_of_two().trailing_zeros() as usize).max(1),
        ];
        let bank_tog = [clause_tog, sum_tog, 1];
        energy += self.core.control_energy(&bank_bits, &bank_tog);

        let predicted = predict_argmax(&sums);
        self.core.prev_features = Some(features.to_vec());
        self.core.prev_clauses = Some(clause_out);
        self.core.prev_sums = Some(sums.clone());
        Ok(InferenceReport {
            predicted,
            class_sums: sums,
            latency: self.core.pipeline_latency(),
            energy_fj: energy,
            sim_events: 0,
        })
    }

    fn cycle_time(&self) -> Time {
        self.core.cycle_time()
    }

    fn tech(&self) -> &TechParams {
        &self.core.blocks.tech
    }

    fn gate_equivalents(&self) -> f64 {
        self.core.gate_equivalents
    }

    fn shape(&self) -> (usize, usize, usize) {
        let p = &self.model.params;
        (p.features, p.clauses, p.classes)
    }
}

/// Convenience constructors matching the paper's four baselines.
pub fn sync_multiclass(model: MultiClassTmModel) -> DigitalMulticlass {
    DigitalMulticlass::new(model, ControlStyle::Synchronous, TechParams::tsmc65_digital())
}
pub fn async_bd_multiclass(model: MultiClassTmModel) -> DigitalMulticlass {
    DigitalMulticlass::new(model, ControlStyle::AsyncBundledData, TechParams::tsmc65_digital())
}
pub fn sync_cotm(model: CoTmModel) -> DigitalCotm {
    DigitalCotm::new(model, ControlStyle::Synchronous, TechParams::tsmc65_digital())
}
pub fn async_bd_cotm(model: CoTmModel) -> DigitalCotm {
    DigitalCotm::new(model, ControlStyle::AsyncBundledData, TechParams::tsmc65_digital())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::data;
    use crate::tm::{cotm_train::train_cotm, train::train_multiclass, TmParams};

    fn models() -> (MultiClassTmModel, CoTmModel, data::Dataset) {
        let d = data::iris().unwrap();
        let (tr, _) = d.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 30, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 30, 3).unwrap();
        (m, cm, d)
    }

    #[test]
    fn predictions_match_software_reference() {
        let (m, cm, d) = models();
        let mut s = sync_multiclass(m.clone());
        let mut a = async_bd_multiclass(m.clone());
        let mut sc = sync_cotm(cm.clone());
        let mut ac = async_bd_cotm(cm.clone());
        for x in d.features.iter().take(40) {
            let want_mc = predict_argmax(&crate::tm::infer::multiclass_class_sums(&m, x));
            let want_co = predict_argmax(&cotm_class_sums(&cm, x));
            assert_eq!(s.infer(x).unwrap().predicted, want_mc);
            assert_eq!(a.infer(x).unwrap().predicted, want_mc);
            assert_eq!(sc.infer(x).unwrap().predicted, want_co);
            assert_eq!(ac.infer(x).unwrap().predicted, want_co);
        }
    }

    #[test]
    fn async_beats_sync_cycle_time() {
        let (m, cm, _) = models();
        assert!(async_bd_multiclass(m.clone()).cycle_time() < sync_multiclass(m).cycle_time());
        assert!(async_bd_cotm(cm.clone()).cycle_time() < sync_cotm(cm).cycle_time());
    }

    #[test]
    fn cotm_has_longer_critical_path_than_multiclass() {
        // Weighted signed arithmetic is deeper than popcounts (the reason
        // the paper's CoTM baselines clock slower).
        let (m, cm, _) = models();
        assert!(sync_cotm(cm).cycle_time() > sync_multiclass(m).cycle_time());
    }

    #[test]
    fn sync_pays_clock_even_when_idle_input_repeats() {
        let (m, _, d) = models();
        let x = &d.features[0];
        let mut s = sync_multiclass(m.clone());
        let mut a = async_bd_multiclass(m);
        let _ = s.infer(x).unwrap();
        let _ = a.infer(x).unwrap();
        // Second identical sample: near-zero datapath activity.
        let es = s.infer(x).unwrap().energy_fj;
        let ea = a.infer(x).unwrap().energy_fj;
        // Sync still pays the full clock tree; async pays only clicks.
        assert!(
            es > 2.0 * ea,
            "sync idle energy {es} should far exceed async {ea}"
        );
    }

    #[test]
    fn energy_depends_on_input_activity() {
        let (m, _, d) = models();
        let mut a = async_bd_multiclass(m);
        let _ = a.infer(&d.features[0]).unwrap();
        let repeat = a.infer(&d.features[0]).unwrap().energy_fj;
        let fresh = a.infer(&d.features[97]).unwrap().energy_fj;
        assert!(fresh > repeat, "fresh {fresh} <= repeat {repeat}");
    }

    #[test]
    fn latency_spans_three_stages() {
        let (m, _, _) = models();
        let s = sync_multiclass(m);
        assert_eq!(s.pipeline_latency_for_test(), s.cycle_time().scale(3.0));
    }
}

#[cfg(test)]
impl DigitalMulticlass {
    fn pipeline_latency_for_test(&self) -> Time {
        self.core.pipeline_latency()
    }
}
