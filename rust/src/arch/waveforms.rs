//! Waveform generation for the paper's Figs. 6–8: drives small
//! event-driven control/classification circuits through the Iris target
//! sequence (2, 0, 1, 1) and dumps standard VCD files (GTKWave-viewable).
//!
//! * Fig. 6 — proposed DT-domain classification: (a) multi-class Hamming
//!   race + WTA grants; (b) CoTM differential rails, TDC done, SR race,
//!   WTA grants.
//! * Figs. 7/8 — digital pipelines: (a) synchronous clocked pipeline
//!   (clock + valid chain through DFFs); (b) asynchronous BD click
//!   pipeline (req/ack/fire per stage). The control behaviour is what
//!   the figures show; the clock period / matched delays are taken from
//!   the corresponding architecture's stage timing (multi-class vs CoTM).

use crate::async_ctrl::click::ClickElement;
use crate::gates::basic::{Gate, GateOp};
use crate::gates::clock::ClockGen;
use crate::gates::delay::{Dcde, DelayCode};
use crate::gates::dff::Dff;
use crate::sim::trace::VcdTracer;
use crate::sim::{Circuit, Logic, TechParams, Time};
use crate::timedomain::hamming::{hamming_delay_units, hamming_score};
use crate::timedomain::CotmRaceUnit;
use crate::tm::infer::{cotm_clause_outputs, multiclass_clause_outputs};
use crate::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};
use crate::wta::{self, WtaKind};
use crate::Result;

/// The four Iris samples whose predictions Fig. 6 shows as (2, 0, 1, 1):
/// one from each class plus a second from class 1.
fn fig6_samples(d: &data::Dataset) -> Vec<Vec<bool>> {
    let idx2 = d.labels.iter().position(|&l| l == 2).unwrap();
    let idx0 = d.labels.iter().position(|&l| l == 0).unwrap();
    let idx1a = d.labels.iter().position(|&l| l == 1).unwrap();
    let idx1b = d.labels.iter().rposition(|&l| l == 1).unwrap();
    vec![
        d.features[idx2].clone(),
        d.features[idx0].clone(),
        d.features[idx1a].clone(),
        d.features[idx1b].clone(),
    ]
}

/// Fig. 6(a): proposed multi-class Hamming race.
pub fn fig6a_multiclass_race(out_path: &str) -> Result<usize> {
    let d = data::iris()?;
    let (tr, _) = d.split(0.8, 42);
    let model = train_multiclass(TmParams::iris_paper(), &tr, 60, 2)?;
    let tech = TechParams::tsmc65_proposed();
    let mut c = Circuit::new(tech.clone());
    let launch = c.net_init("raceDR", Logic::Zero);
    let step = Time::from_ps_f64(tech.hamming_step_ps * tech.dscale());
    let mut codes: Vec<DelayCode> = Vec::new();
    let mut races = Vec::new();
    for i in 0..3 {
        let race = c.net(format!("race_class{i}"));
        let code = DelayCode::default();
        c.add(
            Box::new(Dcde::new(
                format!("hchain{i}"),
                launch,
                race,
                code.clone(),
                step,
                step,
                &tech,
            )),
            vec![launch],
        );
        codes.push(code);
        races.push(race);
    }
    let arb = wta::build(&mut c, WtaKind::Tba, "wta", &races);
    c.trace(launch);
    for &r in &races {
        c.trace(r);
    }
    for &g in &arb.grants {
        c.trace(g);
    }
    c.attach_tracer(VcdTracer::new());
    c.init_components();
    c.run_to_quiescence()?;

    for x in fig6_samples(&d) {
        let outs = multiclass_clause_outputs(&model, &x);
        for (code, o) in codes.iter().zip(&outs) {
            code.set(hamming_delay_units(hamming_score(o), 12) as u64);
        }
        c.drive(launch, Logic::One, Time::ps(200));
        c.run_to_quiescence()?;
        c.drive(launch, Logic::Zero, Time::ps(200));
        c.run_to_quiescence()?;
    }
    let tracer = c.take_tracer().unwrap();
    tracer.write_to(out_path)?;
    Ok(tracer.change_count())
}

/// Fig. 6(b): proposed CoTM differential/LOD/TDC/SR race.
pub fn fig6b_cotm_race(out_path: &str) -> Result<usize> {
    let d = data::iris()?;
    let (tr, _) = d.split(0.8, 42);
    let model = train_cotm(TmParams::iris_paper(), &tr, 100, 3)?;
    let tech = TechParams::tsmc65_proposed();
    let mut c = Circuit::new(tech);
    let unit = CotmRaceUnit::build(&mut c, "cotm", 3, 84, WtaKind::Tba);
    c.trace(unit.launch);
    c.trace(unit.sr_go);
    for &dn in &unit.tdc_dones {
        c.trace(dn);
    }
    for &g in &unit.grants {
        c.trace(g);
    }
    c.attach_tracer(VcdTracer::new());
    c.init_components();
    c.run_to_quiescence()?;

    for x in fig6_samples(&d) {
        let outs = cotm_clause_outputs(&model, &x);
        let sums: Vec<(u64, u64)> = model
            .weights
            .iter()
            .map(|row| {
                let (mut s, mut m) = (0u64, 0u64);
                for (&w, &f) in row.iter().zip(&outs) {
                    if f {
                        if w >= 0 {
                            m += w as u64;
                        } else {
                            s += (-w) as u64;
                        }
                    }
                }
                (s, m)
            })
            .collect();
        unit.classify(&mut c, &sums)?;
    }
    let tracer = c.take_tracer().unwrap();
    tracer.write_to(out_path)?;
    Ok(tracer.change_count())
}

/// Figs. 7(a)/8(a): synchronous pipeline — clock plus a 3-deep valid
/// chain of real DFFs; `period` comes from the architecture's measured
/// clock period (multi-class for Fig. 7, CoTM for Fig. 8).
pub fn fig_sync_pipeline(out_path: &str, period: Time) -> Result<usize> {
    let tech = TechParams::tsmc65_digital();
    let mut c = Circuit::new(tech.clone());
    let clk = c.net("clk");
    let horizon = Time::fs(period.as_fs() * 14);
    let gen = ClockGen::new("ckgen", clk, period, 100, &tech).with_stop_at(horizon);
    c.add(Box::new(gen), vec![clk]);
    let rst = c.net_init("rst", Logic::Zero);
    let din = c.net_init("token_in", Logic::Zero);
    let mut prev = din;
    let mut valids = Vec::new();
    for i in 0..3 {
        let q = c.net(format!("stage{i}_valid"));
        c.add(
            Box::new(Dff::new(format!("vff{i}"), prev, clk, rst, q, &tech)),
            vec![prev, clk, rst],
        );
        valids.push(q);
        prev = q;
    }
    c.trace(clk);
    c.trace(din);
    for &v in &valids {
        c.trace(v);
    }
    c.attach_tracer(VcdTracer::new());
    c.init_components();
    // A burst of 4 tokens, then idle — the clock keeps toggling
    // regardless (the figure's point: sync burns the tree while idle).
    for tok in 0..4u64 {
        let at = Time::fs(period.as_fs() * (2 * tok) + period.as_fs() / 4);
        c.drive_at(din, Logic::One, at)?;
        c.drive_at(din, Logic::Zero, at + period)?;
    }
    c.run_to_quiescence()?;
    let tracer = c.take_tracer().unwrap();
    tracer.write_to(out_path)?;
    Ok(tracer.change_count())
}

/// Figs. 7(b)/8(b): asynchronous BD click pipeline — three real click
/// elements with matched delays, an always-ready two-phase sink, and a
/// token burst on `req0` (elastic: nothing toggles between tokens).
pub fn fig_async_pipeline(out_path: &str, matched: Time) -> Result<usize> {
    let tech = TechParams::tsmc65_digital();
    let mut c = Circuit::new(tech.clone());
    let rst = c.net_init("rst", Logic::Zero);
    let req0 = c.net_init("req0", Logic::Zero);

    // Create stage nets first so clicks can cross-reference.
    let req_out: Vec<_> = (0..3).map(|i| c.net(format!("req{}", i + 1))).collect();
    let ack_out: Vec<_> = (0..3).map(|i| c.net(format!("ack{i}"))).collect();
    let fires: Vec<_> = (0..3).map(|i| c.net(format!("fire{i}"))).collect();
    // Always-ready sink: ack = buffered req3.
    let sink_ack = c.net("sink_ack");
    let t2 = tech.clone();
    c.add(
        Box::new(Gate::new("sink", GateOp::Buf, vec![req_out[2]], sink_ack, &t2)),
        vec![req_out[2]],
    );

    for i in 0..3 {
        let req_in = if i == 0 { req0 } else { req_out[i - 1] };
        let ack_in = if i == 2 { sink_ack } else { ack_out[i + 1] };
        let click = ClickElement::new(
            format!("click{i}"),
            req_in,
            ack_in,
            rst,
            req_out[i],
            ack_out[i],
            fires[i],
            &tech,
        )
        .with_matched_delay(matched);
        c.add(Box::new(click), vec![req_in, ack_in, rst]);
    }
    c.trace(req0);
    for i in 0..3 {
        c.trace(req_out[i]);
        c.trace(ack_out[i]);
        c.trace(fires[i]);
    }
    c.attach_tracer(VcdTracer::new());
    c.init_components();
    c.run_to_quiescence()?;
    // 4 tokens (two-phase toggles), spaced by ~2 matched delays.
    let gap = Time::fs(matched.as_fs() * 2 + Time::ps(300).as_fs());
    for tok in 0..4u64 {
        let v = if tok % 2 == 0 { Logic::One } else { Logic::Zero };
        c.drive_at(req0, v, Time::fs(gap.as_fs() * (tok + 1)))?;
    }
    c.run_to_quiescence()?;
    let tracer = c.take_tracer().unwrap();
    tracer.write_to(out_path)?;
    Ok(tracer.change_count())
}

/// Dump all paper figures into `out_dir`; returns the written paths.
pub fn dump_all(out_dir: &str) -> Result<Vec<String>> {
    let mut written = Vec::new();
    let mc_period = Time::ps(720); // measured multi-class sync period
    let co_period = Time::ps(1300); // measured CoTM sync period
    let mc_matched = Time::ps(520);
    let co_matched = Time::ps(950);
    let jobs: Vec<(String, Box<dyn FnOnce(&str) -> Result<usize>>)> = vec![
        (
            format!("{out_dir}/fig6a_multiclass_dt.vcd"),
            Box::new(fig6a_multiclass_race),
        ),
        (
            format!("{out_dir}/fig6b_cotm_dt.vcd"),
            Box::new(fig6b_cotm_race),
        ),
        (
            format!("{out_dir}/fig7a_multiclass_sync.vcd"),
            Box::new(move |p| fig_sync_pipeline(p, mc_period)),
        ),
        (
            format!("{out_dir}/fig7b_multiclass_async.vcd"),
            Box::new(move |p| fig_async_pipeline(p, mc_matched)),
        ),
        (
            format!("{out_dir}/fig8a_cotm_sync.vcd"),
            Box::new(move |p| fig_sync_pipeline(p, co_period)),
        ),
        (
            format!("{out_dir}/fig8b_cotm_async.vcd"),
            Box::new(move |p| fig_async_pipeline(p, co_matched)),
        ),
    ];
    for (path, job) in jobs {
        let changes = job(&path)?;
        written.push(format!("{path} ({changes} value changes)"));
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("tmtd-waves-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fig6a_produces_race_activity() {
        let p = tmpdir().join("f6a.vcd");
        let n = fig6a_multiclass_race(p.to_str().unwrap()).unwrap();
        // 4 classifications × (launch, 3 races, grants) — dozens of edges.
        assert!(n > 30, "changes={n}");
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("race_class0"));
        assert!(text.contains("$enddefinitions"));
    }

    #[test]
    fn fig6b_produces_cotm_activity() {
        let p = tmpdir().join("f6b.vcd");
        let n = fig6b_cotm_race(p.to_str().unwrap()).unwrap();
        assert!(n > 40, "changes={n}");
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("sr_go"));
    }

    #[test]
    fn sync_pipeline_clock_toggles_while_idle() {
        let p = tmpdir().join("f7a.vcd");
        let n = fig_sync_pipeline(p.to_str().unwrap(), Time::ps(720)).unwrap();
        assert!(n > 20, "changes={n}");
        let text = std::fs::read_to_string(&p).unwrap();
        // clock edges dominate the dump
        assert!(text.contains("clk"));
    }

    #[test]
    fn async_pipeline_tokens_propagate() {
        let p = tmpdir().join("f7b.vcd");
        let n = fig_async_pipeline(p.to_str().unwrap(), Time::ps(520)).unwrap();
        assert!(n > 10, "changes={n}");
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("fire0"));
    }
}
