//! Analytic digital-datapath block models: per-block propagation delay,
//! switching energy (activity-based), and gate-equivalent area.
//!
//! Philosophy (DESIGN.md §6): energy is `transitions × E_gate(V)`. Each
//! block tracks its previous input/output vectors and charges only for
//! bits that actually toggled — so feeding the same sample twice costs
//! (almost) nothing in the async designs, while the synchronous design
//! still pays its clock tree every cycle. Glitching inside multi-level
//! logic is approximated by the `GLITCH_FACTOR` multiplier on
//! combinational blocks (deeper logic glitches more), one of the
//! classic costs the paper's time-domain conversion eliminates.
//!
//! Delay models: ripple-style arithmetic (area-lean, typical for edge
//! accelerators): an n-bit add is `(n + depth)` full-adder stages of
//! `2·d_nand`; comparators likewise. Clause AND-planes are `log₂`-depth
//! trees of 2-input ANDs.

use crate::sim::energy::{GateKind, TechParams};
use crate::sim::Time;

/// Glitch multiplier for multi-level combinational blocks.
pub const GLITCH_FACTOR: f64 = 1.25;

/// Hamming distance between two bool slices (activity).
pub fn toggles(prev: &[bool], cur: &[bool]) -> usize {
    debug_assert_eq!(prev.len(), cur.len());
    prev.iter().zip(cur).filter(|(a, b)| a != b).count()
}

/// A block evaluation result.
#[derive(Debug, Clone, Copy, Default)]
pub struct BlockCost {
    pub delay: Time,
    pub energy_fj: f64,
}

/// Shared timing/energy formulas over a tech corner.
#[derive(Debug, Clone)]
pub struct Blocks {
    pub tech: TechParams,
}

impl Blocks {
    pub fn new(tech: TechParams) -> Blocks {
        Blocks { tech }
    }

    fn d_nand(&self) -> f64 {
        self.tech.gate_delay(GateKind::Nand).as_ps_f64()
    }
    fn e_nand(&self) -> f64 {
        self.tech.gate_energy_fj(GateKind::Nand)
    }
    fn e_inv(&self) -> f64 {
        self.tech.gate_energy_fj(GateKind::Inv)
    }

    // ---------------------------------------------------------- literal

    /// Literal generation (Algorithm 2 lines 8–11): F inverters, plus
    /// wiring fan-out to the clause planes.
    /// Energy: each toggled feature flips x and ¬x lines.
    pub fn literal_gen(&self, feature_toggles: usize) -> BlockCost {
        BlockCost {
            delay: self.tech.gate_delay(GateKind::Inv),
            energy_fj: feature_toggles as f64 * 2.0 * self.e_inv(),
        }
    }

    /// Gate-equivalents of the literal stage for F features.
    pub fn literal_gen_ge(&self, features: usize) -> f64 {
        features as f64 * 0.5
    }

    // ----------------------------------------------------------- clause

    /// One clause AND-plane over `includes` literals (tree of 2-input
    /// ANDs). `lit_toggles` = toggled *included* literals this cycle.
    pub fn clause_plane(&self, includes: usize, lit_toggles: usize) -> BlockCost {
        let depth = (includes.max(1) as f64).log2().ceil().max(1.0);
        BlockCost {
            delay: Time::from_ps_f64(depth * self.d_nand() * self.tech.dscale_rel()),
            // A toggled literal propagates ~depth/2 levels on average.
            energy_fj: lit_toggles as f64 * (depth * 0.5).max(1.0) * self.e_nand()
                * GLITCH_FACTOR,
        }
    }

    /// Gate-equivalents of one clause plane.
    pub fn clause_plane_ge(&self, includes: usize) -> f64 {
        includes.saturating_sub(1).max(1) as f64
    }

    /// Worst-case clause-stage delay over all planes (pipeline sizing).
    pub fn clause_stage_delay(&self, max_includes: usize) -> Time {
        self.clause_plane(max_includes.max(2), 0).delay
    }

    // ------------------------------------------------------- arithmetic

    /// Population-count tree of `n` one-bit inputs (multi-class class
    /// sums): depth ⌈log₂n⌉ of full-adders (2·d_nand each). One-bit
    /// operands keep the per-toggle energy low — the reason the paper's
    /// multi-class baseline is already far more efficient than CoTM.
    pub fn popcount(&self, n: usize, input_toggles: usize) -> BlockCost {
        let depth = (n.max(2) as f64).log2().ceil();
        let fa_count = n.saturating_sub(1) as f64;
        BlockCost {
            delay: Time::from_ps_f64(depth * 2.0 * self.d_nand() * self.tech.dscale_rel()),
            // A toggled one-bit input ripples through ~depth FAs.
            energy_fj: input_toggles as f64 * depth * 1.0 * self.e_nand() * GLITCH_FACTOR
                + fa_count * 0.1 * self.e_nand(), // idle glitch floor
        }
    }

    /// Ripple subtractor / adder of `bits` (full adders).
    pub fn ripple_add(&self, bits: usize, operand_toggles: usize) -> BlockCost {
        BlockCost {
            delay: Time::from_ps_f64(bits as f64 * 2.0 * self.d_nand() * self.tech.dscale_rel()),
            energy_fj: operand_toggles as f64 * 2.5 * self.e_nand() * GLITCH_FACTOR,
        }
    }

    /// Signed weighted adder tree (CoTM Eq. 2): `n` operands of `bits`
    /// width, carry-save compression inside the tree (0.5× the naive
    /// ripple sum of level widths) with a final ripple merge.
    ///
    /// Energy: a toggled multi-bit operand switches ~bits wires at every
    /// one of the ⌈log₂n⌉ levels, and signed (two's-complement) carry
    /// chains glitch hard — the `CARRY_GLITCH` multiplier. This is the
    /// dominant arithmetic cost the proposed design splits away.
    pub fn signed_adder_tree(&self, n: usize, bits: usize, operand_toggles: usize) -> BlockCost {
        const CARRY_GLITCH: f64 = 1.6;
        let depth = (n.max(2) as f64).log2().ceil();
        let total_bits: f64 = (0..depth as usize).map(|l| (bits + l) as f64).sum();
        BlockCost {
            delay: Time::from_ps_f64(
                0.5 * total_bits * 2.0 * self.d_nand() * self.tech.dscale_rel(),
            ),
            energy_fj: operand_toggles as f64 * bits as f64 * depth * 2.5 * self.e_nand()
                * GLITCH_FACTOR
                * CARRY_GLITCH,
        }
    }

    /// Unsigned magnitude accumulator (proposed CoTM's S/M split): same
    /// tree without sign-extension rows — ~70% of the signed cost, and
    /// the two trees (S and M) run in parallel so the delay is one tree.
    pub fn unsigned_adder_tree(&self, n: usize, bits: usize, operand_toggles: usize) -> BlockCost {
        let signed = self.signed_adder_tree(n, bits, operand_toggles);
        BlockCost {
            delay: signed.delay.scale(0.7),
            energy_fj: signed.energy_fj * 0.7,
        }
    }

    /// Weight-selection MUX matrix (binary multiplication matrix,
    /// §II-C.1): `clauses × classes` MUXes of `bits` width.
    pub fn weight_mux(&self, clause_toggles: usize, classes: usize, bits: usize) -> BlockCost {
        let e_mux = self.tech.gate_energy_fj(GateKind::Mux2);
        BlockCost {
            delay: self.tech.gate_delay(GateKind::Mux2),
            energy_fj: clause_toggles as f64 * classes as f64 * bits as f64 * 0.5 * e_mux,
        }
    }

    /// Magnitude-comparator argmax tree over `k` sums of `bits` width
    /// (the block the paper's WTA replaces): ⌈log₂k⌉ serial ripple
    /// comparisons.
    pub fn argmax_tree(&self, k: usize, bits: usize, sum_toggles: usize) -> BlockCost {
        let depth = (k.max(2) as f64).log2().ceil();
        BlockCost {
            delay: Time::from_ps_f64(
                depth * bits as f64 * 2.0 * self.d_nand() * self.tech.dscale_rel(),
            ),
            // A toggled sum bit re-evaluates its comparator column at
            // every tree level; borrow chains glitch like carries.
            energy_fj: sum_toggles as f64 * depth * bits as f64 * 0.6 * self.e_nand()
                * GLITCH_FACTOR
                + (k - 1) as f64 * bits as f64 * 0.3 * self.e_nand(),
        }
    }

    /// LOD priority encoder + fine normaliser (Algorithm 4 in digital
    /// logic): ~2·bits gates, log-depth.
    pub fn lod_encoder(&self, bits: usize, value_toggles: usize) -> BlockCost {
        let depth = (bits.max(2) as f64).log2().ceil() + 1.0;
        BlockCost {
            delay: Time::from_ps_f64(depth * self.d_nand() * self.tech.dscale_rel()),
            energy_fj: value_toggles as f64 * 2.0 * self.e_nand(),
        }
    }

    /// Pipeline register bank: `bits` flops clocked once.
    /// `data_toggles` of them also switch their slave latch.
    pub fn register_bank(&self, bits: usize, data_toggles: usize) -> BlockCost {
        let e_dff = self.tech.gate_energy_fj(GateKind::Dff);
        BlockCost {
            delay: self.tech.gate_delay(GateKind::Dff),
            energy_fj: bits as f64 * 0.5 * e_dff + data_toggles as f64 * 0.5 * e_dff,
        }
    }

    /// Clock-tree energy for one cycle over `flops` leaves (sync only —
    /// paid every cycle regardless of activity).
    pub fn clock_tree_cycle(&self, flops: usize) -> f64 {
        flops as f64 * self.tech.e_clktree_fj * self.tech.vscale()
    }

    /// TA-state / weight memory read: `bits` read per inference.
    pub fn memory_read(&self, bits: usize) -> f64 {
        bits as f64 * self.tech.e_mem_bit_fj * self.tech.vscale()
    }
}

impl TechParams {
    /// Relative delay scale vs the 1.2 V reference corner (used by the
    /// analytic blocks; event-sim components scale via `gate_delay`).
    pub fn dscale_rel(&self) -> f64 {
        self.dscale()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blocks() -> Blocks {
        Blocks::new(TechParams::tsmc65_digital())
    }

    #[test]
    fn no_toggles_no_combinational_energy() {
        let b = blocks();
        assert_eq!(b.literal_gen(0).energy_fj, 0.0);
        assert_eq!(b.weight_mux(0, 3, 4).energy_fj, 0.0);
        // popcount keeps a small glitch floor but far below active cost
        let idle = b.popcount(12, 0).energy_fj;
        let active = b.popcount(12, 6).energy_fj;
        assert!(idle < 0.2 * active);
    }

    #[test]
    fn energy_monotone_in_activity() {
        let b = blocks();
        assert!(b.clause_plane(8, 4).energy_fj > b.clause_plane(8, 1).energy_fj);
        assert!(b.signed_adder_tree(12, 4, 8).energy_fj > b.signed_adder_tree(12, 4, 2).energy_fj);
    }

    #[test]
    fn delay_grows_with_width_and_depth() {
        let b = blocks();
        assert!(b.signed_adder_tree(12, 4, 0).delay > b.popcount(6, 0).delay);
        assert!(b.argmax_tree(8, 8, 0).delay > b.argmax_tree(2, 8, 0).delay);
        assert!(b.ripple_add(8, 0).delay > b.ripple_add(4, 0).delay);
    }

    #[test]
    fn unsigned_tree_cheaper_than_signed() {
        let b = blocks();
        let s = b.signed_adder_tree(12, 4, 6);
        let u = b.unsigned_adder_tree(12, 4, 6);
        assert!(u.delay < s.delay);
        assert!(u.energy_fj < s.energy_fj);
    }

    #[test]
    fn proposed_corner_cheaper_energy_slower_delay() {
        let hi = Blocks::new(TechParams::tsmc65_digital());
        let lo = Blocks::new(TechParams::tsmc65_proposed());
        let e_hi = hi.popcount(12, 6).energy_fj;
        let e_lo = lo.popcount(12, 6).energy_fj;
        assert!(e_lo < e_hi);
        assert!(lo.popcount(12, 6).delay > hi.popcount(12, 6).delay);
    }

    #[test]
    fn toggles_counts_hamming() {
        assert_eq!(toggles(&[true, false, true], &[true, true, false]), 2);
    }

    #[test]
    fn clock_tree_independent_of_activity() {
        let b = blocks();
        // the sync tax: function of flop count only
        assert_eq!(b.clock_tree_cycle(100), 100.0 * 6.0);
    }
}
