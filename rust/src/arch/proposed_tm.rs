//! Proposed multi-class TM architecture: asynchronous bundled-data
//! control + fully time-domain classification (paper §II-C, [12]).
//!
//! Literal generation and clause evaluation stay digital (click-
//! controlled, as in the BD baseline, at the proposed 1.0 V corner).
//! The class sum and argmax are *entirely* replaced: each class's clause
//! outputs program a Hamming-distance delay chain (one mux-selectable
//! delay segment per clause — no adders), all classes race from a common
//! launch, and the WTA grant is the argmax. The race, WTA, and
//! four-phase recovery run in the event simulator; the paper's −21%
//! throughput vs async-BD (the RTZ recovery) and +138% energy efficiency
//! (no arithmetic, no comparators, weak-capacitance delay chains) both
//! emerge from this model rather than being asserted.

use crate::arch::datapath::{toggles, Blocks};
use crate::arch::{Architecture, InferenceReport};
use crate::gates::delay::{Dcde, DelayCode};
use crate::sim::energy::GateKind;
use crate::sim::{Circuit, Logic, NetId, TechParams, Time};
use crate::timedomain::hamming::{hamming_delay_units, hamming_score, score_to_class_sum};
use crate::tm::infer::multiclass_clause_outputs;
use crate::tm::MultiClassTmModel;
use crate::util::stats::Welford;
use crate::wta::{self, WtaKind};

/// The proposed DT-domain multi-class TM.
pub struct ProposedMulticlass {
    model: MultiClassTmModel,
    blocks: Blocks,
    circuit: Circuit,
    launch: NetId,
    codes: Vec<DelayCode>,
    grants: Vec<NetId>,
    digital_stage: Time,
    gate_equivalents: f64,
    prev_features: Option<Vec<bool>>,
    race_cycle: Welford,
    worst_race_cycle: Time,
}

impl ProposedMulticlass {
    pub fn new(model: MultiClassTmModel, wta_kind: WtaKind) -> crate::Result<Self> {
        Self::with_tech(model, wta_kind, TechParams::tsmc65_proposed())
    }

    pub fn with_tech(
        model: MultiClassTmModel,
        wta_kind: WtaKind,
        tech: TechParams,
    ) -> crate::Result<Self> {
        model.validate()?;
        let p = model.params.clone();
        let blocks = Blocks::new(tech.clone());
        let mut circuit = Circuit::new(tech.clone());

        // Hamming race: per class a DCDE whose code is the Hamming
        // distance (C − score); step = hamming_step.
        let launch = circuit.net_init("raceDR", Logic::Zero);
        let step = Time::from_ps_f64(tech.hamming_step_ps * tech.dscale());
        let mut codes = Vec::with_capacity(p.classes);
        let mut races = Vec::with_capacity(p.classes);
        for i in 0..p.classes {
            let race = circuit.net(format!("race{i}"));
            let code: DelayCode = DelayCode::default();
            circuit.add(
                Box::new(Dcde::new(
                    format!("hchain{i}"),
                    launch,
                    race,
                    code.clone(),
                    step, // base: one segment so distance 0 still races
                    step,
                    &tech,
                )),
                vec![launch],
            );
            codes.push(code);
            races.push(race);
        }
        let arb = wta::build(&mut circuit, wta_kind, "wta", &races);
        circuit.init_components();
        circuit.run_to_quiescence()?;

        let max_includes = model
            .clauses
            .iter()
            .flatten()
            .map(|cl| cl.included_count())
            .max()
            .unwrap_or(1)
            .max(2);
        let digital_stage = (blocks.literal_gen(0).delay
            + blocks.clause_stage_delay(max_includes))
        .scale(1.0 + tech.bd_margin)
            + tech.gate_delay(GateKind::Xor)
            + tech.gate_delay(GateKind::And)
            + tech.gate_delay(GateKind::Dff);

        let ge = blocks.literal_gen_ge(p.features)
            + model
                .clauses
                .iter()
                .flatten()
                .map(|cl| blocks.clause_plane_ge(cl.included_count().max(1)))
                .sum::<f64>()
            + (p.classes * p.clauses) as f64 * 1.7 // delay-chain muxes
            + circuit.energy.gate_equivalents
            + 17.4 * 2.0 // click controllers
            + 10.0; // 4→2 phase interface

        let grants = arb.grants;
        Ok(ProposedMulticlass {
            model,
            blocks,
            circuit,
            launch,
            codes,
            grants,
            digital_stage,
            gate_equivalents: ge,
            prev_features: None,
            race_cycle: Welford::default(),
            worst_race_cycle: Time::ZERO,
        })
    }

    /// Run the time-domain classification race for the given per-class
    /// Hamming distances; returns (winner, decision latency, cycle incl.
    /// four-phase recovery).
    fn race(&mut self, distances: &[u32]) -> crate::Result<(usize, Time, Time)> {
        for (code, &d) in self.codes.iter().zip(distances) {
            code.set(d as u64);
        }
        let t0 = self.circuit.now();
        self.circuit.drive(self.launch, Logic::One, Time::ZERO);
        let grants = self.grants.clone();
        let decided = self.circuit.run_while(t0 + Time::ns(10_000), |c| {
            grants.iter().any(|g| c.value(*g) == Logic::One)
        })?;
        if !decided {
            return Err(crate::Error::sim("hamming race never resolved"));
        }
        let mut winner = None;
        for (i, g) in grants.iter().enumerate() {
            if self.circuit.value(*g) == Logic::One {
                winner = Some(i);
                break;
            }
        }
        let latency = self.circuit.now().since(t0);
        // Four-phase recovery: RTZ the launch, wait for all races and the
        // arbiter to release — this is the throughput cost of the
        // time-domain scheme.
        self.circuit.drive(self.launch, Logic::Zero, Time::ZERO);
        self.circuit.run_to_quiescence()?;
        let cycle = self.circuit.now().since(t0);
        Ok((winner.unwrap(), latency, cycle))
    }
}

impl Architecture for ProposedMulticlass {
    fn name(&self) -> &'static str {
        "multiclass-proposed"
    }

    fn infer(&mut self, features: &[bool]) -> crate::Result<InferenceReport> {
        let p = self.model.params.clone();
        if features.len() != p.features {
            return Err(crate::Error::model("feature width mismatch"));
        }
        let feat_tog = self
            .prev_features
            .as_deref()
            .map_or(features.len(), |prev| toggles(prev, features));

        // Digital stage (literals + clauses) — analytic, 1.0 V corner.
        let b = &self.blocks;
        let mut energy = b.literal_gen(feat_tog).energy_fj;
        let lits_tog = 2 * feat_tog;
        for class in &self.model.clauses {
            for cl in class {
                let inc = cl.included_count();
                let plane_tog = (lits_tog * inc) / (2 * p.features).max(1);
                energy += b.clause_plane(inc.max(1), plane_tog).energy_fj;
            }
        }
        energy += b.memory_read(p.classes * p.clauses * 2 * p.features);
        // Click controllers (2 stages) + 4→2 interface, per token.
        energy += 2.0
            * (2.0 * b.tech.gate_energy_fj(GateKind::Xor)
                + b.tech.gate_energy_fj(GateKind::And)
                + 2.0 * b.tech.gate_energy_fj(GateKind::Dff));
        energy += b.tech.gate_energy_fj(GateKind::CElement)
            + b.tech.gate_energy_fj(GateKind::Tff);

        // Time-domain classification.
        let clause_outs = multiclass_clause_outputs(&self.model, features);
        let scores: Vec<u32> = clause_outs.iter().map(|o| hamming_score(o)).collect();
        let distances: Vec<u32> = scores
            .iter()
            .map(|&s| hamming_delay_units(s, p.clauses as u32))
            .collect();
        let sums: Vec<i32> = scores
            .iter()
            .map(|&s| score_to_class_sum(s, p.clauses as u32))
            .collect();

        let e_before = self.circuit.energy.total_dynamic_fj();
        let ev_before = self.circuit.events_processed();
        let (winner, race_latency, race_cycle) = self.race(&distances)?;
        energy += self.circuit.energy.total_dynamic_fj() - e_before;
        let sim_events = self.circuit.events_processed() - ev_before;

        self.race_cycle.push(race_cycle.as_ps_f64());
        self.worst_race_cycle = self.worst_race_cycle.max(race_cycle);
        self.prev_features = Some(features.to_vec());

        Ok(InferenceReport {
            predicted: winner,
            class_sums: sums,
            latency: self.digital_stage + race_latency,
            energy_fj: energy,
            sim_events,
        })
    }

    fn cycle_time(&self) -> Time {
        // Steady state: the digital stage overlaps the previous sample's
        // race only partially (single classification unit, four-phase) —
        // initiation interval = max(digital stage, mean race cycle).
        let race = if self.race_cycle.count() > 0 {
            Time::from_ps_f64(self.race_cycle.mean())
        } else {
            // Pre-measurement estimate: worst-case distance race.
            let t = &self.blocks.tech;
            Time::from_ps_f64(
                t.hamming_step_ps * t.dscale() * (self.model.params.clauses as f64 + 1.0) * 2.0,
            )
        };
        self.digital_stage.max(race)
    }

    fn tech(&self) -> &TechParams {
        &self.blocks.tech
    }

    fn gate_equivalents(&self) -> f64 {
        self.gate_equivalents
    }

    fn shape(&self) -> (usize, usize, usize) {
        let p = &self.model.params;
        (p.features, p.clauses, p.classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::EnergyKind;
    use crate::tm::data;
    use crate::tm::infer::{multiclass_class_sums, predict_argmax};
    use crate::tm::{train::train_multiclass, TmParams};

    fn model() -> (MultiClassTmModel, data::Dataset) {
        let d = data::iris().unwrap();
        let (tr, _) = d.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 30, 2).unwrap();
        (m, d)
    }

    #[test]
    fn race_argmax_matches_exact_argmax() {
        // The Hamming scheme is linear -> exact (up to race ties, which
        // mirror sum ties and resolve to a max-sum class either way).
        let (m, d) = model();
        let mut arch = ProposedMulticlass::new(m.clone(), WtaKind::Tba).unwrap();
        for x in d.features.iter().take(60) {
            let sums = multiclass_class_sums(&m, x);
            let want = predict_argmax(&sums);
            let got = arch.infer(x).unwrap();
            // Winner must be *a* maximiser (ties may pick another max).
            assert_eq!(
                sums[got.predicted], sums[want],
                "sums={sums:?} got={} want={}",
                got.predicted, want
            );
        }
    }

    #[test]
    fn reports_exact_class_sums() {
        let (m, d) = model();
        let mut arch = ProposedMulticlass::new(m.clone(), WtaKind::Tba).unwrap();
        for x in d.features.iter().take(10) {
            let r = arch.infer(x).unwrap();
            assert_eq!(r.class_sums, multiclass_class_sums(&m, x));
        }
    }

    #[test]
    fn uses_delay_line_energy_not_arithmetic() {
        let (m, d) = model();
        let mut arch = ProposedMulticlass::new(m, WtaKind::Tba).unwrap();
        for x in d.features.iter().take(5) {
            arch.infer(x).unwrap();
        }
        let led = &arch.circuit.energy;
        assert!(led.dynamic_fj(EnergyKind::DelayLine) > 0.0);
        assert!(led.dynamic_fj(EnergyKind::Arbiter) > 0.0);
        assert_eq!(led.dynamic_fj(EnergyKind::ClockTree), 0.0);
    }

    #[test]
    fn mesh_and_tba_agree_on_predictions() {
        let (m, d) = model();
        let mut a = ProposedMulticlass::new(m.clone(), WtaKind::Tba).unwrap();
        let mut b = ProposedMulticlass::new(m.clone(), WtaKind::Mesh).unwrap();
        for x in d.features.iter().take(25) {
            let ra = a.infer(x).unwrap();
            let rb = b.infer(x).unwrap();
            // Both must pick a maximiser of the same sums.
            assert_eq!(ra.class_sums[ra.predicted], rb.class_sums[rb.predicted]);
        }
    }

    #[test]
    fn cycle_time_reflects_race_recovery() {
        let (m, d) = model();
        let mut arch = ProposedMulticlass::new(m, WtaKind::Tba).unwrap();
        for x in d.features.iter().take(10) {
            arch.infer(x).unwrap();
        }
        // Four-phase RTZ makes the race cycle > the digital stage.
        assert!(arch.cycle_time() > arch.digital_stage);
    }
}
