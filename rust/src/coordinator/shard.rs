//! Sharded coordinator front door: N independent [`CoordinatorServer`]
//! shards behind a deterministic consistent-hash ring.
//!
//! Each shard owns its own worker pool, dynamic batchers and
//! bit-parallel engines, so the serving tier scales past a single
//! batcher thread: requests are routed by hashing either the feature
//! vector (default) or an explicit `u64` shard key, and the same key
//! always lands on the same shard — per-shard model/cache affinity is
//! preserved across the stream.
//!
//! * **Ring** ([`HashRing`]): each shard contributes
//!   [`DEFAULT_VNODES`] virtual points at `hash(shard, replica)` on a
//!   `u64` ring; a key routes to the shard owning the first point at or
//!   after the key's hash (wrapping). The hash is FNV-1a/64 finished
//!   with the splitmix64 mixer — deterministic and cross-language: the
//!   exact algorithm is mirrored in `python/hashring.py` and pinned by
//!   golden vectors in both test suites, so the routing can be
//!   validated even on toolchain-less CI images.
//! * **Backpressure** is accounted *per shard*: each
//!   [`CoordinatorServer`] keeps its own bounded in-flight budget, so a
//!   hot shard rejects without starving the others (total budget =
//!   `shards x queue_depth`).
//! * **Stats**: [`ShardedCoordinator::stats`] merges counters and
//!   rebuilds one exact latency/batch-size summary from the shards' raw
//!   sample rings; [`ShardedCoordinator::shard_stats`] exposes the
//!   per-shard view.
//! * **Shutdown** drains every shard (worker pools and batchers flush
//!   their queues before joining).

use std::sync::atomic::Ordering;
use std::sync::mpsc;

use crate::config::ServeConfig;
use crate::coordinator::router::{InferRequest, InferResponse};
use crate::coordinator::server::CoordinatorServer;
use crate::coordinator::stats::StatsSnapshot;
use crate::error::{Error, Result};
use crate::tm::{CoTmModel, MultiClassTmModel};
use crate::util::stats::Summary;

/// Virtual nodes per shard on the ring. 128 keeps the observed load of
/// a uniform key stream within roughly +/-25% of fair share for 2..=8
/// shards (see the distribution property tests) at negligible build and
/// lookup cost.
pub const DEFAULT_VNODES: usize = 128;

/// FNV-1a 64-bit over a byte stream.
pub fn fnv1a64(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// splitmix64 finalizer. Raw FNV-1a has poor avalanche on short,
/// mostly-zero inputs like little-endian small integers — vnode points
/// cluster and the ring arcs go lopsided without this.
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// The ring hash: FNV-1a/64 finished with the splitmix64 mixer.
pub fn hash_bytes(bytes: impl IntoIterator<Item = u8>) -> u64 {
    mix64(fnv1a64(bytes))
}

/// Hash an explicit shard key (its little-endian bytes).
pub fn hash_key(key: u64) -> u64 {
    hash_bytes(key.to_le_bytes())
}

/// Hash a boolean feature vector (one byte per feature, 0/1).
pub fn hash_features(features: &[bool]) -> u64 {
    hash_bytes(features.iter().map(|&b| b as u8))
}

/// Ring position of one virtual node.
fn vnode_point(shard: u64, replica: u64) -> u64 {
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&shard.to_le_bytes());
    bytes[8..].copy_from_slice(&replica.to_le_bytes());
    hash_bytes(bytes)
}

/// A deterministic consistent-hash ring over `shards` shards.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// `(position, shard)`, sorted by position (ties — astronomically
    /// unlikely 64-bit collisions — break on shard id, keeping the
    /// order deterministic).
    points: Vec<(u64, u32)>,
}

impl HashRing {
    pub fn new(shards: usize, vnodes: usize) -> Result<HashRing> {
        if shards == 0 {
            return Err(Error::coordinator("hash ring needs >= 1 shard"));
        }
        if vnodes == 0 {
            return Err(Error::coordinator("hash ring needs >= 1 vnode per shard"));
        }
        if shards > u32::MAX as usize {
            return Err(Error::coordinator("too many shards"));
        }
        let mut points = Vec::with_capacity(shards * vnodes);
        for s in 0..shards {
            for r in 0..vnodes {
                points.push((vnode_point(s as u64, r as u64), s as u32));
            }
        }
        points.sort_unstable();
        Ok(HashRing { points })
    }

    /// Number of distinct shards on the ring.
    pub fn shards(&self) -> usize {
        self.points.iter().map(|&(_, s)| s).max().map_or(0, |m| m as usize + 1)
    }

    /// The shard owning hash `h`: first vnode at or after `h`, wrapping
    /// to the ring's first point past the top.
    pub fn shard_for_hash(&self, h: u64) -> usize {
        let i = self.points.partition_point(|&(p, _)| p < h);
        self.points[i % self.points.len()].1 as usize
    }

    /// Every distinct shard in ring order starting at `h`'s owner — the
    /// deterministic failover sequence for networked routing: the
    /// remote router tries the owner first, then each next distinct
    /// shard clockwise while earlier ones are marked unhealthy. First
    /// element always equals [`HashRing::shard_for_hash`]. Mirrored by
    /// `walk_from_hash` in `python/hashring.py`.
    pub fn walk_from_hash(&self, h: u64) -> Vec<usize> {
        let n = self.shards();
        let mut out = Vec::with_capacity(n);
        let start = self.points.partition_point(|&(p, _)| p < h);
        for k in 0..self.points.len() {
            let s = self.points[(start + k) % self.points.len()].1 as usize;
            if !out.contains(&s) {
                out.push(s);
                if out.len() == n {
                    break;
                }
            }
        }
        out
    }
}

/// N coordinator shards behind a consistent-hash front door.
pub struct ShardedCoordinator {
    shards: Vec<CoordinatorServer>,
    ring: HashRing,
}

impl ShardedCoordinator {
    /// Build `cfg.shards` independent [`CoordinatorServer`]s (each with
    /// its own worker pool, batchers and engines compiled from clones
    /// of the trained models) plus the routing ring.
    pub fn new(
        cfg: &ServeConfig,
        mc_model: MultiClassTmModel,
        cotm_model: CoTmModel,
        with_golden: bool,
    ) -> Result<ShardedCoordinator> {
        cfg.validate()?;
        let n = cfg.shards;
        let ring = HashRing::new(n, DEFAULT_VNODES)?;
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            shards.push(CoordinatorServer::new(
                cfg,
                mc_model.clone(),
                cotm_model.clone(),
                with_golden,
            )?);
        }
        Ok(ShardedCoordinator { shards, ring })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The SIMD lane width the shards' packed engines evaluate through
    /// (identical on every shard: all were built from the same config).
    pub fn simd_lanes(&self) -> crate::tm::simd::WordLanes {
        self.shards[0].simd_lanes()
    }

    /// Shard a feature vector routes to (the default routing key).
    pub fn shard_for_features(&self, features: &[bool]) -> usize {
        self.ring.shard_for_hash(hash_features(features))
    }

    /// Shard an explicit key routes to.
    pub fn shard_for_key(&self, key: u64) -> usize {
        self.ring.shard_for_hash(hash_key(key))
    }

    /// Submit a request, routed by its feature vector. Backpressure is
    /// per shard: the owning shard may reject while others have slack.
    pub fn submit(&self, req: InferRequest) -> Result<mpsc::Receiver<Result<InferResponse>>> {
        let s = self.shard_for_features(&req.features);
        self.shards[s].submit(req)
    }

    /// Submit a request pinned by an explicit shard key (e.g. a user or
    /// session id), independent of the feature bits.
    pub fn submit_keyed(
        &self,
        key: u64,
        req: InferRequest,
    ) -> Result<mpsc::Receiver<Result<InferResponse>>> {
        let s = self.shard_for_key(key);
        self.shards[s].submit(req)
    }

    /// Submit and block for the response (feature-routed).
    pub fn infer(&self, req: InferRequest) -> Result<InferResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| Error::coordinator("response channel closed"))?
    }

    /// Per-shard snapshots, indexed by shard id.
    pub fn shard_stats(&self) -> Vec<StatsSnapshot> {
        self.shards.iter().map(|s| s.stats()).collect()
    }

    /// Aggregate snapshot across all shards: counters are summed and
    /// the latency / batch-size summaries are rebuilt from the shards'
    /// raw sample rings (exact percentiles, not merged approximations).
    /// Reads the atomics directly rather than taking per-shard
    /// snapshots, which would sort every shard's sample ring once for
    /// the snapshot and again for the aggregate.
    pub fn stats(&self) -> StatsSnapshot {
        let mut snap = StatsSnapshot {
            submitted: 0,
            completed: 0,
            rejected: 0,
            failed: 0,
            batches_flushed: 0,
            batched_requests: 0,
            mean_batch_size: 0.0,
            latency_us: None,
        };
        let mut latencies = Vec::new();
        let mut batch_sizes = Vec::new();
        for s in &self.shards {
            let h = s.stats_handle();
            snap.submitted += h.submitted.load(Ordering::Relaxed);
            snap.completed += h.completed.load(Ordering::Relaxed);
            snap.rejected += h.rejected.load(Ordering::Relaxed);
            snap.failed += h.failed.load(Ordering::Relaxed);
            snap.batches_flushed += h.batches_flushed.load(Ordering::Relaxed);
            snap.batched_requests += h.batched_requests.load(Ordering::Relaxed);
            latencies.extend(h.latency_samples());
            batch_sizes.extend(h.batch_size_samples());
        }
        snap.mean_batch_size = Summary::of(&batch_sizes).map(|s| s.mean).unwrap_or(0.0);
        snap.latency_us = Summary::of(&latencies);
        snap
    }

    /// Graceful shutdown: drain every shard (pools and batchers flush
    /// pending work before their threads join).
    pub fn shutdown(self) {
        for s in self.shards {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Golden vectors pinned against python/hashring.py (same constants
    // asserted there) — cross-language determinism of the routing.

    #[test]
    fn fnv1a64_golden_vectors() {
        assert_eq!(fnv1a64([0u8; 0]), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64([0u8]), 0xaf63_bd4c_8601_b7df);
        assert_eq!(fnv1a64([1u8, 0, 1, 1]), 0xad2e_2f77_479b_38da);
    }

    #[test]
    fn ring_hash_golden_vectors() {
        assert_eq!(mix64(0), 0);
        assert_eq!(mix64(1), 0x5692_161d_100b_05e5);
        // splitmix64's first output from the golden-ratio seed.
        assert_eq!(mix64(0x9e37_79b9_7f4a_7c15), 0xe220_a839_7b1d_cdaf);
        assert_eq!(hash_bytes([0u8; 0]), 0xf52a_15e9_a9b5_e89b);
        assert_eq!(hash_bytes([1u8, 0, 1, 1]), 0x99d3_1e75_c555_af01);
        assert_eq!(hash_key(0), 0x813f_0174_a236_7c13);
        assert_eq!(hash_key(12345), 0xaa08_da79_26f8_f279);
        assert_eq!(vnode_point(0, 0), 0x6875_2350_ae1d_483f);
        assert_eq!(vnode_point(3, 17), 0x83c6_0dba_0f78_c403);
        assert_eq!(
            hash_features(&[true, false, true, true, false, false, true, false]),
            0xe6b1_ff75_897b_44fc
        );
    }

    #[test]
    fn ring_routing_golden_vectors() {
        let ring4 = HashRing::new(4, DEFAULT_VNODES).unwrap();
        for (key, want) in [
            (0u64, 0usize),
            (1, 1),
            (2, 0),
            (42, 0),
            (12345, 3),
            (999_999_999, 0),
        ] {
            assert_eq!(ring4.shard_for_hash(hash_key(key)), want, "key {key}");
        }
        assert_eq!(
            ring4.shard_for_hash(hash_features(&[
                true, false, true, true, false, false, true, false
            ])),
            3
        );
        let ring3 = HashRing::new(3, DEFAULT_VNODES).unwrap();
        for (key, want) in [(0u64, 0usize), (7, 1), (100, 2)] {
            assert_eq!(ring3.shard_for_hash(hash_key(key)), want, "key {key}");
        }
    }

    #[test]
    fn ring_walk_golden_vectors() {
        let ring4 = HashRing::new(4, DEFAULT_VNODES).unwrap();
        for (key, want) in [
            (0u64, vec![0usize, 2, 1, 3]),
            (1, vec![1, 0, 2, 3]),
            (12345, vec![3, 0, 2, 1]),
        ] {
            assert_eq!(ring4.walk_from_hash(hash_key(key)), want, "key {key}");
        }
        assert_eq!(
            ring4.walk_from_hash(hash_features(&[
                true, false, true, true, false, false, true, false
            ])),
            vec![3, 1, 2, 0]
        );
        let ring3 = HashRing::new(3, DEFAULT_VNODES).unwrap();
        for (key, want) in [(0u64, vec![0usize, 2, 1]), (7, vec![1, 0, 2]), (100, vec![2, 0, 1])] {
            assert_eq!(ring3.walk_from_hash(hash_key(key)), want, "key {key}");
        }
        assert_eq!(HashRing::new(1, DEFAULT_VNODES).unwrap().walk_from_hash(hash_key(0)), vec![0]);
    }

    #[test]
    fn walk_starts_at_owner_and_is_a_permutation() {
        for shards in [1usize, 2, 3, 5, 8] {
            let ring = HashRing::new(shards, 32).unwrap();
            for k in 0..500u64 {
                let h = hash_key(k);
                let walk = ring.walk_from_hash(h);
                assert_eq!(walk.first().copied(), Some(ring.shard_for_hash(h)));
                let mut sorted = walk.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..shards).collect::<Vec<_>>(), "key {k}");
            }
        }
    }

    #[test]
    fn ring_wraps_past_top() {
        // All vnode points are < u64::MAX for these parameters, so the
        // top of the keyspace wraps to the ring's first point — the
        // same shard that owns hash 0.
        for shards in [1usize, 2, 3, 4, 8] {
            let ring = HashRing::new(shards, DEFAULT_VNODES).unwrap();
            assert_eq!(
                ring.shard_for_hash(u64::MAX),
                ring.shard_for_hash(0),
                "{shards} shards"
            );
        }
    }

    #[test]
    fn ring_is_deterministic_and_complete() {
        let a = HashRing::new(5, 32).unwrap();
        let b = HashRing::new(5, 32).unwrap();
        assert_eq!(a.shards(), 5);
        let mut seen = [false; 5];
        for k in 0..2000u64 {
            let s = a.shard_for_hash(hash_key(k));
            assert_eq!(s, b.shard_for_hash(hash_key(k)));
            assert!(s < 5);
            seen[s] = true;
        }
        assert!(seen.iter().all(|&x| x), "every shard owns some keys: {seen:?}");
    }

    #[test]
    fn ring_rejects_degenerate_parameters() {
        assert!(HashRing::new(0, DEFAULT_VNODES).is_err());
        assert!(HashRing::new(4, 0).is_err());
    }

    #[test]
    fn single_shard_ring_routes_everything_to_shard_zero() {
        let ring = HashRing::new(1, DEFAULT_VNODES).unwrap();
        for k in [0u64, 1, 99, u64::MAX] {
            assert_eq!(ring.shard_for_hash(mix64(k)), 0);
        }
    }
}
