//! Dynamic batcher: coalesces single-sample requests into batches for
//! the fixed-batch AOT artifacts and the bit-parallel engines — flush
//! on size or age, whichever comes first (the standard serving
//! trade-off between throughput and tail latency).
//!
//! Replies are **relay-free**: the flush closure sees the whole
//! [`Pending`] entries (item, enqueue time, reply sender) and returns
//! the *final* per-item results, which the batcher thread sends
//! directly on each caller's channel — no short-lived forwarder
//! thread per request between the batcher and the caller. The
//! accounting split that replaces the relay:
//!
//! * the **flush closure** records per-item success/latency (and
//!   backend-reported failures) while building the final responses;
//! * the **batcher** releases the shared in-flight budget exactly once
//!   per item and counts batcher-originated failures (a panicking
//!   flush or an arity mismatch), so a misbehaving backend can neither
//!   leak queue-depth slots nor produce caller-visible errors that
//!   appear in no counter. A panic in the flush fails its batch but
//!   leaves the batcher thread serving.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::stats::ServerStats;
use crate::error::{Error, Result};

/// An item waiting in a batch.
pub struct Pending<T, R> {
    pub item: T,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Result<R>>,
}

impl<T, R> Pending<T, R> {
    /// Microseconds since the item entered the batcher queue — the
    /// service latency the caller observes (submit → reply), available
    /// to the flush closure for per-item latency accounting.
    pub fn elapsed_us(&self) -> f64 {
        self.enqueued.elapsed().as_secs_f64() * 1e6
    }
}

/// Dynamic batcher thread over items `T` with per-item replies `R`.
pub struct DynamicBatcher<T: Send + 'static, R: Send + 'static> {
    tx: Option<mpsc::Sender<Pending<T, R>>>,
    handle: Option<JoinHandle<()>>,
}

impl<T: Send + 'static, R: Send + 'static> DynamicBatcher<T, R> {
    /// `flush(batch) -> per-item results` runs on the batcher thread —
    /// non-`Send` state (e.g. the PJRT service handle) may live in the
    /// closure's captured environment as it is moved in once. The
    /// returned results are sent verbatim on each caller's reply
    /// channel, in order; `R` is the *final* response type the caller
    /// receives (no downstream relay rewrites it).
    ///
    /// `in_flight` is the submitter-side budget: the caller acquires a
    /// slot before `submit()`, the batcher releases it exactly once per
    /// item when the batch leaves the flush — including when the flush
    /// panics or returns the wrong arity (those also increment
    /// `stats.failed`, since no downstream layer exists to count them).
    pub fn new<F>(
        max_batch: usize,
        timeout: Duration,
        stats: Arc<ServerStats>,
        in_flight: Arc<AtomicU64>,
        mut flush: F,
    ) -> Result<DynamicBatcher<T, R>>
    where
        F: FnMut(&[Pending<T, R>]) -> Vec<Result<R>> + Send + 'static,
    {
        if max_batch == 0 {
            return Err(Error::coordinator("max_batch must be >= 1"));
        }
        let (tx, rx) = mpsc::channel::<Pending<T, R>>();
        let handle = std::thread::Builder::new()
            .name("tmtd-batcher".into())
            .spawn(move || {
                let mut queue: Vec<Pending<T, R>> = Vec::new();
                loop {
                    // Wait bounded by the oldest item's remaining age.
                    let wait = if let Some(oldest) = queue.first() {
                        timeout.saturating_sub(oldest.enqueued.elapsed())
                    } else {
                        // Idle: block until something arrives.
                        match rx.recv() {
                            Ok(p) => {
                                queue.push(p);
                                continue;
                            }
                            Err(_) => break, // shut down: drain below
                        }
                    };
                    match rx.recv_timeout(wait) {
                        Ok(p) => queue.push(p),
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            // Final drain after senders vanish.
                            while !queue.is_empty() {
                                let take = queue.len().min(max_batch);
                                let mut batch: Vec<Pending<T, R>> =
                                    queue.drain(..take).collect();
                                Self::run_flush(&mut batch, &mut flush, &stats, &in_flight);
                            }
                            break;
                        }
                    }
                    let oldest_expired = queue
                        .first()
                        .is_some_and(|p| p.enqueued.elapsed() >= timeout);
                    if queue.len() >= max_batch || oldest_expired {
                        let take = queue.len().min(max_batch);
                        let mut batch: Vec<Pending<T, R>> = queue.drain(..take).collect();
                        Self::run_flush(&mut batch, &mut flush, &stats, &in_flight);
                    }
                }
            })
            .map_err(|e| Error::coordinator(format!("spawn batcher: {e}")))?;
        Ok(DynamicBatcher { tx: Some(tx), handle: Some(handle) })
    }

    fn run_flush<F>(
        batch: &mut Vec<Pending<T, R>>,
        flush: &mut F,
        stats: &ServerStats,
        in_flight: &AtomicU64,
    ) where
        F: FnMut(&[Pending<T, R>]) -> Vec<Result<R>>,
    {
        if batch.is_empty() {
            return;
        }
        stats.record_batch(batch.len());
        let outcome = catch_unwind(AssertUnwindSafe(|| flush(&batch[..])));
        // The batch left the queue whatever the flush did: release the
        // in-flight slots exactly once, after the work (so backpressure
        // still covers in-progress batches) but before the replies.
        in_flight.fetch_sub(batch.len() as u64, Ordering::SeqCst);
        // A panicking flush or an arity mismatch = internal error for
        // everyone in the batch, counted here (there is no downstream
        // relay left to count caller-visible failures).
        let mut results = match outcome {
            Ok(r) if r.len() == batch.len() => r,
            outcome => {
                let msg = if outcome.is_err() {
                    "batch flush panicked"
                } else {
                    "batch flush arity mismatch"
                };
                stats.failed.fetch_add(batch.len() as u64, Ordering::Relaxed);
                for p in batch.drain(..) {
                    let _ = p.reply.send(Err(Error::coordinator(msg)));
                }
                return;
            }
        };
        for (p, r) in batch.drain(..).zip(results.drain(..)) {
            let _ = p.reply.send(r);
        }
    }

    /// Enqueue one item; the reply arrives on the returned channel —
    /// this is the *caller's* channel, fed directly from the batcher
    /// thread's flush.
    pub fn submit(&self, item: T) -> Result<mpsc::Receiver<Result<R>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| Error::coordinator("batcher shut down"))?
            .send(Pending { item, enqueued: Instant::now(), reply: reply_tx })
            .map_err(|_| Error::coordinator("batcher thread exited"))?;
        Ok(reply_rx)
    }

    pub fn shutdown(mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<T: Send + 'static, R: Send + 'static> Drop for DynamicBatcher<T, R> {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_batcher(
        max_batch: usize,
        timeout_ms: u64,
    ) -> (DynamicBatcher<u32, (u32, usize)>, Arc<ServerStats>) {
        let stats = Arc::new(ServerStats::new());
        let b = DynamicBatcher::new(
            max_batch,
            Duration::from_millis(timeout_ms),
            Arc::clone(&stats),
            Arc::new(AtomicU64::new(u64::MAX / 2)),
            |batch: &[Pending<u32, (u32, usize)>]| {
                let n = batch.len();
                batch.iter().map(|p| Ok((p.item, n))).collect()
            },
        )
        .unwrap();
        (b, stats)
    }

    #[test]
    fn flushes_on_size() {
        let (b, stats) = echo_batcher(4, 10_000);
        let rxs: Vec<_> = (0..4u32).map(|i| b.submit(i).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let (x, n) = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(x, i as u32);
            assert_eq!(n, 4, "flushed as one full batch");
        }
        assert_eq!(stats.snapshot().batches_flushed, 1);
    }

    #[test]
    fn flushes_on_timeout() {
        let (b, stats) = echo_batcher(64, 30);
        let rx = b.submit(7).unwrap();
        let (x, n) = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!((x, n), (7, 1));
        assert_eq!(stats.snapshot().batches_flushed, 1);
    }

    #[test]
    fn drains_on_shutdown() {
        let (b, _stats) = echo_batcher(64, 60_000);
        let rx = b.submit(3).unwrap();
        b.shutdown(); // must flush the pending item rather than drop it
        let (x, _) = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(x, 3);
    }

    #[test]
    fn shutdown_drain_respects_max_batch() {
        let (b, stats) = echo_batcher(4, 60_000);
        let rxs: Vec<_> = (0..10u32).map(|i| b.submit(i).unwrap()).collect();
        b.shutdown();
        for (i, rx) in rxs.into_iter().enumerate() {
            let (x, n) = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(x, i as u32);
            assert!(n <= 4, "drain batch {n} exceeds max_batch");
        }
        assert_eq!(stats.snapshot().batched_requests, 10);
    }

    #[test]
    fn flush_sees_enqueue_age() {
        let stats = Arc::new(ServerStats::new());
        let b: DynamicBatcher<u32, f64> = DynamicBatcher::new(
            8,
            Duration::from_millis(10),
            Arc::clone(&stats),
            Arc::new(AtomicU64::new(100)),
            |batch| batch.iter().map(|p| Ok(p.elapsed_us())).collect(),
        )
        .unwrap();
        let rx = b.submit(1).unwrap();
        let age_us = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert!(age_us >= 0.0, "age must be non-negative, got {age_us}");
        b.shutdown();
    }

    #[test]
    fn panicking_flush_fails_its_batch_and_keeps_serving() {
        // Panic on a poison *item* (not a call count) so the outcome is
        // independent of how the stream happens to split into batches.
        const POISON: u32 = 666;
        let stats = Arc::new(ServerStats::new());
        let in_flight = Arc::new(AtomicU64::new(100));
        let b: DynamicBatcher<u32, u32> = DynamicBatcher::new(
            4,
            Duration::from_millis(10),
            Arc::clone(&stats),
            Arc::clone(&in_flight),
            |batch: &[Pending<u32, u32>]| {
                if batch.iter().any(|p| p.item == POISON) {
                    panic!("injected flush failure");
                }
                batch.iter().map(|p| Ok(p.item)).collect()
            },
        )
        .unwrap();
        // Every poisoned batch panics: all four callers get an error,
        // the failures are counted, and the slots are released.
        let rxs: Vec<_> = (0..4).map(|_| b.submit(POISON).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(r.is_err(), "panicked batch must fail its callers");
        }
        assert_eq!(stats.failed.load(Ordering::Relaxed), 4);
        // The batcher thread survived the panic: the next batch serves.
        let rx = b.submit(9).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap(), 9);
        assert_eq!(in_flight.load(Ordering::SeqCst), 100 - 5, "slots released exactly once");
        b.shutdown();
    }

    #[test]
    fn oversize_stream_splits_into_batches() {
        let (b, stats) = echo_batcher(8, 20);
        let rxs: Vec<_> = (0..20u32).map(|i| b.submit(i).unwrap()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        }
        let snap = stats.snapshot();
        assert!(snap.batches_flushed >= 3, "batches={}", snap.batches_flushed);
        // Every submitted request must be accounted — the old
        // `batched_requests.max(20) == 20` form was vacuous for any
        // value <= 20.
        assert_eq!(snap.batched_requests, 20);
    }
}
