//! Dynamic batcher: coalesces single-sample requests into batches for
//! the fixed-batch AOT artifacts — flush on size or age, whichever
//! comes first (the standard serving trade-off between throughput and
//! tail latency).

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::stats::ServerStats;
use crate::error::{Error, Result};

/// An item waiting in a batch.
pub struct Pending<T, R> {
    pub item: T,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Result<R>>,
}

/// Dynamic batcher thread over items `T` with per-item replies `R`.
pub struct DynamicBatcher<T: Send + 'static, R: Send + 'static> {
    tx: Option<mpsc::Sender<Pending<T, R>>>,
    handle: Option<JoinHandle<()>>,
}

impl<T: Send + 'static, R: Send + 'static> DynamicBatcher<T, R> {
    /// `flush(batch) -> per-item results` runs on the batcher thread —
    /// non-`Send` state (e.g. the PJRT service handle) may live in the
    /// closure's captured environment as it is moved in once.
    pub fn new<F>(
        max_batch: usize,
        timeout: Duration,
        stats: Arc<ServerStats>,
        mut flush: F,
    ) -> Result<DynamicBatcher<T, R>>
    where
        F: FnMut(Vec<&T>) -> Vec<Result<R>> + Send + 'static,
    {
        if max_batch == 0 {
            return Err(Error::coordinator("max_batch must be >= 1"));
        }
        let (tx, rx) = mpsc::channel::<Pending<T, R>>();
        let handle = std::thread::Builder::new()
            .name("tmtd-batcher".into())
            .spawn(move || {
                let mut queue: Vec<Pending<T, R>> = Vec::new();
                loop {
                    // Wait bounded by the oldest item's remaining age.
                    let wait = if let Some(oldest) = queue.first() {
                        timeout.saturating_sub(oldest.enqueued.elapsed())
                    } else {
                        // Idle: block until something arrives.
                        match rx.recv() {
                            Ok(p) => {
                                queue.push(p);
                                continue;
                            }
                            Err(_) => break, // shut down: drain below
                        }
                    };
                    match rx.recv_timeout(wait) {
                        Ok(p) => queue.push(p),
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            // Final drain after senders vanish.
                            Self::run_flush(&mut queue, &mut flush, &stats);
                            break;
                        }
                    }
                    let oldest_expired = queue
                        .first()
                        .is_some_and(|p| p.enqueued.elapsed() >= timeout);
                    if queue.len() >= max_batch || oldest_expired {
                        let take = queue.len().min(max_batch);
                        let mut batch: Vec<Pending<T, R>> = queue.drain(..take).collect();
                        Self::run_flush(&mut batch, &mut flush, &stats);
                    }
                }
            })
            .map_err(|e| Error::coordinator(format!("spawn batcher: {e}")))?;
        Ok(DynamicBatcher { tx: Some(tx), handle: Some(handle) })
    }

    fn run_flush<F>(batch: &mut Vec<Pending<T, R>>, flush: &mut F, stats: &ServerStats)
    where
        F: FnMut(Vec<&T>) -> Vec<Result<R>>,
    {
        if batch.is_empty() {
            return;
        }
        stats.record_batch(batch.len());
        let items: Vec<&T> = batch.iter().map(|p| &p.item).collect();
        let mut results = flush(items);
        // Arity mismatch from the flush fn = internal error for everyone.
        if results.len() != batch.len() {
            for p in batch.drain(..) {
                let _ = p
                    .reply
                    .send(Err(Error::coordinator("batch flush arity mismatch")));
            }
            return;
        }
        for p in batch.drain(..) {
            let _ = p.reply.send(results.remove(0));
        }
    }

    /// Enqueue one item; the reply arrives on the returned channel.
    pub fn submit(&self, item: T) -> Result<mpsc::Receiver<Result<R>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| Error::coordinator("batcher shut down"))?
            .send(Pending { item, enqueued: Instant::now(), reply: reply_tx })
            .map_err(|_| Error::coordinator("batcher thread exited"))?;
        Ok(reply_rx)
    }

    pub fn shutdown(mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<T: Send + 'static, R: Send + 'static> Drop for DynamicBatcher<T, R> {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_batcher(
        max_batch: usize,
        timeout_ms: u64,
    ) -> (DynamicBatcher<u32, (u32, usize)>, Arc<ServerStats>) {
        let stats = Arc::new(ServerStats::new());
        let b = DynamicBatcher::new(
            max_batch,
            Duration::from_millis(timeout_ms),
            Arc::clone(&stats),
            |items: Vec<&u32>| {
                let n = items.len();
                items.into_iter().map(|&x| Ok((x, n))).collect()
            },
        )
        .unwrap();
        (b, stats)
    }

    #[test]
    fn flushes_on_size() {
        let (b, stats) = echo_batcher(4, 10_000);
        let rxs: Vec<_> = (0..4u32).map(|i| b.submit(i).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let (x, n) = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(x, i as u32);
            assert_eq!(n, 4, "flushed as one full batch");
        }
        assert_eq!(stats.snapshot().batches_flushed, 1);
    }

    #[test]
    fn flushes_on_timeout() {
        let (b, stats) = echo_batcher(64, 30);
        let rx = b.submit(7).unwrap();
        let (x, n) = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!((x, n), (7, 1));
        assert_eq!(stats.snapshot().batches_flushed, 1);
    }

    #[test]
    fn drains_on_shutdown() {
        let (b, _stats) = echo_batcher(64, 60_000);
        let rx = b.submit(3).unwrap();
        b.shutdown(); // must flush the pending item rather than drop it
        let (x, _) = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(x, 3);
    }

    #[test]
    fn oversize_stream_splits_into_batches() {
        let (b, stats) = echo_batcher(8, 20);
        let rxs: Vec<_> = (0..20u32).map(|i| b.submit(i).unwrap()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        }
        let snap = stats.snapshot();
        assert!(snap.batches_flushed >= 3, "batches={}", snap.batches_flushed);
        assert_eq!(snap.batched_requests.max(20), 20);
    }
}
