//! Serving statistics: lock-light counters + latency accumulators.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Summary;
use crate::util::sync::lock_unpoisoned;

/// Capacity of the bounded sample rings.
pub const RING: usize = 100_000;

/// Bounded ring of `f64` samples with a wrapping write cursor: once the
/// ring is full, each new sample overwrites the *oldest* slot, so the
/// summary always reflects the most recent `RING` observations.
///
/// (The previous implementation computed the overwrite index as
/// `len % RING`, which is always 0 once `len == RING` — every new
/// latency landed in slot 0 and the summary froze on the stale first
/// window. `batch_sizes` simply stopped recording at capacity.)
#[derive(Debug, Default)]
struct SampleRing {
    buf: Vec<f64>,
    /// Next slot to overwrite once `buf.len() == RING` (the oldest
    /// sample — slots fill in arrival order, so after the first
    /// wrap-around the cursor always points at the oldest entry).
    cursor: usize,
}

impl SampleRing {
    fn push(&mut self, x: f64) {
        if self.buf.len() < RING {
            self.buf.push(x);
        } else {
            self.buf[self.cursor] = x;
            self.cursor = (self.cursor + 1) % RING;
        }
    }
}

/// Shared server counters (cheap to clone via `Arc`).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub failed: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub batched_requests: AtomicU64,
    /// End-to-end latencies in microseconds (bounded ring).
    latencies_us: Mutex<SampleRing>,
    /// Flushed batch sizes (bounded ring).
    batch_sizes: Mutex<SampleRing>,
}

impl ServerStats {
    pub fn new() -> ServerStats {
        ServerStats::default()
    }

    pub fn record_latency_us(&self, us: f64) {
        lock_unpoisoned(&self.latencies_us).push(us);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches_flushed.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        lock_unpoisoned(&self.batch_sizes).push(size as f64);
    }

    /// Clone of the retained latency samples — used by the sharded
    /// front door to build an *exact* cross-shard summary instead of
    /// approximating merged percentiles.
    pub fn latency_samples(&self) -> Vec<f64> {
        lock_unpoisoned(&self.latencies_us).buf.clone()
    }

    /// Clone of the retained batch-size samples (see
    /// [`ServerStats::latency_samples`]).
    pub fn batch_size_samples(&self) -> Vec<f64> {
        lock_unpoisoned(&self.batch_sizes).buf.clone()
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches_flushed: self.batches_flushed.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            mean_batch_size: {
                let b = lock_unpoisoned(&self.batch_sizes);
                Summary::of(&b.buf).map(|s| s.mean).unwrap_or(0.0)
            },
            latency_us: Summary::of(&lock_unpoisoned(&self.latencies_us).buf),
        }
    }
}

/// A point-in-time view of the counters.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub batches_flushed: u64,
    pub batched_requests: u64,
    pub mean_batch_size: f64,
    pub latency_us: Option<Summary>,
}

impl StatsSnapshot {
    pub fn render(&self) -> String {
        let lat = self
            .latency_us
            .as_ref()
            .map(|l| {
                format!(
                    "latency_us p50={:.1} p95={:.1} p99={:.1} max={:.1}",
                    l.p50, l.p95, l.p99, l.max
                )
            })
            .unwrap_or_else(|| "latency: n/a".into());
        format!(
            "submitted={} completed={} rejected={} failed={} batches={} mean_batch={:.2} {}",
            self.submitted,
            self.completed,
            self.rejected,
            self.failed,
            self.batches_flushed,
            self.mean_batch_size,
            lat
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = ServerStats::new();
        s.submitted.fetch_add(3, Ordering::Relaxed);
        s.completed.fetch_add(2, Ordering::Relaxed);
        s.record_batch(16);
        s.record_batch(8);
        s.record_latency_us(100.0);
        s.record_latency_us(200.0);
        let snap = s.snapshot();
        assert_eq!(snap.submitted, 3);
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.batches_flushed, 2);
        assert_eq!(snap.mean_batch_size, 12.0);
        assert_eq!(snap.latency_us.as_ref().unwrap().count, 2);
        assert!(snap.render().contains("batches=2"));
    }

    #[test]
    fn latency_ring_tracks_recent_samples_past_capacity() {
        // Regression: once full, every new sample used to land in slot 0
        // (`len % RING == 0`), freezing the summary on the first window.
        let s = ServerStats::new();
        for _ in 0..RING {
            s.record_latency_us(10.0);
        }
        assert_eq!(s.snapshot().latency_us.unwrap().mean, 10.0);
        // A full second window must completely replace the first.
        for _ in 0..RING {
            s.record_latency_us(20.0);
        }
        let l = s.snapshot().latency_us.unwrap();
        assert_eq!(l.count, RING, "ring stays bounded");
        assert_eq!(l.min, 20.0, "no stale samples from the first window");
        assert_eq!(l.mean, 20.0);
    }

    #[test]
    fn latency_ring_partial_wrap_overwrites_oldest_not_slot_zero() {
        let s = ServerStats::new();
        for _ in 0..RING {
            s.record_latency_us(10.0);
        }
        // 100 fresh samples: mean must move by exactly 100 replaced
        // slots' worth, not by a single slot-0 churn.
        for _ in 0..100 {
            s.record_latency_us(1010.0);
        }
        let l = s.snapshot().latency_us.unwrap();
        assert_eq!(l.count, RING);
        assert_eq!(l.max, 1010.0);
        // (99_900 * 10 + 100 * 1010) / 100_000 = 11.0
        assert!((l.mean - 11.0).abs() < 1e-9, "mean={}", l.mean);
    }

    #[test]
    fn poisoned_rings_keep_recording() {
        // Regression: every ring access was `.lock().unwrap()`, so one
        // panic while holding a ring lock turned every later
        // record/snapshot call into a panic — cascading the exact
        // failure the batcher's catch_unwind flush guard contains.
        use std::sync::Arc;
        let s = Arc::new(ServerStats::new());
        s.record_latency_us(10.0);
        s.record_batch(4);
        // Deliberately poison both ring mutexes: panic while holding
        // each lock on another thread.
        let s2 = Arc::clone(&s);
        // lint:allow(r2) the panic IS the test: this thread exists to poison the ring mutex
        let _ = std::thread::spawn(move || {
            // lint:allow(r1) bare lock held across a deliberate panic is how the ring gets poisoned
            let _guard = s2.latencies_us.lock().unwrap();
            panic!("poison latencies ring");
        })
        .join();
        let s2 = Arc::clone(&s);
        // lint:allow(r2) the panic IS the test: this thread exists to poison the ring mutex
        let _ = std::thread::spawn(move || {
            // lint:allow(r1) bare lock held across a deliberate panic is how the ring gets poisoned
            let _guard = s2.batch_sizes.lock().unwrap();
            panic!("poison batch ring");
        })
        .join();
        assert!(s.latencies_us.is_poisoned());
        assert!(s.batch_sizes.is_poisoned());
        // Recording, sampling and snapshotting all still work.
        s.record_latency_us(20.0);
        s.record_batch(8);
        assert_eq!(s.latency_samples(), vec![10.0, 20.0]);
        assert_eq!(s.batch_size_samples(), vec![4.0, 8.0]);
        let snap = s.snapshot();
        assert_eq!(snap.latency_us.unwrap().count, 2);
        assert_eq!(snap.mean_batch_size, 6.0);
    }

    #[test]
    fn batch_ring_keeps_recording_past_capacity() {
        // Regression: `batch_sizes` only pushed while len < RING, so
        // `mean_batch_size` went permanently stale on long-running
        // servers.
        let s = ServerStats::new();
        for _ in 0..RING {
            s.record_batch(4);
        }
        assert_eq!(s.snapshot().mean_batch_size, 4.0);
        for _ in 0..1000 {
            s.record_batch(104);
        }
        let snap = s.snapshot();
        assert_eq!(snap.batches_flushed as usize, RING + 1000);
        // (99_000 * 4 + 1000 * 104) / 100_000 = 5.0
        assert!((snap.mean_batch_size - 5.0).abs() < 1e-9, "mean={}", snap.mean_batch_size);
    }
}
