//! Serving statistics: lock-light counters + latency accumulators.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Summary;

/// Shared server counters (cheap to clone via `Arc`).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub failed: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub batched_requests: AtomicU64,
    /// End-to-end latencies in microseconds (bounded ring).
    latencies_us: Mutex<Vec<f64>>,
    /// Flushed batch sizes (bounded ring).
    batch_sizes: Mutex<Vec<f64>>,
}

const RING: usize = 100_000;

impl ServerStats {
    pub fn new() -> ServerStats {
        ServerStats::default()
    }

    pub fn record_latency_us(&self, us: f64) {
        let mut v = self.latencies_us.lock().unwrap();
        if v.len() >= RING {
            let idx = v.len() % RING;
            v[idx % RING] = us;
        } else {
            v.push(us);
        }
    }

    pub fn record_batch(&self, size: usize) {
        self.batches_flushed.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        let mut v = self.batch_sizes.lock().unwrap();
        if v.len() < RING {
            v.push(size as f64);
        }
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches_flushed: self.batches_flushed.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            mean_batch_size: {
                let b = self.batch_sizes.lock().unwrap();
                Summary::of(&b).map(|s| s.mean).unwrap_or(0.0)
            },
            latency_us: Summary::of(&self.latencies_us.lock().unwrap()),
        }
    }
}

/// A point-in-time view of the counters.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub batches_flushed: u64,
    pub batched_requests: u64,
    pub mean_batch_size: f64,
    pub latency_us: Option<Summary>,
}

impl StatsSnapshot {
    pub fn render(&self) -> String {
        let lat = self
            .latency_us
            .as_ref()
            .map(|l| {
                format!(
                    "latency_us p50={:.1} p95={:.1} p99={:.1} max={:.1}",
                    l.p50, l.p95, l.p99, l.max
                )
            })
            .unwrap_or_else(|| "latency: n/a".into());
        format!(
            "submitted={} completed={} rejected={} failed={} batches={} mean_batch={:.2} {}",
            self.submitted,
            self.completed,
            self.rejected,
            self.failed,
            self.batches_flushed,
            self.mean_batch_size,
            lat
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = ServerStats::new();
        s.submitted.fetch_add(3, Ordering::Relaxed);
        s.completed.fetch_add(2, Ordering::Relaxed);
        s.record_batch(16);
        s.record_batch(8);
        s.record_latency_us(100.0);
        s.record_latency_us(200.0);
        let snap = s.snapshot();
        assert_eq!(snap.submitted, 3);
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.batches_flushed, 2);
        assert_eq!(snap.mean_batch_size, 12.0);
        assert_eq!(snap.latency_us.as_ref().unwrap().count, 2);
        assert!(snap.render().contains("batches=2"));
    }
}
