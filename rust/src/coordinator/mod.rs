//! Serving coordinator — the event-driven L3 shell around the inference
//! backends.
//!
//! Routing ([`router`]): every request names a [`router::Backend`] from
//! one of **three backend tiers**, all served through the same front
//! door so the equivalence checks and benchmarks exercise identical
//! plumbing:
//!
//! 1. **Golden / functional** (`golden-*`): the AOT-compiled XLA
//!    artifacts via PJRT — the cross-layer reference. Requires
//!    artifacts on disk and the `xla` feature.
//! 2. **Native batched** (`bitpar-*`, `indexed-*`, `auto-*`): the
//!    production serving tier — no artifact or FFI dependency,
//!    bit-exact with the software reference, and `Send + Sync`, so
//!    *one* engine instance compiled from the trained model is shared
//!    by every serving thread. Two engine families: the packed
//!    bit-parallel engines ([`crate::tm::fast_infer`], 64 samples per
//!    word through the bit-sliced layout — dense models) and the
//!    event-driven inverted-index engines ([`crate::tm::index`],
//!    literal→clause postings + unsatisfied-literal counters — sparse
//!    models). `auto-*` resolves to one of the two per compiled model
//!    by included-literal density
//!    (`ServeConfig.indexed_density_threshold`); large flushes shard
//!    across scoped threads either way.
//! 3. **Hardware models** (`*-sync`, `*-async-bd`, `*-proposed`): the
//!    paper's six event-simulated architectures — the evaluation
//!    targets, carrying latency/energy annotations.
//!
//! Batching ([`batcher`]): golden and bit-parallel requests are
//! coalesced by a dynamic batcher (flush on size or timeout); the
//! golden path pads onto fixed-batch AOT artifacts, the bit-parallel
//! path takes arbitrary batch shapes natively. Replies are
//! **relay-free**: the flush closure builds the final
//! [`router::InferResponse`] per item with the latency / completed
//! accounting inline, the batcher releases the in-flight budget
//! (panic-safely) and counts batcher-originated failures, and the
//! batcher thread replies directly on each caller's channel — no
//! short-lived forwarder thread per request, which is what lets the
//! `bitpar-*` tier run at engine speed instead of thread-spawn speed.
//! (The same event-driven principle as the paper's hardware: remove
//! the per-inference overhead, keep only the computation.)
//!
//! Scale-out ([`shard`]): [`shard::ShardedCoordinator`] fronts N
//! independent [`CoordinatorServer`] shards with a deterministic
//! consistent-hash ring ([`shard::HashRing`], FNV-1a/64 + splitmix64
//! finish, 128 vnodes/shard; mirrored bit-for-bit by
//! `python/hashring.py`). Requests route by feature-vector hash or an
//! explicit shard key; backpressure stays per shard; stats aggregate
//! across shards from the raw sample rings; shutdown drains every
//! shard.
//!
//! Networked scale-out ([`net`]): the same sharded topology across
//! *processes* — `tmtd shard` serves one [`CoordinatorServer`] (with a
//! pinned `.tmc` model pair) over a hand-rolled length-prefixed TCP
//! protocol (`std::net` only), and [`net::RemoteCoordinator`] routes
//! with the identical [`shard::HashRing`], fails over along the
//! deterministic ring walk on transport errors only, propagates
//! per-shard backpressure over the wire, and aggregates exact stats
//! from shipped raw sample rings. Wire format mirrored bit-for-bit by
//! `python/netproto.py`.
//!
//! Concurrency ([`pool`]): hardware models are not `Send` (they embed
//! `Rc`-coded delay elements), so each worker thread *builds its own*
//! architecture set from the (Send) trained models and pulls jobs from
//! a shared queue. The PJRT runtime is likewise thread-pinned
//! ([`crate::runtime::GoldenService`]). Only the bit-parallel engines
//! are shared state — which is why they are the tier that scales.
//!
//! Backpressure: a bounded in-flight budget per shard; submissions
//! beyond it are rejected immediately ([`ServerStats::rejected`]
//! counts them).

pub mod batcher;
pub mod net;
pub mod pool;
pub mod router;
pub mod server;
pub mod shard;
pub mod stats;

pub use net::{RemoteCoordinator, ShardServer};
pub use router::{Backend, InferRequest, InferResponse};
pub use server::CoordinatorServer;
pub use shard::{HashRing, ShardedCoordinator};
pub use stats::{ServerStats, StatsSnapshot};
