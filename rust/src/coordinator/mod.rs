//! Serving coordinator — the event-driven L3 shell around the inference
//! backends.
//!
//! Routing ([`router`]): every request names a [`router::Backend`] —
//! either the XLA *golden/functional path* (AOT artifacts via PJRT,
//! dynamically batched) or one of the six *hardware-model paths*
//! (event-simulated architectures). The golden path is what a
//! production deployment would serve from; the hardware paths are the
//! paper's evaluation targets, served through the same front door so
//! the equivalence checks and benchmarks exercise identical plumbing.
//!
//! Batching ([`batcher`]): golden requests are coalesced by a dynamic
//! batcher (flush on size or timeout) onto the fixed-batch AOT
//! artifacts, padding the tail — the standard serving pattern.
//!
//! Concurrency ([`pool`]): hardware models are not `Send` (they embed
//! `Rc`-coded delay elements), so each worker thread *builds its own*
//! architecture set from the (Send) trained models and pulls jobs from
//! a shared queue. The PJRT runtime is likewise thread-pinned
//! ([`crate::runtime::GoldenService`]).
//!
//! Backpressure: a bounded in-flight budget; submissions beyond it are
//! rejected immediately ([`ServerStats::rejected`] counts them).

pub mod batcher;
pub mod pool;
pub mod router;
pub mod server;
pub mod stats;

pub use router::{Backend, InferRequest, InferResponse};
pub use server::CoordinatorServer;
pub use stats::{ServerStats, StatsSnapshot};
