//! Request/response types and backend routing targets.

use crate::sim::Time;

/// Where a request should execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Functional path: AOT multi-class TM artifact via PJRT (batched).
    GoldenMulticlass,
    /// Functional path: AOT CoTM artifact via PJRT (batched).
    GoldenCotm,
    /// Bit-parallel native CPU path: packed-word clause evaluation,
    /// dynamically batched (see [`crate::tm::fast_infer`]).
    BitParallelMulticlass,
    BitParallelCotm,
    /// Event-driven inverted-index native CPU path: literal→clause
    /// postings + unsatisfied-literal counters, dynamically batched
    /// (see [`crate::tm::index`]). Wins on sparse (low included-literal
    /// density) models.
    IndexedMulticlass,
    IndexedCotm,
    /// Compressed-clause native CPU path (ETHEREAL tier): per-clause
    /// sorted include-literal lists walked with first-miss early exit,
    /// dynamically batched (see [`crate::tm::compressed`]). Wins in the
    /// moderately sparse regime between the indexed and packed tiers.
    CompressedMulticlass,
    CompressedCotm,
    /// Three-way density-based auto-selection between the packed,
    /// indexed and compressed native engines, resolved per compiled
    /// model at server build time. Responses report the *concrete*
    /// backend that served them.
    AutoMulticlass,
    AutoCotm,
    /// Event-simulated hardware models.
    SyncMulticlass,
    AsyncBdMulticlass,
    ProposedMulticlass,
    SyncCotm,
    AsyncBdCotm,
    ProposedCotm,
}

impl Backend {
    pub const ALL: [Backend; 16] = [
        Backend::GoldenMulticlass,
        Backend::GoldenCotm,
        Backend::BitParallelMulticlass,
        Backend::BitParallelCotm,
        Backend::IndexedMulticlass,
        Backend::IndexedCotm,
        Backend::CompressedMulticlass,
        Backend::CompressedCotm,
        Backend::AutoMulticlass,
        Backend::AutoCotm,
        Backend::SyncMulticlass,
        Backend::AsyncBdMulticlass,
        Backend::ProposedMulticlass,
        Backend::SyncCotm,
        Backend::AsyncBdCotm,
        Backend::ProposedCotm,
    ];

    pub fn is_golden(self) -> bool {
        matches!(self, Backend::GoldenMulticlass | Backend::GoldenCotm)
    }

    /// Bit-parallel backends: batched like the golden path but executed
    /// natively, with no artifact dependency.
    pub fn is_bit_parallel(self) -> bool {
        matches!(
            self,
            Backend::BitParallelMulticlass | Backend::BitParallelCotm
        )
    }

    /// Inverted-index backends: the event-driven native tier for sparse
    /// models.
    pub fn is_indexed(self) -> bool {
        matches!(self, Backend::IndexedMulticlass | Backend::IndexedCotm)
    }

    /// Compressed-clause backends: the ETHEREAL include-list tier for
    /// moderately sparse models.
    pub fn is_compressed(self) -> bool {
        matches!(
            self,
            Backend::CompressedMulticlass | Backend::CompressedCotm
        )
    }

    /// Auto-select backends: resolved to a concrete native engine
    /// (packed, indexed or compressed) per compiled model at server
    /// build time.
    pub fn is_auto(self) -> bool {
        matches!(self, Backend::AutoMulticlass | Backend::AutoCotm)
    }

    /// Native batched backends (bit-parallel, indexed or compressed):
    /// always available, served through the shared `Send + Sync`
    /// engines.
    pub fn is_native_batched(self) -> bool {
        self.is_bit_parallel() || self.is_indexed() || self.is_compressed()
    }

    /// AOT artifact family for golden backends.
    pub fn family(self) -> Option<&'static str> {
        match self {
            Backend::GoldenMulticlass => Some("multiclass_tm"),
            Backend::GoldenCotm => Some("cotm"),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::GoldenMulticlass => "golden-multiclass",
            Backend::GoldenCotm => "golden-cotm",
            Backend::BitParallelMulticlass => "bitpar-multiclass",
            Backend::BitParallelCotm => "bitpar-cotm",
            Backend::IndexedMulticlass => "indexed-multiclass",
            Backend::IndexedCotm => "indexed-cotm",
            Backend::CompressedMulticlass => "compressed-multiclass",
            Backend::CompressedCotm => "compressed-cotm",
            Backend::AutoMulticlass => "auto-multiclass",
            Backend::AutoCotm => "auto-cotm",
            Backend::SyncMulticlass => "multiclass-sync",
            Backend::AsyncBdMulticlass => "multiclass-async-bd",
            Backend::ProposedMulticlass => "multiclass-proposed",
            Backend::SyncCotm => "cotm-sync",
            Backend::AsyncBdCotm => "cotm-async-bd",
            Backend::ProposedCotm => "cotm-proposed",
        }
    }

    pub fn parse(s: &str) -> Option<Backend> {
        Backend::ALL.iter().copied().find(|b| b.name() == s)
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub features: Vec<bool>,
    pub backend: Backend,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub backend: Backend,
    pub predicted: usize,
    pub class_sums: Vec<i32>,
    /// Modelled hardware latency (simulated backends only).
    pub hw_latency: Option<Time>,
    /// Modelled per-inference energy in fJ (simulated backends only).
    pub hw_energy_fj: Option<f64>,
    /// Wall-clock service time (host), microseconds.
    pub service_us: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for b in Backend::ALL {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse("bogus"), None);
    }

    #[test]
    fn golden_families() {
        assert_eq!(Backend::GoldenCotm.family(), Some("cotm"));
        assert_eq!(Backend::SyncCotm.family(), None);
        assert!(Backend::GoldenMulticlass.is_golden());
        assert!(!Backend::ProposedCotm.is_golden());
    }

    #[test]
    fn bit_parallel_classification() {
        assert!(Backend::BitParallelMulticlass.is_bit_parallel());
        assert!(Backend::BitParallelCotm.is_bit_parallel());
        assert!(!Backend::BitParallelMulticlass.is_golden());
        assert_eq!(Backend::BitParallelCotm.family(), None);
        assert_eq!(
            Backend::parse("bitpar-multiclass"),
            Some(Backend::BitParallelMulticlass)
        );
        assert!(!Backend::GoldenCotm.is_bit_parallel());
        assert!(!Backend::SyncMulticlass.is_bit_parallel());
    }

    #[test]
    fn indexed_and_auto_classification() {
        assert!(Backend::IndexedMulticlass.is_indexed());
        assert!(Backend::IndexedCotm.is_indexed());
        assert!(!Backend::IndexedMulticlass.is_bit_parallel());
        assert!(!Backend::IndexedMulticlass.is_auto());
        assert!(Backend::AutoMulticlass.is_auto());
        assert!(Backend::AutoCotm.is_auto());
        assert!(!Backend::AutoMulticlass.is_indexed());
        // Auto is a routing alias, not itself a native batched target:
        // it must be resolved before hitting a batcher.
        assert!(!Backend::AutoMulticlass.is_native_batched());
        assert!(Backend::IndexedCotm.is_native_batched());
        assert!(Backend::BitParallelMulticlass.is_native_batched());
        assert!(!Backend::GoldenMulticlass.is_native_batched());
        assert!(!Backend::SyncCotm.is_native_batched());
        assert_eq!(
            Backend::parse("indexed-multiclass"),
            Some(Backend::IndexedMulticlass)
        );
        assert_eq!(Backend::parse("auto-cotm"), Some(Backend::AutoCotm));
        assert_eq!(Backend::IndexedCotm.family(), None);
    }

    #[test]
    fn compressed_classification() {
        assert!(Backend::CompressedMulticlass.is_compressed());
        assert!(Backend::CompressedCotm.is_compressed());
        assert!(!Backend::CompressedMulticlass.is_bit_parallel());
        assert!(!Backend::CompressedMulticlass.is_indexed());
        assert!(!Backend::CompressedMulticlass.is_auto());
        assert!(!Backend::CompressedMulticlass.is_golden());
        assert!(Backend::CompressedMulticlass.is_native_batched());
        assert!(Backend::CompressedCotm.is_native_batched());
        assert!(!Backend::IndexedCotm.is_compressed());
        assert!(!Backend::AutoMulticlass.is_compressed());
        assert_eq!(
            Backend::parse("compressed-multiclass"),
            Some(Backend::CompressedMulticlass)
        );
        assert_eq!(
            Backend::parse("compressed-cotm"),
            Some(Backend::CompressedCotm)
        );
        assert_eq!(Backend::CompressedCotm.family(), None);
    }
}
