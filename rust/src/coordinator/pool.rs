//! Worker thread pool (no tokio offline): a shared job queue drained by
//! N workers. Each worker runs a caller-provided *state factory* once at
//! start-up, so non-`Send` per-worker state (the hardware architecture
//! instances with their `Rc` delay codes) lives entirely inside its
//! thread.
//!
//! Panic containment (the `util::lock_unpoisoned` treatment applied to
//! the job path): a panicking job must not take the serving loop with
//! it. The
//! worker catches the unwind, counts it ([`WorkerPool::panicked`]),
//! rebuilds its state from the factory (the job may have died halfway
//! through mutating it), and keeps draining the queue — so one bad
//! request degrades to one counted failure instead of permanently
//! shrinking the pool. The queue lock is poison-tolerant for the same
//! reason: the mutex only guards `recv()`, so the data under it cannot
//! be left in a torn state and `into_inner` recovery is sound.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

/// A job parameterised over per-worker state `S`.
pub type Job<S> = Box<dyn FnOnce(&mut S) + Send>;

/// Fixed-size worker pool with per-worker state.
pub struct WorkerPool<S: 'static> {
    tx: Option<mpsc::Sender<Job<S>>>,
    handles: Vec<JoinHandle<()>>,
    /// Jobs that panicked (each also rebuilt its worker's state).
    panicked: Arc<AtomicU64>,
}

impl<S: 'static> WorkerPool<S> {
    /// Spawn `n` workers; `factory(worker_index)` builds each worker's
    /// state inside its own thread (the factory itself must be Send).
    pub fn new<F>(n: usize, factory: F) -> Result<WorkerPool<S>>
    where
        F: Fn(usize) -> S + Send + Sync + 'static,
    {
        if n == 0 {
            return Err(Error::coordinator("worker pool needs >= 1 worker"));
        }
        let (tx, rx) = mpsc::channel::<Job<S>>();
        let rx = Arc::new(Mutex::new(rx));
        let factory = Arc::new(factory);
        let panicked = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let factory = Arc::clone(&factory);
            let panicked = Arc::clone(&panicked);
            let handle = std::thread::Builder::new()
                .name(format!("tmtd-worker-{i}"))
                .spawn(move || {
                    let mut state = factory(i);
                    loop {
                        let job = {
                            // Poison-tolerant: the mutex only serialises
                            // recv(), so a panic elsewhere cannot have
                            // torn the guarded data — recover the guard
                            // instead of cascading the poison into every
                            // later worker iteration.
                            crate::util::lock_unpoisoned(&rx).recv()
                        };
                        match job {
                            Ok(job) => {
                                // Contain a panicking job: count it and
                                // rebuild this worker's state (the job
                                // may have died mid-mutation), but keep
                                // the worker serving.
                                if catch_unwind(AssertUnwindSafe(|| job(&mut state)))
                                    .is_err()
                                {
                                    panicked.fetch_add(1, Ordering::Relaxed);
                                    state = factory(i);
                                }
                            }
                            Err(_) => break, // all senders dropped
                        }
                    }
                })
                .map_err(|e| Error::coordinator(format!("spawn worker: {e}")))?;
            handles.push(handle);
        }
        Ok(WorkerPool { tx: Some(tx), handles, panicked })
    }

    /// Enqueue a job.
    pub fn submit(&self, job: Job<S>) -> Result<()> {
        self.tx
            .as_ref()
            .ok_or_else(|| Error::coordinator("pool shut down"))?
            .send(job)
            .map_err(|_| Error::coordinator("pool workers exited"))
    }

    /// Jobs that panicked so far (each was contained: counted, state
    /// rebuilt, worker kept serving).
    pub fn panicked(&self) -> u64 {
        self.panicked.load(Ordering::Relaxed)
    }

    /// Drop the queue and join all workers.
    pub fn shutdown(mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl<S: 'static> Drop for WorkerPool<S> {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_on_all_workers() {
        let pool: WorkerPool<usize> = WorkerPool::new(4, |i| i).unwrap();
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(Box::new(move |_state| {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            }))
            .unwrap();
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        pool.shutdown();
    }

    #[test]
    fn per_worker_state_is_isolated() {
        // Each worker increments its own counter; totals must equal jobs.
        let pool: WorkerPool<u64> = WorkerPool::new(3, |_| 0u64).unwrap();
        let (tx, rx) = mpsc::channel();
        for _ in 0..60 {
            let tx = tx.clone();
            pool.submit(Box::new(move |state| {
                *state += 1;
                let _ = tx.send(*state);
            }))
            .unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..60 {
            seen.push(rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap());
        }
        // Per-worker counters never exceed the job total and are > 0.
        assert!(seen.iter().all(|&v| v >= 1 && v <= 60));
        pool.shutdown();
    }

    #[test]
    fn panicking_job_is_contained_and_counted() {
        // Regression: a panicking job used to kill its worker thread
        // outright — enough of them silently drained the whole pool
        // while submit() kept accepting. Now the worker survives, the
        // panic is counted, and the state is rebuilt from the factory.
        let builds = Arc::new(AtomicUsize::new(0));
        let b = Arc::clone(&builds);
        let pool: WorkerPool<usize> = WorkerPool::new(1, move |_| {
            b.fetch_add(1, Ordering::SeqCst)
        })
        .unwrap();
        let (tx, rx) = mpsc::channel();

        // Job 1 mutates state then panics; the pool must rebuild.
        pool.submit(Box::new(|state| {
            *state = 999;
            panic!("injected job panic");
        }))
        .unwrap();
        // Job 2 must still run — on the SAME worker (n=1) — and see
        // freshly built state, not the half-mutated corpse.
        let tx2 = tx.clone();
        pool.submit(Box::new(move |state| {
            let _ = tx2.send(*state);
        }))
        .unwrap();
        let state_after = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert_ne!(state_after, 999, "panicked job's half-mutation must be discarded");
        assert_eq!(pool.panicked(), 1);
        assert_eq!(builds.load(Ordering::SeqCst), 2, "initial build + one rebuild");

        // A second wave of panics still leaves the pool serving.
        for _ in 0..3 {
            pool.submit(Box::new(|_| panic!("again"))).unwrap();
        }
        let tx3 = tx.clone();
        pool.submit(Box::new(move |_| {
            let _ = tx3.send(42);
        }))
        .unwrap();
        assert_eq!(
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap(),
            42
        );
        assert_eq!(pool.panicked(), 4);
        pool.shutdown();
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(WorkerPool::<u8>::new(0, |_| 0u8).is_err());
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool: WorkerPool<()> = WorkerPool::new(2, |_| ()).unwrap();
        pool.submit(Box::new(|_| {})).unwrap();
        pool.shutdown(); // must not hang
    }
}
