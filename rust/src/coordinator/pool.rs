//! Worker thread pool (no tokio offline): a shared job queue drained by
//! N workers. Each worker runs a caller-provided *state factory* once at
//! start-up, so non-`Send` per-worker state (the hardware architecture
//! instances with their `Rc` delay codes) lives entirely inside its
//! thread.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

/// A job parameterised over per-worker state `S`.
pub type Job<S> = Box<dyn FnOnce(&mut S) + Send>;

/// Fixed-size worker pool with per-worker state.
pub struct WorkerPool<S: 'static> {
    tx: Option<mpsc::Sender<Job<S>>>,
    handles: Vec<JoinHandle<()>>,
}

impl<S: 'static> WorkerPool<S> {
    /// Spawn `n` workers; `factory(worker_index)` builds each worker's
    /// state inside its own thread (the factory itself must be Send).
    pub fn new<F>(n: usize, factory: F) -> Result<WorkerPool<S>>
    where
        F: Fn(usize) -> S + Send + Sync + 'static,
    {
        if n == 0 {
            return Err(Error::coordinator("worker pool needs >= 1 worker"));
        }
        let (tx, rx) = mpsc::channel::<Job<S>>();
        let rx = Arc::new(Mutex::new(rx));
        let factory = Arc::new(factory);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let factory = Arc::clone(&factory);
            let handle = std::thread::Builder::new()
                .name(format!("tmtd-worker-{i}"))
                .spawn(move || {
                    let mut state = factory(i);
                    loop {
                        let job = {
                            let guard = rx.lock().expect("pool queue poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(&mut state),
                            Err(_) => break, // all senders dropped
                        }
                    }
                })
                .map_err(|e| Error::coordinator(format!("spawn worker: {e}")))?;
            handles.push(handle);
        }
        Ok(WorkerPool { tx: Some(tx), handles })
    }

    /// Enqueue a job.
    pub fn submit(&self, job: Job<S>) -> Result<()> {
        self.tx
            .as_ref()
            .ok_or_else(|| Error::coordinator("pool shut down"))?
            .send(job)
            .map_err(|_| Error::coordinator("pool workers exited"))
    }

    /// Drop the queue and join all workers.
    pub fn shutdown(mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl<S: 'static> Drop for WorkerPool<S> {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_on_all_workers() {
        let pool: WorkerPool<usize> = WorkerPool::new(4, |i| i).unwrap();
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(Box::new(move |_state| {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            }))
            .unwrap();
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        pool.shutdown();
    }

    #[test]
    fn per_worker_state_is_isolated() {
        // Each worker increments its own counter; totals must equal jobs.
        let pool: WorkerPool<u64> = WorkerPool::new(3, |_| 0u64).unwrap();
        let (tx, rx) = mpsc::channel();
        for _ in 0..60 {
            let tx = tx.clone();
            pool.submit(Box::new(move |state| {
                *state += 1;
                let _ = tx.send(*state);
            }))
            .unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..60 {
            seen.push(rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap());
        }
        // Per-worker counters never exceed the job total and are > 0.
        assert!(seen.iter().all(|&v| v >= 1 && v <= 60));
        pool.shutdown();
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(WorkerPool::<u8>::new(0, |_| 0u8).is_err());
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool: WorkerPool<()> = WorkerPool::new(2, |_| ()).unwrap();
        pool.submit(Box::new(|_| {})).unwrap();
        pool.shutdown(); // must not hang
    }
}
