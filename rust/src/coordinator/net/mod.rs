//! Networked serving tier: shards as processes, a router in front,
//! `std::net` only.
//!
//! ```text
//!                          tmtd serve --remote-shards a:p,b:p,c:p
//!                        +--------------------------------------+
//!   client requests ---> |  RemoteCoordinator                   |
//!                        |   HashRing (identical to in-process) |
//!                        |   health + heartbeat + failover      |
//!                        +----+-----------+------------+--------+
//!                             | TCP frames (net::frame/msg)
//!                   +---------+   +-------+    +-------+
//!                   v             v             v
//!            tmtd shard      tmtd shard     tmtd shard
//!            --listen a:p    --listen b:p   --listen c:p
//!            --model x.tmc   --model x.tmc  --model x.tmc
//!            (ShardServer over one CoordinatorServer each)
//! ```
//!
//! Layers:
//!
//! * [`frame`] — length-prefixed binary frame codec (magic, version,
//!   bounded length; IO vs protocol error discipline).
//! * [`msg`] — the ten message types and their payload layouts,
//!   mirrored bit-for-bit by `python/netproto.py` and pinned by shared
//!   golden byte-vectors in both test suites.
//! * [`server`] — [`ShardServer`]: a [`CoordinatorServer`] behind a
//!   TCP listener; propagates backpressure as wire-level rejections,
//!   answers heartbeats and stats, drains gracefully.
//! * [`client`] — [`RemoteShard`] / [`RemoteCoordinator`]: connection
//!   pooling, reconnect-with-backoff health tracking, deterministic
//!   ring-walk failover, exact cross-process stats aggregation.
//!
//! See `docs/DEPLOY.md` for the operational walkthrough (pinned `.tmc`
//! models per shard, drain semantics, failure modes).
//!
//! [`CoordinatorServer`]: crate::coordinator::server::CoordinatorServer

pub mod client;
pub mod frame;
pub mod msg;
pub mod server;

pub use client::{RemoteCoordinator, RemoteShard};
pub use frame::{HEADER_LEN, MAGIC, MAX_PAYLOAD, VERSION};
pub use msg::Msg;
pub use server::ShardServer;
