//! Shard-side TCP server: one process serving one
//! [`CoordinatorServer`] over the framed protocol in [`super::msg`].
//!
//! Design:
//!
//! * The accept loop runs on its own thread with a non-blocking
//!   listener so it can poll the stop flag; each accepted connection
//!   gets a handler thread with a short read timeout for the same
//!   reason. Both threads contain panics — one poisoned connection
//!   must never take down the shard process.
//! * Requests are served **synchronously per connection** (one frame
//!   in, one frame out, in order). Routers open several connections
//!   per shard to get pipelining; the per-connection ordering is what
//!   lets a client match replies to requests without request IDs.
//! * Backpressure is propagated, not swallowed: a queue-depth
//!   rejection from [`CoordinatorServer::submit`] becomes a
//!   [`Msg::Reject`] on the wire; any other serving error becomes
//!   [`Msg::Failed`]. The TCP connection stays up either way.
//! * [`Msg::Drain`] answers [`Msg::DrainAck`] and then stops the whole
//!   shard: the accept loop exits, connection handlers finish their
//!   in-flight frame and close, and [`ShardServer::shutdown`] drains
//!   the inner server's pools and batchers.
//! * A peer speaking garbage (bad magic/version/length, malformed
//!   payload) gets its connection closed and counted in
//!   `protocol_errors`; a peer disconnecting mid-frame is closed
//!   silently. Neither can hang or crash the shard.

use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::coordinator::net::msg::Msg;
use crate::coordinator::router::{Backend, InferRequest};
use crate::coordinator::server::CoordinatorServer;
use crate::error::{Error, Result};

/// How long a connection handler blocks in `read` before re-checking
/// the stop flag.
const READ_POLL: Duration = Duration::from_millis(100);
/// Accept-loop poll interval while no connection is pending.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// A [`CoordinatorServer`] listening on a TCP socket.
pub struct ShardServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    protocol_errors: Arc<AtomicU64>,
    accept_thread: Option<thread::JoinHandle<()>>,
    server: Arc<CoordinatorServer>,
}

impl ShardServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral test port) and
    /// start serving `server` in background threads.
    pub fn bind(server: CoordinatorServer, addr: &str) -> Result<ShardServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::coordinator(format!("net: bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::coordinator(format!("net: local_addr: {e}")))?;
        listener.set_nonblocking(true)?;
        let server = Arc::new(server);
        let stop = Arc::new(AtomicBool::new(false));
        let protocol_errors = Arc::new(AtomicU64::new(0));
        let accept_thread = {
            let server = Arc::clone(&server);
            let stop = Arc::clone(&stop);
            let protocol_errors = Arc::clone(&protocol_errors);
            thread::spawn(move || {
                // Contain panics: the accept loop owns no lock, so a
                // contained panic just stops accepting (r2).
                let _ = catch_unwind(AssertUnwindSafe(|| {
                    accept_loop(&listener, &server, &stop, &protocol_errors);
                }));
            })
        };
        Ok(ShardServer {
            addr: local,
            stop,
            protocol_errors,
            accept_thread: Some(accept_thread),
            server,
        })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a drain was received or [`ShardServer::stop`] was
    /// called.
    pub fn is_stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Malformed-frame count (observability for the adversarial
    /// tests: garbage must be *counted*, not silently dropped).
    pub fn protocol_errors(&self) -> u64 {
        self.protocol_errors.load(Ordering::Relaxed)
    }

    /// Ask the server to stop accepting and close idle connections
    /// (the same path a wire-level [`Msg::Drain`] takes).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Block until the accept loop exits (i.e. until a drain arrives
    /// or [`ShardServer::stop`] is called), then drain the inner
    /// server. This is what `tmtd shard` parks on.
    pub fn wait(mut self) {
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        self.shutdown();
    }

    /// Stop serving and drain the inner [`CoordinatorServer`] (pools
    /// and batchers flush before their threads join).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        // The accept loop joins every connection handler before it
        // returns, so this unwrap of the Arc cannot race a live clone.
        if let Ok(server) = Arc::try_unwrap(self.server) {
            server.shutdown();
        }
    }
}

fn accept_loop(
    listener: &TcpListener,
    server: &Arc<CoordinatorServer>,
    stop: &Arc<AtomicBool>,
    protocol_errors: &Arc<AtomicU64>,
) {
    let mut handlers = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let server = Arc::clone(server);
                let stop = Arc::clone(stop);
                let protocol_errors = Arc::clone(protocol_errors);
                handlers.push(thread::spawn(move || {
                    // One hostile or crashing connection must not take
                    // down the shard: contain the panic, drop the
                    // socket (r2).
                    let _ = catch_unwind(AssertUnwindSafe(|| {
                        serve_connection(stream, &server, &stop, &protocol_errors);
                    }));
                }));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(ACCEPT_POLL);
            }
            Err(_) => thread::sleep(ACCEPT_POLL),
        }
        // Reap finished handlers so a long-lived shard doesn't
        // accumulate joined-but-unreleased threads.
        handlers.retain(|h| !h.is_finished());
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// Serve one connection until drain/stop, disconnect, or a protocol
/// violation.
fn serve_connection(
    stream: TcpStream,
    server: &CoordinatorServer,
    stop: &AtomicBool,
    protocol_errors: &AtomicU64,
) {
    let mut stream = stream;
    if stream.set_read_timeout(Some(READ_POLL)).is_err() {
        return;
    }
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let msg = match Msg::read_from(&mut stream) {
            Ok(m) => m,
            Err(Error::Io(e))
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                // Idle read timeout: re-check the stop flag and wait
                // for the next frame.
                continue;
            }
            Err(Error::Io(_)) => return, // peer went away
            Err(_) => {
                // Protocol garbage: the stream offset is unknowable
                // now, so the only safe move is to close. Counted for
                // the adversarial suite.
                protocol_errors.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        let reply = match msg {
            Msg::InferRequest { backend, features } => infer_reply(server, &backend, features),
            Msg::Heartbeat { nonce } => Msg::HeartbeatAck { nonce },
            Msg::StatsRequest => stats_reply(server),
            Msg::Drain => {
                let _ = Msg::DrainAck.write_to(&mut stream);
                stop.store(true, Ordering::SeqCst);
                return;
            }
            // Server-to-client message types arriving at the server
            // are a protocol violation.
            _ => {
                protocol_errors.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        if reply.write_to(&mut stream).is_err() {
            return;
        }
    }
}

fn infer_reply(server: &CoordinatorServer, backend: &str, features: Vec<bool>) -> Msg {
    let Some(backend) = Backend::parse(backend) else {
        // An unknown backend never entered the queue, so it must not
        // disturb the conservation counters — report it as a wire
        // failure only.
        return Msg::Failed { reason: format!("unknown backend {backend:?}") };
    };
    match server.infer(InferRequest { features, backend }) {
        Ok(resp) => Msg::InferResponse {
            backend: resp.backend.name().to_string(),
            predicted: resp.predicted as u32,
            class_sums: resp.class_sums,
            service_us: resp.service_us,
        },
        Err(e) => {
            let reason = e.to_string();
            if reason.contains("backpressure") {
                Msg::Reject { reason }
            } else {
                Msg::Failed { reason }
            }
        }
    }
}

/// Ship the raw counters and sample rings — the router rebuilds exact
/// cross-shard percentiles from these, identical to the in-process
/// `ShardedCoordinator::stats` contract.
fn stats_reply(server: &CoordinatorServer) -> Msg {
    let h = server.stats_handle();
    Msg::StatsReply {
        submitted: h.submitted.load(Ordering::Relaxed),
        completed: h.completed.load(Ordering::Relaxed),
        rejected: h.rejected.load(Ordering::Relaxed),
        failed: h.failed.load(Ordering::Relaxed),
        batches_flushed: h.batches_flushed.load(Ordering::Relaxed),
        batched_requests: h.batched_requests.load(Ordering::Relaxed),
        latency_samples: h.latency_samples(),
        batch_size_samples: h.batch_size_samples(),
    }
}
