//! Length-prefixed binary frame codec for the networked serving tier.
//!
//! Every message on the wire is one frame (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"tmtd"
//! 4       1     protocol version (1)
//! 5       1     message type (see net::msg)
//! 6       4     payload length (u32, <= MAX_PAYLOAD)
//! 10      n     payload
//! ```
//!
//! Mirrored bit-for-bit by `python/netproto.py` (same constants, same
//! validation order) and pinned by shared golden byte-vectors in both
//! test suites, so the wire format validates on toolchain-less CI
//! images.
//!
//! Error discipline: a malformed header or payload is a *protocol*
//! error ([`Error::coordinator`], message prefixed `net:`); a socket
//! failure (disconnect, timeout) passes through as [`Error::Io`] — so
//! callers can distinguish a peer speaking garbage from a peer that
//! went away, and the remote router only fails over on the latter.

use std::io::{Read, Write};

use crate::error::{Error, Result};

/// Frame magic — `b"tmtd"` on the wire.
pub const MAGIC: [u8; 4] = *b"tmtd";
/// Protocol version byte; bumped on any wire-format change.
pub const VERSION: u8 = 1;
/// Fixed frame header size in bytes.
pub const HEADER_LEN: usize = 10;
/// 16 MiB: far above any real message (the stats rings cap at 100k f64
/// samples ~ 800 KB each) while bounding a hostile length prefix — a
/// corrupt or adversarial length can never make a reader allocate or
/// block for gigabytes.
pub const MAX_PAYLOAD: usize = 1 << 24;

/// Write one frame (header + payload) to `w`.
pub fn write_frame(w: &mut impl Write, msg_type: u8, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_PAYLOAD {
        return Err(Error::coordinator(format!(
            "net: payload of {} bytes exceeds MAX_PAYLOAD",
            payload.len()
        )));
    }
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4] = VERSION;
    header[5] = msg_type;
    header[6..].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame from `r`; returns `(msg_type, payload)`.
///
/// Header validation order matches the Python mirror: magic, version,
/// length bound, then the length-checked payload read. IO failures
/// (EOF mid-frame, timeouts) surface as [`Error::Io`].
pub fn read_frame(r: &mut impl Read) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)?;
    if header[..4] != MAGIC {
        return Err(Error::coordinator(format!(
            "net: bad magic {:?} (expected {MAGIC:?})",
            &header[..4]
        )));
    }
    let version = header[4];
    if version != VERSION {
        return Err(Error::coordinator(format!(
            "net: unsupported protocol version {version}"
        )));
    }
    let msg_type = header[5];
    let mut len_bytes = [0u8; 4];
    len_bytes.copy_from_slice(&header[6..]);
    let length = u32::from_le_bytes(len_bytes) as usize;
    if length > MAX_PAYLOAD {
        return Err(Error::coordinator(format!(
            "net: frame length {length} exceeds MAX_PAYLOAD ({MAX_PAYLOAD})"
        )));
    }
    let mut payload = vec![0u8; length];
    r.read_exact(&mut payload)?;
    Ok((msg_type, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_header_and_payload() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 5, &[1, 2, 3]).unwrap();
        assert_eq!(buf.len(), HEADER_LEN + 3);
        assert_eq!(&buf[..4], b"tmtd");
        assert_eq!(buf[4], VERSION);
        assert_eq!(buf[5], 5);
        let (t, p) = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(t, 5);
        assert_eq!(p, vec![1, 2, 3]);
    }

    #[test]
    fn empty_payload_roundtrips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 9, &[]).unwrap();
        assert_eq!(buf.len(), HEADER_LEN);
        let (t, p) = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(t, 9);
        assert!(p.is_empty());
    }

    #[test]
    fn truncated_frames_are_io_errors() {
        // Truncation = the peer disconnected mid-frame; that's an IO
        // error (failover-eligible), not a protocol violation.
        let mut buf = Vec::new();
        write_frame(&mut buf, 5, &[1, 2, 3, 4]).unwrap();
        for cut in 0..buf.len() {
            match read_frame(&mut &buf[..cut]) {
                Err(Error::Io(_)) => {}
                other => panic!("cut {cut}: expected Io error, got {other:?}"),
            }
        }
    }

    #[test]
    fn bad_magic_is_protocol_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 5, &[]).unwrap();
        buf[0] ^= 0xff;
        match read_frame(&mut buf.as_slice()) {
            Err(Error::Coordinator(m)) => assert!(m.contains("bad magic"), "{m}"),
            other => panic!("expected protocol error, got {other:?}"),
        }
    }

    #[test]
    fn bad_version_is_protocol_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 5, &[]).unwrap();
        buf[4] = 99;
        match read_frame(&mut buf.as_slice()) {
            Err(Error::Coordinator(m)) => assert!(m.contains("version"), "{m}"),
            other => panic!("expected protocol error, got {other:?}"),
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 5, &[]).unwrap();
        buf[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        match read_frame(&mut buf.as_slice()) {
            Err(Error::Coordinator(m)) => assert!(m.contains("MAX_PAYLOAD"), "{m}"),
            other => panic!("expected protocol error, got {other:?}"),
        }
        // The writer enforces the same bound.
        let huge = vec![0u8; MAX_PAYLOAD + 1];
        assert!(write_frame(&mut Vec::new(), 5, &huge).is_err());
    }
}
