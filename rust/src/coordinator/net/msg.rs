//! Wire messages for the networked serving tier.
//!
//! One [`Msg`] variant per message type; payload layouts are mirrored
//! bit-for-bit by `python/netproto.py` (strings are u16 length + UTF-8,
//! everything little-endian):
//!
//! | type | message        | payload                                        |
//! |------|----------------|------------------------------------------------|
//! | 1    | InferRequest   | str backend, u32 nfeat, nfeat x u8 (0/1)       |
//! | 2    | InferResponse  | str backend, u32 predicted, u32 n, n x i32, f64 service_us |
//! | 3    | Reject         | str reason (backpressure, never swallowed)     |
//! | 4    | Failed         | str reason (server-side failure)               |
//! | 5    | Heartbeat      | u64 nonce                                      |
//! | 6    | HeartbeatAck   | u64 nonce                                      |
//! | 7    | StatsRequest   | (empty)                                        |
//! | 8    | StatsReply     | 6 x u64 counters, u32 nlat, nlat x f64, u32 nbatch, nbatch x f64 |
//! | 9    | Drain          | (empty)                                        |
//! | 10   | DrainAck       | (empty)                                        |
//!
//! The [`Msg::StatsReply`] ships the shard's *raw* latency /
//! batch-size sample rings, not a pre-digested summary — the router
//! rebuilds exact cross-shard percentiles from the concatenated
//! samples, the same contract `ShardedCoordinator::stats` keeps
//! in-process.

use std::io::{Read, Write};

use crate::coordinator::net::frame::{read_frame, write_frame, MAX_PAYLOAD};
use crate::error::{Error, Result};

/// One protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    InferRequest {
        backend: String,
        features: Vec<bool>,
    },
    InferResponse {
        backend: String,
        predicted: u32,
        class_sums: Vec<i32>,
        service_us: f64,
    },
    /// Backpressure: the shard's queue depth is exhausted. Propagated
    /// over the wire so the caller sees the same rejection it would
    /// in-process.
    Reject { reason: String },
    /// The shard accepted the request but serving it failed.
    Failed { reason: String },
    Heartbeat { nonce: u64 },
    HeartbeatAck { nonce: u64 },
    StatsRequest,
    StatsReply {
        submitted: u64,
        completed: u64,
        rejected: u64,
        failed: u64,
        batches_flushed: u64,
        batched_requests: u64,
        latency_samples: Vec<f64>,
        batch_size_samples: Vec<f64>,
    },
    /// Graceful drain: finish in-flight work, ack, stop accepting.
    Drain,
    DrainAck,
}

fn put_str(out: &mut Vec<u8>, s: &str) -> Result<()> {
    let raw = s.as_bytes();
    if raw.len() > u16::MAX as usize {
        return Err(Error::coordinator("net: string too long for u16 length prefix"));
    }
    out.extend_from_slice(&(raw.len() as u16).to_le_bytes());
    out.extend_from_slice(raw);
    Ok(())
}

/// Bounds-checked cursor over a payload: every take validates the
/// remaining length and errors instead of slicing past the end, so a
/// truncated or hostile payload can never panic the decoder.
struct PayloadReader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    fn new(data: &'a [u8]) -> PayloadReader<'a> {
        PayloadReader { data, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            Error::coordinator("net: payload length overflow")
        })?;
        let chunk = self.data.get(self.pos..end).ok_or_else(|| {
            Error::coordinator(format!(
                "net: truncated payload (wanted {n} bytes, {} left)",
                self.data.len().saturating_sub(self.pos)
            ))
        })?;
        self.pos = end;
        Ok(chunk)
    }

    fn array<const N: usize>(&mut self) -> Result<[u8; N]> {
        self.take(N)?
            .try_into()
            .map_err(|_| Error::coordinator("net: internal length mismatch"))
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.array()?))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.array()?))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.array()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.array()?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.array()?))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|e| Error::coordinator(format!("net: invalid UTF-8 in string: {e}")))
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.data.len() {
            return Err(Error::coordinator(format!(
                "net: {} trailing bytes after message",
                self.data.len() - self.pos
            )));
        }
        Ok(())
    }
}

impl Msg {
    /// The wire type byte for this message.
    pub fn msg_type(&self) -> u8 {
        match self {
            Msg::InferRequest { .. } => 1,
            Msg::InferResponse { .. } => 2,
            Msg::Reject { .. } => 3,
            Msg::Failed { .. } => 4,
            Msg::Heartbeat { .. } => 5,
            Msg::HeartbeatAck { .. } => 6,
            Msg::StatsRequest => 7,
            Msg::StatsReply { .. } => 8,
            Msg::Drain => 9,
            Msg::DrainAck => 10,
        }
    }

    /// Encode just the payload (no frame header).
    pub fn encode_payload(&self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        match self {
            Msg::InferRequest { backend, features } => {
                put_str(&mut out, backend)?;
                out.extend_from_slice(&(features.len() as u32).to_le_bytes());
                out.extend(features.iter().map(|&b| b as u8));
            }
            Msg::InferResponse { backend, predicted, class_sums, service_us } => {
                put_str(&mut out, backend)?;
                out.extend_from_slice(&predicted.to_le_bytes());
                out.extend_from_slice(&(class_sums.len() as u32).to_le_bytes());
                for s in class_sums {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                out.extend_from_slice(&service_us.to_le_bytes());
            }
            Msg::Reject { reason } | Msg::Failed { reason } => {
                put_str(&mut out, reason)?;
            }
            Msg::Heartbeat { nonce } | Msg::HeartbeatAck { nonce } => {
                out.extend_from_slice(&nonce.to_le_bytes());
            }
            Msg::StatsRequest | Msg::Drain | Msg::DrainAck => {}
            Msg::StatsReply {
                submitted,
                completed,
                rejected,
                failed,
                batches_flushed,
                batched_requests,
                latency_samples,
                batch_size_samples,
            } => {
                for c in [submitted, completed, rejected, failed, batches_flushed, batched_requests]
                {
                    out.extend_from_slice(&c.to_le_bytes());
                }
                out.extend_from_slice(&(latency_samples.len() as u32).to_le_bytes());
                for x in latency_samples {
                    out.extend_from_slice(&x.to_le_bytes());
                }
                out.extend_from_slice(&(batch_size_samples.len() as u32).to_le_bytes());
                for x in batch_size_samples {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        Ok(out)
    }

    /// Decode a payload for `msg_type`; rejects trailing bytes, bad
    /// inner counts, non-boolean feature bytes and invalid UTF-8 with
    /// clean protocol errors.
    pub fn decode(msg_type: u8, payload: &[u8]) -> Result<Msg> {
        let mut r = PayloadReader::new(payload);
        let msg = match msg_type {
            1 => {
                let backend = r.string()?;
                let n = r.u32()? as usize;
                let raw = r.take(n)?;
                let mut features = Vec::with_capacity(n);
                for &b in raw {
                    match b {
                        0 => features.push(false),
                        1 => features.push(true),
                        other => {
                            return Err(Error::coordinator(format!(
                                "net: feature byte {other} not 0/1"
                            )))
                        }
                    }
                }
                Msg::InferRequest { backend, features }
            }
            2 => {
                let backend = r.string()?;
                let predicted = r.u32()?;
                let n = r.u32()? as usize;
                if n > MAX_PAYLOAD / 4 {
                    return Err(Error::coordinator(format!(
                        "net: class-sum count {n} too large"
                    )));
                }
                let mut class_sums = Vec::with_capacity(n);
                for _ in 0..n {
                    class_sums.push(r.i32()?);
                }
                let service_us = r.f64()?;
                Msg::InferResponse { backend, predicted, class_sums, service_us }
            }
            3 => Msg::Reject { reason: r.string()? },
            4 => Msg::Failed { reason: r.string()? },
            5 => Msg::Heartbeat { nonce: r.u64()? },
            6 => Msg::HeartbeatAck { nonce: r.u64()? },
            7 => Msg::StatsRequest,
            8 => {
                let submitted = r.u64()?;
                let completed = r.u64()?;
                let rejected = r.u64()?;
                let failed = r.u64()?;
                let batches_flushed = r.u64()?;
                let batched_requests = r.u64()?;
                let nlat = r.u32()? as usize;
                if nlat > MAX_PAYLOAD / 8 {
                    return Err(Error::coordinator(format!(
                        "net: latency sample count {nlat} too large"
                    )));
                }
                let mut latency_samples = Vec::with_capacity(nlat);
                for _ in 0..nlat {
                    latency_samples.push(r.f64()?);
                }
                let nbatch = r.u32()? as usize;
                if nbatch > MAX_PAYLOAD / 8 {
                    return Err(Error::coordinator(format!(
                        "net: batch sample count {nbatch} too large"
                    )));
                }
                let mut batch_size_samples = Vec::with_capacity(nbatch);
                for _ in 0..nbatch {
                    batch_size_samples.push(r.f64()?);
                }
                Msg::StatsReply {
                    submitted,
                    completed,
                    rejected,
                    failed,
                    batches_flushed,
                    batched_requests,
                    latency_samples,
                    batch_size_samples,
                }
            }
            9 => Msg::Drain,
            10 => Msg::DrainAck,
            other => {
                return Err(Error::coordinator(format!(
                    "net: unknown message type {other}"
                )))
            }
        };
        r.finish()?;
        Ok(msg)
    }

    /// Write this message as one frame.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        write_frame(w, self.msg_type(), &self.encode_payload()?)
    }

    /// Read one framed message.
    pub fn read_from(r: &mut impl Read) -> Result<Msg> {
        let (t, payload) = read_frame(r)?;
        Msg::decode(t, &payload)
    }

    /// Encode as one complete frame (header + payload).
    pub fn encode_frame(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.write_to(&mut buf)?;
        Ok(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn goldens() -> Vec<Msg> {
        vec![
            Msg::InferRequest {
                backend: "bitparallel-mc".into(),
                features: vec![true, false, true, true, false, false, true, false],
            },
            Msg::InferResponse {
                backend: "auto".into(),
                predicted: 2,
                class_sums: vec![-5, 3, 17],
                service_us: 123.5,
            },
            Msg::Reject { reason: "backpressure: queue depth exceeded".into() },
            Msg::Failed { reason: "engine dead".into() },
            Msg::Heartbeat { nonce: 81985529216486895 },
            Msg::HeartbeatAck { nonce: 81985529216486895 },
            Msg::StatsRequest,
            Msg::StatsReply {
                submitted: 7,
                completed: 5,
                rejected: 1,
                failed: 1,
                batches_flushed: 2,
                batched_requests: 5,
                latency_samples: vec![1.5, 2.25],
                batch_size_samples: vec![3.0],
            },
            Msg::Drain,
            Msg::DrainAck,
        ]
    }

    #[test]
    fn netproto_golden_frames_match_python_mirror() {
        // Pinned against GOLDEN_FRAMES in python/tests/test_netproto.py
        // (the r5 probe cross-checks the hex constants): one frame per
        // message type, byte for byte.
        let want: Vec<Vec<u8>> = vec![
            vec![
                0x74, 0x6d, 0x74, 0x64, 0x01, 0x01, 0x1c, 0x00, 0x00, 0x00,
                0x0e, 0x00, 0x62, 0x69, 0x74, 0x70, 0x61, 0x72, 0x61, 0x6c,
                0x6c, 0x65, 0x6c, 0x2d, 0x6d, 0x63, 0x08, 0x00, 0x00, 0x00,
                0x01, 0x00, 0x01, 0x01, 0x00, 0x00, 0x01, 0x00,
            ],
            vec![
                0x74, 0x6d, 0x74, 0x64, 0x01, 0x02, 0x22, 0x00, 0x00, 0x00,
                0x04, 0x00, 0x61, 0x75, 0x74, 0x6f, 0x02, 0x00, 0x00, 0x00,
                0x03, 0x00, 0x00, 0x00, 0xfb, 0xff, 0xff, 0xff, 0x03, 0x00,
                0x00, 0x00, 0x11, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
                0x00, 0xe0, 0x5e, 0x40,
            ],
            vec![
                0x74, 0x6d, 0x74, 0x64, 0x01, 0x03, 0x24, 0x00, 0x00, 0x00,
                0x22, 0x00, 0x62, 0x61, 0x63, 0x6b, 0x70, 0x72, 0x65, 0x73,
                0x73, 0x75, 0x72, 0x65, 0x3a, 0x20, 0x71, 0x75, 0x65, 0x75,
                0x65, 0x20, 0x64, 0x65, 0x70, 0x74, 0x68, 0x20, 0x65, 0x78,
                0x63, 0x65, 0x65, 0x64, 0x65, 0x64,
            ],
            vec![
                0x74, 0x6d, 0x74, 0x64, 0x01, 0x04, 0x0d, 0x00, 0x00, 0x00,
                0x0b, 0x00, 0x65, 0x6e, 0x67, 0x69, 0x6e, 0x65, 0x20, 0x64,
                0x65, 0x61, 0x64,
            ],
            vec![
                0x74, 0x6d, 0x74, 0x64, 0x01, 0x05, 0x08, 0x00, 0x00, 0x00,
                0xef, 0xcd, 0xab, 0x89, 0x67, 0x45, 0x23, 0x01,
            ],
            vec![
                0x74, 0x6d, 0x74, 0x64, 0x01, 0x06, 0x08, 0x00, 0x00, 0x00,
                0xef, 0xcd, 0xab, 0x89, 0x67, 0x45, 0x23, 0x01,
            ],
            vec![0x74, 0x6d, 0x74, 0x64, 0x01, 0x07, 0x00, 0x00, 0x00, 0x00],
            vec![
                0x74, 0x6d, 0x74, 0x64, 0x01, 0x08, 0x50, 0x00, 0x00, 0x00,
                0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x05, 0x00,
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x01, 0x00, 0x00, 0x00,
                0x00, 0x00, 0x00, 0x00, 0x01, 0x00, 0x00, 0x00, 0x00, 0x00,
                0x00, 0x00, 0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
                0x05, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x02, 0x00,
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xf8, 0x3f,
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x02, 0x40, 0x01, 0x00,
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x08, 0x40,
            ],
            vec![0x74, 0x6d, 0x74, 0x64, 0x01, 0x09, 0x00, 0x00, 0x00, 0x00],
            vec![0x74, 0x6d, 0x74, 0x64, 0x01, 0x0a, 0x00, 0x00, 0x00, 0x00],
        ];
        let msgs = goldens();
        assert_eq!(msgs.len(), want.len(), "one golden per message type");
        for (m, w) in msgs.iter().zip(&want) {
            assert_eq!(&m.encode_frame().unwrap(), w, "{m:?}");
            assert_eq!(&Msg::read_from(&mut w.as_slice()).unwrap(), m);
        }
    }

    #[test]
    fn every_message_roundtrips() {
        for m in goldens() {
            let buf = m.encode_frame().unwrap();
            assert_eq!(Msg::read_from(&mut buf.as_slice()).unwrap(), m);
        }
    }

    #[test]
    fn edge_values_roundtrip() {
        let msgs = vec![
            Msg::InferRequest { backend: String::new(), features: vec![] },
            Msg::InferRequest {
                backend: "x".into(),
                features: (0..1000).map(|i| i % 2 == 0).collect(),
            },
            Msg::InferResponse {
                backend: "a".into(),
                predicted: u32::MAX,
                class_sums: vec![i32::MIN, i32::MAX],
                service_us: -1.25,
            },
            Msg::Heartbeat { nonce: u64::MAX },
            Msg::StatsReply {
                submitted: u64::MAX,
                completed: 1,
                rejected: 2,
                failed: 3,
                batches_flushed: 4,
                batched_requests: 5,
                latency_samples: (0..100).map(f64::from).collect(),
                batch_size_samples: vec![0.5],
            },
        ];
        for m in msgs {
            let buf = m.encode_frame().unwrap();
            assert_eq!(Msg::read_from(&mut buf.as_slice()).unwrap(), m);
        }
    }

    #[test]
    fn truncated_payloads_error_cleanly_at_every_cut() {
        for m in goldens() {
            let payload = m.encode_payload().unwrap();
            for cut in 0..payload.len() {
                assert!(
                    Msg::decode(m.msg_type(), &payload[..cut]).is_err(),
                    "{m:?} cut {cut}"
                );
            }
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        for m in goldens() {
            let mut payload = m.encode_payload().unwrap();
            payload.push(0);
            assert!(Msg::decode(m.msg_type(), &payload).is_err(), "{m:?}");
        }
    }

    #[test]
    fn unknown_type_and_bad_bytes_are_rejected() {
        assert!(Msg::decode(0xee, &[]).is_err());
        // Feature byte 2.
        let mut p = Vec::new();
        put_str(&mut p, "a").unwrap();
        p.extend_from_slice(&1u32.to_le_bytes());
        p.push(2);
        assert!(Msg::decode(1, &p).is_err());
        // Invalid UTF-8 backend name.
        let bad = [2u8, 0, 0xff, 0xfe, 0, 0, 0, 0];
        assert!(Msg::decode(1, &bad).is_err());
        // Hostile inner count: claims u32::MAX class sums.
        let mut p = Vec::new();
        put_str(&mut p, "a").unwrap();
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Msg::decode(2, &p).is_err());
    }
}
