//! Router-side client for the networked serving tier: a
//! [`RemoteCoordinator`] fronts N shard processes (see
//! [`super::server::ShardServer`]) behind the same consistent-hash
//! ring the in-process [`ShardedCoordinator`] uses, so the TCP front
//! door routes requests to the same shard the in-process front door
//! would.
//!
//! [`ShardedCoordinator`]: crate::coordinator::shard::ShardedCoordinator
//!
//! Failure semantics:
//!
//! * **Failover only on transport failure.** A connect/read/write
//!   error ([`Error::Io`]) marks the shard unhealthy and the request
//!   retries on the next shard in the ring walk
//!   ([`HashRing::walk_from_hash`] — deterministic, starts at the
//!   owner). A [`Msg::Reject`] (backpressure) or [`Msg::Failed`]
//!   (serving error) is a *shard answering correctly* and propagates
//!   to the caller without failover — retrying a rejection elsewhere
//!   would silently defeat per-shard backpressure.
//! * **Reconnect with backoff.** A heartbeat thread probes every
//!   shard; an unhealthy shard is probed on an exponentially growing
//!   tick schedule (capped) and rejoins the healthy set on the first
//!   acked beat. Requests skip unhealthy shards while any healthy one
//!   remains, so a dead shard costs one failed probe per backoff
//!   window, not one timeout per request.
//! * **Exact stats.** [`RemoteCoordinator::cluster_stats`] merges the
//!   shards' counters and rebuilds latency/batch percentiles from the
//!   raw sample rings shipped in [`Msg::StatsReply`] — the same exact
//!   aggregation `ShardedCoordinator::stats` performs in-process.

use std::io::ErrorKind;
use std::net::{TcpStream, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::coordinator::net::msg::Msg;
use crate::coordinator::router::{Backend, InferResponse};
use crate::coordinator::shard::{hash_features, HashRing, DEFAULT_VNODES};
use crate::coordinator::stats::{ServerStats, StatsSnapshot};
use crate::error::{Error, Result};
use crate::util::stats::Summary;
use crate::util::sync::lock_unpoisoned;

/// Cap on the heartbeat backoff: an unhealthy shard is probed at least
/// every `2^MAX_BACKOFF_EXP` heartbeat ticks.
const MAX_BACKOFF_EXP: u32 = 4;
/// Transport timeouts: a shard that accepts but never answers must
/// surface as an [`Error::Io`] (failover), never a hang.
const CONNECT_TIMEOUT: Duration = Duration::from_millis(1_000);
const REPLY_TIMEOUT: Duration = Duration::from_secs(10);

/// One remote shard: address, bounded connection pool, health bit.
pub struct RemoteShard {
    addr: String,
    /// Idle pooled connections (bounded by `max_conns`).
    pool: Mutex<Vec<TcpStream>>,
    max_conns: usize,
    healthy: AtomicBool,
    /// Consecutive failed heartbeat probes (drives the backoff).
    misses: AtomicU32,
    /// Heartbeat ticks to skip before the next probe of an unhealthy
    /// shard.
    skip_ticks: AtomicU32,
}

impl RemoteShard {
    fn new(addr: String, max_conns: usize) -> RemoteShard {
        RemoteShard {
            addr,
            pool: Mutex::new(Vec::new()),
            max_conns,
            healthy: AtomicBool::new(true),
            misses: AtomicU32::new(0),
            skip_ticks: AtomicU32::new(0),
        }
    }

    /// The `host:port` this shard was configured with.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Current health belief (updated by heartbeats and by request
    /// outcomes).
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::SeqCst)
    }

    fn connect(&self) -> Result<TcpStream> {
        let sockaddr = self
            .addr
            .to_socket_addrs()
            .map_err(Error::Io)?
            .next()
            .ok_or_else(|| Error::coordinator(format!("net: {:?} resolves to nothing", self.addr)))?;
        let stream = TcpStream::connect_timeout(&sockaddr, CONNECT_TIMEOUT)?;
        stream.set_read_timeout(Some(REPLY_TIMEOUT))?;
        stream.set_nodelay(true)?;
        Ok(stream)
    }

    fn checkout(&self) -> Result<TcpStream> {
        if let Some(s) = lock_unpoisoned(&self.pool).pop() {
            return Ok(s);
        }
        self.connect()
    }

    fn checkin(&self, stream: TcpStream) {
        let mut pool = lock_unpoisoned(&self.pool);
        if pool.len() < self.max_conns {
            pool.push(stream);
        }
    }

    /// One request/reply exchange. A transport error on a *pooled*
    /// connection retries once on a fresh connect (the pooled socket
    /// may be stale after a shard restart); a fresh-connection failure
    /// is the shard's answer. Updates the health bit on both outcomes.
    pub fn call(&self, msg: &Msg) -> Result<Msg> {
        let mut fresh = false;
        let mut stream = match self.checkout() {
            Ok(s) => s,
            Err(e) => {
                self.mark_unhealthy();
                return Err(e);
            }
        };
        loop {
            match exchange(&mut stream, msg) {
                Ok(reply) => {
                    self.mark_healthy();
                    self.checkin(stream);
                    return Ok(reply);
                }
                Err(Error::Io(_)) if !fresh => {
                    // Stale pooled socket: retry exactly once on a
                    // fresh connection before declaring the shard down.
                    fresh = true;
                    stream = match self.connect() {
                        Ok(s) => s,
                        Err(e) => {
                            self.mark_unhealthy();
                            return Err(e);
                        }
                    };
                }
                Err(e @ Error::Io(_)) => {
                    self.mark_unhealthy();
                    return Err(e);
                }
                Err(e) => {
                    // Protocol error: the stream offset is unknowable,
                    // drop the connection but don't blame the shard's
                    // health — it answered, just not with protocol.
                    return Err(e);
                }
            }
        }
    }

    fn mark_healthy(&self) {
        self.healthy.store(true, Ordering::SeqCst);
        self.misses.store(0, Ordering::SeqCst);
        self.skip_ticks.store(0, Ordering::SeqCst);
    }

    fn mark_unhealthy(&self) {
        self.healthy.store(false, Ordering::SeqCst);
        // Drop pooled sockets — they point at a dead peer.
        lock_unpoisoned(&self.pool).clear();
    }

    /// One heartbeat tick: probe if due, honouring the backoff
    /// schedule for unhealthy shards. `nonce` must be echoed back.
    fn heartbeat_tick(&self, nonce: u64) {
        if !self.is_healthy() {
            let skip = self.skip_ticks.load(Ordering::SeqCst);
            if skip > 0 {
                self.skip_ticks.store(skip - 1, Ordering::SeqCst);
                return;
            }
        }
        match self.call(&Msg::Heartbeat { nonce }) {
            Ok(Msg::HeartbeatAck { nonce: echoed }) if echoed == nonce => {}
            _ => {
                let misses = self.misses.fetch_add(1, Ordering::SeqCst) + 1;
                let exp = misses.min(MAX_BACKOFF_EXP);
                self.healthy.store(false, Ordering::SeqCst);
                self.skip_ticks.store((1 << exp) - 1, Ordering::SeqCst);
            }
        }
    }
}

fn exchange(stream: &mut TcpStream, msg: &Msg) -> Result<Msg> {
    msg.write_to(stream)?;
    match Msg::read_from(stream) {
        // A reply timeout is transport failure for routing purposes.
        Err(Error::Io(e)) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
            Err(Error::Io(std::io::Error::new(
                ErrorKind::TimedOut,
                "net: shard reply timed out",
            )))
        }
        other => other,
    }
}

/// TCP front door over N remote shards.
pub struct RemoteCoordinator {
    shards: Vec<Arc<RemoteShard>>,
    ring: HashRing,
    /// Router-side accounting: submitted/completed/rejected/failed of
    /// requests *through this router* (shard-side counters are
    /// aggregated separately by [`RemoteCoordinator::cluster_stats`]).
    stats: Arc<ServerStats>,
    failovers: Arc<AtomicU64>,
    hb_stop: Arc<AtomicBool>,
    hb_thread: Option<thread::JoinHandle<()>>,
}

impl RemoteCoordinator {
    /// Connect to `addrs` (one `host:port` per shard, ring order =
    /// list order). `connections` bounds the idle pool per shard;
    /// `heartbeat_ms` is the probe period (0 disables the heartbeat
    /// thread — health then updates only from request outcomes).
    pub fn connect(addrs: &[String], connections: usize, heartbeat_ms: u64) -> Result<RemoteCoordinator> {
        if addrs.is_empty() {
            return Err(Error::coordinator("net: no remote shards given"));
        }
        let ring = HashRing::new(addrs.len(), DEFAULT_VNODES)?;
        let shards: Vec<Arc<RemoteShard>> = addrs
            .iter()
            .map(|a| Arc::new(RemoteShard::new(a.clone(), connections.max(1))))
            .collect();
        let hb_stop = Arc::new(AtomicBool::new(false));
        let hb_thread = if heartbeat_ms == 0 {
            None
        } else {
            let shards = shards.clone();
            let stop = Arc::clone(&hb_stop);
            Some(thread::spawn(move || {
                // Health probing is advisory: contain panics so a
                // heartbeat bug degrades to request-outcome health
                // tracking instead of killing the router (r2).
                let _ = catch_unwind(AssertUnwindSafe(|| {
                    let mut nonce: u64 = 0;
                    while !stop.load(Ordering::SeqCst) {
                        for s in &shards {
                            nonce = nonce.wrapping_add(1);
                            s.heartbeat_tick(nonce);
                        }
                        thread::sleep(Duration::from_millis(heartbeat_ms));
                    }
                }));
            }))
        };
        Ok(RemoteCoordinator {
            shards,
            ring,
            stats: Arc::new(ServerStats::new()),
            failovers: Arc::new(AtomicU64::new(0)),
            hb_stop,
            hb_thread,
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Health bits in shard order (heartbeat + request-outcome view).
    pub fn healthy_shards(&self) -> Vec<bool> {
        self.shards.iter().map(|s| s.is_healthy()).collect()
    }

    /// Requests that were transparently retried on another shard
    /// after a transport failure.
    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    /// The shard that owns `features` — identical routing to the
    /// in-process `ShardedCoordinator::shard_for_features`.
    pub fn shard_for_features(&self, features: &[bool]) -> usize {
        self.ring.shard_for_hash(hash_features(features))
    }

    /// Route one inference: owner shard first, deterministic ring-walk
    /// failover on transport errors, rejection/failure propagated from
    /// the first shard that *answers*.
    pub fn infer(&self, features: &[bool], backend: Backend) -> Result<InferResponse> {
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let walk = self.ring.walk_from_hash(hash_features(features));
        // Healthy shards first (in walk order), then the unhealthy
        // rest: when everything is marked down we still try the full
        // walk rather than refusing outright — a recovered shard gets
        // found by the request itself, not only by the next heartbeat.
        let in_walk = |healthy: bool| {
            walk.iter()
                .filter_map(|&i| self.shards.get(i))
                .filter(move |s| s.is_healthy() == healthy)
        };
        let ordered: Vec<&Arc<RemoteShard>> = in_walk(true).chain(in_walk(false)).collect();
        let req = Msg::InferRequest {
            backend: backend.name().to_string(),
            features: features.to_vec(),
        };
        let mut first_err: Option<Error> = None;
        for (attempt, shard) in ordered.iter().enumerate() {
            if attempt > 0 {
                self.failovers.fetch_add(1, Ordering::Relaxed);
            }
            match shard.call(&req) {
                Ok(Msg::InferResponse { backend, predicted, class_sums, service_us }) => {
                    let backend = Backend::parse(&backend).ok_or_else(|| {
                        self.stats.failed.fetch_add(1, Ordering::Relaxed);
                        Error::coordinator(format!("net: shard replied with unknown backend {backend:?}"))
                    })?;
                    self.stats.completed.fetch_add(1, Ordering::Relaxed);
                    self.stats.record_latency_us(service_us);
                    return Ok(InferResponse {
                        backend,
                        predicted: predicted as usize,
                        class_sums,
                        hw_latency: None,
                        hw_energy_fj: None,
                        service_us,
                    });
                }
                Ok(Msg::Reject { reason }) => {
                    // Backpressure is an answer, not an outage.
                    self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::coordinator(reason));
                }
                Ok(Msg::Failed { reason }) => {
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::coordinator(reason));
                }
                Ok(other) => {
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::coordinator(format!(
                        "net: unexpected reply to inference: {other:?}"
                    )));
                }
                Err(Error::Io(e)) => {
                    // Transport failure: walk on. call() already
                    // marked the shard unhealthy.
                    first_err.get_or_insert(Error::Io(e));
                }
                Err(e) => {
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    return Err(e);
                }
            }
        }
        self.stats.failed.fetch_add(1, Ordering::Relaxed);
        Err(first_err.unwrap_or_else(|| Error::coordinator("net: all shards unreachable")))
    }

    /// Router-side counters (requests routed through this process).
    pub fn router_stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Aggregate shard-side stats across the cluster: counters summed,
    /// latency/batch percentiles rebuilt from the raw sample rings
    /// shipped over the wire — exact, like `ShardedCoordinator::stats`.
    /// Errors if any shard is unreachable (partial sums would silently
    /// break the conservation checks the stats exist to support).
    pub fn cluster_stats(&self) -> Result<StatsSnapshot> {
        let mut snap = StatsSnapshot {
            submitted: 0,
            completed: 0,
            rejected: 0,
            failed: 0,
            batches_flushed: 0,
            batched_requests: 0,
            mean_batch_size: 0.0,
            latency_us: None,
        };
        let mut latencies = Vec::new();
        let mut batch_sizes = Vec::new();
        for shard in &self.shards {
            match shard.call(&Msg::StatsRequest)? {
                Msg::StatsReply {
                    submitted,
                    completed,
                    rejected,
                    failed,
                    batches_flushed,
                    batched_requests,
                    latency_samples,
                    batch_size_samples,
                } => {
                    snap.submitted += submitted;
                    snap.completed += completed;
                    snap.rejected += rejected;
                    snap.failed += failed;
                    snap.batches_flushed += batches_flushed;
                    snap.batched_requests += batched_requests;
                    latencies.extend(latency_samples);
                    batch_sizes.extend(batch_size_samples);
                }
                other => {
                    return Err(Error::coordinator(format!(
                        "net: unexpected reply to stats request: {other:?}"
                    )))
                }
            }
        }
        snap.mean_batch_size = Summary::of(&batch_sizes).map(|s| s.mean).unwrap_or(0.0);
        snap.latency_us = Summary::of(&latencies);
        Ok(snap)
    }

    /// Gracefully drain every reachable shard (each acks and stops
    /// accepting). Returns the number of shards that acked.
    pub fn drain(&self) -> usize {
        let mut acked = 0;
        for shard in &self.shards {
            if matches!(shard.call(&Msg::Drain), Ok(Msg::DrainAck)) {
                acked += 1;
            }
        }
        acked
    }

    /// Stop the heartbeat thread and drop the connection pools.
    pub fn shutdown(mut self) {
        self.stop_heartbeat();
    }

    fn stop_heartbeat(&mut self) {
        self.hb_stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.hb_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RemoteCoordinator {
    fn drop(&mut self) {
        self.stop_heartbeat();
    }
}
