//! The coordinator server: one front door over all backends.
//!
//! * Golden requests → dynamic batcher thread → PJRT golden service
//!   (thread-pinned runtime).
//! * Bit-parallel requests → dynamic batcher thread → shared
//!   `Send + Sync` packed-word engines ([`crate::tm::fast_infer`]),
//!   with large flushes sharded across scoped threads. No artifacts
//!   needed — this tier is always available.
//! * Hardware-model requests → worker pool; each worker owns its own six
//!   architecture instances built from the trained models.
//! * Bounded in-flight budget; excess submissions are rejected
//!   immediately (backpressure).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::arch::digital::{
    async_bd_cotm, async_bd_multiclass, sync_cotm, sync_multiclass, DigitalCotm,
    DigitalMulticlass,
};
use crate::arch::proposed_cotm::ProposedCotm;
use crate::arch::proposed_tm::ProposedMulticlass;
use crate::arch::Architecture;
use crate::config::ServeConfig;
use crate::coordinator::batcher::{DynamicBatcher, Pending};
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::router::{Backend, InferRequest, InferResponse};
use crate::coordinator::stats::{ServerStats, StatsSnapshot};
use crate::error::{Error, Result};
use crate::runtime::golden::{GoldenModels, GoldenService};
use crate::tm::fast_infer::{BatchEngine, BitParallelCotm, BitParallelMulticlass};
use crate::tm::{CoTmModel, MultiClassTmModel};

/// Per-worker architecture set (lives inside its worker thread; the
/// architectures embed `Rc` state and are deliberately not `Send`).
pub struct WorkerState {
    sync_mc: DigitalMulticlass,
    async_mc: DigitalMulticlass,
    proposed_mc: ProposedMulticlass,
    sync_co: DigitalCotm,
    async_co: DigitalCotm,
    proposed_co: ProposedCotm,
}

impl WorkerState {
    fn arch(&mut self, b: Backend) -> &mut dyn Architecture {
        match b {
            Backend::SyncMulticlass => &mut self.sync_mc,
            Backend::AsyncBdMulticlass => &mut self.async_mc,
            Backend::ProposedMulticlass => &mut self.proposed_mc,
            Backend::SyncCotm => &mut self.sync_co,
            Backend::AsyncBdCotm => &mut self.async_co,
            Backend::ProposedCotm => &mut self.proposed_co,
            _ => unreachable!("golden and bit-parallel backends are batched, not pooled"),
        }
    }
}

/// A request travelling to the golden batcher.
struct GoldenItem {
    features: Vec<f32>,
}

/// A request travelling to a bit-parallel batcher.
struct BitParItem {
    features: Vec<bool>,
}

/// Build the dynamic batcher for one bit-parallel engine: each flush is
/// evaluated through the shared engine's bit-sliced batch path, sharded
/// across up to `shard_threads` scoped threads when the batch is large
/// (the engine is `Sync`, so shards borrow it without copying).
///
/// Replies are relay-free: the flush builds the final [`InferResponse`]
/// per item with latency/completed accounting inline, and the batcher
/// releases the in-flight slots (panic-safely) — so the receiver
/// handed back by `submit()` is the caller's own channel, with no
/// per-request forwarder thread.
fn bitpar_batcher<E: BatchEngine + Send + 'static>(
    engine: Arc<E>,
    backend: Backend,
    max_batch: usize,
    timeout: Duration,
    stats: Arc<ServerStats>,
    in_flight: Arc<AtomicU64>,
    shard_threads: usize,
) -> Result<DynamicBatcher<BitParItem, InferResponse>> {
    DynamicBatcher::new(
        max_batch,
        timeout,
        Arc::clone(&stats),
        in_flight,
        move |batch: &[Pending<BitParItem, InferResponse>]| {
            let rows: Vec<&[bool]> = batch.iter().map(|p| p.item.features.as_slice()).collect();
            let out = engine.infer_batch_sharded(&rows, shard_threads);
            // Guard the arity *before* any success counting, like the
            // golden path: a short engine result must fail the whole
            // batch, not count truncated items as completed.
            if out.len() != batch.len() {
                stats.failed.fetch_add(batch.len() as u64, Ordering::Relaxed);
                let msg = format!(
                    "bit-parallel engine returned {} results for {} inputs",
                    out.len(),
                    batch.len()
                );
                return batch.iter().map(|_| Err(Error::coordinator(msg.clone()))).collect();
            }
            batch
                .iter()
                .zip(out)
                .map(|(p, (class_sums, predicted))| {
                    let service_us = p.elapsed_us();
                    stats.record_latency_us(service_us);
                    stats.completed.fetch_add(1, Ordering::Relaxed);
                    Ok(InferResponse {
                        backend,
                        predicted,
                        class_sums,
                        hw_latency: None,
                        hw_energy_fj: None,
                        service_us,
                    })
                })
                .collect()
        },
    )
}

/// The coordinator server.
pub struct CoordinatorServer {
    pool: Option<WorkerPool<WorkerState>>,
    /// Keeps the PJRT thread alive for the batchers' clients.
    _golden: Option<GoldenService>,
    /// One batcher per golden family (they hit different artifacts).
    batcher_mc: Option<DynamicBatcher<GoldenItem, InferResponse>>,
    batcher_co: Option<DynamicBatcher<GoldenItem, InferResponse>>,
    /// One batcher per bit-parallel engine (always available).
    batcher_bp_mc: Option<DynamicBatcher<BitParItem, InferResponse>>,
    batcher_bp_co: Option<DynamicBatcher<BitParItem, InferResponse>>,
    stats: Arc<ServerStats>,
    in_flight: Arc<AtomicU64>,
    queue_depth: u64,
    features: usize,
}

impl CoordinatorServer {
    /// Build the server. `golden` is optional: without artifacts on disk
    /// the golden backends report errors but the simulated backends work.
    pub fn new(
        cfg: &ServeConfig,
        mc_model: MultiClassTmModel,
        cotm_model: CoTmModel,
        with_golden: bool,
    ) -> Result<CoordinatorServer> {
        cfg.validate()?;
        let features = mc_model.params.features;
        if cotm_model.params.features != features {
            return Err(Error::coordinator("model feature widths differ"));
        }
        let stats = Arc::new(ServerStats::new());
        let in_flight = Arc::new(AtomicU64::new(0));

        // Worker pool: each worker builds its own architecture set.
        let wta = cfg.wta;
        let mc = mc_model.clone();
        let co = cotm_model.clone();
        let pool = WorkerPool::new(cfg.workers, move |_i| WorkerState {
            sync_mc: sync_multiclass(mc.clone()),
            async_mc: async_bd_multiclass(mc.clone()),
            proposed_mc: ProposedMulticlass::new(mc.clone(), wta)
                .expect("valid multiclass model"),
            sync_co: sync_cotm(co.clone()),
            async_co: async_bd_cotm(co.clone()),
            proposed_co: ProposedCotm::new(co.clone(), wta).expect("valid cotm model"),
        })?;

        // Bit-parallel path: one shared Send+Sync engine per family
        // (compiled once from the trained models — no per-worker
        // rebuild), each behind its own dynamic batcher.
        let timeout = Duration::from_micros(cfg.batch_timeout_us);
        let shard_threads = cfg.workers.max(1);
        let batcher_bp_mc = bitpar_batcher(
            Arc::new(BitParallelMulticlass::from_model(&mc_model)?),
            Backend::BitParallelMulticlass,
            cfg.max_batch,
            timeout,
            Arc::clone(&stats),
            Arc::clone(&in_flight),
            shard_threads,
        )?;
        let batcher_bp_co = bitpar_batcher(
            Arc::new(BitParallelCotm::from_model(&cotm_model)?),
            Backend::BitParallelCotm,
            cfg.max_batch,
            timeout,
            Arc::clone(&stats),
            Arc::clone(&in_flight),
            shard_threads,
        )?;

        // Golden path: one PJRT service thread + a batcher per family.
        // Same relay-free shape as the bit-parallel path: the flush
        // builds the final responses and settles the accounting.
        let (golden, batcher_mc, batcher_co) = if with_golden {
            let svc = GoldenService::spawn(
                cfg.artifacts_dir.clone(),
                GoldenModels {
                    multiclass_include: mc_model.include_f32(),
                    cotm_include: cotm_model.include_f32(),
                    cotm_weights: cotm_model.weights_f32(),
                },
            )?;
            let mk = |backend: Backend,
                      client: crate::runtime::golden::GoldenClient,
                      stats: Arc<ServerStats>,
                      in_flight: Arc<AtomicU64>| {
                let family = backend.family().expect("golden backend has a family");
                DynamicBatcher::new(
                    cfg.max_batch,
                    timeout,
                    Arc::clone(&stats),
                    in_flight,
                    move |batch: &[Pending<GoldenItem, InferResponse>]| {
                        let rows: Vec<Vec<f32>> =
                            batch.iter().map(|p| p.item.features.clone()).collect();
                        // Guard the arity *before* any success counting:
                        // a short artifact reply must fail the whole
                        // batch, not count truncated items as completed.
                        match client.infer_batch(family, rows) {
                            Ok(out) if out.len() == batch.len() => batch
                                .iter()
                                .zip(out)
                                .map(|(p, (sums, predicted))| {
                                    let service_us = p.elapsed_us();
                                    stats.record_latency_us(service_us);
                                    stats.completed.fetch_add(1, Ordering::Relaxed);
                                    Ok(InferResponse {
                                        backend,
                                        predicted,
                                        class_sums: sums
                                            .iter()
                                            .map(|&x| x as i32)
                                            .collect(),
                                        hw_latency: None,
                                        hw_energy_fj: None,
                                        service_us,
                                    })
                                })
                                .collect(),
                            Ok(out) => {
                                stats
                                    .failed
                                    .fetch_add(batch.len() as u64, Ordering::Relaxed);
                                let msg = format!(
                                    "golden: artifact returned {} results for {} inputs",
                                    out.len(),
                                    batch.len()
                                );
                                batch
                                    .iter()
                                    .map(|_| Err(Error::coordinator(msg.clone())))
                                    .collect()
                            }
                            Err(e) => {
                                stats
                                    .failed
                                    .fetch_add(batch.len() as u64, Ordering::Relaxed);
                                batch
                                    .iter()
                                    .map(|_| {
                                        Err(Error::coordinator(format!("golden: {e}")))
                                    })
                                    .collect()
                            }
                        }
                    },
                )
            };
            let b_mc = mk(
                Backend::GoldenMulticlass,
                svc.client(),
                Arc::clone(&stats),
                Arc::clone(&in_flight),
            )?;
            let b_co = mk(
                Backend::GoldenCotm,
                svc.client(),
                Arc::clone(&stats),
                Arc::clone(&in_flight),
            )?;
            (Some(svc), Some(b_mc), Some(b_co))
        } else {
            (None, None, None)
        };

        Ok(CoordinatorServer {
            pool: Some(pool),
            _golden: golden,
            batcher_mc,
            batcher_co,
            batcher_bp_mc: Some(batcher_bp_mc),
            batcher_bp_co: Some(batcher_bp_co),
            stats,
            in_flight,
            queue_depth: cfg.queue_depth as u64,
            features,
        })
    }

    /// Submit a request; returns a receiver for the response.
    /// Fails fast with a backpressure error when the in-flight budget is
    /// exhausted.
    pub fn submit(&self, req: InferRequest) -> Result<mpsc::Receiver<Result<InferResponse>>> {
        if req.features.len() != self.features {
            return Err(Error::coordinator(format!(
                "feature width {} != {}",
                req.features.len(),
                self.features
            )));
        }
        // Backpressure gate.
        let inflight = self.in_flight.fetch_add(1, Ordering::SeqCst);
        if inflight >= self.queue_depth {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(Error::coordinator("backpressure: queue depth exceeded"));
        }
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();

        if req.backend.is_golden() {
            // Relay-free: the receiver comes straight from the batcher;
            // its flush built the final response and did the accounting.
            let batcher = match req.backend {
                Backend::GoldenMulticlass => self.batcher_mc.as_ref(),
                _ => self.batcher_co.as_ref(),
            }
            .ok_or_else(|| {
                self.abort_submit(Error::coordinator("golden path disabled (no artifacts)"))
            })?;
            let item = GoldenItem {
                features: req.features.iter().map(|&b| b as u8 as f32).collect(),
            };
            batcher.submit(item).map_err(|e| self.abort_submit(e))
        } else if req.backend.is_bit_parallel() {
            let batcher = match req.backend {
                Backend::BitParallelMulticlass => self.batcher_bp_mc.as_ref(),
                _ => self.batcher_bp_co.as_ref(),
            }
            .ok_or_else(|| {
                self.abort_submit(Error::coordinator("bit-parallel batcher shut down"))
            })?;
            batcher
                .submit(BitParItem { features: req.features })
                .map_err(|e| self.abort_submit(e))
        } else {
            let (tx, rx) = mpsc::channel();
            let stats = Arc::clone(&self.stats);
            let in_flight = Arc::clone(&self.in_flight);
            let backend = req.backend;
            let features = req.features;
            self.pool
                .as_ref()
                .ok_or_else(|| self.abort_submit(Error::coordinator("pool shut down")))?
                .submit(Box::new(move |state: &mut WorkerState| {
                    let result = state
                        .arch(backend)
                        .infer(&features)
                        .map(|r| {
                            let service_us = t0.elapsed().as_secs_f64() * 1e6;
                            stats.record_latency_us(service_us);
                            stats.completed.fetch_add(1, Ordering::Relaxed);
                            InferResponse {
                                backend,
                                predicted: r.predicted,
                                class_sums: r.class_sums,
                                hw_latency: Some(r.latency),
                                hw_energy_fj: Some(r.energy_fj),
                                service_us,
                            }
                        })
                        .map_err(|e| {
                            stats.failed.fetch_add(1, Ordering::Relaxed);
                            e
                        });
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    let _ = tx.send(result);
                }))
                .map_err(|e| self.abort_submit(e))?;
            Ok(rx)
        }
    }

    /// Undo the in-flight/submitted accounting for a request that
    /// errored out of `submit()` after passing the backpressure gate —
    /// without this, each such error permanently consumes a slot of
    /// `queue_depth` and breaks `submitted == completed + failed`.
    fn abort_submit(&self, e: Error) -> Error {
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
        self.stats.failed.fetch_add(1, Ordering::Relaxed);
        e
    }

    /// Submit and block for the response.
    pub fn infer(&self, req: InferRequest) -> Result<InferResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| Error::coordinator("response channel closed"))?
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Shared handle to the raw counters — used by the sharded front
    /// door ([`crate::coordinator::shard`]) to aggregate exact latency
    /// summaries across shards without copying snapshots.
    pub fn stats_handle(&self) -> Arc<ServerStats> {
        Arc::clone(&self.stats)
    }

    /// Graceful shutdown: drain workers and batchers.
    pub fn shutdown(mut self) {
        if let Some(p) = self.pool.take() {
            p.shutdown();
        }
        if let Some(b) = self.batcher_mc.take() {
            b.shutdown();
        }
        if let Some(b) = self.batcher_co.take() {
            b.shutdown();
        }
        if let Some(b) = self.batcher_bp_mc.take() {
            b.shutdown();
        }
        if let Some(b) = self.batcher_bp_co.take() {
            b.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};

    fn server(with_golden: bool, cfg: Option<ServeConfig>) -> (CoordinatorServer, data::Dataset) {
        let d = data::iris().unwrap();
        let (tr, _) = d.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
        let cfg = cfg.unwrap_or(ServeConfig { workers: 2, ..ServeConfig::default() });
        (
            CoordinatorServer::new(&cfg, m, cm, with_golden).unwrap(),
            d,
        )
    }

    #[test]
    fn serves_all_simulated_backends() {
        let (srv, d) = server(false, None);
        for b in [
            Backend::SyncMulticlass,
            Backend::AsyncBdMulticlass,
            Backend::ProposedMulticlass,
            Backend::SyncCotm,
            Backend::AsyncBdCotm,
            Backend::ProposedCotm,
        ] {
            let r = srv
                .infer(InferRequest { features: d.features[0].clone(), backend: b })
                .unwrap();
            assert_eq!(r.backend, b);
            assert!(r.hw_latency.is_some());
            assert!(r.hw_energy_fj.unwrap() > 0.0);
        }
        assert_eq!(srv.stats().completed, 6);
        srv.shutdown();
    }

    #[test]
    fn bitparallel_backends_serve_without_artifacts() {
        // The bit-parallel tier needs no AOT artifacts: it must serve
        // even when the golden path is disabled, and its sums must be
        // bit-exact against the software reference.
        let (srv, d) = server(false, None);
        let dset = data::iris().unwrap();
        let (tr, _) = dset.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
        for i in [0usize, 17, 80, 149] {
            let r = srv
                .infer(InferRequest {
                    features: d.features[i].clone(),
                    backend: Backend::BitParallelMulticlass,
                })
                .unwrap();
            assert_eq!(r.backend, Backend::BitParallelMulticlass);
            assert!(r.hw_latency.is_none(), "native path has no hw model");
            assert_eq!(
                r.class_sums,
                crate::tm::infer::multiclass_class_sums(&m, &d.features[i]),
                "sample {i}"
            );
            let r = srv
                .infer(InferRequest {
                    features: d.features[i].clone(),
                    backend: Backend::BitParallelCotm,
                })
                .unwrap();
            assert_eq!(
                r.class_sums,
                crate::tm::infer::cotm_class_sums(&cm, &d.features[i]),
                "sample {i}"
            );
        }
        srv.shutdown();
    }

    #[test]
    fn bitparallel_concurrent_submissions_are_batched_and_exact() {
        // Generous flush timeout so coalescing is deterministic even on
        // a slow machine (flush-on-size dominates).
        let cfg = ServeConfig {
            workers: 2,
            max_batch: 32,
            batch_timeout_us: 50_000,
            ..ServeConfig::default()
        };
        let (srv, d) = server(false, Some(cfg));
        let dset = data::iris().unwrap();
        let (tr, _) = dset.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let rxs: Vec<_> = (0..100)
            .map(|i| {
                (
                    i,
                    srv.submit(InferRequest {
                        features: d.features[i % d.len()].clone(),
                        backend: Backend::BitParallelMulticlass,
                    })
                    .unwrap(),
                )
            })
            .collect();
        for (i, rx) in rxs {
            let r = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .unwrap()
                .unwrap();
            let want =
                crate::tm::infer::multiclass_class_sums(&m, &d.features[i % d.len()]);
            assert_eq!(r.class_sums, want, "request {i}");
            assert_eq!(r.predicted, crate::tm::infer::predict_argmax(&want));
        }
        // The dynamic batcher actually coalesced (not 100 singletons).
        let snap = srv.stats();
        assert!(snap.batches_flushed < 100, "batches={}", snap.batches_flushed);
        assert_eq!(snap.completed, 100);
        srv.shutdown();
    }

    #[test]
    fn golden_disabled_errors_cleanly() {
        let (srv, d) = server(false, None);
        let err = srv
            .infer(InferRequest {
                features: d.features[0].clone(),
                backend: Backend::GoldenCotm,
            })
            .unwrap_err();
        assert!(err.to_string().contains("golden path disabled"), "{err}");
        srv.shutdown();
    }

    #[test]
    fn rejects_wrong_feature_width() {
        let (srv, _) = server(false, None);
        assert!(srv
            .submit(InferRequest { features: vec![true; 3], backend: Backend::SyncCotm })
            .is_err());
        srv.shutdown();
    }

    #[test]
    fn backpressure_rejects_beyond_queue_depth() {
        let cfg = ServeConfig {
            workers: 1,
            queue_depth: 16,
            max_batch: 16,
            ..ServeConfig::default()
        };
        let (srv, d) = server(false, Some(cfg));
        let mut receivers = Vec::new();
        let mut rejected = 0;
        for i in 0..200 {
            match srv.submit(InferRequest {
                features: d.features[i % d.len()].clone(),
                backend: Backend::ProposedCotm,
            }) {
                Ok(rx) => receivers.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        for rx in receivers {
            let _ = rx.recv_timeout(std::time::Duration::from_secs(30));
        }
        assert_eq!(srv.stats().rejected as usize, rejected);
        srv.shutdown();
    }

    #[test]
    fn concurrent_mixed_load_is_consistent() {
        let (srv, d) = server(false, None);
        let mut receivers = Vec::new();
        for i in 0..30 {
            let backend = if i % 2 == 0 {
                Backend::AsyncBdMulticlass
            } else {
                Backend::ProposedMulticlass
            };
            receivers.push((
                i,
                srv.submit(InferRequest {
                    features: d.features[i % d.len()].clone(),
                    backend,
                })
                .unwrap(),
            ));
        }
        for (i, rx) in receivers {
            let r = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .unwrap()
                .unwrap();
            // Both backends implement the same model: sums must agree
            // with the software reference.
            let want = crate::tm::infer::multiclass_class_sums(
                &{
                    let dset = data::iris().unwrap();
                    let (tr, _) = dset.split(0.8, 42);
                    train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap()
                },
                &d.features[i % d.len()],
            );
            assert_eq!(r.class_sums, want, "request {i}");
        }
        srv.shutdown();
    }
}
