//! The coordinator server: one front door over all backends.
//!
//! * Golden requests → dynamic batcher thread → PJRT golden service
//!   (thread-pinned runtime).
//! * Native batched requests → dynamic batcher thread → shared
//!   `Send + Sync` engines, with large flushes sharded across scoped
//!   threads. Three engine families, no artifacts needed — this tier is
//!   always available: the packed bit-parallel engines
//!   ([`crate::tm::fast_infer`], dense models), the event-driven
//!   inverted-index engines ([`crate::tm::index`], extremely sparse
//!   models) and the compressed include-list engines
//!   ([`crate::tm::compressed`], the moderately sparse ETHEREAL
//!   regime). The `auto-*` backends resolve to one of the three per
//!   compiled model by included-literal density
//!   (`ServeConfig.indexed_density_threshold` /
//!   `compressed_density_threshold`); responses report the concrete
//!   backend that served them.
//! * Hardware-model requests → worker pool; each worker owns its own six
//!   architecture instances built from the trained models.
//! * Bounded in-flight budget; excess submissions are rejected
//!   immediately (backpressure).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::arch::digital::{
    async_bd_cotm, async_bd_multiclass, sync_cotm, sync_multiclass, DigitalCotm,
    DigitalMulticlass,
};
use crate::arch::proposed_cotm::ProposedCotm;
use crate::arch::proposed_tm::ProposedMulticlass;
use crate::arch::Architecture;
use crate::config::ServeConfig;
use crate::coordinator::batcher::{DynamicBatcher, Pending};
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::router::{Backend, InferRequest, InferResponse};
use crate::coordinator::stats::{ServerStats, StatsSnapshot};
use crate::error::{Error, Result};
use crate::runtime::golden::{GoldenModels, GoldenService};
use crate::tm::compile::{CompileMode, CompiledCotm, CompiledMulticlass, ModelCompiler};
use crate::tm::compressed::{select_engine, CompressedCotm, CompressedMulticlass, EngineChoice};
use crate::tm::fast_infer::{BatchEngine, BitParallelCotm, BitParallelMulticlass};
use crate::tm::index::{IndexedCotm, IndexedMulticlass};
use crate::tm::simd::WordLanes;
use crate::tm::{CoTmModel, MultiClassTmModel};

/// Per-worker architecture set (lives inside its worker thread; the
/// architectures embed `Rc` state and are deliberately not `Send`).
pub struct WorkerState {
    sync_mc: DigitalMulticlass,
    async_mc: DigitalMulticlass,
    proposed_mc: ProposedMulticlass,
    sync_co: DigitalCotm,
    async_co: DigitalCotm,
    proposed_co: ProposedCotm,
}

impl WorkerState {
    fn arch(&mut self, b: Backend) -> &mut dyn Architecture {
        match b {
            Backend::SyncMulticlass => &mut self.sync_mc,
            Backend::AsyncBdMulticlass => &mut self.async_mc,
            Backend::ProposedMulticlass => &mut self.proposed_mc,
            Backend::SyncCotm => &mut self.sync_co,
            Backend::AsyncBdCotm => &mut self.async_co,
            Backend::ProposedCotm => &mut self.proposed_co,
            _ => unreachable!("golden and native backends are batched, not pooled"),
        }
    }
}

/// Synthetic calibration batch shape for `compile = "full"` when no
/// real traffic sample is available at startup (reordering is
/// output-invariant, so these only steer speed, never sums).
const CALIB_SAMPLES: usize = 256;
const CALIB_SEED: u64 = 7;

/// A request travelling to the golden batcher.
struct GoldenItem {
    features: Vec<f32>,
}

/// A request travelling to a native-engine batcher (bit-parallel,
/// inverted-index or compressed).
struct NativeItem {
    features: Vec<bool>,
}

/// Build the dynamic batcher for one native engine (packed
/// bit-parallel, event-driven inverted-index or compressed
/// include-list — anything implementing [`BatchEngine`]): each flush is evaluated through the shared
/// engine's batch path, sharded across up to `shard_threads` scoped
/// threads when the batch is large (the engine is `Sync`, so shards
/// borrow it without copying).
///
/// Replies are relay-free: the flush builds the final [`InferResponse`]
/// per item with latency/completed accounting inline, and the batcher
/// releases the in-flight slots (panic-safely) — so the receiver
/// handed back by `submit()` is the caller's own channel, with no
/// per-request forwarder thread.
fn native_batcher<E: BatchEngine + Send + 'static>(
    engine: Arc<E>,
    backend: Backend,
    max_batch: usize,
    timeout: Duration,
    stats: Arc<ServerStats>,
    in_flight: Arc<AtomicU64>,
    shard_threads: usize,
) -> Result<DynamicBatcher<NativeItem, InferResponse>> {
    DynamicBatcher::new(
        max_batch,
        timeout,
        Arc::clone(&stats),
        in_flight,
        move |batch: &[Pending<NativeItem, InferResponse>]| {
            let rows: Vec<&[bool]> = batch.iter().map(|p| p.item.features.as_slice()).collect();
            let out = engine.infer_batch_sharded(&rows, shard_threads);
            // Guard the arity *before* any success counting, like the
            // golden path: a short engine result must fail the whole
            // batch, not count truncated items as completed.
            if out.len() != batch.len() {
                stats.failed.fetch_add(batch.len() as u64, Ordering::Relaxed);
                let msg = format!(
                    "native engine returned {} results for {} inputs",
                    out.len(),
                    batch.len()
                );
                return batch.iter().map(|_| Err(Error::coordinator(msg.clone()))).collect();
            }
            batch
                .iter()
                .zip(out)
                .map(|(p, (class_sums, predicted))| {
                    let service_us = p.elapsed_us();
                    stats.record_latency_us(service_us);
                    stats.completed.fetch_add(1, Ordering::Relaxed);
                    Ok(InferResponse {
                        backend,
                        predicted,
                        class_sums,
                        hw_latency: None,
                        hw_energy_fj: None,
                        service_us,
                    })
                })
                .collect()
        },
    )
}

/// The always-available native serving tier built from compiled
/// artifacts: six batchers (three engine families x two model
/// families) plus the per-model `auto-*` resolutions. Both
/// [`CoordinatorServer::new`] (compile-at-build) and
/// [`CoordinatorServer::from_compiled_artifacts`] (pinned `.tmc`
/// artifacts, the networked shard path) build through this.
struct NativeTier {
    batcher_bp_mc: DynamicBatcher<NativeItem, InferResponse>,
    batcher_bp_co: DynamicBatcher<NativeItem, InferResponse>,
    batcher_ix_mc: DynamicBatcher<NativeItem, InferResponse>,
    batcher_ix_co: DynamicBatcher<NativeItem, InferResponse>,
    batcher_cp_mc: DynamicBatcher<NativeItem, InferResponse>,
    batcher_cp_co: DynamicBatcher<NativeItem, InferResponse>,
    auto_mc: Backend,
    auto_co: Backend,
}

fn build_native_tier(
    cfg: &ServeConfig,
    compiled_mc: &CompiledMulticlass,
    compiled_co: &CompiledCotm,
    simd: WordLanes,
    stats: &Arc<ServerStats>,
    in_flight: &Arc<AtomicU64>,
) -> Result<NativeTier> {
    let timeout = Duration::from_micros(cfg.batch_timeout_us);
    let shard_threads = cfg.workers.max(1);
    let batcher_bp_mc = native_batcher(
        Arc::new(BitParallelMulticlass::from_compiled(compiled_mc)?.with_lanes(simd)),
        Backend::BitParallelMulticlass,
        cfg.max_batch,
        timeout,
        Arc::clone(stats),
        Arc::clone(in_flight),
        shard_threads,
    )?;
    let batcher_bp_co = native_batcher(
        Arc::new(BitParallelCotm::from_compiled(compiled_co)?.with_lanes(simd)),
        Backend::BitParallelCotm,
        cfg.max_batch,
        timeout,
        Arc::clone(stats),
        Arc::clone(in_flight),
        shard_threads,
    )?;
    let ix_mc = Arc::new(IndexedMulticlass::from_compiled(compiled_mc)?);
    let ix_co = Arc::new(IndexedCotm::from_compiled(compiled_co)?);
    let cp_mc = Arc::new(CompressedMulticlass::from_compiled(compiled_mc)?);
    let cp_co = Arc::new(CompressedCotm::from_compiled(compiled_co)?);
    // Resolve `auto-*` per compiled model with the three-way density
    // decision: extremely sparse models go through the inverted index,
    // moderately sparse ones through the compressed include-list walk,
    // dense ones through the packed words. The density comes from the
    // compile-pass stats, so dead clauses never dilute the crossover.
    // The choice can only affect speed — all three engine families are
    // held to the same bit-exactness bar by the conformance suite.
    let auto_mc = match select_engine(
        compiled_mc.stats.density,
        cfg.indexed_density_threshold,
        cfg.compressed_density_threshold,
    ) {
        EngineChoice::Indexed => Backend::IndexedMulticlass,
        EngineChoice::Compressed => Backend::CompressedMulticlass,
        EngineChoice::Packed => Backend::BitParallelMulticlass,
    };
    let auto_co = match select_engine(
        compiled_co.stats.density,
        cfg.indexed_density_threshold,
        cfg.compressed_density_threshold,
    ) {
        EngineChoice::Indexed => Backend::IndexedCotm,
        EngineChoice::Compressed => Backend::CompressedCotm,
        EngineChoice::Packed => Backend::BitParallelCotm,
    };
    let batcher_ix_mc = native_batcher(
        ix_mc,
        Backend::IndexedMulticlass,
        cfg.max_batch,
        timeout,
        Arc::clone(stats),
        Arc::clone(in_flight),
        shard_threads,
    )?;
    let batcher_ix_co = native_batcher(
        ix_co,
        Backend::IndexedCotm,
        cfg.max_batch,
        timeout,
        Arc::clone(stats),
        Arc::clone(in_flight),
        shard_threads,
    )?;
    let batcher_cp_mc = native_batcher(
        cp_mc,
        Backend::CompressedMulticlass,
        cfg.max_batch,
        timeout,
        Arc::clone(stats),
        Arc::clone(in_flight),
        shard_threads,
    )?;
    let batcher_cp_co = native_batcher(
        cp_co,
        Backend::CompressedCotm,
        cfg.max_batch,
        timeout,
        Arc::clone(stats),
        Arc::clone(in_flight),
        shard_threads,
    )?;
    Ok(NativeTier {
        batcher_bp_mc,
        batcher_bp_co,
        batcher_ix_mc,
        batcher_ix_co,
        batcher_cp_mc,
        batcher_cp_co,
        auto_mc,
        auto_co,
    })
}

/// The coordinator server.
pub struct CoordinatorServer {
    pool: Option<WorkerPool<WorkerState>>,
    /// Keeps the PJRT thread alive for the batchers' clients.
    _golden: Option<GoldenService>,
    /// One batcher per golden family (they hit different artifacts).
    batcher_mc: Option<DynamicBatcher<GoldenItem, InferResponse>>,
    batcher_co: Option<DynamicBatcher<GoldenItem, InferResponse>>,
    /// One batcher per native engine (always available): packed
    /// bit-parallel, event-driven inverted-index and compressed
    /// include-list, per model family.
    batcher_bp_mc: Option<DynamicBatcher<NativeItem, InferResponse>>,
    batcher_bp_co: Option<DynamicBatcher<NativeItem, InferResponse>>,
    batcher_ix_mc: Option<DynamicBatcher<NativeItem, InferResponse>>,
    batcher_ix_co: Option<DynamicBatcher<NativeItem, InferResponse>>,
    batcher_cp_mc: Option<DynamicBatcher<NativeItem, InferResponse>>,
    batcher_cp_co: Option<DynamicBatcher<NativeItem, InferResponse>>,
    /// Per-model `auto-*` resolutions (a concrete native backend each),
    /// decided once at build time from included-literal density.
    auto_mc: Backend,
    auto_co: Backend,
    /// Lane width the packed engines evaluate through (resolved from
    /// `ServeConfig.simd` at build time).
    simd: WordLanes,
    stats: Arc<ServerStats>,
    in_flight: Arc<AtomicU64>,
    queue_depth: u64,
    features: usize,
}

/// Releases one in-flight slot exactly once, even when the job body
/// panics: a worker-pool job that dies mid-inference must not consume a
/// `queue_depth` slot forever (the batched paths already have this
/// guarantee from the batcher; this is the pooled path's counterpart).
/// A drop without `finish()` (the panic path) also counts the request
/// as failed, since no downstream layer exists to count it.
struct JobGuard {
    stats: Arc<ServerStats>,
    in_flight: Arc<AtomicU64>,
    done: bool,
}

impl JobGuard {
    fn new(stats: Arc<ServerStats>, in_flight: Arc<AtomicU64>) -> JobGuard {
        JobGuard { stats, in_flight, done: false }
    }

    /// Normal completion: release the slot; success/failure counting
    /// already happened inline.
    fn finish(mut self) {
        self.done = true;
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Drop for JobGuard {
    fn drop(&mut self) {
        if !self.done {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            self.stats.failed.fetch_add(1, Ordering::Relaxed);
        }
    }
}

impl CoordinatorServer {
    /// Build the server. `golden` is optional: without artifacts on disk
    /// the golden backends report errors but the simulated backends work.
    pub fn new(
        cfg: &ServeConfig,
        mc_model: MultiClassTmModel,
        cotm_model: CoTmModel,
        with_golden: bool,
    ) -> Result<CoordinatorServer> {
        cfg.validate()?;
        let features = mc_model.params.features;
        if cotm_model.params.features != features {
            return Err(Error::coordinator("model feature widths differ"));
        }
        let stats = Arc::new(ServerStats::new());
        let in_flight = Arc::new(AtomicU64::new(0));
        // Resolve the configured SIMD lane width once; a forced level
        // the host cannot run fails the build here, not mid-request.
        let simd = cfg.simd.resolve()?;

        // Probe-build the proposed architectures once on this thread so
        // an invalid model surfaces as a clean Err from `new()` instead
        // of an `expect` panic inside every worker thread (which the
        // pool would survive, but with workers dying at startup).
        ProposedMulticlass::new(mc_model.clone(), cfg.wta)?;
        ProposedCotm::new(cotm_model.clone(), cfg.wta)?;

        // Worker pool: each worker builds its own architecture set.
        let wta = cfg.wta;
        let mc = mc_model.clone();
        let co = cotm_model.clone();
        let pool = WorkerPool::new(cfg.workers, move |_i| WorkerState {
            sync_mc: sync_multiclass(mc.clone()),
            async_mc: async_bd_multiclass(mc.clone()),
            // Unreachable panics: the probe builds above proved these
            // constructions succeed for exactly these inputs.
            proposed_mc: ProposedMulticlass::new(mc.clone(), wta)
                .expect("valid multiclass model"),
            sync_co: sync_cotm(co.clone()),
            async_co: async_bd_cotm(co.clone()),
            proposed_co: ProposedCotm::new(co.clone(), wta).expect("valid cotm model"),
        })?;

        // Native batched path: the trained models go through the
        // model-compile pass exactly once (`cfg.compile` — dead-clause
        // pruning by default, plus fire-probability reordering under
        // "full"), and every engine family builds from the shared
        // compiled artifact; no per-engine re-derivation. The compiled
        // stats also carry the live-clause density the auto-select
        // decision reads.
        let mut compiler = ModelCompiler::new(cfg.compile);
        if cfg.compile == CompileMode::Full {
            compiler = compiler.with_synthetic_calibration(features, CALIB_SAMPLES, CALIB_SEED);
        }
        let compiled_mc = compiler.clone().compile_multiclass(&mc_model)?;
        let compiled_co = compiler.compile_cotm(&cotm_model)?;
        let timeout = Duration::from_micros(cfg.batch_timeout_us);
        let native = build_native_tier(cfg, &compiled_mc, &compiled_co, simd, &stats, &in_flight)?;

        // Golden path: one PJRT service thread + a batcher per family.
        // Same relay-free shape as the bit-parallel path: the flush
        // builds the final responses and settles the accounting.
        let (golden, batcher_mc, batcher_co) = if with_golden {
            let svc = GoldenService::spawn(
                cfg.artifacts_dir.clone(),
                GoldenModels {
                    multiclass_include: mc_model.include_f32(),
                    cotm_include: cotm_model.include_f32(),
                    cotm_weights: cotm_model.weights_f32(),
                },
            )?;
            let mk = |backend: Backend,
                      client: crate::runtime::golden::GoldenClient,
                      stats: Arc<ServerStats>,
                      in_flight: Arc<AtomicU64>| {
                let family = backend.family().expect("golden backend has a family");
                DynamicBatcher::new(
                    cfg.max_batch,
                    timeout,
                    Arc::clone(&stats),
                    in_flight,
                    move |batch: &[Pending<GoldenItem, InferResponse>]| {
                        let rows: Vec<Vec<f32>> =
                            batch.iter().map(|p| p.item.features.clone()).collect();
                        // Guard the arity *before* any success counting:
                        // a short artifact reply must fail the whole
                        // batch, not count truncated items as completed.
                        match client.infer_batch(family, rows) {
                            Ok(out) if out.len() == batch.len() => batch
                                .iter()
                                .zip(out)
                                .map(|(p, (sums, predicted))| {
                                    let service_us = p.elapsed_us();
                                    stats.record_latency_us(service_us);
                                    stats.completed.fetch_add(1, Ordering::Relaxed);
                                    Ok(InferResponse {
                                        backend,
                                        predicted,
                                        class_sums: sums
                                            .iter()
                                            .map(|&x| x as i32)
                                            .collect(),
                                        hw_latency: None,
                                        hw_energy_fj: None,
                                        service_us,
                                    })
                                })
                                .collect(),
                            Ok(out) => {
                                stats
                                    .failed
                                    .fetch_add(batch.len() as u64, Ordering::Relaxed);
                                let msg = format!(
                                    "golden: artifact returned {} results for {} inputs",
                                    out.len(),
                                    batch.len()
                                );
                                batch
                                    .iter()
                                    .map(|_| Err(Error::coordinator(msg.clone())))
                                    .collect()
                            }
                            Err(e) => {
                                stats
                                    .failed
                                    .fetch_add(batch.len() as u64, Ordering::Relaxed);
                                batch
                                    .iter()
                                    .map(|_| {
                                        Err(Error::coordinator(format!("golden: {e}")))
                                    })
                                    .collect()
                            }
                        }
                    },
                )
            };
            let b_mc = mk(
                Backend::GoldenMulticlass,
                svc.client(),
                Arc::clone(&stats),
                Arc::clone(&in_flight),
            )?;
            let b_co = mk(
                Backend::GoldenCotm,
                svc.client(),
                Arc::clone(&stats),
                Arc::clone(&in_flight),
            )?;
            (Some(svc), Some(b_mc), Some(b_co))
        } else {
            (None, None, None)
        };

        Ok(CoordinatorServer {
            pool: Some(pool),
            _golden: golden,
            batcher_mc,
            batcher_co,
            batcher_bp_mc: Some(native.batcher_bp_mc),
            batcher_bp_co: Some(native.batcher_bp_co),
            batcher_ix_mc: Some(native.batcher_ix_mc),
            batcher_ix_co: Some(native.batcher_ix_co),
            batcher_cp_mc: Some(native.batcher_cp_mc),
            batcher_cp_co: Some(native.batcher_cp_co),
            auto_mc: native.auto_mc,
            auto_co: native.auto_co,
            simd,
            stats,
            in_flight,
            queue_depth: cfg.queue_depth as u64,
            features,
        })
    }

    /// Build a native-tier-only server directly from pinned compiled
    /// artifacts (`.tmc` files via [`crate::tm::serde`]) — the `tmtd
    /// shard` startup path: a shard process serves exactly the compiled
    /// model it was pinned to, skipping training, re-compilation, the
    /// hardware-simulation worker pool and the golden/PJRT tier.
    /// Requests for golden or hardware backends fail cleanly with the
    /// same errors a shut-down pool reports; the six native batchers
    /// and the `auto-*` density resolutions behave exactly as in
    /// [`CoordinatorServer::new`] because they build from the same
    /// compiled artifacts through the same code path.
    pub fn from_compiled_artifacts(
        cfg: &ServeConfig,
        compiled_mc: CompiledMulticlass,
        compiled_co: CompiledCotm,
    ) -> Result<CoordinatorServer> {
        cfg.validate()?;
        let features = compiled_mc.params.features;
        if compiled_co.params.features != features {
            return Err(Error::coordinator("compiled artifact feature widths differ"));
        }
        let stats = Arc::new(ServerStats::new());
        let in_flight = Arc::new(AtomicU64::new(0));
        let simd = cfg.simd.resolve()?;
        let native = build_native_tier(cfg, &compiled_mc, &compiled_co, simd, &stats, &in_flight)?;
        Ok(CoordinatorServer {
            pool: None,
            _golden: None,
            batcher_mc: None,
            batcher_co: None,
            batcher_bp_mc: Some(native.batcher_bp_mc),
            batcher_bp_co: Some(native.batcher_bp_co),
            batcher_ix_mc: Some(native.batcher_ix_mc),
            batcher_ix_co: Some(native.batcher_ix_co),
            batcher_cp_mc: Some(native.batcher_cp_mc),
            batcher_cp_co: Some(native.batcher_cp_co),
            auto_mc: native.auto_mc,
            auto_co: native.auto_co,
            simd,
            stats,
            in_flight,
            queue_depth: cfg.queue_depth as u64,
            features,
        })
    }

    /// The SIMD lane width the packed engines evaluate through —
    /// surfaced by `tmtd serve` / `selfcheck` next to the serving
    /// stats (a speed decision only; sums are dispatch-invariant).
    pub fn simd_lanes(&self) -> WordLanes {
        self.simd
    }

    /// The concrete native backends the `auto-*` aliases resolved to
    /// for this server's compiled models (multiclass, cotm).
    pub fn auto_backends(&self) -> (Backend, Backend) {
        (self.auto_mc, self.auto_co)
    }

    /// Submit a request; returns a receiver for the response.
    /// Fails fast with a backpressure error when the in-flight budget is
    /// exhausted.
    pub fn submit(&self, req: InferRequest) -> Result<mpsc::Receiver<Result<InferResponse>>> {
        if req.features.len() != self.features {
            return Err(Error::coordinator(format!(
                "feature width {} != {}",
                req.features.len(),
                self.features
            )));
        }
        // Backpressure gate.
        let inflight = self.in_flight.fetch_add(1, Ordering::SeqCst);
        if inflight >= self.queue_depth {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(Error::coordinator("backpressure: queue depth exceeded"));
        }
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();

        // Resolve the `auto-*` aliases to the concrete native backend
        // chosen for this model at build time; the reply reports the
        // engine that actually served the request.
        let backend = match req.backend {
            Backend::AutoMulticlass => self.auto_mc,
            Backend::AutoCotm => self.auto_co,
            b => b,
        };

        if backend.is_golden() {
            // Relay-free: the receiver comes straight from the batcher;
            // its flush built the final response and did the accounting.
            let batcher = match backend {
                Backend::GoldenMulticlass => self.batcher_mc.as_ref(),
                _ => self.batcher_co.as_ref(),
            }
            .ok_or_else(|| {
                self.abort_submit(Error::coordinator("golden path disabled (no artifacts)"))
            })?;
            let item = GoldenItem {
                features: req.features.iter().map(|&b| b as u8 as f32).collect(),
            };
            batcher.submit(item).map_err(|e| self.abort_submit(e))
        } else if backend.is_native_batched() {
            let batcher = match backend {
                Backend::BitParallelMulticlass => self.batcher_bp_mc.as_ref(),
                Backend::BitParallelCotm => self.batcher_bp_co.as_ref(),
                Backend::IndexedMulticlass => self.batcher_ix_mc.as_ref(),
                Backend::IndexedCotm => self.batcher_ix_co.as_ref(),
                Backend::CompressedMulticlass => self.batcher_cp_mc.as_ref(),
                _ => self.batcher_cp_co.as_ref(),
            }
            .ok_or_else(|| {
                self.abort_submit(Error::coordinator("native batcher shut down"))
            })?;
            batcher
                .submit(NativeItem { features: req.features })
                .map_err(|e| self.abort_submit(e))
        } else {
            let (tx, rx) = mpsc::channel();
            let stats = Arc::clone(&self.stats);
            let in_flight = Arc::clone(&self.in_flight);
            let features = req.features;
            self.pool
                .as_ref()
                .ok_or_else(|| self.abort_submit(Error::coordinator("pool shut down")))?
                .submit(Box::new(move |state: &mut WorkerState| {
                    // The guard releases the in-flight slot exactly once
                    // even when `infer` panics (the pool survives the
                    // panic and rebuilds the worker's state; without the
                    // guard each such panic would leak a queue_depth
                    // slot and vanish from the counters).
                    let guard = JobGuard::new(Arc::clone(&stats), in_flight);
                    let result = state
                        .arch(backend)
                        .infer(&features)
                        .map(|r| {
                            let service_us = t0.elapsed().as_secs_f64() * 1e6;
                            stats.record_latency_us(service_us);
                            stats.completed.fetch_add(1, Ordering::Relaxed);
                            InferResponse {
                                backend,
                                predicted: r.predicted,
                                class_sums: r.class_sums,
                                hw_latency: Some(r.latency),
                                hw_energy_fj: Some(r.energy_fj),
                                service_us,
                            }
                        })
                        .map_err(|e| {
                            stats.failed.fetch_add(1, Ordering::Relaxed);
                            e
                        });
                    guard.finish();
                    let _ = tx.send(result);
                }))
                .map_err(|e| self.abort_submit(e))?;
            Ok(rx)
        }
    }

    /// Undo the in-flight/submitted accounting for a request that
    /// errored out of `submit()` after passing the backpressure gate —
    /// without this, each such error permanently consumes a slot of
    /// `queue_depth` and breaks `submitted == completed + failed`.
    fn abort_submit(&self, e: Error) -> Error {
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
        self.stats.failed.fetch_add(1, Ordering::Relaxed);
        e
    }

    /// Submit and block for the response.
    pub fn infer(&self, req: InferRequest) -> Result<InferResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| Error::coordinator("response channel closed"))?
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Shared handle to the raw counters — used by the sharded front
    /// door ([`crate::coordinator::shard`]) to aggregate exact latency
    /// summaries across shards without copying snapshots.
    pub fn stats_handle(&self) -> Arc<ServerStats> {
        Arc::clone(&self.stats)
    }

    /// Graceful shutdown: drain workers and batchers.
    pub fn shutdown(mut self) {
        if let Some(p) = self.pool.take() {
            p.shutdown();
        }
        if let Some(b) = self.batcher_mc.take() {
            b.shutdown();
        }
        if let Some(b) = self.batcher_co.take() {
            b.shutdown();
        }
        if let Some(b) = self.batcher_bp_mc.take() {
            b.shutdown();
        }
        if let Some(b) = self.batcher_bp_co.take() {
            b.shutdown();
        }
        if let Some(b) = self.batcher_ix_mc.take() {
            b.shutdown();
        }
        if let Some(b) = self.batcher_ix_co.take() {
            b.shutdown();
        }
        if let Some(b) = self.batcher_cp_mc.take() {
            b.shutdown();
        }
        if let Some(b) = self.batcher_cp_co.take() {
            b.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};

    fn server(with_golden: bool, cfg: Option<ServeConfig>) -> (CoordinatorServer, data::Dataset) {
        let d = data::iris().unwrap();
        let (tr, _) = d.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
        let cfg = cfg.unwrap_or(ServeConfig { workers: 2, ..ServeConfig::default() });
        (
            CoordinatorServer::new(&cfg, m, cm, with_golden).unwrap(),
            d,
        )
    }

    #[test]
    fn serves_all_simulated_backends() {
        let (srv, d) = server(false, None);
        for b in [
            Backend::SyncMulticlass,
            Backend::AsyncBdMulticlass,
            Backend::ProposedMulticlass,
            Backend::SyncCotm,
            Backend::AsyncBdCotm,
            Backend::ProposedCotm,
        ] {
            let r = srv
                .infer(InferRequest { features: d.features[0].clone(), backend: b })
                .unwrap();
            assert_eq!(r.backend, b);
            assert!(r.hw_latency.is_some());
            assert!(r.hw_energy_fj.unwrap() > 0.0);
        }
        assert_eq!(srv.stats().completed, 6);
        srv.shutdown();
    }

    #[test]
    fn bitparallel_backends_serve_without_artifacts() {
        // The bit-parallel tier needs no AOT artifacts: it must serve
        // even when the golden path is disabled, and its sums must be
        // bit-exact against the software reference.
        let (srv, d) = server(false, None);
        let dset = data::iris().unwrap();
        let (tr, _) = dset.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
        for i in [0usize, 17, 80, 149] {
            let r = srv
                .infer(InferRequest {
                    features: d.features[i].clone(),
                    backend: Backend::BitParallelMulticlass,
                })
                .unwrap();
            assert_eq!(r.backend, Backend::BitParallelMulticlass);
            assert!(r.hw_latency.is_none(), "native path has no hw model");
            assert_eq!(
                r.class_sums,
                crate::tm::infer::multiclass_class_sums(&m, &d.features[i]),
                "sample {i}"
            );
            let r = srv
                .infer(InferRequest {
                    features: d.features[i].clone(),
                    backend: Backend::BitParallelCotm,
                })
                .unwrap();
            assert_eq!(
                r.class_sums,
                crate::tm::infer::cotm_class_sums(&cm, &d.features[i]),
                "sample {i}"
            );
        }
        srv.shutdown();
    }

    #[test]
    fn bitparallel_concurrent_submissions_are_batched_and_exact() {
        // Generous flush timeout so coalescing is deterministic even on
        // a slow machine (flush-on-size dominates).
        let cfg = ServeConfig {
            workers: 2,
            max_batch: 32,
            batch_timeout_us: 50_000,
            ..ServeConfig::default()
        };
        let (srv, d) = server(false, Some(cfg));
        let dset = data::iris().unwrap();
        let (tr, _) = dset.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let rxs: Vec<_> = (0..100)
            .map(|i| {
                (
                    i,
                    srv.submit(InferRequest {
                        features: d.features[i % d.len()].clone(),
                        backend: Backend::BitParallelMulticlass,
                    })
                    .unwrap(),
                )
            })
            .collect();
        for (i, rx) in rxs {
            let r = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .unwrap()
                .unwrap();
            let want =
                crate::tm::infer::multiclass_class_sums(&m, &d.features[i % d.len()]);
            assert_eq!(r.class_sums, want, "request {i}");
            assert_eq!(r.predicted, crate::tm::infer::predict_argmax(&want));
        }
        // The dynamic batcher actually coalesced (not 100 singletons).
        let snap = srv.stats();
        assert!(snap.batches_flushed < 100, "batches={}", snap.batches_flushed);
        assert_eq!(snap.completed, 100);
        srv.shutdown();
    }

    #[test]
    fn indexed_backends_serve_bit_exact_without_artifacts() {
        // The inverted-index tier is held to the same bar as the packed
        // tier: no artifacts, bit-exact class sums vs the scalar
        // reference, through the real batcher plumbing.
        let (srv, d) = server(false, None);
        let dset = data::iris().unwrap();
        let (tr, _) = dset.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
        for i in [0usize, 17, 80, 149] {
            let r = srv
                .infer(InferRequest {
                    features: d.features[i].clone(),
                    backend: Backend::IndexedMulticlass,
                })
                .unwrap();
            assert_eq!(r.backend, Backend::IndexedMulticlass);
            assert!(r.hw_latency.is_none(), "native path has no hw model");
            assert_eq!(
                r.class_sums,
                crate::tm::infer::multiclass_class_sums(&m, &d.features[i]),
                "sample {i}"
            );
            let r = srv
                .infer(InferRequest {
                    features: d.features[i].clone(),
                    backend: Backend::IndexedCotm,
                })
                .unwrap();
            assert_eq!(r.backend, Backend::IndexedCotm);
            assert_eq!(
                r.class_sums,
                crate::tm::infer::cotm_class_sums(&cm, &d.features[i]),
                "sample {i}"
            );
        }
        srv.shutdown();
    }

    #[test]
    fn from_compiled_artifacts_matches_full_server_on_native_tier() {
        // The pinned-artifact shard path: a server built straight from
        // compiled artifacts must serve the native backends bit-
        // identically to a full `new()` server over the same models
        // (same compile pass, same engines), resolve `auto-*` the same
        // way, and fail golden/hardware requests cleanly rather than
        // panic.
        let d = data::iris().unwrap();
        let (tr, _) = d.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
        let cfg = ServeConfig { workers: 2, ..ServeConfig::default() };
        let compiler = ModelCompiler::new(cfg.compile);
        let compiled_mc = compiler.clone().compile_multiclass(&m).unwrap();
        let compiled_co = compiler.compile_cotm(&cm).unwrap();
        let pinned =
            CoordinatorServer::from_compiled_artifacts(&cfg, compiled_mc, compiled_co).unwrap();
        let full = CoordinatorServer::new(&cfg, m, cm, false).unwrap();
        assert_eq!(pinned.auto_backends(), full.auto_backends());
        for b in [
            Backend::BitParallelMulticlass,
            Backend::IndexedMulticlass,
            Backend::CompressedMulticlass,
            Backend::AutoMulticlass,
            Backend::BitParallelCotm,
            Backend::IndexedCotm,
            Backend::CompressedCotm,
            Backend::AutoCotm,
        ] {
            for i in [0usize, 17, 80, 149] {
                let a = pinned
                    .infer(InferRequest { features: d.features[i].clone(), backend: b })
                    .unwrap();
                let bres = full
                    .infer(InferRequest { features: d.features[i].clone(), backend: b })
                    .unwrap();
                assert_eq!(a.class_sums, bres.class_sums, "{b:?} sample {i}");
                assert_eq!(a.predicted, bres.predicted, "{b:?} sample {i}");
                assert_eq!(a.backend, bres.backend, "{b:?} sample {i}");
            }
        }
        // Unsupported tiers: a clean error and conserved counters.
        for b in [Backend::GoldenMulticlass, Backend::SyncMulticlass] {
            assert!(pinned
                .submit(InferRequest { features: d.features[0].clone(), backend: b })
                .is_err());
        }
        let snap = pinned.stats();
        assert_eq!(snap.submitted + snap.rejected, snap.completed + snap.failed + snap.rejected);
        assert_eq!(snap.completed + snap.failed, snap.submitted);
        pinned.shutdown();
        full.shutdown();
    }

    #[test]
    fn auto_backends_resolve_by_density_and_stay_bit_exact() {
        // The three-way crossover forced to each tier in turn:
        // indexed_threshold 1.0 forces the indexed engines; (0.0, 1.0)
        // forces the compressed engines; (0.0, 0.0) (on trained Iris
        // models, whose densities are > 0) forces the packed engines.
        // The choice must never change the sums.
        let dset = data::iris().unwrap();
        let (tr, _) = dset.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
        // Precondition for the threshold-0.0 expectations: the trained
        // models actually include literals (density strictly > 0).
        assert!(crate::tm::IndexedMulticlass::from_model(&m).unwrap().density() > 0.0);
        assert!(crate::tm::IndexedCotm::from_model(&cm).unwrap().density() > 0.0);
        let mut sums_by_choice = Vec::new();
        for (it, ct, want_mc, want_co) in [
            (1.0, 0.0, Backend::IndexedMulticlass, Backend::IndexedCotm),
            (0.0, 1.0, Backend::CompressedMulticlass, Backend::CompressedCotm),
            (0.0, 0.0, Backend::BitParallelMulticlass, Backend::BitParallelCotm),
        ] {
            let cfg = ServeConfig {
                workers: 2,
                indexed_density_threshold: it,
                compressed_density_threshold: ct,
                ..ServeConfig::default()
            };
            let (srv, d) = server(false, Some(cfg));
            assert_eq!(srv.auto_backends(), (want_mc, want_co), "thresholds ({it}, {ct})");
            let mut sums = Vec::new();
            for i in [0usize, 40, 99] {
                let r = srv
                    .infer(InferRequest {
                        features: d.features[i].clone(),
                        backend: Backend::AutoMulticlass,
                    })
                    .unwrap();
                // The reply names the engine that actually served it.
                assert_eq!(r.backend, want_mc);
                assert_eq!(
                    r.class_sums,
                    crate::tm::infer::multiclass_class_sums(&m, &d.features[i])
                );
                sums.push(r.class_sums);
                let r = srv
                    .infer(InferRequest {
                        features: d.features[i].clone(),
                        backend: Backend::AutoCotm,
                    })
                    .unwrap();
                assert_eq!(r.backend, want_co);
                sums.push(r.class_sums);
            }
            sums_by_choice.push(sums);
            srv.shutdown();
        }
        // Auto-select changed the engine, not the outputs.
        assert_eq!(sums_by_choice[0], sums_by_choice[1]);
        assert_eq!(sums_by_choice[1], sums_by_choice[2]);
    }

    #[test]
    fn compressed_backends_serve_bit_exact_without_artifacts() {
        // The compressed include-list tier is held to the same bar as
        // the packed and indexed tiers: no artifacts, bit-exact class
        // sums vs the scalar reference, through the real batcher
        // plumbing.
        let (srv, d) = server(false, None);
        let dset = data::iris().unwrap();
        let (tr, _) = dset.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
        for i in [0usize, 17, 80, 149] {
            let r = srv
                .infer(InferRequest {
                    features: d.features[i].clone(),
                    backend: Backend::CompressedMulticlass,
                })
                .unwrap();
            assert_eq!(r.backend, Backend::CompressedMulticlass);
            assert!(r.hw_latency.is_none(), "native path has no hw model");
            assert_eq!(
                r.class_sums,
                crate::tm::infer::multiclass_class_sums(&m, &d.features[i]),
                "sample {i}"
            );
            let r = srv
                .infer(InferRequest {
                    features: d.features[i].clone(),
                    backend: Backend::CompressedCotm,
                })
                .unwrap();
            assert_eq!(r.backend, Backend::CompressedCotm);
            assert_eq!(
                r.class_sums,
                crate::tm::infer::cotm_class_sums(&cm, &d.features[i]),
                "sample {i}"
            );
        }
        srv.shutdown();
    }

    #[test]
    fn forced_simd_levels_serve_bit_exact() {
        // Every lane width the host offers, forced through the real
        // serving config, must produce the reference sums — and the
        // server must report the level it resolved.
        use crate::tm::simd::{SimdChoice, SimdLevel};
        let dset = data::iris().unwrap();
        let (tr, _) = dset.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        for level in SimdLevel::available() {
            let cfg = ServeConfig {
                workers: 2,
                simd: SimdChoice::Forced(level),
                ..ServeConfig::default()
            };
            let (srv, d) = server(false, Some(cfg));
            assert_eq!(srv.simd_lanes().level(), level);
            for i in [0usize, 60, 149] {
                let r = srv
                    .infer(InferRequest {
                        features: d.features[i].clone(),
                        backend: Backend::BitParallelMulticlass,
                    })
                    .unwrap();
                assert_eq!(
                    r.class_sums,
                    crate::tm::infer::multiclass_class_sums(&m, &d.features[i]),
                    "sample {i} level {}",
                    level.name()
                );
            }
            srv.shutdown();
        }
        // Auto resolves to the widest detected level.
        let (srv, _) = server(false, None);
        assert_eq!(srv.simd_lanes().level(), SimdLevel::detect_best());
        srv.shutdown();
    }

    #[test]
    fn compile_modes_serve_bit_exact_through_every_native_backend() {
        // The serve-time compile knob (off/prune/full) restructures the
        // clause layout the engines execute, but the sums must stay
        // bit-identical to the scalar reference through the real
        // batcher plumbing in every mode, for every native backend.
        let dset = data::iris().unwrap();
        let (tr, _) = dset.split(0.8, 42);
        let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
        let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
        for mode in [CompileMode::Off, CompileMode::Prune, CompileMode::Full] {
            let cfg = ServeConfig { workers: 2, compile: mode, ..ServeConfig::default() };
            let (srv, d) = server(false, Some(cfg));
            for i in [0usize, 60, 149] {
                for b in [
                    Backend::BitParallelMulticlass,
                    Backend::IndexedMulticlass,
                    Backend::CompressedMulticlass,
                ] {
                    let r = srv
                        .infer(InferRequest { features: d.features[i].clone(), backend: b })
                        .unwrap();
                    assert_eq!(
                        r.class_sums,
                        crate::tm::infer::multiclass_class_sums(&m, &d.features[i]),
                        "sample {i} backend {b:?} mode {}",
                        mode.name()
                    );
                }
                for b in [
                    Backend::BitParallelCotm,
                    Backend::IndexedCotm,
                    Backend::CompressedCotm,
                ] {
                    let r = srv
                        .infer(InferRequest { features: d.features[i].clone(), backend: b })
                        .unwrap();
                    assert_eq!(
                        r.class_sums,
                        crate::tm::infer::cotm_class_sums(&cm, &d.features[i]),
                        "sample {i} backend {b:?} mode {}",
                        mode.name()
                    );
                }
            }
            srv.shutdown();
        }
    }

    #[test]
    fn job_guard_counts_panicked_jobs_and_frees_the_slot() {
        // Regression for the pooled-path slot leak: a job that dies
        // without calling finish() (the panic path) must release its
        // in-flight slot and surface in `failed`; a finished job
        // releases the slot without touching `failed`.
        let stats = Arc::new(ServerStats::new());
        let in_flight = Arc::new(AtomicU64::new(2));

        let g = JobGuard::new(Arc::clone(&stats), Arc::clone(&in_flight));
        drop(g); // abandoned (what unwinding does)
        assert_eq!(in_flight.load(Ordering::SeqCst), 1);
        assert_eq!(stats.failed.load(Ordering::Relaxed), 1);

        let g = JobGuard::new(Arc::clone(&stats), Arc::clone(&in_flight));
        g.finish();
        assert_eq!(in_flight.load(Ordering::SeqCst), 0);
        assert_eq!(stats.failed.load(Ordering::Relaxed), 1, "finish() is not a failure");
    }

    #[test]
    fn pooled_job_panic_keeps_budget_and_counters_conserved() {
        // End-to-end: drive a panicking job through a real WorkerPool
        // with the same guard wiring submit() uses, then prove the
        // serving loop still works and the accounting identity
        // submitted == completed + failed holds.
        let stats = Arc::new(ServerStats::new());
        let in_flight = Arc::new(AtomicU64::new(0));
        let pool: WorkerPool<()> = WorkerPool::new(1, |_| ()).unwrap();
        for i in 0..4u32 {
            let stats = Arc::clone(&stats);
            let in_flight = Arc::clone(&in_flight);
            in_flight.fetch_add(1, Ordering::SeqCst);
            stats.submitted.fetch_add(1, Ordering::Relaxed);
            pool.submit(Box::new(move |_| {
                let guard = JobGuard::new(Arc::clone(&stats), in_flight);
                if i % 2 == 0 {
                    panic!("injected job failure");
                }
                stats.completed.fetch_add(1, Ordering::Relaxed);
                guard.finish();
            }))
            .unwrap();
        }
        pool.shutdown();
        assert_eq!(in_flight.load(Ordering::SeqCst), 0, "no leaked slots");
        let snap = stats.snapshot();
        assert_eq!(snap.submitted, 4);
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.failed, 2);
    }

    #[test]
    fn golden_disabled_errors_cleanly() {
        let (srv, d) = server(false, None);
        let err = srv
            .infer(InferRequest {
                features: d.features[0].clone(),
                backend: Backend::GoldenCotm,
            })
            .unwrap_err();
        assert!(err.to_string().contains("golden path disabled"), "{err}");
        srv.shutdown();
    }

    #[test]
    fn rejects_wrong_feature_width() {
        let (srv, _) = server(false, None);
        assert!(srv
            .submit(InferRequest { features: vec![true; 3], backend: Backend::SyncCotm })
            .is_err());
        srv.shutdown();
    }

    #[test]
    fn backpressure_rejects_beyond_queue_depth() {
        let cfg = ServeConfig {
            workers: 1,
            queue_depth: 16,
            max_batch: 16,
            ..ServeConfig::default()
        };
        let (srv, d) = server(false, Some(cfg));
        let mut receivers = Vec::new();
        let mut rejected = 0;
        for i in 0..200 {
            match srv.submit(InferRequest {
                features: d.features[i % d.len()].clone(),
                backend: Backend::ProposedCotm,
            }) {
                Ok(rx) => receivers.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        for rx in receivers {
            let _ = rx.recv_timeout(std::time::Duration::from_secs(30));
        }
        assert_eq!(srv.stats().rejected as usize, rejected);
        srv.shutdown();
    }

    #[test]
    fn concurrent_mixed_load_is_consistent() {
        let (srv, d) = server(false, None);
        let mut receivers = Vec::new();
        for i in 0..30 {
            let backend = if i % 2 == 0 {
                Backend::AsyncBdMulticlass
            } else {
                Backend::ProposedMulticlass
            };
            receivers.push((
                i,
                srv.submit(InferRequest {
                    features: d.features[i % d.len()].clone(),
                    backend,
                })
                .unwrap(),
            ));
        }
        for (i, rx) in receivers {
            let r = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .unwrap()
                .unwrap();
            // Both backends implement the same model: sums must agree
            // with the software reference.
            let want = crate::tm::infer::multiclass_class_sums(
                &{
                    let dset = data::iris().unwrap();
                    let (tr, _) = dset.split(0.8, 42);
                    train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap()
                },
                &d.features[i % d.len()],
            );
            assert_eq!(r.class_sums, want, "request {i}");
        }
        srv.shutdown();
    }
}
