//! `tmtd` — the leader binary: train, simulate, evaluate, serve.

#![deny(unsafe_code)]

use tsetlin_td::arch::digital::{
    async_bd_cotm, async_bd_multiclass, sync_cotm, sync_multiclass,
};
use tsetlin_td::arch::metrics::{evaluate, render_table_iv};
use tsetlin_td::arch::proposed_cotm::ProposedCotm;
use tsetlin_td::arch::proposed_tm::ProposedMulticlass;
use tsetlin_td::arch::Architecture;
use tsetlin_td::cli::{Args, USAGE};
use tsetlin_td::config::{parse_remote_shards, ServeConfig};
use tsetlin_td::coordinator::{
    Backend, CoordinatorServer, InferRequest, RemoteCoordinator, ShardServer, ShardedCoordinator,
};
use tsetlin_td::sim::TechParams;
use tsetlin_td::tm::simd::{SimdChoice, SimdLevel, WordLanes};
use tsetlin_td::tm::{
    self, cotm_train::train_cotm_with, data, train::train_multiclass_with, train_cotm_async,
    train_multiclass_async, BatchEngine, CompileMode, ModelCompiler, TmParams, TrainerChoice,
    TrainerEngine,
};
use tsetlin_td::util::SplitMix64;
use tsetlin_td::wta::{analysis, WtaKind};
use tsetlin_td::{Error, Result};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "train" => cmd_train(args),
        "infer" => cmd_infer(args),
        "eval" | "table4" => cmd_eval(args),
        "table1" => cmd_table1(args),
        "table3" => cmd_table3(args),
        "waveform" => cmd_waveform(args),
        "compile" => cmd_compile(args),
        "serve" => cmd_serve(args),
        "shard" => cmd_shard(args),
        "selfcheck" => cmd_selfcheck(args),
        "help" | "" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(Error::config(format!("unknown command {other:?}\n\n{USAGE}"))),
    }
}

fn load_dataset(name: &str, seed: u64) -> Result<data::Dataset> {
    match name {
        "iris" => data::iris(),
        "xor" => Ok(data::xor_noise(400, 8, 0.05, seed)),
        "blobs" => Ok(data::prototype_blobs(300, 16, 3, 0.05, seed)),
        other => Err(Error::config(format!("unknown dataset {other:?}"))),
    }
}

fn train_pair(
    dataset: &data::Dataset,
    epochs: usize,
    seed: u64,
) -> Result<(tm::MultiClassTmModel, tm::CoTmModel)> {
    train_pair_with(dataset, epochs, seed, TrainerEngine::default())
}

fn train_pair_with(
    dataset: &data::Dataset,
    epochs: usize,
    seed: u64,
    engine: TrainerEngine,
) -> Result<(tm::MultiClassTmModel, tm::CoTmModel)> {
    let params = TmParams {
        features: dataset.num_features(),
        classes: dataset.classes,
        ..TmParams::iris_paper()
    };
    let (train, _) = dataset.split(0.8, 42);
    let m = train_multiclass_with(params.clone(), &train, epochs, seed, engine)?;
    let cm = train_cotm_with(params, &train, epochs.max(100), seed + 1, engine)?;
    Ok((m, cm))
}

/// Resolve the trainer tier + thread count: serve.toml `[coordinator]`
/// `trainer`/`train_threads` knobs supply defaults when `--config` is
/// given; `--trainer`/`--threads` override.
fn trainer_choice(args: &Args) -> Result<(TrainerChoice, usize)> {
    let cfg = match args.flag("config") {
        Some(path) => ServeConfig::load(path)?,
        None => ServeConfig::default(),
    };
    let name = args.flag_or("trainer", cfg.trainer.name());
    let choice = TrainerChoice::parse(&name).ok_or_else(|| {
        Error::config(format!(
            "unknown --trainer {name:?} (packed|reference|async|async-indexed)"
        ))
    })?;
    let threads = args.flag_parse("threads", cfg.train_threads)?;
    if threads == 0 {
        return Err(Error::config("--threads must be >= 1"));
    }
    Ok((choice, threads))
}

/// Train the demo model pair through the selected tier: deterministic
/// engines go through the bit-exact trainers, async choices through
/// the clause-parallel stale-vote tier.
fn train_pair_choice(
    dataset: &data::Dataset,
    epochs: usize,
    seed: u64,
    choice: TrainerChoice,
    threads: usize,
) -> Result<(tm::MultiClassTmModel, tm::CoTmModel)> {
    match choice.engine() {
        Some(engine) => train_pair_with(dataset, epochs, seed, engine),
        None => {
            let params = TmParams {
                features: dataset.num_features(),
                classes: dataset.classes,
                ..TmParams::iris_paper()
            };
            let (train, _) = dataset.split(0.8, 42);
            let m = train_multiclass_async(
                params.clone(), &train, epochs, seed, threads, choice.indexed(),
            )?;
            let cm = train_cotm_async(
                params, &train, epochs.max(100), seed + 1, threads, choice.indexed(),
            )?;
            Ok((m, cm))
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let dataset = load_dataset(&args.flag_or("dataset", "iris"), 7)?;
    let epochs = args.flag_parse("epochs", 60usize)?;
    let seed = args.flag_parse("seed", 2u64)?;
    let (choice, threads) = trainer_choice(args)?;
    let out_dir = args.flag_or("out-dir", "models");
    std::fs::create_dir_all(&out_dir)?;
    match choice.engine() {
        Some(engine) => println!(
            "trainer engine: {} (deterministic; bit-identical per seed)",
            engine.name()
        ),
        None => println!(
            "trainer engine: {} ({threads} clause-partition threads; stale-vote \
             async tier, statistically equivalent rather than bit-reproducible)",
            choice.name()
        ),
    }
    let (m, cm) = train_pair_choice(&dataset, epochs, seed, choice, threads)?;
    let (tr, te) = dataset.split(0.8, 42);
    println!(
        "multiclass: train acc {:.3}, test acc {:.3}",
        tm::infer::multiclass_accuracy(&m, &tr.features, &tr.labels),
        tm::infer::multiclass_accuracy(&m, &te.features, &te.labels)
    );
    println!(
        "cotm:       train acc {:.3}, test acc {:.3}",
        tm::infer::cotm_accuracy(&cm, &tr.features, &tr.labels),
        tm::infer::cotm_accuracy(&cm, &te.features, &te.labels)
    );
    tm::serde::save_multiclass(&m, format!("{out_dir}/multiclass.tm"))?;
    tm::serde::save_cotm(&cm, format!("{out_dir}/cotm.tm"))?;
    println!("saved {out_dir}/multiclass.tm and {out_dir}/cotm.tm");
    Ok(())
}

fn wta_kind(args: &Args) -> Result<WtaKind> {
    match args.flag_or("wta", "tba").as_str() {
        "tba" => Ok(WtaKind::Tba),
        "mesh" => Ok(WtaKind::Mesh),
        other => Err(Error::config(format!("unknown --wta {other:?}"))),
    }
}

fn cmd_infer(args: &Args) -> Result<()> {
    let model_dir = args.flag_or("model-dir", "models");
    let backend = Backend::parse(&args.flag_or("backend", "cotm-proposed"))
        .ok_or_else(|| Error::config("unknown --backend"))?;
    let dataset = data::iris()?;
    let sample = args.flag_parse("sample", 0usize)?;
    if sample >= dataset.len() {
        return Err(Error::config(format!("--sample out of range (<{})", dataset.len())));
    }
    let m = tm::serde::load_multiclass(format!("{model_dir}/multiclass.tm"))?;
    let cm = tm::serde::load_cotm(format!("{model_dir}/cotm.tm"))?;
    let cfg = ServeConfig { workers: 1, ..ServeConfig::default() };
    let srv = CoordinatorServer::new(&cfg, m, cm, backend.is_golden())?;
    let r = srv.infer(InferRequest { features: dataset.features[sample].clone(), backend })?;
    println!(
        "sample {sample}: predicted class {} (true {}), sums {:?}",
        r.predicted, dataset.labels[sample], r.class_sums
    );
    if let Some(l) = r.hw_latency {
        println!("hw latency {l}, energy {:.1} fJ", r.hw_energy_fj.unwrap_or(0.0));
    }
    srv.shutdown();
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dataset = load_dataset(&args.flag_or("dataset", "iris"), 7)?;
    let epochs = args.flag_parse("epochs", 60usize)?;
    let seed = args.flag_parse("seed", 2u64)?;
    let wta = wta_kind(args)?;
    let (m, cm) = train_pair(&dataset, epochs, seed)?;
    let mut archs: Vec<Box<dyn Architecture>> = vec![
        Box::new(sync_multiclass(m.clone())),
        Box::new(async_bd_multiclass(m.clone())),
        Box::new(ProposedMulticlass::new(m.clone(), wta)?),
        Box::new(sync_cotm(cm.clone())),
        Box::new(async_bd_cotm(cm.clone())),
        Box::new(ProposedCotm::new(cm.clone(), wta)?),
    ];
    let mut rows = Vec::new();
    for a in archs.iter_mut() {
        rows.push(evaluate(a.as_mut(), &dataset.features, &dataset.labels)?);
    }
    println!("Table IV — performance summary ({} / wta={})", dataset.name, wta.name());
    println!("{}", render_table_iv(&rows));
    Ok(())
}

fn cmd_table1(_args: &Args) -> Result<()> {
    let tech = TechParams::tsmc65_digital();
    let mut t = tsetlin_td::util::Table::new(vec![
        "Config.",
        "m",
        "Arbitration Depth",
        "Cell Count",
        "Latency theory (ps)",
        "Latency measured (ps)",
    ]);
    for m in [2usize, 3, 4, 8, 16, 32] {
        let a = analysis::tba_analysis(m, &tech);
        t.row(vec![
            "TBA".to_string(),
            m.to_string(),
            a.arbitration_depth.to_string(),
            a.cell_count.to_string(),
            format!("{:.0}", a.latency_theory.as_ps_f64()),
            format!(
                "{:.0}",
                analysis::measured_latency(WtaKind::Tba, m, &tech).as_ps_f64()
            ),
        ]);
        let a = analysis::mesh_analysis(m, &tech);
        t.row(vec![
            "Mesh-Like".to_string(),
            m.to_string(),
            a.arbitration_depth.to_string(),
            a.cell_count.to_string(),
            format!("{:.0}", a.latency_theory.as_ps_f64()),
            format!(
                "{:.0}",
                analysis::measured_latency(WtaKind::Mesh, m, &tech).as_ps_f64()
            ),
        ]);
    }
    println!("Table I — WTA implementations");
    println!("{}", t.render());
    Ok(())
}

fn cmd_table3(args: &Args) -> Result<()> {
    // Reported literature rows + our two measured columns.
    let dataset = data::iris()?;
    let (m, cm) = train_pair(&dataset, 60, 2)?;
    let wta = wta_kind(args)?;
    let mut prop_mc = ProposedMulticlass::new(m, wta)?;
    let mut prop_co = ProposedCotm::new(cm, wta)?;
    let r_mc = evaluate(&mut prop_mc, &dataset.features, &dataset.labels)?;
    let r_co = evaluate(&mut prop_co, &dataset.features, &dataset.labels)?;
    let mut t = tsetlin_td::util::Table::new(vec![
        "Parameter", "[21]", "[4]", "[8]", "[11]", "Proposed (TM)", "Proposed (CoTM)",
    ]);
    t.row(vec!["Architecture", "Async QDI", "Async BD", "Sync", "Async QDI", "Async BD", "Async BD"]);
    t.row(vec!["Computing Domain", "Digital", "Digital", "Time", "Digital", "Time", "Hybrid"]);
    t.row(vec!["Technology (nm)", "65", "28", "65", "65", "65 (sim)", "65 (sim)"]);
    t.row(vec!["Voltage (V)", "1.2", "0.9", "1.2", "1.2", "1.0", "1.0"]);
    t.row(vec![
        "Energy Eff. (TOp/J)".to_string(),
        "1.87 (reported)".to_string(),
        "0.42 (reported)".to_string(),
        "116 (reported)".to_string(),
        "873 (reported)".to_string(),
        format!("{:.1} (measured)", r_mc.energy_eff_tops_per_j),
        format!("{:.1} (measured)", r_co.energy_eff_tops_per_j),
    ]);
    t.row(vec!["ML Algorithm", "CNN", "SNN", "BNN", "Multi-class TM", "Multi-class TM", "CoTM"]);
    println!("Table III — comparison with state-of-the-art (literature rows quoted from the paper)");
    println!("{}", t.render());
    Ok(())
}

fn cmd_waveform(args: &Args) -> Result<()> {
    let out_dir = args.flag_or("out-dir", "waves");
    std::fs::create_dir_all(&out_dir)?;
    let written = tsetlin_td::arch::waveforms::dump_all(&out_dir)?;
    for w in written {
        println!("wrote {w}");
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let model_dir = args.flag_or("model-dir", "models");
    let out_dir = args.flag_or("out-dir", &model_dir);
    let mode_name = args.flag_or("mode", CompileMode::default().name());
    let mode = CompileMode::parse(&mode_name)
        .ok_or_else(|| Error::config(format!("unknown --mode {mode_name:?} (off|prune|full)")))?;
    let calib_samples = args.flag_parse("calib-samples", 256usize)?;
    let seed = args.flag_parse("seed", 7u64)?;
    std::fs::create_dir_all(&out_dir)?;
    let m = tm::serde::load_multiclass(format!("{model_dir}/multiclass.tm"))?;
    let cm = tm::serde::load_cotm(format!("{model_dir}/cotm.tm"))?;
    let compiler = |features: usize| {
        let c = ModelCompiler::new(mode);
        if mode == CompileMode::Full {
            c.with_synthetic_calibration(features, calib_samples, seed)
        } else {
            c
        }
    };
    let cmc = compiler(m.params.features).compile_multiclass(&m)?;
    let cco = compiler(cm.params.features).compile_cotm(&cm)?;
    for (name, stats) in [("multiclass", &cmc.stats), ("cotm", &cco.stats)] {
        println!(
            "{name}: {} clauses -> {} live ({} all-exclude + {} contradictory dead), \
             {} postings, density {:.4}, plans {} sweep / {} skip",
            stats.total_clauses,
            stats.live_clauses,
            stats.dead_all_exclude,
            stats.dead_contradictory,
            stats.postings,
            stats.density,
            stats.lane_sweep_clauses,
            stats.skip_list_clauses
        );
    }
    tm::serde::save_compiled_multiclass(&cmc, format!("{out_dir}/multiclass.tmc"))?;
    tm::serde::save_compiled_cotm(&cco, format!("{out_dir}/cotm.tmc"))?;
    println!("saved {out_dir}/multiclass.tmc and {out_dir}/cotm.tmc (mode {})", mode.name());
    Ok(())
}

/// Load the serve config and apply the CLI overrides shared by
/// `serve` and `shard`.
fn serve_config(args: &Args) -> Result<ServeConfig> {
    let mut cfg = match args.flag("config") {
        Some(path) => ServeConfig::load(path)?,
        None => ServeConfig::default(),
    };
    // CLI overrides the config file's shard count and SIMD level.
    cfg.shards = args.flag_parse("shards", cfg.shards)?;
    if let Some(name) = args.flag("simd") {
        cfg.simd = SimdChoice::parse(name).ok_or_else(|| {
            Error::config(format!(
                "unknown --simd {name:?} (auto|scalar|portable|neon|avx2|avx512)"
            ))
        })?;
    }
    if let Some(name) = args.flag("compile") {
        cfg.compile = CompileMode::parse(name).ok_or_else(|| {
            Error::config(format!("unknown --compile {name:?} (off|prune|full)"))
        })?;
    }
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = serve_config(args)?;
    // `--remote-shards a:p,b:p` (or `remote_shards` in serve.toml)
    // switches the front door to the networked tier: this process
    // routes over TCP instead of hosting the shards itself.
    let remote = match args.flag("remote-shards") {
        Some(list) => parse_remote_shards(list)?,
        None => cfg.remote_shards.clone(),
    };
    if !remote.is_empty() {
        return cmd_serve_remote(&cfg, &remote, args);
    }
    let with_golden = !args.switch("no-golden");
    let n_requests = args.flag_parse("requests", 200usize)?;
    let dataset = data::iris()?;
    let (m, cm) = train_pair(&dataset, 60, 2)?;
    let srv = ShardedCoordinator::new(&cfg, m, cm, with_golden)?;
    println!(
        "serving {n_requests} mixed requests across {} shard(s) (golden={with_golden}, \
         simd={} requested {}) ...",
        srv.num_shards(),
        srv.simd_lanes().name(),
        cfg.simd.name()
    );
    let mut rng = SplitMix64::new(1);
    let backends: Vec<Backend> = Backend::ALL
        .iter()
        .copied()
        .filter(|b| with_golden || !b.is_golden())
        .collect();
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let b = backends[rng.index(backends.len())];
        match srv.submit(InferRequest {
            features: dataset.features[i % dataset.len()].clone(),
            backend: b,
        }) {
            Ok(rx) => pending.push(rx),
            Err(e) => eprintln!("rejected: {e}"),
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "done: {ok}/{n_requests} ok in {:.1} ms ({:.0} req/s)",
        dt.as_secs_f64() * 1e3,
        ok as f64 / dt.as_secs_f64()
    );
    println!("simd lanes: {} (x{})", srv.simd_lanes().name(), srv.simd_lanes().level().lanes());
    println!("{}", srv.stats().render());
    if srv.num_shards() > 1 {
        for (i, s) in srv.shard_stats().iter().enumerate() {
            println!("  shard {i}: {}", s.render());
        }
    }
    srv.shutdown();
    Ok(())
}

/// The networked `serve` branch: route demo traffic over TCP to
/// already-running `tmtd shard` processes.
fn cmd_serve_remote(cfg: &ServeConfig, addrs: &[String], args: &Args) -> Result<()> {
    let n_requests = args.flag_parse("requests", 200usize)?;
    let dataset = data::iris()?;
    let router = RemoteCoordinator::connect(addrs, cfg.net_connections, cfg.net_heartbeat_ms)?;
    println!(
        "routing {n_requests} requests across {} remote shard(s): {}",
        router.num_shards(),
        addrs.join(", ")
    );
    // Remote shards serve the native tier (shards pin compiled .tmc
    // artifacts; golden and hardware backends need in-process state).
    let backends: Vec<Backend> = Backend::ALL
        .iter()
        .copied()
        .filter(|b| b.is_native_batched() || b.is_auto())
        .collect();
    let mut rng = SplitMix64::new(1);
    let t0 = std::time::Instant::now();
    let mut ok = 0usize;
    for i in 0..n_requests {
        let b = backends[rng.index(backends.len())];
        match router.infer(&dataset.features[i % dataset.len()], b) {
            Ok(_) => ok += 1,
            Err(e) => eprintln!("request failed: {e}"),
        }
    }
    let dt = t0.elapsed();
    println!(
        "done: {ok}/{n_requests} ok in {:.1} ms ({:.0} req/s), {} failover(s)",
        dt.as_secs_f64() * 1e3,
        ok as f64 / dt.as_secs_f64(),
        router.failovers()
    );
    println!("router:  {}", router.router_stats().render());
    match router.cluster_stats() {
        Ok(s) => println!("cluster: {}", s.render()),
        Err(e) => eprintln!("cluster stats unavailable: {e}"),
    }
    if args.switch("drain") {
        println!("drained {}/{} shards", router.drain(), router.num_shards());
    }
    router.shutdown();
    Ok(())
}

/// One shard process: serve a [`CoordinatorServer`] over TCP until a
/// drain arrives. Models are pinned from compiled `.tmc` artifacts
/// (`--model` + `--cotm-model`, see `tmtd compile`); with neither
/// flag, a demo pair is trained and compiled in-process.
fn cmd_shard(args: &Args) -> Result<()> {
    let mut cfg = serve_config(args)?;
    // One process = one shard; in-process sharding stays available by
    // running more shard processes.
    cfg.shards = 1;
    let listen = match args.flag("listen") {
        Some(a) => a.to_string(),
        None if !cfg.listen.is_empty() => cfg.listen.clone(),
        None => {
            return Err(Error::config(
                "shard needs --listen host:port (or `listen` under [coordinator] in serve.toml)",
            ))
        }
    };
    let (cmc, cco) = match (args.flag("model"), args.flag("cotm-model")) {
        (Some(mc_path), Some(co_path)) => {
            let cmc = tm::serde::load_compiled_multiclass(mc_path)?;
            let cco = tm::serde::load_compiled_cotm(co_path)?;
            println!("pinned models: {mc_path} + {co_path}");
            (cmc, cco)
        }
        (None, None) => {
            println!("no --model/--cotm-model given; training a demo iris pair");
            let dataset = data::iris()?;
            let (m, cm) = train_pair(&dataset, 60, 2)?;
            let compiler = ModelCompiler::new(cfg.compile);
            (compiler.compile_multiclass(&m)?, compiler.compile_cotm(&cm)?)
        }
        _ => {
            return Err(Error::config(
                "--model and --cotm-model must be given together (a compiled .tmc pair)",
            ))
        }
    };
    let server = CoordinatorServer::from_compiled_artifacts(&cfg, cmc, cco)?;
    let (auto_mc, auto_co) = server.auto_backends();
    let lanes = server.simd_lanes();
    let shard = ShardServer::bind(server, &listen)?;
    println!(
        "shard listening on {} (simd {}, auto -> {}/{}); send Drain to stop",
        shard.local_addr(),
        lanes.name(),
        auto_mc.name(),
        auto_co.name()
    );
    shard.wait();
    println!("shard drained; exiting");
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    // The full backend registry, up front: lint rule R6 holds selfcheck
    // to covering every routable name, and iterating Backend::ALL keeps
    // that coverage drift-proof as backends are added.
    let registered: Vec<&str> = Backend::ALL.iter().map(|b| b.name()).collect();
    println!("backends registered ({}): {}", registered.len(), registered.join(", "));
    let dataset = data::iris()?;
    let (m, cm) = train_pair(&dataset, 60, 2)?;
    let wta = wta_kind(args)?;
    let mut archs: Vec<Box<dyn Architecture>> = vec![
        Box::new(sync_multiclass(m.clone())),
        Box::new(async_bd_multiclass(m.clone())),
        Box::new(ProposedMulticlass::new(m.clone(), wta)?),
        Box::new(sync_cotm(cm.clone())),
        Box::new(async_bd_cotm(cm.clone())),
        Box::new(ProposedCotm::new(cm.clone(), wta)?),
    ];
    let mut failures: Vec<String> = Vec::new();
    for a in archs.iter_mut() {
        let mut agree = 0usize;
        for x in &dataset.features {
            let r = a.infer(x)?;
            let exact = tm::infer::predict_argmax(&r.class_sums);
            if r.predicted == exact || r.class_sums[r.predicted] == r.class_sums[exact] {
                agree += 1;
            }
        }
        let pct = 100.0 * agree as f64 / dataset.len() as f64;
        println!("{:24} argmax agreement {pct:.1}%", a.name());
        if pct < 95.0 {
            failures.push(format!("{}: argmax agreement {pct:.1}% < 95%", a.name()));
        }
    }
    // The native batched tiers are held to a stricter bar than the
    // hardware models: bit-exact class sums, not just argmax agreement.
    let bp_mc = tm::BitParallelMulticlass::from_model(&m)?;
    let bp_co = tm::BitParallelCotm::from_model(&cm)?;
    let ix_mc = tm::IndexedMulticlass::from_model(&m)?;
    let ix_co = tm::IndexedCotm::from_model(&cm)?;
    let cp_mc = tm::CompressedMulticlass::from_model(&m)?;
    let cp_co = tm::CompressedCotm::from_model(&cm)?;
    let mut exact = [0usize; 6];
    for x in &dataset.features {
        let want_mc = tm::infer::multiclass_class_sums(&m, x);
        let want_co = tm::infer::cotm_class_sums(&cm, x);
        exact[0] += (tm::BatchEngine::class_sums(&bp_mc, x) == want_mc) as usize;
        exact[1] += (tm::BatchEngine::class_sums(&bp_co, x) == want_co) as usize;
        exact[2] += (tm::BatchEngine::class_sums(&ix_mc, x) == want_mc) as usize;
        exact[3] += (tm::BatchEngine::class_sums(&ix_co, x) == want_co) as usize;
        exact[4] += (tm::BatchEngine::class_sums(&cp_mc, x) == want_mc) as usize;
        exact[5] += (tm::BatchEngine::class_sums(&cp_co, x) == want_co) as usize;
    }
    for (name, exact) in [
        ("bitpar-multiclass", exact[0]),
        ("bitpar-cotm", exact[1]),
        ("indexed-multiclass", exact[2]),
        ("indexed-cotm", exact[3]),
        ("compressed-multiclass", exact[4]),
        ("compressed-cotm", exact[5]),
    ] {
        let pct = 100.0 * exact as f64 / dataset.len() as f64;
        println!("{name:24} bit-exact sums    {pct:.1}%");
        if exact != dataset.len() {
            failures.push(format!(
                "{name}: only {exact}/{} samples bit-exact vs reference",
                dataset.len()
            ));
        }
    }
    // SIMD lane sweep: every lane width this host offers must hold the
    // same bit-exact bar through the packed engines (the dispatch
    // choice is a speed decision only). Unavailable levels are
    // reported, not silently skipped.
    println!(
        "simd dispatch: auto resolves to {} on this host",
        SimdLevel::detect_best().name()
    );
    for level in SimdLevel::ALL {
        let bar = format!("simd-{}", level.name());
        if !level.is_available() {
            println!("{bar:24} skipped (not available on this host)");
            continue;
        }
        let lanes = WordLanes::new(level)?;
        let bp_mc = tm::BitParallelMulticlass::from_model(&m)?.with_lanes(lanes);
        let bp_co = tm::BitParallelCotm::from_model(&cm)?.with_lanes(lanes);
        let mut exact = 0usize;
        for x in &dataset.features {
            exact += (bp_mc.class_sums(x) == tm::infer::multiclass_class_sums(&m, x)
                && bp_co.class_sums(x) == tm::infer::cotm_class_sums(&cm, x))
                as usize;
        }
        // The batched tile path is held to the same bar as the
        // single-sample path, per lane width.
        let batch = bp_mc.infer_batch(&dataset.features);
        let mut batch_exact = 0usize;
        for (out, x) in batch.iter().zip(&dataset.features) {
            if out.0 == tm::infer::multiclass_class_sums(&m, x) {
                batch_exact += 1;
            }
        }
        let pct = 100.0 * exact.min(batch_exact) as f64 / dataset.len() as f64;
        println!("{bar:24} bit-exact sums    {pct:.1}% (x{} lanes)", level.lanes());
        if exact != dataset.len() || batch_exact != dataset.len() {
            failures.push(format!(
                "{bar}: only {}/{} samples bit-exact vs reference",
                exact.min(batch_exact),
                dataset.len()
            ));
        }
    }
    // Compile-pass bar: pruning and fire-probability reordering must
    // be invisible in the served sums — engines rebuilt from compiled
    // artifacts match the reference scalar walk bit-for-bit, in every
    // mode.
    for mode in [CompileMode::Off, CompileMode::Prune, CompileMode::Full] {
        let mut compiler = ModelCompiler::new(mode);
        if mode == CompileMode::Full {
            compiler = compiler.with_synthetic_calibration(m.params.features, 64, 11);
        }
        let cmc = compiler.clone().compile_multiclass(&m)?;
        let cco = compiler.compile_cotm(&cm)?;
        let bp = tm::BitParallelMulticlass::from_compiled(&cmc)?;
        let co = tm::BitParallelCotm::from_compiled(&cco)?;
        let mut exact = 0usize;
        for x in &dataset.features {
            exact += (tm::BatchEngine::class_sums(&bp, x)
                == tm::infer::multiclass_class_sums(&m, x)
                && tm::BatchEngine::class_sums(&co, x) == tm::infer::cotm_class_sums(&cm, x))
                as usize;
        }
        let bar = format!("compile-{}", mode.name());
        println!(
            "{bar:24} bit-exact sums    {:.1}% ({}/{} live clauses, density {:.3})",
            100.0 * exact as f64 / dataset.len() as f64,
            cmc.stats.live_clauses,
            cmc.stats.total_clauses,
            cmc.stats.density
        );
        if exact != dataset.len() {
            failures.push(format!(
                "{bar}: only {exact}/{} samples bit-exact vs reference",
                dataset.len()
            ));
        }
    }
    // Auto-select is a routing decision, not a numeric one: report
    // where the default three-way thresholds land these models.
    let cfg = ServeConfig::default();
    let (it, ct) = (cfg.indexed_density_threshold, cfg.compressed_density_threshold);
    for (name, density) in [
        ("auto-multiclass", ix_mc.density()),
        ("auto-cotm", ix_co.density()),
    ] {
        let choice = tm::compressed::select_engine(density, it, ct).name();
        println!(
            "{name:24} density {density:.3} -> {choice} (thresholds {it}/{ct})"
        );
    }
    // Trainer-parity bar: the packed-evaluation trainer must reproduce
    // the reference per-literal trainer bit-for-bit for the same seed
    // (few epochs keep selfcheck fast; the full boundary-width sweep is
    // tests/train_equivalence.rs).
    let (ptrain, _) = dataset.split(0.8, 42);
    let tparams = TmParams {
        features: dataset.num_features(),
        classes: dataset.classes,
        ..TmParams::iris_paper()
    };
    let mc_parity = train_multiclass_with(tparams.clone(), &ptrain, 5, 17, TrainerEngine::Reference)?
        == train_multiclass_with(tparams.clone(), &ptrain, 5, 17, TrainerEngine::Packed)?;
    let co_parity = train_cotm_with(tparams.clone(), &ptrain, 5, 19, TrainerEngine::Reference)?
        == train_cotm_with(tparams.clone(), &ptrain, 5, 19, TrainerEngine::Packed)?;
    for (name, ok) in [
        ("trainer-parity-multiclass", mc_parity),
        ("trainer-parity-cotm", co_parity),
    ] {
        println!(
            "{name:24} {}",
            if ok { "bit-identical models" } else { "MODELS DIVERGED" }
        );
        if !ok {
            failures.push(format!(
                "{name}: packed trainer model != reference trainer model for the same seed"
            ));
        }
    }
    // Async-trainer accuracy-parity bar: the clause-parallel tier is
    // deliberately nondeterministic under threading (stale votes, racy
    // schedule), so it is held to a statistical bar instead of
    // bit-identity — over seeded runs, held-out accuracy must land
    // within epsilon of the deterministic reference tier's.
    let (choice, threads) = trainer_choice(args)?;
    println!(
        "trainer config:          {} ({threads} threads for the async tiers)",
        choice.name()
    );
    const ASYNC_PARITY_EPS: f64 = 0.15;
    let async_threads = threads.max(2); // exercise real concurrency
    let (_, ptest) = dataset.split(0.8, 42);
    let (mut worst_mc, mut worst_co) = (0.0f64, 0.0f64);
    for seed in [5u64, 6, 7] {
        let reference =
            train_multiclass_with(tparams.clone(), &ptrain, 10, seed, TrainerEngine::Packed)?;
        let parallel = train_multiclass_async(
            tparams.clone(), &ptrain, 10, seed, async_threads, choice.indexed(),
        )?;
        let d = tm::infer::multiclass_accuracy(&reference, &ptest.features, &ptest.labels)
            - tm::infer::multiclass_accuracy(&parallel, &ptest.features, &ptest.labels);
        worst_mc = worst_mc.max(d.abs());
        let reference =
            train_cotm_with(tparams.clone(), &ptrain, 10, seed, TrainerEngine::Packed)?;
        let parallel = train_cotm_async(
            tparams.clone(), &ptrain, 10, seed, async_threads, choice.indexed(),
        )?;
        let d = tm::infer::cotm_accuracy(&reference, &ptest.features, &ptest.labels)
            - tm::infer::cotm_accuracy(&parallel, &ptest.features, &ptest.labels);
        worst_co = worst_co.max(d.abs());
    }
    for (name, worst) in [
        ("async-parity-multiclass", worst_mc),
        ("async-parity-cotm", worst_co),
    ] {
        println!(
            "{name:24} worst |acc delta| {worst:.3} over 3 seeds \
             ({async_threads} threads, eps {ASYNC_PARITY_EPS})"
        );
        if worst > ASYNC_PARITY_EPS {
            failures.push(format!(
                "{name}: async trainer accuracy drifted {worst:.3} (> {ASYNC_PARITY_EPS}) \
                 from the reference tier"
            ));
        }
    }
    if !failures.is_empty() {
        return Err(Error::model(format!("selfcheck failed: {}", failures.join("; "))));
    }
    println!("selfcheck OK");
    Ok(())
}
