//! Mesh-Like arbiter ([18]): an all-pair cyclic-comparison network.
//!
//! Every unordered class pair (i, j) shares one Mutex; class i's one-hot
//! grant is the conjunction of winning *all* its m−1 pairwise mutexes.
//! m(m−1)/2 cells, winner emerges after m−1 stages — Table I row 2.
//! Denser than the TBA but flat: no multi-level propagation, so for
//! small m its latency can undercut the tree (the Table I trade-off the
//! `wta_explore` example sweeps).

use crate::gates::basic::{Gate, GateOp};
use crate::gates::mutex::Mutex;
use crate::sim::energy::EnergyKind;
use crate::sim::{Circuit, NetId};

/// Build a mesh arbiter over `races`; returns per-class grant nets.
pub fn build_mesh(c: &mut Circuit, name: &str, races: &[NetId]) -> Vec<NetId> {
    let m = races.len();
    assert!(m >= 1);
    if m == 1 {
        return vec![races[0]];
    }
    let tech = c.tech.clone();
    // pairwise_grants[i] = mutex grants class i must win.
    let mut pairwise: Vec<Vec<NetId>> = vec![Vec::with_capacity(m - 1); m];
    for i in 0..m {
        for j in (i + 1)..m {
            let (gi, gj) = Mutex::build(c, &format!("{name}.mx{i}_{j}"), races[i], races[j]);
            pairwise[i].push(gi);
            pairwise[j].push(gj);
        }
    }
    pairwise
        .into_iter()
        .enumerate()
        .map(|(i, path)| {
            if path.len() == 1 {
                path[0]
            } else {
                let out = c.net(format!("{name}.grant{i}"));
                c.add(
                    Box::new(
                        Gate::new(format!("{name}.and{i}"), GateOp::And, path.clone(), out, &tech)
                            .with_energy_kind(EnergyKind::Arbiter),
                    ),
                    path,
                );
                out
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use crate::wta::test_support::race_winner;
    use crate::wta::WtaKind;

    #[test]
    fn first_arrival_wins() {
        assert_eq!(race_winner(WtaKind::Mesh, &[300, 100, 200]), 1);
        assert_eq!(race_winner(WtaKind::Mesh, &[100, 300, 200]), 0);
        assert_eq!(race_winner(WtaKind::Mesh, &[300, 200, 100]), 2);
    }

    #[test]
    fn all_sizes_up_to_eight() {
        for m in 2usize..=8 {
            for winner in 0..m {
                let delays: Vec<u64> = (0..m)
                    .map(|i| if i == winner { 100 } else { 500 + 30 * i as u64 })
                    .collect();
                assert_eq!(
                    race_winner(WtaKind::Mesh, &delays),
                    winner,
                    "m={m} winner={winner}"
                );
            }
        }
    }

    #[test]
    fn close_race_still_one_hot() {
        assert_eq!(race_winner(WtaKind::Mesh, &[100, 102, 101]), 0);
    }

    #[test]
    fn agrees_with_tba_on_random_races() {
        let mut rng = crate::util::SplitMix64::new(123);
        for _ in 0..30 {
            let m = 2 + rng.index(5);
            // Well-separated random delays (≥ 60 ps apart) so both
            // topologies must pick the same unambiguous winner.
            let mut delays: Vec<u64> = (0..m as u64).map(|i| 100 + i * 60).collect();
            rng.shuffle(&mut delays);
            let a = race_winner(WtaKind::Mesh, &delays);
            let b = race_winner(WtaKind::Tba, &delays);
            assert_eq!(a, b, "delays={delays:?}");
        }
    }
}
