//! Theoretical WTA analysis — reproduces paper Table I, and measures the
//! same quantities from the event simulator for cross-validation.

use crate::sim::energy::{GateKind, TechParams};
use crate::sim::{Circuit, Logic, NetId, Time};
use crate::wta::{build, WtaKind};

/// One Table I row.
#[derive(Debug, Clone, PartialEq)]
pub struct WtaAnalysis {
    pub kind: WtaKind,
    pub classes: usize,
    pub arbitration_depth: usize,
    pub cell_count: usize,
    /// Theoretical latency per Table I's formula.
    pub latency_theory: Time,
}

/// Table I row 1: TBA — depth ⌈log₂ m⌉, m−1 cells,
/// latency = log₂m · (d_Mutex + d_OR + d_C-element).
pub fn tba_analysis(m: usize, tech: &TechParams) -> WtaAnalysis {
    assert!(m >= 2);
    let depth = (m as f64).log2().ceil() as usize;
    let d_mutex = tech.gate_delay(GateKind::Nand) + tech.gate_delay(GateKind::Inv);
    let per_layer =
        d_mutex + tech.gate_delay(GateKind::Or) + tech.gate_delay(GateKind::CElement);
    WtaAnalysis {
        kind: WtaKind::Tba,
        classes: m,
        arbitration_depth: depth,
        cell_count: m - 1,
        latency_theory: per_layer.scale(depth as f64),
    }
}

/// Table I row 2: Mesh — depth m−1, m(m−1)/2 cells,
/// latency = (m−1) · d_Mutex.
pub fn mesh_analysis(m: usize, tech: &TechParams) -> WtaAnalysis {
    assert!(m >= 2);
    let d_mutex = tech.gate_delay(GateKind::Nand) + tech.gate_delay(GateKind::Inv);
    WtaAnalysis {
        kind: WtaKind::Mesh,
        classes: m,
        arbitration_depth: m - 1,
        cell_count: m * (m - 1) / 2,
        latency_theory: d_mutex.scale((m - 1) as f64),
    }
}

/// Measured arbitration latency: drive class 0 first by a wide margin and
/// report grant time − first-arrival time.
pub fn measured_latency(kind: WtaKind, m: usize, tech: &TechParams) -> Time {
    let mut c = Circuit::new(tech.clone());
    let races: Vec<NetId> = (0..m)
        .map(|i| c.net_init(format!("race{i}"), Logic::Zero))
        .collect();
    let wta = build(&mut c, kind, "wta", &races);
    c.init_components();
    c.run_to_quiescence().unwrap();
    let t0 = Time::ps(100);
    for (i, &r) in races.iter().enumerate() {
        let d = if i == 0 { t0 } else { t0 + Time::ps(2_000 * (i as u64 + 1)) };
        c.drive(r, Logic::One, d);
    }
    let g0 = wta.grants[0];
    let fired = c
        .run_while(Time::ns(10_000), |cc| cc.value(g0) == Logic::One)
        .unwrap();
    assert!(fired, "grant never issued");
    c.now().since(t0)
}

/// Measured arbitration energy for a single race resolution (fJ).
pub fn measured_energy_fj(kind: WtaKind, m: usize, tech: &TechParams) -> f64 {
    let mut c = Circuit::new(tech.clone());
    let races: Vec<NetId> = (0..m)
        .map(|i| c.net_init(format!("race{i}"), Logic::Zero))
        .collect();
    build(&mut c, kind, "wta", &races);
    c.init_components();
    c.run_to_quiescence().unwrap();
    let before = c.energy.dynamic_fj(crate::sim::EnergyKind::Arbiter);
    for (i, &r) in races.iter().enumerate() {
        c.drive(r, Logic::One, Time::ps(100 + 80 * i as u64));
    }
    c.run_to_quiescence().unwrap();
    c.energy.dynamic_fj(crate::sim::EnergyKind::Arbiter) - before
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_formulas() {
        let t = TechParams::tsmc65_digital();
        let tba = tba_analysis(8, &t);
        assert_eq!(tba.arbitration_depth, 3);
        assert_eq!(tba.cell_count, 7);
        let mesh = mesh_analysis(8, &t);
        assert_eq!(mesh.arbitration_depth, 7);
        assert_eq!(mesh.cell_count, 28);
    }

    #[test]
    fn tba_cells_scale_linearly_mesh_quadratically() {
        let t = TechParams::tsmc65_digital();
        assert_eq!(tba_analysis(64, &t).cell_count, 63);
        assert_eq!(mesh_analysis(64, &t).cell_count, 2016);
    }

    #[test]
    fn measured_latency_orders_match_theory_for_large_m() {
        let t = TechParams::tsmc65_digital();
        // For large m the tree's log depth beats the mesh's flat AND of
        // m−1 grants only in cell count; latency-wise our mesh resolves
        // all pairs concurrently, so just sanity-check both are positive
        // and TBA grows with depth.
        let tba4 = measured_latency(WtaKind::Tba, 4, &t);
        let tba16 = measured_latency(WtaKind::Tba, 16, &t);
        assert!(tba16 > tba4, "tree latency grows with depth");
        let mesh4 = measured_latency(WtaKind::Mesh, 4, &t);
        assert!(mesh4 > Time::ZERO);
    }

    #[test]
    fn mesh_energy_exceeds_tba_energy_for_large_m() {
        // m(m−1)/2 cells vs m−1 cells — energy must reflect it.
        let t = TechParams::tsmc65_digital();
        let e_tba = measured_energy_fj(WtaKind::Tba, 16, &t);
        let e_mesh = measured_energy_fj(WtaKind::Mesh, 16, &t);
        assert!(e_mesh > e_tba, "mesh {e_mesh} <= tba {e_tba}");
    }
}
