//! Winner-Takes-All arbitration (paper §II-C.4, Table I).
//!
//! The WTA monitors the rising edges of the m concurrent race signals
//! `RaceClass[m-1:0]` and grants the first arrival — it is the terminal
//! of the time-domain path and implements argmax. Two topologies:
//!
//! * [`tba`] — Tree-Based Arbiter: ⌈log₂ m⌉ layers, m−1 Mutex cells.
//! * [`mesh`] — Mesh-Like arbiter: all-pair cyclic comparison,
//!   m(m−1)/2 Mutex cells, winner after m−1 stages.
//!
//! [`analysis`] reproduces Table I's theoretical columns.

pub mod analysis;
pub mod mesh;
pub mod tba;

use crate::sim::{Circuit, NetId};

/// Which arbiter topology to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WtaKind {
    Tba,
    Mesh,
}

impl WtaKind {
    pub fn name(self) -> &'static str {
        match self {
            WtaKind::Tba => "tba",
            WtaKind::Mesh => "mesh",
        }
    }
}

/// A built arbiter: one grant net per competing class (one-hot).
pub struct Wta {
    pub kind: WtaKind,
    pub grants: Vec<NetId>,
}

/// Build an arbiter of the chosen topology over `races`.
pub fn build(c: &mut Circuit, kind: WtaKind, name: &str, races: &[NetId]) -> Wta {
    let grants = match kind {
        WtaKind::Tba => tba::build_tba(c, name, races),
        WtaKind::Mesh => mesh::build_mesh(c, name, races),
    };
    Wta { kind, grants }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::{Logic, Time};

    /// Drive races with the given delays (ps); return the granted index.
    pub fn race_winner(kind: WtaKind, delays_ps: &[u64]) -> usize {
        let t = TechParams::tsmc65_digital();
        let mut c = Circuit::new(t);
        let races: Vec<NetId> = (0..delays_ps.len())
            .map(|i| c.net_init(format!("race{i}"), Logic::Zero))
            .collect();
        let wta = build(&mut c, kind, "wta", &races);
        c.init_components();
        c.run_to_quiescence().unwrap();
        for (i, &d) in delays_ps.iter().enumerate() {
            c.drive(races[i], Logic::One, Time::ps(d));
        }
        c.run_to_quiescence().unwrap();
        let granted: Vec<usize> = wta
            .grants
            .iter()
            .enumerate()
            .filter(|(_, g)| c.value(**g) == Logic::One)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(granted.len(), 1, "{kind:?}: grants not one-hot: {granted:?}");
        granted[0]
    }
}
