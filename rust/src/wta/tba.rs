//! Tree-Based Arbiter ([12]): a binary tournament of Mutex cells.
//!
//! Each node arbitrates two subtree winners; the local winner's request
//! propagates upward through an OR gate until the root recognises the
//! global winner. For m classes: ⌈log₂ m⌉ layers, m−1 Mutex cells,
//! latency ≈ log₂m · (d_Mutex + d_OR [+ d_C-element for QDI completion])
//! — Table I row 1.
//!
//! The per-class one-hot grant is the AND of the class's grant chain
//! down the tree (a class wins iff it won at every level).

use crate::gates::basic::{Gate, GateOp};
use crate::gates::delay::DelayElement;
use crate::gates::mutex::Mutex;
use crate::sim::energy::{EnergyKind, GateKind};
use crate::sim::{Circuit, NetId};

struct Node {
    /// Request propagating up from this subtree.
    req: NetId,
    /// (class index, mutex grants the class must win along its path).
    members: Vec<(usize, Vec<NetId>)>,
}

/// Build a TBA over `races`; returns per-class grant nets.
pub fn build_tba(c: &mut Circuit, name: &str, races: &[NetId]) -> Vec<NetId> {
    assert!(!races.is_empty());
    let tech = c.tech.clone();
    let mut level: Vec<Node> = races
        .iter()
        .enumerate()
        .map(|(i, &r)| Node { req: r, members: vec![(i, Vec::new())] })
        .collect();
    let mut depth = 0usize;
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        let mut pair_idx = 0usize;
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => {
                    let prefix = format!("{name}.l{depth}n{pair_idx}");
                    let (ga, gb) = Mutex::build(c, &prefix, a.req, b.req);
                    // Local winner's request propagates up.
                    let up = c.net(format!("{prefix}.up"));
                    c.add(
                        Box::new(
                            Gate::new(format!("{prefix}.or"), GateOp::Or, vec![ga, gb], up, &tech)
                                .with_energy_kind(EnergyKind::Arbiter),
                        ),
                        vec![ga, gb],
                    );
                    let mut members = Vec::with_capacity(a.members.len() + b.members.len());
                    for (cls, mut path) in a.members {
                        path.push(ga);
                        members.push((cls, path));
                    }
                    for (cls, mut path) in b.members {
                        path.push(gb);
                        members.push((cls, path));
                    }
                    next.push(Node { req: up, members });
                }
                None => {
                    // Bye: forwarded through a *matching delay* equal to
                    // one arbitration layer (Mutex + OR), so arrival
                    // order at the next level reflects input order — the
                    // standard fairness fix for non-power-of-two trees.
                    let matched = c.net(format!("{name}.l{depth}bye{pair_idx}"));
                    let d = tech.gate_delay(GateKind::Nand)
                        + tech.gate_delay(GateKind::Inv)
                        + tech.gate_delay(GateKind::Or);
                    c.add(
                        Box::new(DelayElement::new(
                            format!("{name}.l{depth}bye{pair_idx}.del"),
                            a.req,
                            matched,
                            d,
                            &tech,
                        )),
                        vec![a.req],
                    );
                    next.push(Node { req: matched, members: a.members });
                }
            }
            pair_idx += 1;
        }
        level = next;
        depth += 1;
    }
    // Emit one-hot grants: AND of each class's grant path.
    let root = level.pop().unwrap();
    let mut grants = vec![NetId(u32::MAX); races.len()];
    for (cls, path) in root.members {
        let g = match path.len() {
            0 => races[cls], // single competitor: its race is its grant
            1 => path[0],
            _ => {
                let out = c.net(format!("{name}.grant{cls}"));
                c.add(
                    Box::new(
                        Gate::new(
                            format!("{name}.and{cls}"),
                            GateOp::And,
                            path.clone(),
                            out,
                            &tech,
                        )
                        .with_energy_kind(EnergyKind::Arbiter),
                    ),
                    path,
                );
                out
            }
        };
        grants[cls] = g;
    }
    grants
}

#[cfg(test)]
mod tests {
    use crate::wta::test_support::race_winner;
    use crate::wta::WtaKind;

    #[test]
    fn first_arrival_wins_three_way() {
        assert_eq!(race_winner(WtaKind::Tba, &[300, 100, 200]), 1);
        assert_eq!(race_winner(WtaKind::Tba, &[100, 300, 200]), 0);
        assert_eq!(race_winner(WtaKind::Tba, &[300, 200, 100]), 2);
    }

    #[test]
    fn works_for_non_power_of_two() {
        for m in [3usize, 5, 6, 7] {
            for winner in 0..m {
                let delays: Vec<u64> = (0..m)
                    .map(|i| if i == winner { 100 } else { 400 + 50 * i as u64 })
                    .collect();
                assert_eq!(
                    race_winner(WtaKind::Tba, &delays),
                    winner,
                    "m={m} winner={winner}"
                );
            }
        }
    }

    #[test]
    fn close_race_still_one_hot() {
        // 1 ps apart: metastability dwell, but exactly one grant.
        assert_eq!(race_winner(WtaKind::Tba, &[100, 101, 500, 500]), 0);
        assert_eq!(race_winner(WtaKind::Tba, &[101, 100, 500, 500]), 1);
    }

    #[test]
    fn two_way_degenerates_to_single_mutex() {
        assert_eq!(race_winner(WtaKind::Tba, &[200, 100]), 1);
    }

    #[test]
    fn exact_tie_resolves_deterministically() {
        // Equal arrivals: exactly one grant (asserted inside race_winner)
        // and the outcome is reproducible — which side wins a true tie is
        // a topology property, not a specification.
        let a = race_winner(WtaKind::Tba, &[100, 100, 100]);
        let b = race_winner(WtaKind::Tba, &[100, 100, 100]);
        assert_eq!(a, b);
    }
}
