//! Four-to-two phase interface (paper §II-C.5).
//!
//! The classification module is four-phase QDI (launch/return-to-zero
//! controlled by Muller C-elements); the TM pipeline controller is
//! two-phase bundled data. The boundary is bridged by:
//!
//! * request side — a two-phase toggle on `req2` produces a four-phase
//!   `req4↑`; the module's completion (`done4↑`) lets `req4` return to
//!   zero (C-element discipline);
//! * acknowledge side — a TFF converts the four-phase `done4` pulse into
//!   a two-phase `ack2` toggle.

use crate::sim::energy::{EnergyKind, GateKind};
use crate::sim::{Component, Ctx, Logic, NetId, Time};

/// Behavioural 4↔2 phase bridge.
/// Pins: `[req2, done4, rst]`; outputs: `req4` (RTZ level), `ack2` (toggle).
pub struct Phase4To2 {
    name: String,
    req2: NetId,
    done4: NetId,
    rst: NetId,
    req4: NetId,
    ack2: NetId,
    last_req2: Logic,
    last_done4: Logic,
    ack_phase: bool,
    delay: Time,
    e_fj: f64,
    pub launches: u64,
}

impl Phase4To2 {
    pub fn new(
        name: impl Into<String>,
        req2: NetId,
        done4: NetId,
        rst: NetId,
        req4: NetId,
        ack2: NetId,
        tech: &crate::sim::TechParams,
    ) -> Phase4To2 {
        Phase4To2 {
            name: name.into(),
            req2,
            done4,
            rst,
            req4,
            ack2,
            last_req2: Logic::Zero,
            last_done4: Logic::Zero,
            ack_phase: false,
            delay: tech.gate_delay(GateKind::CElement),
            e_fj: tech.gate_energy_fj(GateKind::CElement)
                + tech.gate_energy_fj(GateKind::Tff),
            launches: 0,
        }
    }
}

impl Component for Phase4To2 {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        ctx.schedule(self.req4, Logic::Zero, Time::ZERO);
        ctx.schedule(self.ack2, Logic::Zero, Time::ZERO);
    }

    fn on_input(&mut self, pin: usize, ctx: &mut Ctx) {
        if ctx.get(self.rst) == Logic::One {
            self.last_req2 = Logic::Zero;
            self.last_done4 = Logic::Zero;
            self.ack_phase = false;
            ctx.schedule_if_changed(self.req4, Logic::Zero, self.delay);
            ctx.schedule_if_changed(self.ack2, Logic::Zero, self.delay);
            return;
        }
        match pin {
            0 => {
                // Two-phase request: any defined toggle launches req4↑.
                let v = ctx.get(self.req2);
                if v.is_defined() && v != self.last_req2 {
                    self.last_req2 = v;
                    self.launches += 1;
                    ctx.spend(EnergyKind::Handshake, self.e_fj);
                    ctx.schedule(self.req4, Logic::One, self.delay);
                }
            }
            1 => {
                let v = ctx.get(self.done4);
                let rising = self.last_done4 == Logic::Zero && v == Logic::One;
                let falling = self.last_done4 == Logic::One && v == Logic::Zero;
                if v.is_defined() {
                    self.last_done4 = v;
                }
                if rising {
                    // Completion: return req4 to zero and toggle ack2 (TFF).
                    self.ack_phase = !self.ack_phase;
                    ctx.spend(EnergyKind::Handshake, self.e_fj);
                    ctx.schedule(self.req4, Logic::Zero, self.delay);
                    ctx.schedule(
                        self.ack2,
                        Logic::from_bool(self.ack_phase),
                        self.delay + self.delay,
                    );
                } else if falling {
                    // RTZ of done completes the four-phase cycle; nothing
                    // to emit on the two-phase side.
                }
            }
            _ => {}
        }
    }

    fn gate_equivalents(&self) -> f64 {
        10.0 // C-element + TFF + glue
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::Circuit;

    fn fixture() -> (Circuit, NetId, NetId, NetId, NetId) {
        let t = TechParams::tsmc65_digital();
        let mut c = Circuit::new(t.clone());
        let req2 = c.net_init("req2", Logic::Zero);
        let done4 = c.net_init("done4", Logic::Zero);
        let rst = c.net_init("rst", Logic::Zero);
        let req4 = c.net("req4");
        let ack2 = c.net("ack2");
        c.add(
            Box::new(Phase4To2::new("if", req2, done4, rst, req4, ack2, &t)),
            vec![req2, done4, rst],
        );
        c.init_components();
        c.run_to_quiescence().unwrap();
        (c, req2, done4, req4, ack2)
    }

    #[test]
    fn toggle_launches_four_phase_request() {
        let (mut c, req2, _done4, req4, _ack2) = fixture();
        c.drive(req2, Logic::One, Time::ps(10));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(req4), Logic::One);
    }

    #[test]
    fn done_returns_req_to_zero_and_toggles_ack() {
        let (mut c, req2, done4, req4, ack2) = fixture();
        c.drive(req2, Logic::One, Time::ps(10));
        c.run_to_quiescence().unwrap();
        c.drive(done4, Logic::One, Time::ps(10));
        c.drive(done4, Logic::Zero, Time::ps(40));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(req4), Logic::Zero);
        assert_eq!(c.value(ack2), Logic::One); // first toggle

        // Second transaction: req2 toggles back to 0.
        c.drive(req2, Logic::Zero, Time::ps(10));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(req4), Logic::One);
        c.drive(done4, Logic::One, Time::ps(10));
        c.drive(done4, Logic::Zero, Time::ps(40));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(ack2), Logic::Zero); // second toggle
    }

    #[test]
    fn both_req2_polarities_launch() {
        let (mut c, req2, done4, req4, _ack2) = fixture();
        for (i, v) in [Logic::One, Logic::Zero, Logic::One].iter().enumerate() {
            c.drive(req2, *v, Time::ps(10));
            c.run_to_quiescence().unwrap();
            assert_eq!(c.value(req4), Logic::One, "launch {i}");
            c.drive(done4, Logic::One, Time::ps(10));
            c.drive(done4, Logic::Zero, Time::ps(40));
            c.run_to_quiescence().unwrap();
            assert_eq!(c.value(req4), Logic::Zero, "rtz {i}");
        }
    }
}
