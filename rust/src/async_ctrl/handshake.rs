//! Handshake protocol monitors — checker components that assert protocol
//! legality during simulation (the software analogue of SVA protocol
//! assertions in the paper's verification flow). Used by integration
//! tests and by the failure-injection suite.
//!
//! Monitors publish their counters through shared [`Counters`] handles so
//! tests can inspect them after the monitor is boxed into the circuit.

use std::cell::Cell;
use std::rc::Rc;

use crate::sim::{Component, Ctx, Logic, NetId};

/// Shared observation counters for a protocol monitor.
#[derive(Debug, Clone, Default)]
pub struct Counters {
    pub violations: Rc<Cell<u64>>,
    pub transactions: Rc<Cell<u64>>,
    pub outstanding: Rc<Cell<i64>>,
}

impl Counters {
    pub fn new() -> Counters {
        Counters::default()
    }
    fn violate(&self) {
        self.violations.set(self.violations.get() + 1);
    }
    fn complete(&self) {
        self.transactions.set(self.transactions.get() + 1);
    }
}

/// Two-phase (transition-signalling) monitor: every transition on
/// `req`/`ack` is an event; legality = strict req/ack alternation.
pub struct TwoPhaseMonitor {
    name: String,
    req: NetId,
    ack: NetId,
    pub counters: Counters,
}

impl TwoPhaseMonitor {
    pub fn new(name: impl Into<String>, req: NetId, ack: NetId, counters: Counters) -> Self {
        TwoPhaseMonitor { name: name.into(), req, ack, counters }
    }
}

impl Component for TwoPhaseMonitor {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_input(&mut self, pin: usize, ctx: &mut Ctx) {
        let v = if pin == 0 { ctx.get(self.req) } else { ctx.get(self.ack) };
        if !v.is_defined() {
            return;
        }
        let out = &self.counters.outstanding;
        if pin == 0 {
            out.set(out.get() + 1);
            if out.get() > 1 {
                self.counters.violate(); // second req before ack
            }
        } else {
            out.set(out.get() - 1);
            if out.get() < 0 {
                self.counters.violate(); // ack without req
            } else {
                self.counters.complete();
            }
        }
    }

    fn gate_equivalents(&self) -> f64 {
        0.0 // testbench artefact, not silicon
    }
}

/// Four-phase (return-to-zero) monitor: legal per-transaction sequence is
/// `req↑ ack↑ req↓ ack↓`.
pub struct FourPhaseMonitor {
    name: String,
    req: NetId,
    ack: NetId,
    state: u8, // 0 idle, 1 req↑, 2 ack↑, 3 req↓ (awaiting ack↓)
    pub counters: Counters,
}

impl FourPhaseMonitor {
    pub fn new(name: impl Into<String>, req: NetId, ack: NetId, counters: Counters) -> Self {
        FourPhaseMonitor { name: name.into(), req, ack, state: 0, counters }
    }

    /// Whether observed levels are consistent with the current state
    /// (filters notifications that carry no edge for this monitor).
    fn consistent(&self, req: Logic, ack: Logic) -> bool {
        match self.state {
            0 => req == Logic::Zero && ack == Logic::Zero,
            1 => req == Logic::One && ack == Logic::Zero,
            2 => req == Logic::One && ack == Logic::One,
            3 => req == Logic::Zero && ack == Logic::One,
            _ => false,
        }
    }
}

impl Component for FourPhaseMonitor {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_input(&mut self, pin: usize, ctx: &mut Ctx) {
        let req = ctx.get(self.req);
        let ack = ctx.get(self.ack);
        if !req.is_defined() || !ack.is_defined() {
            return;
        }
        match (self.state, pin) {
            (0, 0) if req == Logic::One => self.state = 1,
            (1, 1) if ack == Logic::One => self.state = 2,
            (2, 0) if req == Logic::Zero => self.state = 3,
            (3, 1) if ack == Logic::Zero => {
                self.state = 0;
                self.counters.complete();
            }
            _ if self.consistent(req, ack) => {}
            _ => self.counters.violate(),
        }
    }

    fn gate_equivalents(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::{Circuit, Time};

    #[test]
    fn two_phase_alternation_is_clean() {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let req = c.net_init("req", Logic::Zero);
        let ack = c.net_init("ack", Logic::Zero);
        let ctr = Counters::new();
        c.add(
            Box::new(TwoPhaseMonitor::new("mon", req, ack, ctr.clone())),
            vec![req, ack],
        );
        let mut t = Time::ps(10);
        for i in 0..4 {
            let v = if i % 2 == 0 { Logic::One } else { Logic::Zero };
            c.drive(req, v, t);
            t += Time::ps(10);
            c.drive(ack, v, t);
            t += Time::ps(10);
        }
        c.run_to_quiescence().unwrap();
        assert_eq!(ctr.violations.get(), 0);
        assert_eq!(ctr.transactions.get(), 4);
        assert_eq!(ctr.outstanding.get(), 0);
    }

    #[test]
    fn two_phase_double_req_flags_violation() {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let req = c.net_init("req", Logic::Zero);
        let ack = c.net_init("ack", Logic::Zero);
        let ctr = Counters::new();
        c.add(
            Box::new(TwoPhaseMonitor::new("mon", req, ack, ctr.clone())),
            vec![req, ack],
        );
        c.drive(req, Logic::One, Time::ps(10));
        c.drive(req, Logic::Zero, Time::ps(20)); // second token, no ack
        c.run_to_quiescence().unwrap();
        assert_eq!(ctr.violations.get(), 1);
    }

    #[test]
    fn four_phase_full_transaction_counted() {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let req = c.net_init("req", Logic::Zero);
        let ack = c.net_init("ack", Logic::Zero);
        let ctr = Counters::new();
        c.add(
            Box::new(FourPhaseMonitor::new("mon", req, ack, ctr.clone())),
            vec![req, ack],
        );
        for base in [10u64, 100] {
            c.drive(req, Logic::One, Time::ps(base));
            c.drive(ack, Logic::One, Time::ps(base + 10));
            c.drive(req, Logic::Zero, Time::ps(base + 20));
            c.drive(ack, Logic::Zero, Time::ps(base + 30));
        }
        c.run_to_quiescence().unwrap();
        assert_eq!(ctr.violations.get(), 0);
        assert_eq!(ctr.transactions.get(), 2);
    }

    #[test]
    fn four_phase_early_ack_drop_is_violation() {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let req = c.net_init("req", Logic::Zero);
        let ack = c.net_init("ack", Logic::Zero);
        let ctr = Counters::new();
        c.add(
            Box::new(FourPhaseMonitor::new("mon", req, ack, ctr.clone())),
            vec![req, ack],
        );
        c.drive(req, Logic::One, Time::ps(10));
        c.drive(ack, Logic::One, Time::ps(20));
        c.drive(ack, Logic::Zero, Time::ps(30)); // ack↓ before req↓
        c.run_to_quiescence().unwrap();
        assert!(ctr.violations.get() >= 1);
    }
}
