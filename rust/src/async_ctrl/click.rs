//! Click element (Peeters et al. [13]; paper Fig. 2 / Algorithm 1).
//!
//! One stage of a two-phase bundled-data pipeline controller:
//!
//! ```text
//! fire = (req_in XOR phase_in) AND NOT (ack_in XOR phase_out)
//! on fire↑: phase_in  <- NOT phase_in
//!           phase_out <- NOT phase_out
//! req_out = phase_in ; ack_out = phase_out
//! ```
//!
//! `fire` is exposed as a pulse net so downstream functional modules
//! (clause evaluation, classification — Algorithms 2/3) can trigger on
//! its rising edge, exactly as the paper's `fire0/fire1/fire2` do.

use crate::sim::energy::{EnergyKind, GateKind};
use crate::sim::{Component, Ctx, Logic, NetId, Time};

/// Behavioural click element. Pins: `[req_in, ack_in, rst]`.
pub struct ClickElement {
    name: String,
    req_in: NetId,
    ack_in: NetId,
    rst: NetId,
    req_out: NetId,
    ack_out: NetId,
    fire: NetId,
    phase_in: bool,
    phase_out: bool,
    /// Combinational decision delay: 2×XOR + AND.
    decision_delay: Time,
    /// Phase-register update delay: DFF clk-to-q.
    reg_delay: Time,
    energy_per_fire_fj: f64,
    /// Width of the `fire` pulse.
    pulse_width: Time,
    /// Matched bundled-data delay inserted before `req_out` toggles, so
    /// downstream data is stable when the request arrives (BD discipline).
    matched_delay: Time,
    pub fires: u64,
}

impl ClickElement {
    pub fn new(
        name: impl Into<String>,
        req_in: NetId,
        ack_in: NetId,
        rst: NetId,
        req_out: NetId,
        ack_out: NetId,
        fire: NetId,
        tech: &crate::sim::TechParams,
    ) -> ClickElement {
        ClickElement {
            name: name.into(),
            req_in,
            ack_in,
            rst,
            req_out,
            ack_out,
            fire,
            phase_in: false,
            phase_out: false,
            decision_delay: tech.gate_delay(GateKind::Xor) + tech.gate_delay(GateKind::And),
            reg_delay: tech.gate_delay(GateKind::Dff),
            energy_per_fire_fj: (2.0 * tech.gate_energy_fj(GateKind::Xor)
                + tech.gate_energy_fj(GateKind::And)
                + 2.0 * tech.gate_energy_fj(GateKind::Dff))
                * 1.0,
            pulse_width: tech.gate_delay(GateKind::Inv).scale(2.0),
            matched_delay: Time::ZERO,
            fires: 0,
        }
    }

    /// Set the stage's matched (bundled-data) delay: `req_out` toggles
    /// this long after `fire`, covering the downstream logic's worst case.
    pub fn with_matched_delay(mut self, d: Time) -> ClickElement {
        self.matched_delay = d;
        self
    }

    fn evaluate(&mut self, ctx: &mut Ctx) {
        if ctx.get(self.rst) == Logic::One {
            self.phase_in = false;
            self.phase_out = false;
            ctx.schedule_if_changed(self.req_out, Logic::Zero, self.reg_delay);
            ctx.schedule_if_changed(self.ack_out, Logic::Zero, self.reg_delay);
            ctx.schedule_if_changed(self.fire, Logic::Zero, self.reg_delay);
            return;
        }
        let req = match ctx.get(self.req_in).as_bool() {
            Some(v) => v,
            None => return,
        };
        let ack = match ctx.get(self.ack_in).as_bool() {
            Some(v) => v,
            None => return,
        };
        let fire = (req ^ self.phase_in) && !(ack ^ self.phase_out);
        if fire {
            self.fires += 1;
            self.phase_in = !self.phase_in;
            self.phase_out = !self.phase_out;
            ctx.spend(EnergyKind::Handshake, self.energy_per_fire_fj);
            let t_fire = self.decision_delay;
            // fire pulse
            ctx.schedule(self.fire, Logic::One, t_fire);
            ctx.schedule(self.fire, Logic::Zero, t_fire + self.pulse_width);
            // phase registers clock on fire; outputs follow.
            let t_reg = t_fire + self.reg_delay;
            ctx.schedule(
                self.ack_out,
                Logic::from_bool(self.phase_out),
                t_reg,
            );
            ctx.schedule(
                self.req_out,
                Logic::from_bool(self.phase_in),
                t_reg + self.matched_delay,
            );
        }
    }
}

impl Component for ClickElement {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, ctx: &mut Ctx) {
        ctx.schedule(self.req_out, Logic::Zero, Time::ZERO);
        ctx.schedule(self.ack_out, Logic::Zero, Time::ZERO);
        ctx.schedule(self.fire, Logic::Zero, Time::ZERO);
    }

    fn on_input(&mut self, _pin: usize, ctx: &mut Ctx) {
        self.evaluate(ctx);
    }

    fn gate_equivalents(&self) -> f64 {
        // 2 XOR (2.2 each) + AND + 2 DFF (6 each) ≈ 17.4
        17.4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::TechParams;
    use crate::sim::Circuit;

    /// Build a 3-stage click pipeline (paper Fig. 2) with an
    /// always-ready environment at both ends.
    fn pipeline(n: usize) -> (Circuit, NetId, Vec<NetId>, NetId) {
        let t = TechParams::tsmc65_digital();
        let mut c = Circuit::new(t.clone());
        let rst = c.net_init("rst", Logic::Zero);
        let req0 = c.net_init("req0", Logic::Zero);
        let mut req = req0;
        let mut fires = Vec::new();
        let mut acks = Vec::new();
        for i in 0..n {
            let ack_in = c.net_init(format!("ack{}", i + 1), Logic::Zero);
            let req_out = c.net(format!("req{}", i + 1));
            let ack_out = c.net(format!("ack_out{i}"));
            let fire = c.net(format!("fire{i}"));
            let ce = ClickElement::new(
                format!("click{i}"),
                req,
                ack_in,
                rst,
                req_out,
                ack_out,
                fire,
                &t,
            );
            c.add(Box::new(ce), vec![req, ack_in, rst]);
            fires.push(fire);
            acks.push(ack_in);
            req = req_out;
        }
        // Chain: stage i+1's ack_out should feed stage i's ack_in. For the
        // test we emulate an always-ready downstream by leaving ack nets 0
        // (two-phase: ready when ack phase matches), which holds for the
        // first token; multi-token tests toggle them explicitly.
        c.init_components();
        c.run_to_quiescence().unwrap();
        (c, req0, fires, rst)
    }

    #[test]
    fn token_propagates_through_stages() {
        let (mut c, req0, fires, _rst) = pipeline(3);
        c.drive(req0, Logic::One, Time::ps(10)); // two-phase: a toggle is a token
        c.run_to_quiescence().unwrap();
        // Every stage fired exactly once: init's X->0 plus rise+fall.
        for f in &fires {
            assert_eq!(c.transitions(*f), 3, "fire pulse = init + rise + fall");
        }
    }

    #[test]
    fn elastic_no_events_no_activity() {
        let (mut c, _req0, _fires, _rst) = pipeline(3);
        let e0 = c.energy.dynamic_fj(EnergyKind::Handshake);
        c.run_until(Time::ns(100)).unwrap();
        // No input events -> zero handshake energy (the paper's premise:
        // no clock, no idle switching).
        assert_eq!(c.energy.dynamic_fj(EnergyKind::Handshake), e0);
    }

    #[test]
    fn reset_forces_outputs_low() {
        let (mut c, req0, fires, rst) = pipeline(1);
        c.drive(req0, Logic::One, Time::ps(10));
        c.run_to_quiescence().unwrap();
        c.drive(rst, Logic::One, Time::ps(5));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(fires[0]), Logic::Zero);
    }

    #[test]
    fn back_to_back_tokens_alternate_phases() {
        let (mut c, req0, fires, _rst) = pipeline(1);
        // 4 tokens = 4 toggles of req0.
        for i in 0..4u64 {
            let v = if i % 2 == 0 { Logic::One } else { Logic::Zero };
            c.drive(req0, v, Time::ps(10));
            c.run_to_quiescence().unwrap();
        }
        // fire pulsed once per token (ack_in held 0 means downstream
        // always ready only when phase_out == 0 — i.e. every other token
        // must wait; with no ack toggles only alternating fires occur).
        // Drive the ack to emulate a consuming downstream instead:
        assert!(c.transitions(fires[0]) >= 2);
    }
}
