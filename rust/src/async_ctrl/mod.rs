//! Asynchronous control fabric: click-element pipeline controllers
//! (two-phase bundled-data, Fig. 2 / Algorithm 1), handshake protocol
//! monitors, and the four-to-two phase interface (§II-C.5).

pub mod click;
pub mod handshake;
pub mod phase_iface;

pub use click::ClickElement;
pub use handshake::{FourPhaseMonitor, TwoPhaseMonitor};
pub use phase_iface::Phase4To2;
