//! Hand-rolled CLI argument parsing (no clap offline).
//!
//! Grammar: `tmtd <subcommand> [--flag value] [--switch]`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: subcommand, flags, positional args.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut it = raw.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut args = Args { command, ..Args::default() };
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::config("bare `--` not supported"));
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    args.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("bad value for --{name}: {v:?}"))),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
tmtd — event-driven digital-time-domain Tsetlin machine inference

USAGE: tmtd <command> [options]

COMMANDS:
  train      Train models on a dataset and save them
             --dataset iris|xor|blobs  --out-dir models/ --epochs N --seed N
             [--trainer packed|reference|async|async-indexed]
             [--threads N] [--config serve.toml]
             (default packed: clause evaluation through incrementally-
              maintained packed include words; bit-identical to the
              reference trainer per seed. async partitions clauses
              across --threads workers that train against stale
              relaxed-atomic class sums — near-linear multicore
              scaling, statistically equivalent rather than
              bit-reproducible; async-indexed additionally routes
              feedback through per-worker literal->clause postings so
              sparse models pay O(touched literals) per update.
              --config reads trainer/train_threads defaults from
              serve.toml; the flags override)
  infer      Run one inference through a backend
             --backend <name> --model-dir models/ --sample N
  eval       Evaluate all six architectures (Table IV)
             --epochs N --seed N [--wta tba|mesh]
  table1     WTA theoretical + measured analysis (Table I)
  table3     State-of-the-art comparison (Table III)
  table4     Alias of `eval`
  waveform   Dump VCD waveforms for Figs. 6-8  --out-dir waves/
  compile    Compile saved models into serving artifacts (.tmc)
             --model-dir models/ [--out-dir models/]
             [--mode off|prune|full] [--calib-samples N --seed N]
             (prune drops dead clauses bit-exactly; full additionally
              reorders clauses by fire probability measured on a
              synthetic calibration batch — outputs stay identical)
  serve      Run the serving coordinator demo
             --config serve.toml --requests N [--no-golden] [--shards N]
             [--simd auto|scalar|portable|neon|avx2|avx512]
             [--compile off|prune|full]
             [--remote-shards host:port,host:port,...] [--drain]
             (--shards N fronts N coordinator shards with a
              deterministic consistent-hash ring; default from config.
              --remote-shards routes over TCP to running `tmtd shard`
              processes instead — same ring, same routing; --drain
              gracefully stops the remote shards afterwards)
  shard      Serve one coordinator shard over TCP (see docs/DEPLOY.md)
             --listen host:port [--config serve.toml]
             [--model multiclass.tmc --cotm-model cotm.tmc]
             [--simd ...] [--compile off|prune|full]
             (pins the compiled .tmc artifact pair from `tmtd compile`;
              without them a demo iris pair is trained in-process.
              Runs until a Drain message arrives)
  selfcheck  Train + verify every backend agrees on Iris, that the
             packed trainer reproduces the reference trainer
             bit-for-bit, that the async clause-parallel trainer stays
             within epsilon of the reference tier's accuracy over
             seeded runs (printing the configured trainer + thread
             count), and that every available SIMD lane width
             (scalar/portable/neon/avx2/avx512) is bit-exact
  help       Show this text

Backends: golden-multiclass golden-cotm bitpar-multiclass bitpar-cotm
          indexed-multiclass indexed-cotm
          compressed-multiclass compressed-cotm
          auto-multiclass auto-cotm
          multiclass-sync multiclass-async-bd multiclass-proposed
          cotm-sync cotm-async-bd cotm-proposed

bitpar-* is the native bit-parallel serving tier (packed-word clause
evaluation, dynamically batched; no artifacts needed).
indexed-* is the event-driven inverted-index tier (literal->clause
postings + unsatisfied-literal counters; only clauses a sample's set
literals touch are visited — the fast path for sparse models).
compressed-* is the compressed-clause tier (each clause stored as its
sorted include-literal list, hot literals reordered first; evaluation
walks only the includes and early-exits on the first unsatisfied one —
the fast path for moderately sparse models).
auto-* picks indexed vs compressed vs packed per compiled model by
included-literal density: at or below `indexed_density_threshold`
(default 0.05) the indexed engine serves, else at or below
`compressed_density_threshold` (default 0.2) the compressed engine,
above that the packed engine (both knobs live under [coordinator] in
serve.toml). Replies name the concrete engine used; the choice never
changes the sums.

serve.toml knobs, all under [coordinator]:
  shards                         front-door shard count (>= 1)
  workers                        worker threads per coordinator (>= 1)
  max_batch                      max requests per flushed batch (>= 1)
  batch_timeout_us               flush deadline for a partial batch
  queue_depth                    in-flight cap before submit rejects
  artifacts_dir                  XLA golden-path artifact directory
  wta                            winner-takes-all arbiter: tba|mesh
  indexed_density_threshold      auto-* indexed cutoff (0..=1)
  compressed_density_threshold   auto-* compressed cutoff (0..=1)
  simd                           lane width (see below)
  compile                        model-compile pass: off|prune|full
                                 (default prune; see `tmtd compile`)
  remote_shards                  comma list of host:port shard
                                 addresses; non-empty switches `serve`
                                 to the networked front door
  listen                         default --listen address for `shard`
  trainer                        training tier: packed|reference|
                                 async|async-indexed (default packed;
                                 see `tmtd train`)
  train_threads                  clause-partition workers for the
                                 async trainer tiers (>= 1)
  net_connections                pooled TCP connections per remote
                                 shard (>= 1)
  net_heartbeat_ms               shard health-probe period (>= 1;
                                 unhealthy shards are probed with
                                 exponential backoff and rejoin on the
                                 first acked beat)

The packed engines evaluate in SIMD word lanes (`simd` under
[coordinator], or --simd on serve): \"auto\" (default) picks the widest
level the host supports at build time — AVX-512 (8x64-bit lanes, needs
the `avx512` cargo feature), AVX2 (4 lanes), NEON on aarch64 (2 lanes),
else the portable 4x-unrolled baseline; \"scalar\" keeps the historic
one-word-per-op walk. Forcing an undetected level fails at startup. The
level only changes speed: all levels are bit-exact (see `tmtd
selfcheck`).
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse("train --dataset iris --epochs 60 models/");
        assert_eq!(a.command, "train");
        assert_eq!(a.flag("dataset"), Some("iris"));
        assert_eq!(a.flag_parse("epochs", 0usize).unwrap(), 60);
        assert_eq!(a.positional, vec!["models/"]);
    }

    #[test]
    fn equals_form_and_switches() {
        let a = parse("serve --config=serve.toml --no-golden");
        assert_eq!(a.flag("config"), Some("serve.toml"));
        assert!(a.switch("no-golden"));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn trailing_switch_not_eaten_by_flag() {
        let a = parse("x --alpha --beta");
        assert!(a.switch("alpha"));
        assert!(a.switch("beta"));
    }

    #[test]
    fn bad_parse_value_is_error() {
        let a = parse("x --n abc");
        assert!(a.flag_parse("n", 1usize).is_err());
    }
}
