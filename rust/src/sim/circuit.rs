//! The circuit: netlist container + event-driven run loop.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::component::{Component, Ctx};
use super::energy::{EnergyLedger, TechParams};
use super::event::Event;
use super::net::{Logic, NetId, NetInfo};
use super::time::Time;
use super::trace::VcdTracer;
use crate::error::{Error, Result};

/// An event-driven circuit: nets, components, a scheduler, energy
/// accounting and optional VCD tracing.
pub struct Circuit {
    pub tech: TechParams,
    nets: Vec<NetInfo>,
    values: Vec<Logic>,
    comps: Vec<Box<dyn Component>>,
    /// comp index -> input net list (pin order).
    inputs: Vec<Vec<NetId>>,
    queue: BinaryHeap<Reverse<Event>>,
    scheduled_buf: Vec<(NetId, Logic, Time)>,
    now: Time,
    seq: u64,
    pub energy: EnergyLedger,
    tracer: Option<VcdTracer>,
    events_processed: u64,
    /// Safety valve against runaway oscillation.
    pub max_events: u64,
}

impl Circuit {
    pub fn new(tech: TechParams) -> Circuit {
        Circuit {
            tech,
            nets: Vec::new(),
            values: Vec::new(),
            comps: Vec::new(),
            inputs: Vec::new(),
            queue: BinaryHeap::new(),
            scheduled_buf: Vec::new(),
            now: Time::ZERO,
            seq: 0,
            energy: EnergyLedger::default(),
            tracer: None,
            events_processed: 0,
            max_events: 50_000_000,
        }
    }

    // ------------------------------------------------------------ build

    /// Create a net, initially X.
    pub fn net(&mut self, name: impl Into<String>) -> NetId {
        let id = NetId(self.nets.len() as u32);
        self.nets.push(NetInfo {
            name: name.into(),
            sinks: Vec::new(),
            traced: false,
            transitions: 0,
        });
        self.values.push(Logic::X);
        id
    }

    /// Create a net with a defined initial value (no event generated).
    pub fn net_init(&mut self, name: impl Into<String>, v: Logic) -> NetId {
        let id = self.net(name);
        self.values[id.index()] = v;
        id
    }

    /// Add a component; `inputs` lists the nets feeding its pins in order.
    pub fn add(&mut self, comp: Box<dyn Component>, inputs: Vec<NetId>) -> usize {
        let ci = self.comps.len();
        self.energy.gate_equivalents += comp.gate_equivalents();
        for (pin, net) in inputs.iter().enumerate() {
            self.nets[net.index()].sinks.push((ci, pin));
        }
        self.comps.push(comp);
        self.inputs.push(inputs);
        ci
    }

    /// Mark a net for VCD tracing.
    pub fn trace(&mut self, net: NetId) {
        self.nets[net.index()].traced = true;
    }

    /// Attach a VCD tracer (all `trace()`d nets are recorded).
    pub fn attach_tracer(&mut self, mut tracer: VcdTracer) {
        for (i, info) in self.nets.iter().enumerate() {
            if info.traced {
                tracer.declare(NetId(i as u32), &info.name);
            }
        }
        self.tracer = Some(tracer);
    }

    /// Detach and return the tracer (to finalise the VCD file).
    pub fn take_tracer(&mut self) -> Option<VcdTracer> {
        self.tracer.take()
    }

    // ------------------------------------------------------------ drive

    /// Externally drive a net at an absolute time ≥ now.
    pub fn drive_at(&mut self, net: NetId, value: Logic, at: Time) -> Result<()> {
        if at < self.now {
            return Err(Error::sim(format!(
                "drive_at {} in the past (now {})",
                at, self.now
            )));
        }
        self.push_event(at, net, value);
        Ok(())
    }

    /// Externally drive a net `delay` after now.
    pub fn drive(&mut self, net: NetId, value: Logic, delay: Time) {
        self.push_event(self.now + delay, net, value);
    }

    fn push_event(&mut self, at: Time, net: NetId, value: Logic) {
        let ev = Event { time: at, seq: self.seq, net, value };
        self.seq += 1;
        self.queue.push(Reverse(ev));
    }

    // -------------------------------------------------------------- run

    /// Initialise all components (drives reset values etc.).
    pub fn init_components(&mut self) {
        for ci in 0..self.comps.len() {
            let mut ctx = Ctx {
                now: self.now,
                values: &self.values,
                scheduled: &mut self.scheduled_buf,
                energy: &mut self.energy,
            };
            self.comps[ci].init(&mut ctx);
            let buf: Vec<_> = self.scheduled_buf.drain(..).collect();
            for (net, value, delay) in buf {
                self.push_event(self.now + delay, net, value);
            }
        }
    }

    /// Current simulation time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Total events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Value of a net.
    pub fn value(&self, net: NetId) -> Logic {
        self.values[net.index()]
    }

    /// Transition count of a net (activity).
    pub fn transitions(&self, net: NetId) -> u64 {
        self.nets[net.index()].transitions
    }

    /// Net name (for diagnostics).
    pub fn net_name(&self, net: NetId) -> &str {
        &self.nets[net.index()].name
    }

    /// Run until the queue empties or `until` is reached.
    /// Returns the time of the last processed event.
    pub fn run_until(&mut self, until: Time) -> Result<Time> {
        while let Some(Reverse(ev)) = self.queue.peek().copied() {
            if ev.time > until {
                break;
            }
            self.queue.pop();
            self.step_event(ev)?;
        }
        // Advance wall time to the horizon even if no event landed on it.
        if self.now < until {
            self.now = until;
        }
        Ok(self.now)
    }

    /// Run until the event queue is exhausted (or `max_events` trips).
    pub fn run_to_quiescence(&mut self) -> Result<Time> {
        while let Some(Reverse(ev)) = self.queue.pop() {
            self.step_event(ev)?;
        }
        Ok(self.now)
    }

    /// Run until `predicate` returns true after an event, the queue
    /// empties, or `deadline` passes. Returns true if predicate fired.
    pub fn run_while(
        &mut self,
        deadline: Time,
        mut predicate: impl FnMut(&Circuit) -> bool,
    ) -> Result<bool> {
        loop {
            let ev = match self.queue.peek().copied() {
                Some(Reverse(ev)) if ev.time <= deadline => {
                    self.queue.pop();
                    ev
                }
                _ => return Ok(false),
            };
            self.step_event(ev)?;
            if predicate(self) {
                return Ok(true);
            }
        }
    }

    fn step_event(&mut self, ev: Event) -> Result<()> {
        debug_assert!(ev.time >= self.now, "event in the past");
        self.now = ev.time;
        self.events_processed += 1;
        if self.events_processed > self.max_events {
            return Err(Error::sim(format!(
                "exceeded max_events={} (oscillation?) at t={}",
                self.max_events, self.now
            )));
        }
        let ni = ev.net.index();
        let old = self.values[ni];
        if old == ev.value {
            return Ok(()); // no transition; transport-delay duplicate
        }
        self.values[ni] = ev.value;
        self.nets[ni].transitions += 1;
        if self.nets[ni].traced {
            if let Some(tr) = &mut self.tracer {
                tr.change(self.now, ev.net, ev.value);
            }
        }
        // Notify sinks. The sink list is stable during a run (no dynamic
        // connections), so index it directly — copying the (usize, usize)
        // pair per iteration avoids both the per-event Vec clone and any
        // aliasing with `comps` (hot path: §Perf in EXPERIMENTS.md).
        let n_sinks = self.nets[ni].sinks.len();
        for si in 0..n_sinks {
            let (ci, pin) = self.nets[ni].sinks[si];
            let mut ctx = Ctx {
                now: self.now,
                values: &self.values,
                scheduled: &mut self.scheduled_buf,
                energy: &mut self.energy,
            };
            self.comps[ci].on_input(pin, &mut ctx);
            if !self.scheduled_buf.is_empty() {
                // Reuse the buffer's allocation across events: take it,
                // drain, put it back (capacity preserved).
                let mut buf = std::mem::take(&mut self.scheduled_buf);
                for (net, value, delay) in buf.drain(..) {
                    self.push_event(self.now + delay, net, value);
                }
                self.scheduled_buf = buf;
            }
        }
        Ok(())
    }

    /// Pending event count (diagnostics).
    pub fn pending_events(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::energy::EnergyKind;

    /// Minimal test component: inverter with fixed 10 ps delay.
    struct TestInv {
        input: NetId,
        output: NetId,
    }
    impl Component for TestInv {
        fn name(&self) -> &str {
            "test_inv"
        }
        fn on_input(&mut self, _pin: usize, ctx: &mut Ctx) {
            let v = ctx.get(self.input).not();
            ctx.spend(EnergyKind::Logic, 0.6);
            ctx.schedule(self.output, v, Time::ps(10));
        }
    }

    fn inv_chain(n: usize) -> (Circuit, NetId, NetId) {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let first = c.net("in");
        let mut prev = first;
        let mut last = first;
        for i in 0..n {
            let out = c.net(format!("n{i}"));
            c.add(Box::new(TestInv { input: prev, output: out }), vec![prev]);
            prev = out;
            last = out;
        }
        (c, first, last)
    }

    #[test]
    fn inverter_chain_propagates_with_delay() {
        let (mut c, input, out) = inv_chain(4);
        c.drive(input, Logic::Zero, Time::ZERO);
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(out), Logic::Zero); // 4 inversions of 0
        assert_eq!(c.now(), Time::ps(40));
    }

    #[test]
    fn energy_accumulates_per_transition() {
        let (mut c, input, _) = inv_chain(3);
        c.drive(input, Logic::Zero, Time::ZERO);
        c.run_to_quiescence().unwrap();
        // 3 inverters fire once each.
        assert_eq!(c.energy.transitions(EnergyKind::Logic), 3);
        assert!((c.energy.dynamic_fj(EnergyKind::Logic) - 1.8).abs() < 1e-12);
    }

    #[test]
    fn same_value_event_is_not_a_transition() {
        let (mut c, input, _) = inv_chain(1);
        c.drive(input, Logic::Zero, Time::ZERO);
        c.run_to_quiescence().unwrap();
        let t0 = c.transitions(input);
        c.drive(input, Logic::Zero, Time::ps(5));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.transitions(input), t0);
    }

    #[test]
    fn deterministic_tie_break() {
        // Two events at the same instant are processed in schedule order.
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let a = c.net("a");
        c.drive(a, Logic::One, Time::ps(5));
        c.drive(a, Logic::Zero, Time::ps(5));
        c.run_to_quiescence().unwrap();
        assert_eq!(c.value(a), Logic::Zero); // last scheduled wins the tie
        assert_eq!(c.transitions(a), 2);
    }

    #[test]
    fn drive_in_past_rejected() {
        let (mut c, input, _) = inv_chain(1);
        c.drive(input, Logic::One, Time::ps(10));
        c.run_to_quiescence().unwrap();
        assert!(c.drive_at(input, Logic::Zero, Time::ps(5)).is_err());
    }

    #[test]
    fn max_events_trips_on_oscillator() {
        // Ring oscillator: single inverter feeding itself.
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let n = c.net("ring");
        c.add(Box::new(TestInv { input: n, output: n }), vec![n]);
        c.max_events = 1000;
        c.drive(n, Logic::Zero, Time::ZERO);
        let err = c.run_to_quiescence().unwrap_err();
        assert!(err.to_string().contains("max_events"));
    }

    #[test]
    fn run_while_predicate_stops_early() {
        let (mut c, input, out) = inv_chain(8);
        c.drive(input, Logic::Zero, Time::ZERO);
        let fired = c
            .run_while(Time::ns(1), |c| c.value(out) != Logic::X)
            .unwrap();
        assert!(fired);
        assert!(c.now() <= Time::ps(80));
    }
}
