//! Discrete-event digital/time-domain circuit simulator.
//!
//! This is the substrate that replaces the paper's Cadence AMS testbench
//! (DESIGN.md §Substitutions): femtosecond-resolution event queue,
//! three-valued logic, component netlists, per-transition switching-energy
//! accounting, and VCD waveform tracing.
//!
//! The simulator is deliberately *event-driven* in exactly the paper's
//! sense: nothing is evaluated on a clock grid; a component runs only when
//! one of its input nets transitions, and time advances to the next
//! scheduled event. A synchronous design is simulated by instantiating an
//! explicit [`gates::clock::ClockGen`](crate::gates) component — the clock
//! is an ordinary signal, and its energy cost is an ordinary measured
//! quantity, which is precisely the comparison the paper draws.

pub mod circuit;
pub mod component;
pub mod energy;
pub mod event;
pub mod net;
pub mod time;
pub mod trace;

pub use circuit::Circuit;
pub use component::{Component, Ctx};
pub use energy::{EnergyKind, EnergyLedger, TechParams};
pub use event::Event;
pub use net::{Logic, NetId};
pub use time::Time;
