//! Nets (signals) and three-valued logic.

use std::fmt;

/// Three-valued logic: 0, 1, and X (uninitialised / unknown).
///
/// X models power-on state; any gate seeing an X input produces X unless
/// the output is forced by a controlling value (e.g. a NAND with one
/// input at 0 outputs 1 regardless of the other input), matching standard
/// HDL semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Logic {
    Zero,
    One,
    X,
}

impl Logic {
    pub fn from_bool(b: bool) -> Logic {
        if b {
            Logic::One
        } else {
            Logic::Zero
        }
    }

    /// `Some(bool)` for defined values, `None` for X.
    pub fn as_bool(self) -> Option<bool> {
        match self {
            Logic::Zero => Some(false),
            Logic::One => Some(true),
            Logic::X => None,
        }
    }

    pub fn is_defined(self) -> bool {
        self != Logic::X
    }

    /// Logical NOT with X propagation.
    pub fn not(self) -> Logic {
        match self {
            Logic::Zero => Logic::One,
            Logic::One => Logic::Zero,
            Logic::X => Logic::X,
        }
    }

    /// AND with controlling-0 semantics.
    pub fn and(self, rhs: Logic) -> Logic {
        match (self, rhs) {
            (Logic::Zero, _) | (_, Logic::Zero) => Logic::Zero,
            (Logic::One, Logic::One) => Logic::One,
            _ => Logic::X,
        }
    }

    /// OR with controlling-1 semantics.
    pub fn or(self, rhs: Logic) -> Logic {
        match (self, rhs) {
            (Logic::One, _) | (_, Logic::One) => Logic::One,
            (Logic::Zero, Logic::Zero) => Logic::Zero,
            _ => Logic::X,
        }
    }

    /// XOR (X-propagating).
    pub fn xor(self, rhs: Logic) -> Logic {
        match (self.as_bool(), rhs.as_bool()) {
            (Some(a), Some(b)) => Logic::from_bool(a ^ b),
            _ => Logic::X,
        }
    }
}

impl fmt::Display for Logic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Logic::Zero => write!(f, "0"),
            Logic::One => write!(f, "1"),
            Logic::X => write!(f, "x"),
        }
    }
}

/// Handle to a net in a [`crate::sim::Circuit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NetId(pub u32);

impl NetId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Net metadata held by the circuit (values live in a parallel vector for
/// borrow-friendly access during component evaluation).
#[derive(Debug, Clone)]
pub struct NetInfo {
    pub name: String,
    /// (component index, input pin) pairs notified on a value change.
    pub sinks: Vec<(usize, usize)>,
    /// Whether transitions on this net are recorded by the VCD tracer.
    pub traced: bool,
    /// Number of value changes observed (activity factor, for reports).
    pub transitions: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn not_truth_table() {
        assert_eq!(Logic::Zero.not(), Logic::One);
        assert_eq!(Logic::One.not(), Logic::Zero);
        assert_eq!(Logic::X.not(), Logic::X);
    }

    #[test]
    fn and_controlling_zero() {
        assert_eq!(Logic::Zero.and(Logic::X), Logic::Zero);
        assert_eq!(Logic::X.and(Logic::Zero), Logic::Zero);
        assert_eq!(Logic::One.and(Logic::X), Logic::X);
        assert_eq!(Logic::One.and(Logic::One), Logic::One);
    }

    #[test]
    fn or_controlling_one() {
        assert_eq!(Logic::One.or(Logic::X), Logic::One);
        assert_eq!(Logic::X.or(Logic::One), Logic::One);
        assert_eq!(Logic::Zero.or(Logic::X), Logic::X);
        assert_eq!(Logic::Zero.or(Logic::Zero), Logic::Zero);
    }

    #[test]
    fn xor_propagates_x() {
        assert_eq!(Logic::One.xor(Logic::Zero), Logic::One);
        assert_eq!(Logic::One.xor(Logic::One), Logic::Zero);
        assert_eq!(Logic::One.xor(Logic::X), Logic::X);
    }
}
