//! Component trait and evaluation context.

use super::energy::{EnergyKind, EnergyLedger};
use super::net::{Logic, NetId};
use super::time::Time;

/// Evaluation context handed to a component when one of its inputs
/// transitions. Provides read access to all net values, output
/// scheduling, and energy attribution — everything a component may do.
pub struct Ctx<'a> {
    /// Current simulation time.
    pub now: Time,
    /// Values of all nets (indexed by `NetId::index()`).
    pub(super) values: &'a [Logic],
    /// Transitions to schedule: (net, value, delay from now).
    pub(super) scheduled: &'a mut Vec<(NetId, Logic, Time)>,
    pub(super) energy: &'a mut EnergyLedger,
}

impl<'a> Ctx<'a> {
    /// Read a net's current value.
    pub fn get(&self, net: NetId) -> Logic {
        self.values[net.index()]
    }

    /// Read as bool, treating X as `false` (components that must see X
    /// explicitly should use [`Ctx::get`]).
    pub fn get_bool(&self, net: NetId) -> bool {
        self.values[net.index()] == Logic::One
    }

    /// Schedule `net <- value` after `delay`.
    pub fn schedule(&mut self, net: NetId, value: Logic, delay: Time) {
        self.scheduled.push((net, value, delay));
    }

    /// Schedule only if the value differs from the net's current value
    /// (cheap glitch suppression for level-sensitive logic).
    pub fn schedule_if_changed(&mut self, net: NetId, value: Logic, delay: Time) {
        if self.get(net) != value {
            self.schedule(net, value, delay);
        }
    }

    /// Attribute `fj` femtojoules of dynamic energy to `kind`.
    pub fn spend(&mut self, kind: EnergyKind, fj: f64) {
        self.energy.add(kind, fj);
    }
}

/// A circuit component: evaluated when any connected input net changes.
///
/// Components range from single gates ([`crate::gates`]) to behavioural
/// datapath blocks ([`crate::arch::datapath`]); both obey the same
/// event-driven contract, so gate-level and block-level models compose in
/// one netlist.
pub trait Component {
    /// Debug name (instance path).
    fn name(&self) -> &str;

    /// Called at t=0 so components can initialise outputs (e.g. drive a
    /// known reset value). Default: do nothing.
    fn init(&mut self, _ctx: &mut Ctx) {}

    /// Input pin `pin` (index into the component's input list) changed.
    fn on_input(&mut self, pin: usize, ctx: &mut Ctx);

    /// Gate-equivalents for leakage accounting.
    fn gate_equivalents(&self) -> f64 {
        1.0
    }
}
