//! Switching-energy and leakage accounting — the software stand-in for
//! the paper's post-implementation power reports (DESIGN.md §6).
//!
//! First-order CMOS physics: every output transition of a gate charges or
//! discharges that node's effective capacitance, costing `E = C·V²/2`
//! (folded into a per-gate-type energy constant at the reference voltage);
//! leakage accrues per gate-equivalent per unit time; a synchronous design
//! additionally pays the clock tree every cycle on every flop. All
//! constants are anchored to published 65 nm figures and scale as
//! `(V/Vref)²` so the proposed design's 1.0 V operation is modelled.


use super::time::Time;

/// Categories used to attribute energy in reports (Table IV breakdowns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EnergyKind {
    /// Combinational std-cell switching (NAND/NOR/INV/...).
    Logic,
    /// Flip-flop clocking + data toggles.
    Sequential,
    /// Clock-tree distribution (synchronous designs only).
    ClockTree,
    /// Handshake control (click elements, C-elements) — async designs.
    Handshake,
    /// Time-domain delay elements (the weak-capacitance path).
    DelayLine,
    /// Arbitration (Mutex cells, WTA trees).
    Arbiter,
    /// Time-to-digital conversion.
    Tdc,
    /// Memory access (TA state / weight reads).
    Memory,
    /// Static leakage (accrued once per run from gate count × time).
    Leakage,
}

impl EnergyKind {
    /// Dense index for array-backed accounting (hot path: every gate
    /// transition calls `EnergyLedger::add`).
    #[inline]
    pub fn index(self) -> usize {
        match self {
            EnergyKind::Logic => 0,
            EnergyKind::Sequential => 1,
            EnergyKind::ClockTree => 2,
            EnergyKind::Handshake => 3,
            EnergyKind::DelayLine => 4,
            EnergyKind::Arbiter => 5,
            EnergyKind::Tdc => 6,
            EnergyKind::Memory => 7,
            EnergyKind::Leakage => 8,
        }
    }

    pub const ALL: [EnergyKind; 9] = [
        EnergyKind::Logic,
        EnergyKind::Sequential,
        EnergyKind::ClockTree,
        EnergyKind::Handshake,
        EnergyKind::DelayLine,
        EnergyKind::Arbiter,
        EnergyKind::Tdc,
        EnergyKind::Memory,
        EnergyKind::Leakage,
    ];

    pub fn name(self) -> &'static str {
        match self {
            EnergyKind::Logic => "logic",
            EnergyKind::Sequential => "sequential",
            EnergyKind::ClockTree => "clock-tree",
            EnergyKind::Handshake => "handshake",
            EnergyKind::DelayLine => "delay-line",
            EnergyKind::Arbiter => "arbiter",
            EnergyKind::Tdc => "tdc",
            EnergyKind::Memory => "memory",
            EnergyKind::Leakage => "leakage",
        }
    }
}

/// 65 nm technology parameters (anchors documented in DESIGN.md §6).
#[derive(Debug, Clone)]
pub struct TechParams {
    /// Operating voltage (V). Baselines: 1.2 V; proposed designs: 1.0 V.
    pub voltage: f64,
    /// Reference voltage the energy constants below are quoted at.
    pub vref: f64,
    /// NAND2 switching energy per output transition at `vref` (fJ).
    pub e_nand_fj: f64,
    /// NOR2 switching energy (fJ).
    pub e_nor_fj: f64,
    /// Inverter switching energy (fJ).
    pub e_inv_fj: f64,
    /// XOR2 switching energy (fJ) — ~2.2 NAND equivalents.
    pub e_xor_fj: f64,
    /// D flip-flop energy per active clock edge (fJ).
    pub e_dff_fj: f64,
    /// Clock-tree energy per flop per cycle (fJ) — synchronous only.
    pub e_clktree_fj: f64,
    /// Delay-line stage energy per traversing event (fJ) — the paper's
    /// weak-capacitance premise: far below a std-cell transition.
    pub e_delay_stage_fj: f64,
    /// SRAM/register-file read energy per bit (fJ).
    pub e_mem_bit_fj: f64,
    /// Leakage power per gate-equivalent (nW) at `vref`.
    pub leak_nw_per_ge: f64,
    /// Gate delays (ps) at `vref`.
    pub d_nand_ps: f64,
    pub d_nor_ps: f64,
    pub d_inv_ps: f64,
    pub d_xor_ps: f64,
    pub d_dff_ps: f64,
    /// Mutex intrinsic resolution time-constant τ_m (ps) for the
    /// metastability model `t_res = τ_m · ln(Δ₀/Δt)`.
    pub mutex_tau_ps: f64,
    /// Coarse time-domain unit delay τ (ps), per §II-C.3.
    pub tau_ps: f64,
    /// Fine-delay resolution bits `e` (fine step = τ/2ᵉ).
    pub fine_bits: u32,
    /// Vernier TDC resolution (ps), per [14].
    pub tdc_res_ps: f64,
    /// Gaussian σ of PVT delay jitter as a fraction of nominal delay
    /// (0.0 = nominal corner).
    pub pvt_sigma: f64,
    /// Synchronous clock-period margin over the worst-case stage delay
    /// (PVT guard band + setup) — the tax the paper's Contradiction #1
    /// identifies.
    pub sync_margin: f64,
    /// Clock skew + jitter allowance added to the period (ps).
    pub clock_skew_ps: f64,
    /// Bundled-data matched-delay margin (small: the matched line tracks
    /// the datapath across PVT far better than a global clock).
    pub bd_margin: f64,
    /// Step of the multi-class Hamming race delay chain (ps per unit of
    /// Hamming distance).
    pub hamming_step_ps: f64,
    /// Coarse unit delay τ of the *CoTM race unit* (ps). Smaller than the
    /// generic τ: the CoTM rails traverse up to k_max segments per
    /// classification, so short segments keep the race competitive with
    /// the digital pipeline (§II-C.3's "short length" claim).
    pub cotm_tau_ps: f64,
    /// Single-rail DCDE segment length (ps per TDC code step). Decoupled
    /// from the TDC resolution: `dc` indexes segments, it does not need
    /// to reproduce the measured interval at full scale. Sized above the
    /// Mutex metastability window's dwell spread so adjacent codes
    /// arbitrate in order (a one-code gap may still tie — quantisation
    /// the `ablation_fine_res` bench quantifies).
    pub sr_step_ps: f64,
}

impl TechParams {
    /// TSMC-65nm-class parameters at 1.2 V (digital baselines).
    pub fn tsmc65_digital() -> TechParams {
        TechParams {
            voltage: 1.2,
            vref: 1.2,
            e_nand_fj: 1.0,
            e_nor_fj: 1.1,
            e_inv_fj: 0.6,
            e_xor_fj: 2.2,
            e_dff_fj: 4.0,
            e_clktree_fj: 6.0,
            e_delay_stage_fj: 0.08,
            e_mem_bit_fj: 0.12,
            leak_nw_per_ge: 0.5,
            d_nand_ps: 25.0,
            d_nor_ps: 30.0,
            d_inv_ps: 15.0,
            d_xor_ps: 45.0,
            d_dff_ps: 80.0,
            mutex_tau_ps: 12.0,
            tau_ps: 100.0,
            fine_bits: 4,
            tdc_res_ps: 5.0,
            pvt_sigma: 0.0,
            sync_margin: 0.45,
            clock_skew_ps: 60.0,
            bd_margin: 0.08,
            hamming_step_ps: 20.0,
            cotm_tau_ps: 40.0,
            sr_step_ps: 12.0,
        }
    }

    /// Tech corner for the CoTM race unit: identical except the coarse
    /// unit delay τ is the short `cotm_tau_ps` segment.
    pub fn cotm_race_corner(&self) -> TechParams {
        TechParams { tau_ps: self.cotm_tau_ps, ..self.clone() }
    }

    /// The proposed designs run at 1.0 V (paper Table III).
    pub fn tsmc65_proposed() -> TechParams {
        TechParams { voltage: 1.0, ..Self::tsmc65_digital() }
    }

    /// Voltage-scaling factor for energy: (V/Vref)².
    pub fn vscale(&self) -> f64 {
        (self.voltage / self.vref).powi(2)
    }

    /// Delay scaling with voltage: first-order alpha-power model — lower
    /// V means slower gates; at 65 nm, ~1.3× slower at 1.0 V vs 1.2 V.
    pub fn dscale(&self) -> f64 {
        // alpha-power with alpha≈1.3, Vth≈0.35 V:
        // d ∝ V / (V - Vth)^1.3, normalised to vref.
        let vth = 0.35;
        let num = self.voltage / (self.voltage - vth).powf(1.3);
        let den = self.vref / (self.vref - vth).powf(1.3);
        num / den
    }

    /// Energy (fJ) of a given gate kind per output transition, at the
    /// operating voltage.
    pub fn gate_energy_fj(&self, kind: GateKind) -> f64 {
        let base = match kind {
            GateKind::Nand | GateKind::And => self.e_nand_fj,
            GateKind::Nor | GateKind::Or => self.e_nor_fj,
            GateKind::Inv | GateKind::Buf => self.e_inv_fj,
            GateKind::Xor | GateKind::Xnor => self.e_xor_fj,
            GateKind::Mux2 => 1.4 * self.e_nand_fj,
            GateKind::Dff | GateKind::Tff => self.e_dff_fj,
            GateKind::CElement => 2.0 * self.e_nand_fj,
            GateKind::DelayStage => self.e_delay_stage_fj,
        };
        base * self.vscale()
    }

    /// Nominal propagation delay of a gate kind at the operating voltage.
    pub fn gate_delay(&self, kind: GateKind) -> Time {
        let ps = match kind {
            GateKind::Nand | GateKind::And => self.d_nand_ps,
            GateKind::Nor | GateKind::Or => self.d_nor_ps,
            GateKind::Inv | GateKind::Buf => self.d_inv_ps,
            GateKind::Xor | GateKind::Xnor => self.d_xor_ps,
            GateKind::Mux2 => 1.5 * self.d_nand_ps,
            GateKind::Dff | GateKind::Tff => self.d_dff_ps,
            GateKind::CElement => 2.0 * self.d_nand_ps,
            GateKind::DelayStage => self.tau_ps,
        };
        Time::from_ps_f64(ps * self.dscale())
    }

    /// Fine delay step τ/2ᵉ.
    pub fn fine_step(&self) -> Time {
        Time::from_ps_f64(self.tau_ps / (1u64 << self.fine_bits) as f64)
    }

    /// Coarse delay unit τ.
    pub fn tau(&self) -> Time {
        Time::from_ps_f64(self.tau_ps)
    }
}

/// Gate families recognised by the energy model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GateKind {
    Nand,
    Nor,
    And,
    Or,
    Inv,
    Buf,
    Xor,
    Xnor,
    Mux2,
    Dff,
    Tff,
    CElement,
    DelayStage,
}

/// Accumulates energy by category over a simulation run.
/// Array-backed: `add` is on the per-transition hot path (§Perf).
#[derive(Debug, Clone, Default)]
pub struct EnergyLedger {
    dynamic_fj: [f64; 9],
    transitions: [u64; 9],
    /// Total gate-equivalents of the instantiated design (for leakage).
    pub gate_equivalents: f64,
}

impl EnergyLedger {
    #[inline]
    pub fn add(&mut self, kind: EnergyKind, fj: f64) {
        let i = kind.index();
        self.dynamic_fj[i] += fj;
        self.transitions[i] += 1;
    }

    pub fn dynamic_fj(&self, kind: EnergyKind) -> f64 {
        self.dynamic_fj[kind.index()]
    }

    pub fn transitions(&self, kind: EnergyKind) -> u64 {
        self.transitions[kind.index()]
    }

    /// Total dynamic energy (fJ) across categories.
    pub fn total_dynamic_fj(&self) -> f64 {
        self.dynamic_fj.iter().sum()
    }

    /// Leakage energy (fJ) over a span at the given tech corner.
    /// `P_leak = GE × leak_nw_per_ge × (V/Vref)` (leakage ~linear in V to
    /// first order around the operating point).
    pub fn leakage_fj(&self, tech: &TechParams, span: Time) -> f64 {
        let p_nw = self.gate_equivalents * tech.leak_nw_per_ge * (tech.voltage / tech.vref);
        // nW × s = nJ; convert to fJ (×1e6).
        p_nw * span.as_secs_f64() * 1.0e6
    }

    /// Total energy including leakage over `span`.
    pub fn total_fj(&self, tech: &TechParams, span: Time) -> f64 {
        self.total_dynamic_fj() + self.leakage_fj(tech, span)
    }

    /// Merge another ledger into this one (used when aggregating stages).
    pub fn merge(&mut self, other: &EnergyLedger) {
        for i in 0..9 {
            self.dynamic_fj[i] += other.dynamic_fj[i];
            self.transitions[i] += other.transitions[i];
        }
        self.gate_equivalents += other.gate_equivalents;
    }

    /// Per-category breakdown, largest first.
    pub fn breakdown(&self) -> Vec<(EnergyKind, f64)> {
        let mut v: Vec<(EnergyKind, f64)> = EnergyKind::ALL
            .iter()
            .map(|&k| (k, self.dynamic_fj[k.index()]))
            .filter(|(_, e)| *e > 0.0)
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn voltage_scaling_quadratic() {
        let hi = TechParams::tsmc65_digital();
        let lo = TechParams::tsmc65_proposed();
        let r = lo.gate_energy_fj(GateKind::Nand) / hi.gate_energy_fj(GateKind::Nand);
        assert!((r - (1.0f64 / 1.2).powi(2)).abs() < 1e-12);
    }

    #[test]
    fn lower_voltage_is_slower() {
        let hi = TechParams::tsmc65_digital();
        let lo = TechParams::tsmc65_proposed();
        assert!(lo.gate_delay(GateKind::Nand) > hi.gate_delay(GateKind::Nand));
    }

    #[test]
    fn delay_stage_is_weak_capacitance() {
        // The paper's core premise: a delay-line event costs far less than
        // a std-cell transition.
        let t = TechParams::tsmc65_digital();
        assert!(t.gate_energy_fj(GateKind::DelayStage) < 0.2 * t.gate_energy_fj(GateKind::Nand));
    }

    #[test]
    fn fine_step_is_tau_over_2e() {
        let t = TechParams::tsmc65_digital();
        assert_eq!(t.fine_step(), Time::from_ps_f64(6.25));
    }

    #[test]
    fn ledger_accumulates_and_merges() {
        let mut a = EnergyLedger::default();
        a.add(EnergyKind::Logic, 2.0);
        a.add(EnergyKind::Logic, 3.0);
        a.gate_equivalents = 10.0;
        let mut b = EnergyLedger::default();
        b.add(EnergyKind::Arbiter, 1.0);
        b.gate_equivalents = 5.0;
        a.merge(&b);
        assert_eq!(a.dynamic_fj(EnergyKind::Logic), 5.0);
        assert_eq!(a.dynamic_fj(EnergyKind::Arbiter), 1.0);
        assert_eq!(a.transitions(EnergyKind::Logic), 2);
        assert_eq!(a.gate_equivalents, 15.0);
    }

    #[test]
    fn leakage_scales_with_time_and_gates() {
        let t = TechParams::tsmc65_digital();
        let mut l = EnergyLedger::default();
        l.gate_equivalents = 1000.0;
        let e1 = l.leakage_fj(&t, Time::ns(10));
        let e2 = l.leakage_fj(&t, Time::ns(20));
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
        // 1000 GE × 0.5 nW = 500 nW; over 10 ns = 5e-15 J = 5 fJ.
        assert!((e1 - 5.0).abs() < 1e-9, "e1={e1}");
    }
}
