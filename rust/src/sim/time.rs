//! Simulation time: femtosecond-resolution monotonic timestamps.
//!
//! Femtoseconds in a `u64` cover ~5.1 hours of simulated time — vastly
//! more than any inference run — while resolving the Vernier TDC's
//! sub-picosecond residues and the fine delay step τ/2ᵉ (6.25 ps at the
//! default τ = 100 ps, e = 4) without rounding.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in (or span of) simulated time, in femtoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Time(pub u64);

impl Time {
    pub const ZERO: Time = Time(0);

    /// One femtosecond.
    pub const FS: Time = Time(1);
    /// One picosecond.
    pub const PS: Time = Time(1_000);
    /// One nanosecond.
    pub const NS: Time = Time(1_000_000);
    /// One microsecond.
    pub const US: Time = Time(1_000_000_000);

    pub fn fs(v: u64) -> Time {
        Time(v)
    }
    pub fn ps(v: u64) -> Time {
        Time(v * 1_000)
    }
    pub fn ns(v: u64) -> Time {
        Time(v * 1_000_000)
    }

    /// Construct from a (possibly fractional) picosecond value, rounding
    /// to the nearest femtosecond.
    pub fn from_ps_f64(ps: f64) -> Time {
        assert!(ps >= 0.0, "negative time: {ps} ps");
        Time((ps * 1_000.0).round() as u64)
    }

    pub fn as_fs(self) -> u64 {
        self.0
    }
    pub fn as_ps_f64(self) -> f64 {
        self.0 as f64 / 1_000.0
    }
    pub fn as_ns_f64(self) -> f64 {
        self.0 as f64 / 1_000_000.0
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 * 1e-15
    }

    /// Saturating difference (span from `earlier` to `self`).
    pub fn since(self, earlier: Time) -> Time {
        Time(self.0.saturating_sub(earlier.0))
    }

    /// Scale a span by a dimensionless factor (used for margins/jitter).
    pub fn scale(self, factor: f64) -> Time {
        assert!(factor >= 0.0, "negative scale: {factor}");
        Time((self.0 as f64 * factor).round() as u64)
    }
}

impl Add for Time {
    type Output = Time;
    fn add(self, rhs: Time) -> Time {
        Time(self.0 + rhs.0)
    }
}

impl AddAssign for Time {
    fn add_assign(&mut self, rhs: Time) {
        self.0 += rhs.0;
    }
}

impl Sub for Time {
    type Output = Time;
    fn sub(self, rhs: Time) -> Time {
        Time(self.0.checked_sub(rhs.0).expect("time underflow"))
    }
}

impl fmt::Display for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= Time::NS.0 {
            write!(f, "{:.3}ns", self.as_ns_f64())
        } else if self.0 >= Time::PS.0 {
            write!(f, "{:.3}ps", self.as_ps_f64())
        } else {
            write!(f, "{}fs", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversions() {
        assert_eq!(Time::ps(1).as_fs(), 1_000);
        assert_eq!(Time::ns(2), Time::ps(2_000));
        assert_eq!(Time::from_ps_f64(6.25).as_fs(), 6_250);
    }

    #[test]
    fn arithmetic() {
        assert_eq!(Time::ps(5) + Time::ps(7), Time::ps(12));
        assert_eq!(Time::ps(7) - Time::ps(5), Time::ps(2));
        assert_eq!(Time::ps(10).scale(1.5), Time::ps(15));
        assert_eq!(Time::ps(3).since(Time::ps(10)), Time::ZERO);
    }

    #[test]
    #[should_panic(expected = "time underflow")]
    fn subtraction_underflow_panics() {
        let _ = Time::ps(1) - Time::ps(2);
    }

    #[test]
    fn display_picks_unit() {
        assert_eq!(format!("{}", Time::ps(1500)), "1.500ns");
        assert_eq!(format!("{}", Time::fs(500)), "500fs");
    }
}
