//! VCD (Value Change Dump) waveform tracing — regenerates the paper's
//! Figs. 6–8 as standard waveform files viewable in GTKWave.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use super::net::{Logic, NetId};
use super::time::Time;

/// Records value changes for declared nets and serialises to VCD.
#[derive(Debug, Clone, Default)]
pub struct VcdTracer {
    /// net -> (identifier code, name)
    vars: BTreeMap<NetId, (String, String)>,
    /// (time, net, value), in occurrence order.
    changes: Vec<(Time, NetId, Logic)>,
    next_code: u32,
}

impl VcdTracer {
    pub fn new() -> VcdTracer {
        VcdTracer::default()
    }

    /// Declare a net for tracing. Called by `Circuit::attach_tracer`.
    pub fn declare(&mut self, net: NetId, name: &str) {
        let code = Self::code_for(self.next_code);
        self.next_code += 1;
        self.vars.insert(net, (code, sanitise(name)));
    }

    /// VCD identifier codes: printable ASCII 33..=126, base-94.
    fn code_for(mut n: u32) -> String {
        let mut s = String::new();
        loop {
            s.push((33 + (n % 94)) as u8 as char);
            n /= 94;
            if n == 0 {
                break;
            }
        }
        s
    }

    /// Record a change (only for declared nets).
    pub fn change(&mut self, at: Time, net: NetId, value: Logic) {
        if self.vars.contains_key(&net) {
            self.changes.push((at, net, value));
        }
    }

    pub fn change_count(&self) -> usize {
        self.changes.len()
    }

    /// Render the VCD document as a string (1 fs timescale).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("$date repro: event-driven DT-domain TM $end\n");
        out.push_str("$version tsetlin-td simulator $end\n");
        out.push_str("$timescale 1fs $end\n");
        out.push_str("$scope module top $end\n");
        for (code, name) in self.vars.values() {
            out.push_str(&format!("$var wire 1 {code} {name} $end\n"));
        }
        out.push_str("$upscope $end\n$enddefinitions $end\n");
        // Initial dump: everything X.
        out.push_str("$dumpvars\n");
        for (code, _) in self.vars.values() {
            out.push_str(&format!("x{code}\n"));
        }
        out.push_str("$end\n");
        let mut last_t: Option<Time> = None;
        for (t, net, v) in &self.changes {
            if last_t != Some(*t) {
                out.push_str(&format!("#{}\n", t.as_fs()));
                last_t = Some(*t);
            }
            let (code, _) = &self.vars[net];
            out.push_str(&format!("{v}{code}\n"));
        }
        out
    }

    /// Write the VCD to a file.
    pub fn write_to(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.render().as_bytes())
    }
}

/// VCD identifiers may not contain whitespace; swap awkward chars.
fn sanitise(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_whitespace() || c == '$' { '_' } else { c })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_unique_and_printable() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..500 {
            let c = VcdTracer::code_for(i);
            assert!(c.bytes().all(|b| (33..=126).contains(&b)));
            assert!(seen.insert(c));
        }
    }

    #[test]
    fn renders_header_and_changes() {
        let mut t = VcdTracer::new();
        t.declare(NetId(0), "req in");
        t.declare(NetId(1), "ack");
        t.change(Time::ps(1), NetId(0), Logic::One);
        t.change(Time::ps(1), NetId(1), Logic::Zero);
        t.change(Time::ps(3), NetId(0), Logic::Zero);
        let s = t.render();
        assert!(s.contains("$timescale 1fs $end"));
        assert!(s.contains("req_in"));
        assert!(s.contains("#1000\n"));
        assert!(s.contains("#3000\n"));
        // two changes share one timestamp line
        assert_eq!(s.matches("#1000").count(), 1);
    }

    #[test]
    fn undeclared_nets_are_ignored() {
        let mut t = VcdTracer::new();
        t.declare(NetId(0), "a");
        t.change(Time::ps(1), NetId(9), Logic::One);
        assert_eq!(t.change_count(), 0);
    }
}
