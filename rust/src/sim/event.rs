//! Simulation events and their deterministic ordering.

use super::net::{Logic, NetId};
use super::time::Time;

/// A scheduled net transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub time: Time,
    /// Monotonic sequence number: ties at equal `time` are resolved in
    /// scheduling order, making every run bit-reproducible.
    pub seq: u64,
    pub net: NetId,
    pub value: Logic,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; Circuit wraps events in `Reverse`.
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_seq() {
        let a = Event { time: Time::ps(1), seq: 5, net: NetId(0), value: Logic::One };
        let b = Event { time: Time::ps(2), seq: 1, net: NetId(0), value: Logic::One };
        let c = Event { time: Time::ps(1), seq: 6, net: NetId(1), value: Logic::Zero };
        assert!(a < b);
        assert!(a < c);
        assert!(c < b);
    }
}
