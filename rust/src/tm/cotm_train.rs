//! Coalesced Tsetlin Machine training (Glimsdal & Granmo 2021 [10]).
//!
//! One shared clause pool; each (class, clause) pair has a signed integer
//! weight. Per sample, the target class receives a positive update and a
//! sampled other class a negative update:
//!
//! * positive update, clause fires: `w += 1` and Type I feedback;
//!   clause silent: Type I forget;
//! * negative update, clause fires: `w -= 1` and Type II feedback.
//!
//! Weights saturate at ±`max_weight` (the hardware's weight register
//! width; the paper's binary multiplication matrix selects these).

use super::data::Dataset;
use super::model::{make_literals, CoTmModel, TmParams};
use crate::error::Result;
use crate::util::SplitMix64;

/// CoTM trainer: shared TA pool + weight matrix.
pub struct CoTmTrainer {
    pub params: TmParams,
    /// `[clause][literal]` TA states in `1..=2N` (shared pool).
    states: Vec<Vec<u32>>,
    /// `[class][clause]` signed weights.
    weights: Vec<Vec<i32>>,
    rng: SplitMix64,
}

impl CoTmTrainer {
    pub fn new(params: TmParams, seed: u64) -> Result<CoTmTrainer> {
        params.validate()?;
        let mut rng = SplitMix64::new(seed);
        let n = params.ta_states;
        let states = (0..params.clauses)
            .map(|_| {
                (0..params.literals())
                    .map(|_| if rng.next_bool() { n } else { n + 1 })
                    .collect()
            })
            .collect();
        // Weights start at ±1 alternating per class to break symmetry.
        let weights = (0..params.classes)
            .map(|k| {
                (0..params.clauses)
                    .map(|j| if (j + k) % 2 == 0 { 1 } else { -1 })
                    .collect()
            })
            .collect();
        Ok(CoTmTrainer { params, states, weights, rng })
    }

    fn clause_fires(states: &[u32], lits: &[bool], n: u32) -> bool {
        states.iter().zip(lits).all(|(&st, &lit)| st <= n || lit)
    }

    fn clause_outputs(&self, lits: &[bool]) -> Vec<bool> {
        let n = self.params.ta_states;
        self.states
            .iter()
            .map(|cl| Self::clause_fires(cl, lits, n))
            .collect()
    }

    fn class_sum(&self, class: usize, outputs: &[bool]) -> i32 {
        self.weights[class]
            .iter()
            .zip(outputs)
            .map(|(&w, &c)| if c { w } else { 0 })
            .sum()
    }

    fn type_i(&mut self, clause: usize, lits: &[bool], fired: bool) {
        let n = self.params.ta_states;
        let s = self.params.specificity;
        let p_forget = 1.0 / s;
        let p_reinforce = (s - 1.0) / s;
        for (l, &lit) in lits.iter().enumerate() {
            let st = self.states[clause][l];
            if fired && lit {
                if self.rng.chance(p_reinforce) && st < 2 * n {
                    self.states[clause][l] = st + 1;
                }
            } else if self.rng.chance(p_forget) && st > 1 {
                self.states[clause][l] = st - 1;
            }
        }
    }

    fn type_ii(&mut self, clause: usize, lits: &[bool]) {
        let n = self.params.ta_states;
        for (l, &lit) in lits.iter().enumerate() {
            let st = self.states[clause][l];
            if !lit && st <= n {
                self.states[clause][l] = st + 1;
            }
        }
    }

    fn update_class(&mut self, class: usize, lits: &[bool], positive: bool) {
        let t = self.params.threshold;
        let outputs = self.clause_outputs(lits);
        let sum = self.class_sum(class, &outputs).clamp(-t, t);
        let p_update = if positive {
            (t - sum) as f64 / (2 * t) as f64
        } else {
            (t + sum) as f64 / (2 * t) as f64
        };
        let wmax = self.params.max_weight;
        for j in 0..self.params.clauses {
            if !self.rng.chance(p_update) {
                continue;
            }
            let fired = outputs[j];
            let w = self.weights[class][j]; // pre-update sign decides role
            if positive {
                if fired {
                    // Clause fired on a sample of this class.
                    self.weights[class][j] = (w + 1).min(wmax);
                    if w >= 0 {
                        // Supporting clause recognised correctly: Type Ia.
                        self.type_i(j, lits, true);
                    } else {
                        // Opposing clause fired wrongly: Type II blocks it.
                        self.type_ii(j, lits);
                    }
                } else if w >= 0 {
                    // Supporting clause stayed silent: Type Ib forget.
                    self.type_i(j, lits, false);
                }
            } else if fired {
                // Clause fired on a sample NOT of this class.
                self.weights[class][j] = (w - 1).max(-wmax);
                if w > 0 {
                    // Supporting clause fired wrongly: Type II blocks it.
                    self.type_ii(j, lits);
                } else {
                    // Opposing clause recognised correctly: Type Ia
                    // (reinforce the opposition pattern).
                    self.type_i(j, lits, true);
                }
            } else if w < 0 {
                // Opposing clause silent on a negative sample: forget.
                self.type_i(j, lits, false);
            }
        }
    }

    pub fn epoch(&mut self, data: &Dataset) {
        let mut order: Vec<usize> = (0..data.len()).collect();
        self.rng.shuffle(&mut order);
        for i in order {
            let lits = make_literals(&data.features[i]);
            let y = data.labels[i];
            self.update_class(y, &lits, true);
            if self.params.classes > 1 {
                let mut neg = self.rng.index(self.params.classes - 1);
                if neg >= y {
                    neg += 1;
                }
                self.update_class(neg, &lits, false);
            }
        }
    }

    pub fn train(&mut self, data: &Dataset, epochs: usize) -> CoTmModel {
        for _ in 0..epochs {
            self.epoch(data);
        }
        self.export()
    }

    pub fn export(&self) -> CoTmModel {
        let n = self.params.ta_states;
        let mut model = CoTmModel::zeroed(self.params.clone());
        for (j, cl) in self.states.iter().enumerate() {
            for (l, &st) in cl.iter().enumerate() {
                model.clauses[j].include[l] = st > n;
            }
        }
        model.weights = self.weights.clone();
        model
    }
}

/// Convenience: train a CoTM on a dataset.
pub fn train_cotm(
    params: TmParams,
    data: &Dataset,
    epochs: usize,
    seed: u64,
) -> Result<CoTmModel> {
    let mut tr = CoTmTrainer::new(params, seed)?;
    Ok(tr.train(data, epochs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::data;
    use crate::tm::infer::cotm_accuracy;

    #[test]
    fn learns_blobs() {
        let d = data::prototype_blobs(300, 10, 3, 0.05, 21);
        let p = TmParams {
            features: 10,
            clauses: 12,
            classes: 3,
            ta_states: 64,
            threshold: 6,
            specificity: 3.0,
            max_weight: 7,
        };
        let m = train_cotm(p, &d, 30, 2).unwrap();
        let acc = cotm_accuracy(&m, &d.features, &d.labels);
        assert!(acc > 0.9, "blobs accuracy {acc}");
    }

    #[test]
    fn learns_iris_to_paper_grade() {
        let d = data::iris().unwrap();
        let (train, test) = d.split(0.8, 42);
        let m = train_cotm(TmParams::iris_paper(), &train, 150, 3).unwrap();
        let acc = cotm_accuracy(&m, &test.features, &test.labels);
        assert!(acc >= 0.85, "iris CoTM test accuracy {acc}");
    }

    #[test]
    fn weights_respect_saturation() {
        let d = data::prototype_blobs(200, 8, 2, 0.05, 31);
        let p = TmParams {
            features: 8,
            clauses: 6,
            classes: 2,
            ta_states: 32,
            threshold: 5,
            specificity: 3.0,
            max_weight: 3,
        };
        let m = train_cotm(p, &d, 20, 5).unwrap();
        assert!(m.validate().is_ok());
        assert!(m
            .weights
            .iter()
            .flatten()
            .all(|w| w.abs() <= 3));
    }

    #[test]
    fn deterministic_per_seed() {
        let d = data::xor_noise(150, 4, 0.0, 8);
        let p = TmParams {
            features: 4,
            clauses: 8,
            classes: 2,
            ta_states: 32,
            threshold: 4,
            specificity: 3.0,
            max_weight: 7,
        };
        let a = train_cotm(p.clone(), &d, 10, 17).unwrap();
        let b = train_cotm(p, &d, 10, 17).unwrap();
        assert_eq!(a, b);
    }
}
