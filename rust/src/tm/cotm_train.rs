//! Coalesced Tsetlin Machine training (Glimsdal & Granmo 2021 [10]).
//!
//! One shared clause pool; each (class, clause) pair has a signed integer
//! weight. Per sample, the target class receives a positive update and a
//! sampled other class a negative update:
//!
//! * positive update, clause fires: `w += 1` and Type I feedback;
//!   clause silent: Type I forget;
//! * negative update, clause fires: `w -= 1` and Type II feedback.
//!
//! Weights saturate at ±`max_weight` (the hardware's weight register
//! width; the paper's binary multiplication matrix selects these).
//!
//! The Type I/II feedback core and the clause state (TA counters plus
//! the incrementally-packed include mask) are shared with the
//! multi-class trainer via [`super::trainer_engine`]; clause evaluation
//! runs through either engine of [`TrainerEngine`], bit-identically per
//! seed.

use super::bitpack::pack_literals;
use super::data::Dataset;
use super::model::{make_literals, CoTmModel, TmParams};
use super::trainer_engine::{type_i, type_ii, ClauseState, TrainerEngine};
use crate::error::Result;
use crate::util::SplitMix64;

/// CoTM trainer: shared TA pool + weight matrix.
pub struct CoTmTrainer {
    pub params: TmParams,
    pub engine: TrainerEngine,
    /// Shared clause pool (TA counters + packed mask per clause).
    states: Vec<ClauseState>,
    /// `[class][clause]` signed weights.
    weights: Vec<Vec<i32>>,
    rng: SplitMix64,
}

impl CoTmTrainer {
    /// New trainer with the default (packed) evaluation engine.
    pub fn new(params: TmParams, seed: u64) -> Result<CoTmTrainer> {
        Self::with_engine(params, seed, TrainerEngine::default())
    }

    /// New trainer with an explicit evaluation engine. Both engines
    /// produce bit-identical models for the same seed.
    pub fn with_engine(
        params: TmParams,
        seed: u64,
        engine: TrainerEngine,
    ) -> Result<CoTmTrainer> {
        params.validate()?;
        let mut rng = SplitMix64::new(seed);
        let n = params.ta_states;
        let states = (0..params.clauses)
            .map(|_| ClauseState::init(params.literals(), n, &mut rng))
            .collect();
        // Weights start at ±1 alternating per class to break symmetry.
        let weights = (0..params.classes)
            .map(|k| {
                (0..params.clauses)
                    .map(|j| if (j + k) % 2 == 0 { 1 } else { -1 })
                    .collect()
            })
            .collect();
        Ok(CoTmTrainer { params, engine, states, weights, rng })
    }

    /// The shared clause pool, for invariant tests.
    pub fn clause_states(&self) -> &[ClauseState] {
        &self.states
    }

    /// Training-time clause outputs: empty clauses fire.
    fn clause_outputs(&self, lits: &[bool], words: Option<&[u64]>) -> Vec<bool> {
        let n = self.params.ta_states;
        self.states.iter().map(|cl| cl.fires(lits, words, n)).collect()
    }

    fn class_sum(&self, class: usize, outputs: &[bool]) -> i32 {
        self.weights[class]
            .iter()
            .zip(outputs)
            .map(|(&w, &c)| if c { w } else { 0 })
            .sum()
    }

    fn update_class(
        &mut self,
        class: usize,
        lits: &[bool],
        words: Option<&[u64]>,
        positive: bool,
    ) {
        let t = self.params.threshold;
        let outputs = self.clause_outputs(lits, words);
        let sum = self.class_sum(class, &outputs).clamp(-t, t);
        let p_update = if positive {
            (t - sum) as f64 / (2 * t) as f64
        } else {
            (t + sum) as f64 / (2 * t) as f64
        };
        let n = self.params.ta_states;
        let s = self.params.specificity;
        let wmax = self.params.max_weight;
        for j in 0..self.params.clauses {
            if !self.rng.chance(p_update) {
                continue;
            }
            let fired = outputs[j];
            let w = self.weights[class][j]; // pre-update sign decides role
            if positive {
                if fired {
                    // Clause fired on a sample of this class.
                    self.weights[class][j] = (w + 1).min(wmax);
                    if w >= 0 {
                        // Supporting clause recognised correctly: Type Ia.
                        type_i(&mut self.states[j], lits, true, n, s, &mut self.rng);
                    } else {
                        // Opposing clause fired wrongly: Type II blocks it.
                        type_ii(&mut self.states[j], lits, n);
                    }
                } else if w >= 0 {
                    // Supporting clause stayed silent: Type Ib forget.
                    type_i(&mut self.states[j], lits, false, n, s, &mut self.rng);
                }
            } else if fired {
                // Clause fired on a sample NOT of this class.
                self.weights[class][j] = (w - 1).max(-wmax);
                if w > 0 {
                    // Supporting clause fired wrongly: Type II blocks it.
                    type_ii(&mut self.states[j], lits, n);
                } else {
                    // Opposing clause recognised correctly: Type Ia
                    // (reinforce the opposition pattern).
                    type_i(&mut self.states[j], lits, true, n, s, &mut self.rng);
                }
            } else if w < 0 {
                // Opposing clause silent on a negative sample: forget.
                type_i(&mut self.states[j], lits, false, n, s, &mut self.rng);
            }
        }
    }

    pub fn epoch(&mut self, data: &Dataset) {
        let mut order: Vec<usize> = (0..data.len()).collect();
        self.rng.shuffle(&mut order);
        for i in order {
            let lits = make_literals(&data.features[i]);
            let words = match self.engine {
                TrainerEngine::Packed => Some(pack_literals(&data.features[i])),
                TrainerEngine::Reference => None,
            };
            let y = data.labels[i];
            self.update_class(y, &lits, words.as_deref(), true);
            if self.params.classes > 1 {
                let mut neg = self.rng.index(self.params.classes - 1);
                if neg >= y {
                    neg += 1;
                }
                self.update_class(neg, &lits, words.as_deref(), false);
            }
        }
    }

    pub fn train(&mut self, data: &Dataset, epochs: usize) -> CoTmModel {
        for _ in 0..epochs {
            self.epoch(data);
        }
        self.export()
    }

    pub fn export(&self) -> CoTmModel {
        let n = self.params.ta_states;
        let mut model = CoTmModel::zeroed(self.params.clone());
        for (j, cl) in self.states.iter().enumerate() {
            model.clauses[j] = cl.include_mask(n);
        }
        model.weights = self.weights.clone();
        model
    }

    /// Trainer invariants: every TA in `1..=2N`, every incremental
    /// include mask coherent, every weight within ±`max_weight`.
    pub fn check_invariants(&self) -> Result<()> {
        let n = self.params.ta_states;
        for cl in &self.states {
            cl.check(n)?;
        }
        if self
            .weights
            .iter()
            .flatten()
            .any(|w| w.abs() > self.params.max_weight)
        {
            return Err(crate::Error::model("weight outside ±max_weight"));
        }
        Ok(())
    }
}

/// Convenience: train a CoTM on a dataset (packed engine).
pub fn train_cotm(
    params: TmParams,
    data: &Dataset,
    epochs: usize,
    seed: u64,
) -> Result<CoTmModel> {
    let mut tr = CoTmTrainer::new(params, seed)?;
    Ok(tr.train(data, epochs))
}

/// Train with an explicit evaluation engine.
pub fn train_cotm_with(
    params: TmParams,
    data: &Dataset,
    epochs: usize,
    seed: u64,
    engine: TrainerEngine,
) -> Result<CoTmModel> {
    let mut tr = CoTmTrainer::with_engine(params, seed, engine)?;
    Ok(tr.train(data, epochs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::data;
    use crate::tm::infer::cotm_accuracy;

    #[test]
    fn learns_blobs() {
        let d = data::prototype_blobs(300, 10, 3, 0.05, 21);
        let p = TmParams {
            features: 10,
            clauses: 12,
            classes: 3,
            ta_states: 64,
            threshold: 6,
            specificity: 3.0,
            max_weight: 7,
        };
        let m = train_cotm(p, &d, 30, 2).unwrap();
        let acc = cotm_accuracy(&m, &d.features, &d.labels);
        assert!(acc > 0.9, "blobs accuracy {acc}");
    }

    #[test]
    fn learns_iris_to_paper_grade() {
        let d = data::iris().unwrap();
        let (train, test) = d.split(0.8, 42);
        let m = train_cotm(TmParams::iris_paper(), &train, 150, 3).unwrap();
        let acc = cotm_accuracy(&m, &test.features, &test.labels);
        assert!(acc >= 0.85, "iris CoTM test accuracy {acc}");
    }

    #[test]
    fn weights_respect_saturation() {
        let d = data::prototype_blobs(200, 8, 2, 0.05, 31);
        let p = TmParams {
            features: 8,
            clauses: 6,
            classes: 2,
            ta_states: 32,
            threshold: 5,
            specificity: 3.0,
            max_weight: 3,
        };
        let m = train_cotm(p, &d, 20, 5).unwrap();
        assert!(m.validate().is_ok());
        assert!(m
            .weights
            .iter()
            .flatten()
            .all(|w| w.abs() <= 3));
    }

    #[test]
    fn deterministic_per_seed() {
        let d = data::xor_noise(150, 4, 0.0, 8);
        let p = TmParams {
            features: 4,
            clauses: 8,
            classes: 2,
            ta_states: 32,
            threshold: 4,
            specificity: 3.0,
            max_weight: 7,
        };
        let a = train_cotm(p.clone(), &d, 10, 17).unwrap();
        let b = train_cotm(p, &d, 10, 17).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn packed_and_reference_trainers_bit_identical() {
        let d = data::prototype_blobs(150, 9, 3, 0.1, 23);
        let p = TmParams {
            features: 9,
            clauses: 7, // odd clause counts are legal for CoTM
            classes: 3,
            ta_states: 32,
            threshold: 4,
            specificity: 3.0,
            max_weight: 5,
        };
        let a = train_cotm_with(p.clone(), &d, 6, 31, TrainerEngine::Reference).unwrap();
        let b = train_cotm_with(p, &d, 6, 31, TrainerEngine::Packed).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn invariants_hold_across_epochs() {
        let d = data::prototype_blobs(120, 8, 3, 0.1, 3);
        let p = TmParams {
            features: 8,
            clauses: 8,
            classes: 3,
            ta_states: 16,
            threshold: 4,
            specificity: 2.5,
            max_weight: 4,
        };
        for engine in [TrainerEngine::Reference, TrainerEngine::Packed] {
            let mut tr = CoTmTrainer::with_engine(p.clone(), 4, engine).unwrap();
            for _ in 0..8 {
                tr.epoch(&d);
                tr.check_invariants().expect("invariants after epoch");
            }
        }
    }
}
