//! Tsetlin Machine substrate: model structures, software inference,
//! bit-parallel production inference ([`bitpack`] + [`fast_infer`],
//! evaluated in multi-word [`simd`] lanes behind runtime dispatch),
//! event-driven inverted-index inference for sparse models ([`index`]),
//! compressed include-list inference for the ETHEREAL clause regime
//! ([`compressed`]), the load-time model-compile pass every serving
//! engine builds from ([`compile`]), training (multi-class TM and Coalesced TM, both with a shared
//! feedback core and packed-evaluation or reference clause engines via
//! [`trainer_engine`]), feature booleanisation, datasets, and model
//! (de)serialisation. [`async_train`] adds the clause-parallel
//! stale-vote training tier on top of the same feedback core.
//!
//! This is the ML-algorithm layer the paper's hardware implements. The
//! software inference here is the L3-local golden reference (checked
//! against the AOT-compiled L2 JAX model and against every hardware
//! architecture in `tests/equivalence.rs`, mirroring §III-A).

pub mod async_train;
pub mod bitpack;
pub mod booleanize;
pub mod compile;
pub mod compressed;
pub mod cotm_train;
pub mod data;
pub mod fast_infer;
pub mod index;
pub mod infer;
pub mod iris_data;
pub mod model;
pub mod serde;
pub mod simd;
pub mod train;
pub mod trainer_engine;

pub use async_train::{
    train_cotm_async, train_multiclass_async, AsyncCoTmTrainer, AsyncMultiClassTrainer,
    TrainerChoice,
};
pub use bitpack::{BitSlicedBatch, PackedClause};
pub use booleanize::Booleanizer;
pub use compile::{
    ClausePlan, CompileMode, CompileStats, CompiledClause, CompiledCotm,
    CompiledMulticlass, ModelCompiler,
};
pub use compressed::{CompressedCotm, CompressedModel, CompressedMulticlass, EngineChoice};
pub use data::Dataset;
pub use fast_infer::{BatchEngine, BitParallelCotm, BitParallelMulticlass};
pub use index::{IndexedCotm, IndexedMulticlass, InvertedIndex};
pub use infer::{cotm_class_sums, multiclass_class_sums, predict_argmax};
pub use model::{ClauseMask, CoTmModel, MultiClassTmModel, TmParams};
pub use simd::{SimdChoice, SimdLevel, WordLanes};
pub use trainer_engine::{ClauseState, TrainerEngine};
