//! Multi-class Tsetlin Machine training (Granmo 2018 [9]).
//!
//! The paper deploys *pre-trained* models in hardware; this module is the
//! substrate that produces them. Standard two-action Tsetlin automata
//! with Type I / Type II feedback (the feedback core itself lives in
//! [`super::trainer_engine`], shared with the CoTM trainer):
//!
//! * each (clause, literal) pair has a TA with states `1..=2N`
//!   (`> N` = include the literal);
//! * per sample, the target class receives a positive update and one
//!   uniformly sampled other class a negative update, each gated by the
//!   clamped class sum against threshold `T`;
//! * Type I feedback (recognise): on firing clauses, reinforce matching
//!   literals (prob `(s-1)/s`) and forget mismatching ones (prob `1/s`);
//!   on silent clauses, forget all (prob `1/s`);
//! * Type II feedback (reject): on firing clauses, include literals that
//!   are 0 in the sample, driving the clause towards not firing.
//!
//! During *training*, an empty clause evaluates to 1 (it must fire to
//! receive Type I feedback and grow); during *inference* it outputs 0 —
//! both conventions are standard and mirrored in the Python oracle.
//!
//! Clause evaluation — the training hot path — runs through either the
//! per-literal reference walk or the packed-word evaluator
//! ([`TrainerEngine`]); the two are bit-identical per seed (see
//! `trainer_engine.rs` and `tests/train_equivalence.rs`).

use super::bitpack::pack_literals;
use super::data::Dataset;
use super::model::{make_literals, MultiClassTmModel, TmParams};
use super::trainer_engine::{type_i, type_ii, ClauseState, TrainerEngine};
use crate::error::Result;
use crate::util::SplitMix64;

/// Trainer holding TA state alongside the exported model.
pub struct MultiClassTrainer {
    pub params: TmParams,
    pub engine: TrainerEngine,
    /// `[class][clause]` clause states (TA counters + packed mask).
    states: Vec<Vec<ClauseState>>,
    rng: SplitMix64,
}

impl MultiClassTrainer {
    /// New trainer with the default (packed) evaluation engine.
    pub fn new(params: TmParams, seed: u64) -> Result<MultiClassTrainer> {
        Self::with_engine(params, seed, TrainerEngine::default())
    }

    /// New trainer with an explicit evaluation engine. Both engines
    /// produce bit-identical models for the same seed.
    pub fn with_engine(
        params: TmParams,
        seed: u64,
        engine: TrainerEngine,
    ) -> Result<MultiClassTrainer> {
        params.validate()?;
        if params.clauses % 2 != 0 {
            return Err(crate::Error::model(
                "multi-class TM needs an even clause count (+/− polarity pairs)",
            ));
        }
        let mut rng = SplitMix64::new(seed);
        let n = params.ta_states;
        // Initialise each TA uniformly to N or N+1 (the decision
        // boundary) — one next_bool per literal, in class/clause order.
        let states = (0..params.classes)
            .map(|_| {
                (0..params.clauses)
                    .map(|_| ClauseState::init(params.literals(), n, &mut rng))
                    .collect()
            })
            .collect();
        Ok(MultiClassTrainer { params, engine, states, rng })
    }

    /// The clause states (`[class][clause]`), for invariant tests.
    pub fn clause_states(&self) -> &[Vec<ClauseState>] {
        &self.states
    }

    /// Training-time class sum: empty clauses fire (see module docs).
    fn class_sum(&self, class: usize, lits: &[bool], words: Option<&[u64]>) -> i32 {
        let n = self.params.ta_states;
        self.states[class]
            .iter()
            .enumerate()
            .map(|(j, cl)| {
                let out = cl.fires(lits, words, n) as i32;
                if j % 2 == 0 {
                    out
                } else {
                    -out
                }
            })
            .sum()
    }

    /// One positive/negative update for `class` on a sample.
    fn update_class(
        &mut self,
        class: usize,
        lits: &[bool],
        words: Option<&[u64]>,
        positive: bool,
    ) {
        let t = self.params.threshold;
        let sum = self.class_sum(class, lits, words).clamp(-t, t);
        let p_update = if positive {
            (t - sum) as f64 / (2 * t) as f64
        } else {
            (t + sum) as f64 / (2 * t) as f64
        };
        let n = self.params.ta_states;
        let s = self.params.specificity;
        for j in 0..self.params.clauses {
            if !self.rng.chance(p_update) {
                continue;
            }
            let fired = self.states[class][j].fires(lits, words, n);
            let positive_clause = j % 2 == 0;
            // Positive update: + clauses learn (Type I), − clauses reject
            // (Type II on firing). Negative update: roles swap.
            if positive == positive_clause {
                type_i(&mut self.states[class][j], lits, fired, n, s, &mut self.rng);
            } else if fired {
                type_ii(&mut self.states[class][j], lits, n);
            }
        }
    }

    /// One epoch over the dataset (order shuffled per epoch).
    pub fn epoch(&mut self, data: &Dataset) {
        let mut order: Vec<usize> = (0..data.len()).collect();
        self.rng.shuffle(&mut order);
        for i in order {
            let lits = make_literals(&data.features[i]);
            // Pack the sample's literals once per sample; every clause
            // evaluation below reuses the words.
            let words = match self.engine {
                TrainerEngine::Packed => Some(pack_literals(&data.features[i])),
                TrainerEngine::Reference => None,
            };
            let y = data.labels[i];
            self.update_class(y, &lits, words.as_deref(), true);
            // Sample one negative class uniformly.
            if self.params.classes > 1 {
                let mut neg = self.rng.index(self.params.classes - 1);
                if neg >= y {
                    neg += 1;
                }
                self.update_class(neg, &lits, words.as_deref(), false);
            }
        }
    }

    /// Train for `epochs`, returning the exported (inference) model.
    pub fn train(&mut self, data: &Dataset, epochs: usize) -> MultiClassTmModel {
        for _ in 0..epochs {
            self.epoch(data);
        }
        self.export()
    }

    /// Export include masks (state > N) as an inference model.
    pub fn export(&self) -> MultiClassTmModel {
        let n = self.params.ta_states;
        let mut model = MultiClassTmModel::zeroed(self.params.clone());
        for (ci, class) in self.states.iter().enumerate() {
            for (j, cl) in class.iter().enumerate() {
                model.clauses[ci][j] = cl.include_mask(n);
            }
        }
        model
    }

    /// Trainer invariants: every TA in `1..=2N`, every incremental
    /// include mask coherent with its TA states.
    pub fn check_invariants(&self) -> Result<()> {
        let n = self.params.ta_states;
        for class in &self.states {
            for cl in class {
                cl.check(n)?;
            }
        }
        Ok(())
    }
}

/// Convenience: train a multi-class TM on a dataset (packed engine).
pub fn train_multiclass(
    params: TmParams,
    data: &Dataset,
    epochs: usize,
    seed: u64,
) -> Result<MultiClassTmModel> {
    let mut tr = MultiClassTrainer::new(params, seed)?;
    Ok(tr.train(data, epochs))
}

/// Train with an explicit evaluation engine.
pub fn train_multiclass_with(
    params: TmParams,
    data: &Dataset,
    epochs: usize,
    seed: u64,
    engine: TrainerEngine,
) -> Result<MultiClassTmModel> {
    let mut tr = MultiClassTrainer::with_engine(params, seed, engine)?;
    Ok(tr.train(data, epochs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::data;
    use crate::tm::infer::multiclass_accuracy;

    #[test]
    fn learns_noisy_xor() {
        let d = data::xor_noise(400, 4, 0.05, 11);
        let params = TmParams {
            features: 4,
            clauses: 10,
            classes: 2,
            ta_states: 64,
            threshold: 5,
            specificity: 3.0,
            max_weight: 7,
        };
        let model = train_multiclass(params, &d, 30, 1).unwrap();
        let clean = data::xor_noise(200, 4, 0.0, 99);
        let acc = multiclass_accuracy(&model, &clean.features, &clean.labels);
        assert!(acc > 0.9, "xor accuracy {acc}");
    }

    #[test]
    fn learns_iris_to_paper_grade() {
        let d = data::iris().unwrap();
        let (train, test) = d.split(0.8, 42);
        let model = train_multiclass(TmParams::iris_paper(), &train, 60, 2).unwrap();
        let acc = multiclass_accuracy(&model, &test.features, &test.labels);
        assert!(acc >= 0.85, "iris test accuracy {acc}");
    }

    #[test]
    fn training_is_deterministic_per_seed() {
        let d = data::xor_noise(100, 4, 0.0, 5);
        let p = TmParams {
            features: 4,
            clauses: 6,
            classes: 2,
            ta_states: 32,
            threshold: 4,
            specificity: 3.0,
            max_weight: 7,
        };
        let a = train_multiclass(p.clone(), &d, 5, 9).unwrap();
        let b = train_multiclass(p, &d, 5, 9).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn packed_and_reference_trainers_bit_identical() {
        // The module-level contract, at unit scope (the full
        // boundary-width sweep lives in tests/train_equivalence.rs).
        let d = data::xor_noise(120, 6, 0.05, 13);
        let p = TmParams {
            features: 6,
            clauses: 8,
            classes: 2,
            ta_states: 32,
            threshold: 4,
            specificity: 3.0,
            max_weight: 7,
        };
        let a = train_multiclass_with(p.clone(), &d, 6, 21, TrainerEngine::Reference).unwrap();
        let b = train_multiclass_with(p, &d, 6, 21, TrainerEngine::Packed).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn states_stay_in_bounds_and_masks_coherent() {
        let d = data::prototype_blobs(120, 8, 3, 0.1, 3);
        let p = TmParams {
            features: 8,
            clauses: 8,
            classes: 3,
            ta_states: 16,
            threshold: 4,
            specificity: 2.5,
            max_weight: 7,
        };
        for engine in [TrainerEngine::Reference, TrainerEngine::Packed] {
            let mut tr = MultiClassTrainer::with_engine(p.clone(), 4, engine).unwrap();
            for _ in 0..10 {
                tr.epoch(&d);
                tr.check_invariants().expect("invariants after epoch");
            }
            for class in tr.clause_states() {
                for clause in class {
                    for &st in clause.states() {
                        assert!((1..=32).contains(&st));
                    }
                }
            }
        }
    }
}
