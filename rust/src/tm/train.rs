//! Multi-class Tsetlin Machine training (Granmo 2018 [9]).
//!
//! The paper deploys *pre-trained* models in hardware; this module is the
//! substrate that produces them. Standard two-action Tsetlin automata
//! with Type I / Type II feedback:
//!
//! * each (clause, literal) pair has a TA with states `1..=2N`
//!   (`> N` = include the literal);
//! * per sample, the target class receives a positive update and one
//!   uniformly sampled other class a negative update, each gated by the
//!   clamped class sum against threshold `T`;
//! * Type I feedback (recognise): on firing clauses, reinforce matching
//!   literals (prob `(s-1)/s`) and forget mismatching ones (prob `1/s`);
//!   on silent clauses, forget all (prob `1/s`);
//! * Type II feedback (reject): on firing clauses, include literals that
//!   are 0 in the sample, driving the clause towards not firing.
//!
//! During *training*, an empty clause evaluates to 1 (it must fire to
//! receive Type I feedback and grow); during *inference* it outputs 0 —
//! both conventions are standard and mirrored in the Python oracle.

use super::data::Dataset;
use super::model::{make_literals, MultiClassTmModel, TmParams};
use crate::error::Result;
use crate::util::SplitMix64;

/// TA state array for one automaton team (one class): `[clause][literal]`.
type TaStates = Vec<Vec<u32>>;

/// Trainer holding TA state alongside the exported model.
pub struct MultiClassTrainer {
    pub params: TmParams,
    /// `[class][clause][literal]` TA states in `1..=2N`.
    states: Vec<TaStates>,
    rng: SplitMix64,
}

impl MultiClassTrainer {
    pub fn new(params: TmParams, seed: u64) -> Result<MultiClassTrainer> {
        params.validate()?;
        if params.clauses % 2 != 0 {
            return Err(crate::Error::model(
                "multi-class TM needs an even clause count (+/− polarity pairs)",
            ));
        }
        let mut rng = SplitMix64::new(seed);
        let n = params.ta_states;
        // Initialise each TA uniformly to N or N+1 (the decision boundary).
        let states = (0..params.classes)
            .map(|_| {
                (0..params.clauses)
                    .map(|_| {
                        (0..params.literals())
                            .map(|_| if rng.next_bool() { n } else { n + 1 })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        Ok(MultiClassTrainer { params, states, rng })
    }

    /// Training-time clause evaluation: empty clauses fire.
    fn clause_fires(states: &[u32], lits: &[bool], n: u32) -> bool {
        states
            .iter()
            .zip(lits)
            .all(|(&st, &lit)| st <= n || lit)
    }

    fn class_sum(&self, class: usize, lits: &[bool]) -> i32 {
        let n = self.params.ta_states;
        self.states[class]
            .iter()
            .enumerate()
            .map(|(j, cl)| {
                let out = Self::clause_fires(cl, lits, n) as i32;
                if j % 2 == 0 {
                    out
                } else {
                    -out
                }
            })
            .sum()
    }

    /// Type I feedback to one clause.
    fn type_i(&mut self, class: usize, clause: usize, lits: &[bool], fired: bool) {
        let n = self.params.ta_states;
        let s = self.params.specificity;
        let p_forget = 1.0 / s;
        let p_reinforce = (s - 1.0) / s;
        for (l, &lit) in lits.iter().enumerate() {
            let st = self.states[class][clause][l];
            if fired && lit {
                // Reinforce inclusion of true literals.
                if self.rng.chance(p_reinforce) && st < 2 * n {
                    self.states[class][clause][l] = st + 1;
                }
            } else {
                // Forget: silent clause, or false literal in firing clause.
                if self.rng.chance(p_forget) && st > 1 {
                    self.states[class][clause][l] = st - 1;
                }
            }
        }
    }

    /// Type II feedback to one firing clause: include 0-literals.
    fn type_ii(&mut self, class: usize, clause: usize, lits: &[bool]) {
        let n = self.params.ta_states;
        for (l, &lit) in lits.iter().enumerate() {
            let st = self.states[class][clause][l];
            if !lit && st <= n {
                self.states[class][clause][l] = st + 1;
            }
        }
    }

    /// One positive/negative update for `class` on a sample.
    fn update_class(&mut self, class: usize, lits: &[bool], positive: bool) {
        let t = self.params.threshold;
        let sum = self.class_sum(class, lits).clamp(-t, t);
        let p_update = if positive {
            (t - sum) as f64 / (2 * t) as f64
        } else {
            (t + sum) as f64 / (2 * t) as f64
        };
        let n = self.params.ta_states;
        for j in 0..self.params.clauses {
            if !self.rng.chance(p_update) {
                continue;
            }
            let fired = Self::clause_fires(&self.states[class][j], lits, n);
            let positive_clause = j % 2 == 0;
            // Positive update: + clauses learn (Type I), − clauses reject
            // (Type II on firing). Negative update: roles swap.
            if positive == positive_clause {
                self.type_i(class, j, lits, fired);
            } else if fired {
                self.type_ii(class, j, lits);
            }
        }
    }

    /// One epoch over the dataset (order shuffled per epoch).
    pub fn epoch(&mut self, data: &Dataset) {
        let mut order: Vec<usize> = (0..data.len()).collect();
        self.rng.shuffle(&mut order);
        for i in order {
            let lits = make_literals(&data.features[i]);
            let y = data.labels[i];
            self.update_class(y, &lits, true);
            // Sample one negative class uniformly.
            if self.params.classes > 1 {
                let mut neg = self.rng.index(self.params.classes - 1);
                if neg >= y {
                    neg += 1;
                }
                self.update_class(neg, &lits, false);
            }
        }
    }

    /// Train for `epochs`, returning the exported (inference) model.
    pub fn train(&mut self, data: &Dataset, epochs: usize) -> MultiClassTmModel {
        for _ in 0..epochs {
            self.epoch(data);
        }
        self.export()
    }

    /// Export include masks (state > N) as an inference model.
    pub fn export(&self) -> MultiClassTmModel {
        let n = self.params.ta_states;
        let mut model = MultiClassTmModel::zeroed(self.params.clone());
        for (ci, class) in self.states.iter().enumerate() {
            for (j, cl) in class.iter().enumerate() {
                for (l, &st) in cl.iter().enumerate() {
                    model.clauses[ci][j].include[l] = st > n;
                }
            }
        }
        model
    }
}

/// Convenience: train a multi-class TM on a dataset.
pub fn train_multiclass(
    params: TmParams,
    data: &Dataset,
    epochs: usize,
    seed: u64,
) -> Result<MultiClassTmModel> {
    let mut tr = MultiClassTrainer::new(params, seed)?;
    Ok(tr.train(data, epochs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::data;
    use crate::tm::infer::multiclass_accuracy;

    #[test]
    fn learns_noisy_xor() {
        let d = data::xor_noise(400, 4, 0.05, 11);
        let params = TmParams {
            features: 4,
            clauses: 10,
            classes: 2,
            ta_states: 64,
            threshold: 5,
            specificity: 3.0,
            max_weight: 7,
        };
        let model = train_multiclass(params, &d, 30, 1).unwrap();
        let clean = data::xor_noise(200, 4, 0.0, 99);
        let acc = multiclass_accuracy(&model, &clean.features, &clean.labels);
        assert!(acc > 0.9, "xor accuracy {acc}");
    }

    #[test]
    fn learns_iris_to_paper_grade() {
        let d = data::iris().unwrap();
        let (train, test) = d.split(0.8, 42);
        let model = train_multiclass(TmParams::iris_paper(), &train, 60, 2).unwrap();
        let acc = multiclass_accuracy(&model, &test.features, &test.labels);
        assert!(acc >= 0.85, "iris test accuracy {acc}");
    }

    #[test]
    fn training_is_deterministic_per_seed() {
        let d = data::xor_noise(100, 4, 0.0, 5);
        let p = TmParams {
            features: 4,
            clauses: 6,
            classes: 2,
            ta_states: 32,
            threshold: 4,
            specificity: 3.0,
            max_weight: 7,
        };
        let a = train_multiclass(p.clone(), &d, 5, 9).unwrap();
        let b = train_multiclass(p, &d, 5, 9).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn states_stay_in_bounds() {
        let d = data::prototype_blobs(120, 8, 3, 0.1, 3);
        let p = TmParams {
            features: 8,
            clauses: 8,
            classes: 3,
            ta_states: 16,
            threshold: 4,
            specificity: 2.5,
            max_weight: 7,
        };
        let mut tr = MultiClassTrainer::new(p, 4).unwrap();
        for _ in 0..10 {
            tr.epoch(&d);
        }
        for class in &tr.states {
            for clause in class {
                for &st in clause {
                    assert!((1..=32).contains(&st));
                }
            }
        }
    }
}
