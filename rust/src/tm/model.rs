//! TM model structures.
//!
//! Literal order is **interleaved** — `literal[2i] = x_i`,
//! `literal[2i+1] = ¬x_i` — matching Algorithm 2 of the paper and the
//! Python L1/L2 layers (`python/compile/kernels/ref.py`).

use crate::error::{Error, Result};

/// Hyper-parameters shared by both TM variants.
#[derive(Debug, Clone, PartialEq)]
pub struct TmParams {
    /// Boolean input features F (after booleanisation).
    pub features: usize,
    /// Clauses per class (multi-class TM) or shared clauses (CoTM).
    pub clauses: usize,
    /// Output classes K.
    pub classes: usize,
    /// Tsetlin-automaton states per action half (2N total states).
    pub ta_states: u32,
    /// Feedback threshold T.
    pub threshold: i32,
    /// Specificity s (> 1).
    pub specificity: f64,
    /// Max |weight| for CoTM integer weights.
    pub max_weight: i32,
}

impl TmParams {
    /// The paper's Iris configuration: 16 features, 12 clauses, 3 classes.
    pub fn iris_paper() -> TmParams {
        TmParams {
            features: 16,
            clauses: 12,
            classes: 3,
            ta_states: 128,
            threshold: 4,
            specificity: 3.0,
            max_weight: 7,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.features == 0 || self.clauses == 0 || self.classes < 2 {
            return Err(Error::model(format!(
                "degenerate shape F={} C={} K={}",
                self.features, self.clauses, self.classes
            )));
        }
        if self.specificity <= 1.0 {
            return Err(Error::model("specificity must be > 1"));
        }
        Ok(())
    }

    /// Number of literals (2F).
    pub fn literals(&self) -> usize {
        2 * self.features
    }
}

/// A clause's include mask over the 2F literals (true = literal included).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ClauseMask {
    pub include: Vec<bool>,
}

impl ClauseMask {
    pub fn empty(literals: usize) -> ClauseMask {
        ClauseMask { include: vec![false; literals] }
    }

    pub fn is_empty(&self) -> bool {
        !self.include.iter().any(|&b| b)
    }

    pub fn included_count(&self) -> usize {
        self.include.iter().filter(|&&b| b).count()
    }

    /// Evaluate on interleaved literals: fires iff every included literal
    /// is 1; empty clauses output 0 at inference (standard convention).
    pub fn evaluate(&self, literals: &[bool]) -> bool {
        debug_assert_eq!(literals.len(), self.include.len());
        if self.is_empty() {
            return false;
        }
        self.include
            .iter()
            .zip(literals)
            .all(|(&inc, &lit)| !inc || lit)
    }
}

/// Multi-class TM: per class, `clauses` clause masks with alternating
/// polarity (+ for even clause index, − for odd; Eq. 1).
#[derive(Debug, Clone, PartialEq)]
pub struct MultiClassTmModel {
    pub params: TmParams,
    /// `[class][clause]` include masks.
    pub clauses: Vec<Vec<ClauseMask>>,
}

impl MultiClassTmModel {
    pub fn zeroed(params: TmParams) -> MultiClassTmModel {
        let masks = (0..params.classes)
            .map(|_| {
                (0..params.clauses)
                    .map(|_| ClauseMask::empty(params.literals()))
                    .collect()
            })
            .collect();
        MultiClassTmModel { params, clauses: masks }
    }

    /// Flattened include mask as f32 rows (K*C, 2F) — the layout the AOT
    /// artifacts take as input.
    pub fn include_f32(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.params.classes * self.params.clauses * self.params.literals());
        for class in &self.clauses {
            for cl in class {
                v.extend(cl.include.iter().map(|&b| if b { 1.0 } else { 0.0 }));
            }
        }
        v
    }

    pub fn validate(&self) -> Result<()> {
        self.params.validate()?;
        if self.params.clauses % 2 != 0 {
            // Multi-class-only constraint: clause polarity alternates in
            // +/− pairs (Eq. 1). CoTM has no such requirement (Eq. 2).
            return Err(Error::model(
                "multi-class TM needs an even clause count (+/− polarity pairs)",
            ));
        }
        if self.clauses.len() != self.params.classes {
            return Err(Error::model("class count mismatch"));
        }
        for (i, class) in self.clauses.iter().enumerate() {
            if class.len() != self.params.clauses {
                return Err(Error::model(format!("clause count mismatch in class {i}")));
            }
            for (j, cl) in class.iter().enumerate() {
                if cl.include.len() != self.params.literals() {
                    return Err(Error::model(format!("literal width mismatch at [{i}][{j}]")));
                }
            }
        }
        Ok(())
    }
}

/// Coalesced TM: one shared clause pool plus a signed integer weight
/// matrix `[class][clause]` (Eq. 2).
#[derive(Debug, Clone, PartialEq)]
pub struct CoTmModel {
    pub params: TmParams,
    pub clauses: Vec<ClauseMask>,
    /// `[class][clause]` signed weights.
    pub weights: Vec<Vec<i32>>,
}

impl CoTmModel {
    pub fn zeroed(params: TmParams) -> CoTmModel {
        let clauses = (0..params.clauses)
            .map(|_| ClauseMask::empty(params.literals()))
            .collect();
        let weights = vec![vec![0; params.clauses]; params.classes];
        CoTmModel { params, clauses, weights }
    }

    /// Include mask as f32 rows (C, 2F).
    pub fn include_f32(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.params.clauses * self.params.literals());
        for cl in &self.clauses {
            v.extend(cl.include.iter().map(|&b| if b { 1.0 } else { 0.0 }));
        }
        v
    }

    /// Weights as f32 rows (K, C).
    pub fn weights_f32(&self) -> Vec<f32> {
        self.weights
            .iter()
            .flat_map(|row| row.iter().map(|&w| w as f32))
            .collect()
    }

    pub fn validate(&self) -> Result<()> {
        self.params.validate()?;
        if self.clauses.len() != self.params.clauses {
            return Err(Error::model("clause count mismatch"));
        }
        if self.weights.len() != self.params.classes {
            return Err(Error::model("weight row count mismatch"));
        }
        for row in &self.weights {
            if row.len() != self.params.clauses {
                return Err(Error::model("weight col count mismatch"));
            }
            if row.iter().any(|w| w.abs() > self.params.max_weight) {
                return Err(Error::model("weight exceeds max_weight"));
            }
        }
        Ok(())
    }
}

/// Expand boolean features into interleaved literals `[x0, ¬x0, x1, …]`.
pub fn make_literals(features: &[bool]) -> Vec<bool> {
    let mut lits = Vec::with_capacity(features.len() * 2);
    for &f in features {
        lits.push(f);
        lits.push(!f);
    }
    lits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_interleaved() {
        assert_eq!(
            make_literals(&[true, false]),
            vec![true, false, false, true]
        );
    }

    #[test]
    fn empty_clause_outputs_zero() {
        let m = ClauseMask::empty(4);
        assert!(!m.evaluate(&[true, true, true, true]));
    }

    #[test]
    fn clause_requires_all_included() {
        let mut m = ClauseMask::empty(4);
        m.include[0] = true; // x0
        m.include[3] = true; // ¬x1
        assert!(m.evaluate(&make_literals(&[true, false])));
        assert!(!m.evaluate(&make_literals(&[true, true])));
        assert!(!m.evaluate(&make_literals(&[false, false])));
    }

    #[test]
    fn params_validation() {
        let mut p = TmParams::iris_paper();
        assert!(p.validate().is_ok());
        p.specificity = 0.5;
        assert!(p.validate().is_err());
        // Odd clause counts are fine for CoTM but not multi-class.
        let odd = TmParams { clauses: 7, specificity: 3.0, ..TmParams::iris_paper() };
        assert!(odd.validate().is_ok());
        assert!(MultiClassTmModel::zeroed(odd).validate().is_err());
    }

    #[test]
    fn include_f32_layout() {
        let p = TmParams {
            features: 2,
            clauses: 2,
            classes: 2,
            ..TmParams::iris_paper()
        };
        let mut m = MultiClassTmModel::zeroed(p);
        m.clauses[1][0].include[3] = true;
        let v = m.include_f32();
        assert_eq!(v.len(), 2 * 2 * 4);
        // class 1, clause 0 starts at offset (1*2+0)*4 = 8; literal 3.
        assert_eq!(v[8 + 3], 1.0);
        assert_eq!(v.iter().sum::<f32>(), 1.0);
    }

    #[test]
    fn cotm_validation_rejects_oversized_weight() {
        let p = TmParams { features: 2, clauses: 2, classes: 2, max_weight: 3, ..TmParams::iris_paper() };
        let mut m = CoTmModel::zeroed(p);
        m.weights[0][0] = 5;
        assert!(m.validate().is_err());
    }
}
