//! Feature booleanisation: thermometer (cumulative threshold) encoding.
//!
//! The paper's Iris configuration uses 16 boolean features for the 4 raw
//! measurements — i.e. 4 quantile thresholds per feature, exactly what a
//! fitted [`Booleanizer`] with `bits = 4` produces.

use crate::error::{Error, Result};

/// Thermometer encoder: per raw feature, `bits` thresholds chosen at
/// training-set quantiles; bit b = (x >= threshold_b).
#[derive(Debug, Clone, PartialEq)]
pub struct Booleanizer {
    /// `[feature][bit]` thresholds, ascending.
    pub thresholds: Vec<Vec<f32>>,
}

impl Booleanizer {
    /// Fit thresholds at evenly spaced quantiles of each raw feature.
    /// Non-finite raw values (NaN, ±∞) are rejected: quantiles of a
    /// column containing them are meaningless, and the previous
    /// `partial_cmp(..).unwrap()` sort panicked on NaN instead of
    /// returning an error.
    pub fn fit(raw: &[Vec<f32>], bits: usize) -> Result<Booleanizer> {
        if raw.is_empty() {
            return Err(Error::model("cannot fit booleanizer on empty data"));
        }
        let dims = raw[0].len();
        if raw.iter().any(|r| r.len() != dims) {
            return Err(Error::model("ragged raw feature rows"));
        }
        let n = raw.len();
        let mut thresholds = Vec::with_capacity(dims);
        for d in 0..dims {
            let mut col: Vec<f32> = raw.iter().map(|r| r[d]).collect();
            if let Some(bad) = col.iter().find(|x| !x.is_finite()) {
                return Err(Error::model(format!(
                    "non-finite raw feature value {bad} in column {d}"
                )));
            }
            col.sort_by(|a, b| a.total_cmp(b));
            let mut ts = Vec::with_capacity(bits);
            for b in 0..bits {
                // Quantiles at (b+1)/(bits+1): e.g. bits=4 -> 20/40/60/80%.
                let q = (b + 1) as f64 / (bits + 1) as f64;
                let idx = ((n - 1) as f64 * q).round() as usize;
                ts.push(col[idx]);
            }
            thresholds.push(ts);
        }
        Ok(Booleanizer { thresholds })
    }

    /// Number of boolean output features (dims × bits).
    pub fn output_features(&self) -> usize {
        self.thresholds.iter().map(|t| t.len()).sum()
    }

    /// Encode one raw sample. NaN is rejected: `NaN >= t` is false for
    /// every threshold, which would silently encode as an all-zero
    /// thermometer code indistinguishable from a genuinely small value.
    /// (±∞ stay well-defined — all-ones / all-zeros — and are allowed.)
    pub fn encode(&self, raw: &[f32]) -> Result<Vec<bool>> {
        if raw.len() != self.thresholds.len() {
            return Err(Error::model(format!(
                "raw dims {} != fitted dims {}",
                raw.len(),
                self.thresholds.len()
            )));
        }
        let mut out = Vec::with_capacity(self.output_features());
        for (d, (x, ts)) in raw.iter().zip(&self.thresholds).enumerate() {
            if x.is_nan() {
                return Err(Error::model(format!("NaN raw feature in column {d}")));
            }
            for t in ts {
                out.push(x >= t);
            }
        }
        Ok(out)
    }

    /// Encode a batch.
    pub fn encode_all(&self, raw: &[Vec<f32>]) -> Result<Vec<Vec<bool>>> {
        raw.iter().map(|r| self.encode(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thermometer_is_monotone() {
        let raw: Vec<Vec<f32>> = (0..100).map(|i| vec![i as f32]).collect();
        let b = Booleanizer::fit(&raw, 4).unwrap();
        let low = b.encode(&[0.0]).unwrap();
        let mid = b.encode(&[50.0]).unwrap();
        let high = b.encode(&[99.0]).unwrap();
        let ones = |v: &[bool]| v.iter().filter(|&&x| x).count();
        assert!(ones(&low) <= ones(&mid) && ones(&mid) <= ones(&high));
        assert_eq!(ones(&high), 4);
        assert_eq!(ones(&low), 0);
        // Thermometer property: ones are a prefix-of-threshold pattern
        // (no 1 after a 0 within one feature's bits).
        for v in [low, mid, high] {
            let mut seen_zero = false;
            for &bit in &v {
                if seen_zero {
                    assert!(!bit, "non-contiguous thermometer code");
                }
                if !bit {
                    seen_zero = true;
                }
            }
        }
    }

    #[test]
    fn iris_shape_matches_paper() {
        let raw: Vec<Vec<f32>> = crate::tm::iris_data::IRIS_FEATURES
            .iter()
            .map(|r| r.to_vec())
            .collect();
        let b = Booleanizer::fit(&raw, 4).unwrap();
        assert_eq!(b.output_features(), 16); // the paper's 16 features
        let enc = b.encode(&raw[0]).unwrap();
        assert_eq!(enc.len(), 16);
    }

    #[test]
    fn rejects_dim_mismatch() {
        let raw = vec![vec![1.0, 2.0]];
        let b = Booleanizer::fit(&raw, 2).unwrap();
        assert!(b.encode(&[1.0]).is_err());
    }

    #[test]
    fn rejects_empty_fit() {
        assert!(Booleanizer::fit(&[], 4).is_err());
    }

    #[test]
    fn fit_rejects_non_finite_instead_of_panicking() {
        // Regression: the quantile sort used
        // `partial_cmp(..).unwrap()`, which panicked on NaN input.
        let nan_raw = vec![vec![1.0, 2.0], vec![1.5, f32::NAN], vec![2.0, 3.0]];
        let err = Booleanizer::fit(&nan_raw, 2).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        assert!(err.to_string().contains("column 1"), "{err}");
        for bad in [f32::INFINITY, f32::NEG_INFINITY] {
            let raw = vec![vec![bad], vec![1.0]];
            assert!(Booleanizer::fit(&raw, 2).is_err(), "{bad}");
        }
        // Finite data is unaffected.
        assert!(Booleanizer::fit(&[vec![1.0], vec![2.0]], 2).is_ok());
    }

    #[test]
    fn encode_rejects_nan_but_allows_infinities() {
        let raw = vec![vec![0.0], vec![1.0], vec![2.0], vec![3.0]];
        let b = Booleanizer::fit(&raw, 2).unwrap();
        let err = b.encode(&[f32::NAN]).unwrap_err();
        assert!(err.to_string().contains("NaN"), "{err}");
        // ±∞ have well-defined thermometer codes.
        assert_eq!(b.encode(&[f32::INFINITY]).unwrap(), vec![true, true]);
        assert_eq!(b.encode(&[f32::NEG_INFINITY]).unwrap(), vec![false, false]);
        // And a NaN anywhere in a batch fails the whole batch.
        assert!(b.encode_all(&[vec![1.0], vec![f32::NAN]]).is_err());
    }
}
