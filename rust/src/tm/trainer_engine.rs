//! Shared training engine: the Type I / Type II feedback core and the
//! packed-evaluation clause state used by both trainers
//! ([`super::train::MultiClassTrainer`] and
//! [`super::cotm_train::CoTmTrainer`]).
//!
//! Before this module the two trainers duplicated the feedback math and
//! evaluated clauses by walking per-literal `Vec<u32>` TA state —
//! O(2F) per clause per evaluation — while inference got packed-`u64`
//! (`bitpack`/`fast_infer`) and inverted-index (`index`) engines. Here
//! the TA counters stay per-literal in `1..=2N` (feedback semantics
//! untouched), but each clause *additionally* maintains a packed
//! include mask ([`ClauseState::include_words`]), updated incrementally
//! and only when a TA crosses the N/N+1 include boundary. Clause firing
//! and class sums then go through the packed evaluator — 64 literals
//! per word — which is where training spends most of its time (the
//! massively-parallel TM architecture of arXiv 2009.04861 measures
//! clause evaluation dominating training cost; arXiv 2004.03188 applies
//! the same observation to learning).
//!
//! # The bit-identity contract
//!
//! [`TrainerEngine::Packed`] changes only *how* clause firing is
//! computed, never *what* fires and never the RNG consumption order, so
//! a packed trainer must produce a model **bit-identical** to the
//! reference trainer for the same seed:
//!
//! * packed evaluation is exact — `include & !literals == 0` per word
//!   is the same predicate as the per-literal walk (tail padding is
//!   zero on both sides);
//! * **training-time empty-clause semantics**: an all-exclude clause
//!   has all-zero include words, the word-AND reduction is vacuously
//!   true, and the clause *fires* — matching the reference trainer's
//!   convention (an empty clause must fire to receive Type I feedback
//!   and grow) and deliberately opposite to the inference convention of
//!   [`super::bitpack::PackedClause::evaluate`];
//! * evaluation consumes no randomness, so the Bernoulli/shuffle stream
//!   is byte-for-byte the stream the reference path consumes;
//! * the packed predicate dispatches through the detected
//!   [`super::simd::WordLanes`] width — every lane level computes the
//!   identical word predicate (pinned by `tests/simd_dispatch.rs` and
//!   the lane-parity test below), so SIMD dispatch cannot perturb the
//!   trained model either.
//!
//! Enforced by `tests/train_equivalence.rs`, the `tmtd selfcheck`
//! trainer-parity bar, and the Python mirror (`python/packedtrain.py`,
//! validated on toolchain-less CI). The golden vectors in the tests
//! below are asserted *identically* in
//! `python/tests/test_packedtrain.py` — if either language's trainer
//! drifts, both suites fail.

use super::bitpack::{eval_words_train, pack_bools, WORD_BITS};
use super::model::ClauseMask;
use crate::error::{Error, Result};
use crate::util::SplitMix64;

/// Which clause evaluator a trainer uses. Both produce bit-identical
/// models for the same seed; `Packed` is the production default.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TrainerEngine {
    /// Walk per-literal TA state (`st <= N || lit`) — the original
    /// trainer hot path, kept as the conformance reference.
    Reference,
    /// Evaluate through the incrementally-maintained packed include
    /// words — 64 literals per instruction.
    #[default]
    Packed,
}

impl TrainerEngine {
    /// Parse a CLI name (`--trainer packed|reference`).
    pub fn parse(name: &str) -> Option<TrainerEngine> {
        match name {
            "reference" | "ref" => Some(TrainerEngine::Reference),
            "packed" => Some(TrainerEngine::Packed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TrainerEngine::Reference => "reference",
            TrainerEngine::Packed => "packed",
        }
    }
}

/// One clause's training state: per-literal TA counters in `1..=2N`
/// plus the incrementally-updated packed include mask (`state > N` =
/// include). All TA writes go through [`ClauseState::set_ta`] so the
/// mask can never drift from the counters (checked by
/// [`ClauseState::coherent`] in the invariant tests).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClauseState {
    /// TA states, one per literal, each in `1..=2N`.
    states: Vec<u32>,
    /// Packed include mask over the literals (bit `l` of word `l/64`).
    include_words: Vec<u64>,
    /// Number of included literals (kept for density/debug reporting).
    included: usize,
}

impl ClauseState {
    /// Initialise each TA uniformly to N or N+1 (the decision
    /// boundary), consuming one `next_bool` per literal — the exact
    /// draw order of the original trainers.
    pub fn init(literals: usize, n: u32, rng: &mut SplitMix64) -> ClauseState {
        let states = (0..literals)
            .map(|_| if rng.next_bool() { n } else { n + 1 })
            .collect();
        ClauseState::from_states(states, n)
    }

    /// Build from explicit TA states (used by tests and fuzzing).
    pub fn from_states(states: Vec<u32>, n: u32) -> ClauseState {
        let include: Vec<bool> = states.iter().map(|&st| st > n).collect();
        let included = include.iter().filter(|&&b| b).count();
        ClauseState { include_words: pack_bools(&include), included, states }
    }

    /// The per-literal TA states.
    pub fn states(&self) -> &[u32] {
        &self.states
    }

    /// One TA state.
    #[inline]
    pub fn ta(&self, l: usize) -> u32 {
        self.states[l]
    }

    /// The incrementally-maintained packed include words.
    pub fn include_words(&self) -> &[u64] {
        &self.include_words
    }

    /// Number of included literals.
    pub fn included_count(&self) -> usize {
        self.included
    }

    /// Write a TA state, updating the packed mask only when the N/N+1
    /// include boundary is crossed (the common case — a reinforce or
    /// forget step away from the boundary — touches no word).
    #[inline]
    pub fn set_ta(&mut self, l: usize, st: u32, n: u32) {
        let was = self.states[l] > n;
        let now = st > n;
        self.states[l] = st;
        if was != now {
            let (w, bit) = (l / WORD_BITS, 1u64 << (l % WORD_BITS));
            if now {
                self.include_words[w] |= bit;
                self.included += 1;
            } else {
                self.include_words[w] &= !bit;
                self.included -= 1;
            }
        }
    }

    /// Training-time packed evaluation: fires iff
    /// `include & !literals == 0` in every word. An empty clause has
    /// all-zero words, so the reduction is vacuously true and it
    /// *fires* — the training convention, not the inference one.
    #[inline]
    pub fn fires_packed(&self, literal_words: &[u64]) -> bool {
        eval_words_train(&self.include_words, literal_words)
    }

    /// Training-time per-literal evaluation (the reference path).
    #[inline]
    pub fn fires_reference(&self, lits: &[bool], n: u32) -> bool {
        self.states.iter().zip(lits).all(|(&st, &lit)| st <= n || lit)
    }

    /// Engine dispatch: packed words when the trainer packed them for
    /// this sample, the per-literal walk otherwise.
    #[inline]
    pub fn fires(&self, lits: &[bool], literal_words: Option<&[u64]>, n: u32) -> bool {
        match literal_words {
            Some(words) => self.fires_packed(words),
            None => self.fires_reference(lits, n),
        }
    }

    /// The include mask recomputed from scratch — what the incremental
    /// words must always equal.
    pub fn recomputed_words(&self, n: u32) -> Vec<u64> {
        pack_bools(&self.states.iter().map(|&st| st > n).collect::<Vec<bool>>())
    }

    /// Coherence invariant: incremental words and count match a
    /// from-scratch recompute.
    pub fn coherent(&self, n: u32) -> bool {
        self.include_words == self.recomputed_words(n)
            && self.included == self.states.iter().filter(|&&st| st > n).count()
    }

    /// Export the include mask (`state > N`) for the inference model.
    pub fn include_mask(&self, n: u32) -> ClauseMask {
        ClauseMask { include: self.states.iter().map(|&st| st > n).collect() }
    }

    /// Bounds + coherence check, used by the trainers' `check_invariants`.
    pub fn check(&self, n: u32) -> Result<()> {
        if let Some(&bad) = self.states.iter().find(|&&st| st < 1 || st > 2 * n) {
            return Err(Error::model(format!("TA state {bad} outside 1..={}", 2 * n)));
        }
        if !self.coherent(n) {
            return Err(Error::model(
                "incremental include mask diverged from TA states",
            ));
        }
        Ok(())
    }
}

/// Type I feedback (recognise) to one clause. Consumes exactly one
/// Bernoulli draw per literal, in literal order — the stream contract
/// both trainers and both engines share: on a firing clause, true
/// literals are reinforced with probability `(s-1)/s`; everything else
/// (silent clause, or false literal in a firing clause) is forgotten
/// with probability `1/s`.
pub fn type_i(
    clause: &mut ClauseState,
    lits: &[bool],
    fired: bool,
    n: u32,
    s: f64,
    rng: &mut SplitMix64,
) {
    let p_forget = 1.0 / s;
    let p_reinforce = (s - 1.0) / s;
    for (l, &lit) in lits.iter().enumerate() {
        let st = clause.ta(l);
        if fired && lit {
            if rng.chance(p_reinforce) && st < 2 * n {
                clause.set_ta(l, st + 1, n);
            }
        } else if rng.chance(p_forget) && st > 1 {
            clause.set_ta(l, st - 1, n);
        }
    }
}

/// Type II feedback (reject) to one firing clause: include literals
/// that are 0 in the sample, driving the clause towards not firing.
/// Consumes no randomness.
pub fn type_ii(clause: &mut ClauseState, lits: &[bool], n: u32) {
    for (l, &lit) in lits.iter().enumerate() {
        let st = clause.ta(l);
        if !lit && st <= n {
            clause.set_ta(l, st + 1, n);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::bitpack::pack_literals;
    use crate::tm::cotm_train::train_cotm_with;
    use crate::tm::data::Dataset;
    use crate::tm::model::{make_literals, TmParams};
    use crate::tm::train::train_multiclass_with;
    use crate::testutil::prop;

    // -----------------------------------------------------------------
    // Cross-language golden vectors, asserted identically in
    // python/tests/test_packedtrain.py. The Python mirror generated
    // them; if either side's algorithm drifts, both suites fail.
    // -----------------------------------------------------------------

    /// Closed-form dataset shared verbatim with the Python tests.
    fn synth(f: usize, n_samples: usize, classes: usize) -> Dataset {
        let features = (0..n_samples)
            .map(|s| (0..f).map(|i| (i * i + 3 * i * s + 2 * s) % 7 < 3).collect())
            .collect();
        let labels = (0..n_samples).map(|s| s % classes).collect();
        Dataset { features, labels, classes, name: "synth".into() }
    }

    fn mask_bits(m: &ClauseMask) -> String {
        m.include.iter().map(|&b| if b { '1' } else { '0' }).collect()
    }

    #[test]
    fn splitmix_stream_matches_python_mirror() {
        // Pins the RNG mirror: python/packedtrain.py::SplitMix64 must
        // produce exactly this stream (test_splitmix_stream_goldens).
        let mut r = SplitMix64::new(42);
        assert_eq!(
            [r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64()],
            [
                0xBDD7_3226_2FEB_6E95,
                0x28EF_E333_B266_F103,
                0x4752_6757_130F_9F52,
                0x581C_E1FF_0E4A_E394,
            ]
        );
        let mut r = SplitMix64::new(7);
        let chances: String = (0..32)
            .map(|_| if r.chance(1.0 / 3.0) { '1' } else { '0' })
            .collect();
        assert_eq!(chances, "01000101101000000100010000100001");
        let mut r = SplitMix64::new(9);
        let idx: Vec<usize> = (0..12).map(|_| r.index(5)).collect();
        assert_eq!(idx, vec![3, 3, 1, 3, 1, 0, 3, 4, 1, 3, 2, 1]);
        let mut xs: Vec<u32> = (0..8).collect();
        SplitMix64::new(3).shuffle(&mut xs);
        assert_eq!(xs, vec![2, 5, 1, 6, 7, 3, 4, 0]);
    }

    #[test]
    fn multiclass_trained_golden_model_matches_python_mirror() {
        // F=5 C=4 K=2 N=8 T=3 s=3.0, 12 samples, 3 epochs, seed 42.
        let golden = [
            ["0000000001", "0001000001", "0000100001", "0000000001"], // class 0
            ["0010000000", "0000000001", "1010000001", "1000000100"], // class 1
        ];
        let d = synth(5, 12, 2);
        let p = TmParams {
            features: 5,
            clauses: 4,
            classes: 2,
            ta_states: 8,
            threshold: 3,
            specificity: 3.0,
            max_weight: 7,
        };
        for engine in [TrainerEngine::Reference, TrainerEngine::Packed] {
            let m = train_multiclass_with(p.clone(), &d, 3, 42, engine).unwrap();
            for (k, class) in m.clauses.iter().enumerate() {
                for (j, cl) in class.iter().enumerate() {
                    assert_eq!(
                        mask_bits(cl),
                        golden[k][j],
                        "{} class {k} clause {j}",
                        engine.name()
                    );
                }
            }
        }
    }

    #[test]
    fn cotm_trained_golden_model_matches_python_mirror() {
        // F=5 C=5 K=3 N=8 T=3 s=3.0 wmax=3, 12 samples, 3 epochs, seed 43.
        let golden_masks = [
            "0000000110",
            "1010011000",
            "0000000001",
            "1010001010",
            "0100010010",
        ];
        let golden_weights = vec![
            vec![-1, 1, 0, -1, 0],
            vec![-1, 2, 0, 2, -2],
            vec![0, -3, 0, 0, 1],
        ];
        let d = synth(5, 12, 3);
        let p = TmParams {
            features: 5,
            clauses: 5,
            classes: 3,
            ta_states: 8,
            threshold: 3,
            specificity: 3.0,
            max_weight: 3,
        };
        for engine in [TrainerEngine::Reference, TrainerEngine::Packed] {
            let m = train_cotm_with(p.clone(), &d, 3, 43, engine).unwrap();
            for (j, cl) in m.clauses.iter().enumerate() {
                assert_eq!(mask_bits(cl), golden_masks[j], "{} clause {j}", engine.name());
            }
            assert_eq!(m.weights, golden_weights, "{}", engine.name());
        }
    }

    // -----------------------------------------------------------------
    // ClauseState unit + fuzz level.
    // -----------------------------------------------------------------

    #[test]
    fn engine_parse_names() {
        assert_eq!(TrainerEngine::parse("packed"), Some(TrainerEngine::Packed));
        assert_eq!(TrainerEngine::parse("reference"), Some(TrainerEngine::Reference));
        assert_eq!(TrainerEngine::parse("ref"), Some(TrainerEngine::Reference));
        assert_eq!(TrainerEngine::parse("golden"), None);
        assert_eq!(TrainerEngine::default(), TrainerEngine::Packed);
        assert_eq!(TrainerEngine::Packed.name(), "packed");
    }

    #[test]
    fn empty_clause_fires_at_training_time() {
        // The convention that must NOT match inference: all-exclude
        // fires here (it needs Type I feedback to grow), while
        // bitpack::PackedClause::evaluate returns false.
        let n = 8;
        let cs = ClauseState::from_states(vec![n; 10], n);
        assert_eq!(cs.included_count(), 0);
        let x = [true, false, true, false, true];
        assert!(cs.fires_packed(&pack_literals(&x)));
        assert!(cs.fires_reference(&make_literals(&x), n));
    }

    #[test]
    fn set_ta_crossing_updates_words_and_count() {
        let n = 4;
        let mut cs = ClauseState::from_states(vec![n; 70], n);
        assert_eq!(cs.include_words().len(), 2);
        // Cross up at a word-boundary literal (64) and a low one (3).
        cs.set_ta(64, n + 1, n);
        cs.set_ta(3, n + 1, n);
        assert_eq!(cs.included_count(), 2);
        assert_eq!(cs.include_words()[0], 1 << 3);
        assert_eq!(cs.include_words()[1], 1 << 0);
        // Moving within a side of the boundary touches nothing.
        cs.set_ta(64, n + 2, n);
        cs.set_ta(5, n - 1, n);
        assert_eq!(cs.included_count(), 2);
        // Cross back down.
        cs.set_ta(64, n, n);
        assert_eq!(cs.included_count(), 1);
        assert_eq!(cs.include_words()[1], 0);
        assert!(cs.coherent(n));
    }

    #[test]
    fn incremental_mask_matches_recompute_under_random_walks() {
        prop("clause-state mask coherence", 60, |g| {
            let lits = g.usize(1..140);
            let n = g.u64(1..64) as u32;
            let states: Vec<u32> =
                (0..lits).map(|_| g.u64(1..2 * n as u64 + 1) as u32).collect();
            let mut cs = ClauseState::from_states(states, n);
            assert!(cs.coherent(n));
            for _ in 0..200 {
                let l = g.usize(0..lits);
                let st = g.u64(1..2 * n as u64 + 1) as u32;
                cs.set_ta(l, st, n);
            }
            assert!(cs.coherent(n));
            assert!(cs.check(n).is_ok());
        });
    }

    #[test]
    fn packed_firing_matches_per_literal_firing() {
        // Training-time semantics on both paths, across word-boundary
        // widths, including empty clauses — and at every available lane
        // width, since fires_packed dispatches through WordLanes.
        use crate::tm::bitpack::eval_words_train_with;
        use crate::tm::simd::{SimdLevel, WordLanes};
        prop("packed vs per-literal training eval", 200, |g| {
            let f = g.usize(1..80);
            let n = 8u32;
            let states: Vec<u32> = (0..2 * f)
                .map(|_| if g.chance(0.7) { n } else { g.u64(1..17) as u32 })
                .collect();
            let cs = ClauseState::from_states(states, n);
            let x = g.bools(f);
            let want = cs.fires_reference(&make_literals(&x), n);
            let words = pack_literals(&x);
            assert_eq!(cs.fires_packed(&words), want, "f={f}");
            for level in SimdLevel::available() {
                assert_eq!(
                    eval_words_train_with(
                        cs.include_words(),
                        &words,
                        WordLanes::new(level).unwrap()
                    ),
                    want,
                    "f={f} level {}",
                    level.name()
                );
            }
        });
    }

    #[test]
    fn feedback_keeps_states_in_bounds_and_mask_coherent() {
        prop("feedback invariants", 40, |g| {
            let f = g.usize(1..40);
            let n = g.u64(1..16) as u32;
            let mut rng = SplitMix64::new(g.u64(0..u64::MAX));
            let mut cs = ClauseState::init(2 * f, n, &mut rng);
            for _ in 0..100 {
                let x = g.bools(f);
                let lits = make_literals(&x);
                if g.bool() {
                    let fired = g.bool();
                    type_i(&mut cs, &lits, fired, n, 3.0, &mut rng);
                } else {
                    type_ii(&mut cs, &lits, n);
                }
                cs.check(n).expect("invariants after feedback");
            }
        });
    }

    #[test]
    fn check_rejects_incoherent_state() {
        let n = 4;
        let mut cs = ClauseState::from_states(vec![n + 1, n], n);
        assert!(cs.check(n).is_ok());
        // Corrupt the mask behind set_ta's back: check must catch it.
        cs.include_words[0] = 0;
        assert!(cs.check(n).is_err());
        let bad = ClauseState::from_states(vec![2 * n + 5], n);
        assert!(bad.check(n).is_err());
    }
}
