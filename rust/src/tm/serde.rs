//! Model (de)serialisation — a small line-oriented text format (no serde
//! crate offline). Stable across versions via an explicit header.
//!
//! ```text
//! tm-model v1 multiclass
//! params features=16 clauses=12 classes=3 ta_states=128 threshold=8 specificity=3 max_weight=7
//! clause 0 0 010010...            # class, clause index, 2F include bits
//! ...
//! ```
//!
//! CoTM adds `weights <class> w0 w1 ...` rows and omits the class index
//! on `clause` rows.
//!
//! Compiled artifacts (`tm-compiled v1 ...`, conventionally `.tmc`
//! files — the cheap serializable form for per-shard model pinning) add
//! a `mode` line, a `stats` line (the compile-time stats of the
//! *source* model, which a pruned artifact could not otherwise
//! recover), and per-clause records carrying source id, execution plan,
//! and the explicit vote (polarity for multiclass; per-clause `weights`
//! rows for CoTM):
//!
//! ```text
//! tm-compiled v1 multiclass
//! params features=3 clauses=4 classes=2 ...
//! mode full
//! stats total=8 dead_ae=2 dead_contra=2 postings=6 density=0.25 sweep=0 skip=4 hist=0,2,2,0,0,0,0,0
//! clause 0 3 -1 skip 100000       # class, source id, polarity, plan, 2F bits
//! ...
//! ```

use std::fmt::Write as _;
use std::path::Path;

use super::compile::{
    ClausePlan, CompileMode, CompileStats, CompiledClause, CompiledCotm,
    CompiledMulticlass, HIST_BUCKETS,
};
use super::model::{ClauseMask, CoTmModel, MultiClassTmModel, TmParams};
use crate::error::{Error, Result};

fn params_line(p: &TmParams) -> String {
    format!(
        "params features={} clauses={} classes={} ta_states={} threshold={} specificity={} max_weight={}",
        p.features, p.clauses, p.classes, p.ta_states, p.threshold, p.specificity, p.max_weight
    )
}

fn mask_bits(m: &ClauseMask) -> String {
    m.include.iter().map(|&b| if b { '1' } else { '0' }).collect()
}

fn parse_mask(bits: &str, literals: usize) -> Result<ClauseMask> {
    if bits.len() != literals {
        return Err(Error::model(format!(
            "clause width {} != 2F {}",
            bits.len(),
            literals
        )));
    }
    Ok(ClauseMask {
        include: bits
            .chars()
            .map(|c| match c {
                '1' => Ok(true),
                '0' => Ok(false),
                other => Err(Error::model(format!("bad mask char {other:?}"))),
            })
            .collect::<Result<Vec<bool>>>()?,
    })
}

fn parse_params(line: &str) -> Result<TmParams> {
    let mut p = TmParams {
        features: 0,
        clauses: 0,
        classes: 0,
        ta_states: 0,
        threshold: 0,
        specificity: 0.0,
        max_weight: 0,
    };
    for tok in line.split_whitespace().skip(1) {
        let (k, v) = tok
            .split_once('=')
            .ok_or_else(|| Error::model(format!("bad param token {tok:?}")))?;
        let fail = |_| Error::model(format!("bad value for {k}: {v:?}"));
        match k {
            "features" => p.features = v.parse().map_err(fail)?,
            "clauses" => p.clauses = v.parse().map_err(fail)?,
            "classes" => p.classes = v.parse().map_err(fail)?,
            "ta_states" => p.ta_states = v.parse().map_err(fail)?,
            "threshold" => p.threshold = v.parse().map_err(fail)?,
            "specificity" => {
                p.specificity = v.parse::<f64>().map_err(|_| Error::model("bad specificity"))?
            }
            "max_weight" => p.max_weight = v.parse().map_err(fail)?,
            _ => return Err(Error::model(format!("unknown param {k:?}"))),
        }
    }
    Ok(p)
}

/// Serialise a multi-class TM model.
pub fn multiclass_to_string(m: &MultiClassTmModel) -> String {
    let mut s = String::new();
    s.push_str("tm-model v1 multiclass\n");
    s.push_str(&params_line(&m.params));
    s.push('\n');
    for (ci, class) in m.clauses.iter().enumerate() {
        for (j, cl) in class.iter().enumerate() {
            let _ = writeln!(s, "clause {ci} {j} {}", mask_bits(cl));
        }
    }
    s
}

/// Parse a multi-class TM model.
pub fn multiclass_from_str(text: &str) -> Result<MultiClassTmModel> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| Error::model("empty model file"))?;
    if header.trim() != "tm-model v1 multiclass" {
        return Err(Error::model(format!("bad header {header:?}")));
    }
    let params = parse_params(
        lines
            .next()
            .ok_or_else(|| Error::model("missing params line"))?,
    )?;
    let mut model = MultiClassTmModel::zeroed(params);
    for line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("clause") => {
                let ci: usize = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad clause class idx"))?;
                let j: usize = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad clause idx"))?;
                let bits = it.next().ok_or_else(|| Error::model("missing mask"))?;
                if ci >= model.params.classes || j >= model.params.clauses {
                    return Err(Error::model(format!("clause [{ci}][{j}] out of range")));
                }
                model.clauses[ci][j] = parse_mask(bits, model.params.literals())?;
            }
            Some(other) => return Err(Error::model(format!("unknown record {other:?}"))),
            None => {}
        }
    }
    model.validate()?;
    Ok(model)
}

/// Serialise a CoTM model.
pub fn cotm_to_string(m: &CoTmModel) -> String {
    let mut s = String::new();
    s.push_str("tm-model v1 cotm\n");
    s.push_str(&params_line(&m.params));
    s.push('\n');
    for (j, cl) in m.clauses.iter().enumerate() {
        let _ = writeln!(s, "clause {j} {}", mask_bits(cl));
    }
    for (k, row) in m.weights.iter().enumerate() {
        let ws: Vec<String> = row.iter().map(|w| w.to_string()).collect();
        let _ = writeln!(s, "weights {k} {}", ws.join(" "));
    }
    s
}

/// Parse a CoTM model.
pub fn cotm_from_str(text: &str) -> Result<CoTmModel> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| Error::model("empty model file"))?;
    if header.trim() != "tm-model v1 cotm" {
        return Err(Error::model(format!("bad header {header:?}")));
    }
    let params = parse_params(
        lines
            .next()
            .ok_or_else(|| Error::model("missing params line"))?,
    )?;
    let mut model = CoTmModel::zeroed(params);
    for line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("clause") => {
                let j: usize = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad clause idx"))?;
                let bits = it.next().ok_or_else(|| Error::model("missing mask"))?;
                if j >= model.params.clauses {
                    return Err(Error::model(format!("clause {j} out of range")));
                }
                model.clauses[j] = parse_mask(bits, model.params.literals())?;
            }
            Some("weights") => {
                let k: usize = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad weight class idx"))?;
                if k >= model.params.classes {
                    return Err(Error::model(format!("weights {k} out of range")));
                }
                let row: Vec<i32> = it
                    .map(|t| t.parse().map_err(|_| Error::model("bad weight")))
                    .collect::<Result<_>>()?;
                if row.len() != model.params.clauses {
                    return Err(Error::model("weight row width mismatch"));
                }
                model.weights[k] = row;
            }
            Some(other) => return Err(Error::model(format!("unknown record {other:?}"))),
            None => {}
        }
    }
    model.validate()?;
    Ok(model)
}

fn stats_line(s: &CompileStats) -> String {
    let hist: Vec<String> = s.length_histogram.iter().map(|n| n.to_string()).collect();
    format!(
        "stats total={} dead_ae={} dead_contra={} postings={} density={} sweep={} skip={} hist={}",
        s.total_clauses,
        s.dead_all_exclude,
        s.dead_contradictory,
        s.postings,
        s.density,
        s.lane_sweep_clauses,
        s.skip_list_clauses,
        hist.join(",")
    )
}

fn parse_stats(line: &str) -> Result<CompileStats> {
    let mut s = CompileStats {
        total_clauses: 0,
        live_clauses: 0,
        dead_all_exclude: 0,
        dead_contradictory: 0,
        postings: 0,
        density: 0.0,
        lane_sweep_clauses: 0,
        skip_list_clauses: 0,
        length_histogram: [0; HIST_BUCKETS],
    };
    for tok in line.split_whitespace().skip(1) {
        let (k, v) = tok
            .split_once('=')
            .ok_or_else(|| Error::model(format!("bad stats token {tok:?}")))?;
        let fail = |_| Error::model(format!("bad stats value for {k}: {v:?}"));
        match k {
            "total" => s.total_clauses = v.parse().map_err(fail)?,
            "dead_ae" => s.dead_all_exclude = v.parse().map_err(fail)?,
            "dead_contra" => s.dead_contradictory = v.parse().map_err(fail)?,
            "postings" => s.postings = v.parse().map_err(fail)?,
            "density" => {
                s.density = v.parse::<f64>().map_err(|_| Error::model("bad density"))?
            }
            "sweep" => s.lane_sweep_clauses = v.parse().map_err(fail)?,
            "skip" => s.skip_list_clauses = v.parse().map_err(fail)?,
            "hist" => {
                let buckets: Vec<usize> = v
                    .split(',')
                    .map(|t| t.parse().map_err(|_| Error::model("bad hist bucket")))
                    .collect::<Result<_>>()?;
                if buckets.len() != HIST_BUCKETS {
                    return Err(Error::model("stats hist must have 8 buckets"));
                }
                s.length_histogram.copy_from_slice(&buckets);
            }
            _ => return Err(Error::model(format!("unknown stats key {k:?}"))),
        }
    }
    if s.dead_all_exclude + s.dead_contradictory > s.total_clauses {
        return Err(Error::model("stats dead count exceeds total"));
    }
    s.live_clauses = s.total_clauses - s.dead_all_exclude - s.dead_contradictory;
    Ok(s)
}

fn parse_mode(line: &str) -> Result<CompileMode> {
    let name = line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| Error::model("missing compile mode"))?;
    CompileMode::parse(name)
        .ok_or_else(|| Error::model(format!("compile mode must be off|prune|full, got {name:?}")))
}

fn parse_plan(tok: &str) -> Result<ClausePlan> {
    ClausePlan::parse(tok)
        .ok_or_else(|| Error::model(format!("clause plan must be skip|sweep, got {tok:?}")))
}

/// Serialise a compiled multiclass artifact.
pub fn compiled_multiclass_to_string(c: &CompiledMulticlass) -> String {
    let mut s = String::new();
    s.push_str("tm-compiled v1 multiclass\n");
    s.push_str(&params_line(&c.params));
    s.push('\n');
    let _ = writeln!(s, "mode {}", c.mode.name());
    s.push_str(&stats_line(&c.stats));
    s.push('\n');
    for (k, (class, pols)) in c.classes.iter().zip(&c.polarities).enumerate() {
        for (cc, pol) in class.iter().zip(pols) {
            let _ = writeln!(
                s,
                "clause {k} {} {pol} {} {}",
                cc.source,
                cc.plan.name(),
                mask_bits(&cc.mask)
            );
        }
    }
    s
}

/// Parse a compiled multiclass artifact (validated before return, so a
/// tampered file cannot reach an engine constructor).
pub fn compiled_multiclass_from_str(text: &str) -> Result<CompiledMulticlass> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| Error::model("empty artifact file"))?;
    if header.trim() != "tm-compiled v1 multiclass" {
        return Err(Error::model(format!("bad header {header:?}")));
    }
    let params = parse_params(
        lines.next().ok_or_else(|| Error::model("missing params line"))?,
    )?;
    let mode = parse_mode(lines.next().ok_or_else(|| Error::model("missing mode line"))?)?;
    let stats = parse_stats(lines.next().ok_or_else(|| Error::model("missing stats line"))?)?;
    let mut classes: Vec<Vec<CompiledClause>> = vec![Vec::new(); params.classes];
    let mut polarities: Vec<Vec<i32>> = vec![Vec::new(); params.classes];
    for line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("clause") => {
                let k: usize = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad clause class idx"))?;
                let source: u32 = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad clause source id"))?;
                let pol: i32 = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad clause polarity"))?;
                let plan = parse_plan(it.next().ok_or_else(|| Error::model("missing plan"))?)?;
                let bits = it.next().ok_or_else(|| Error::model("missing mask"))?;
                if k >= params.classes {
                    return Err(Error::model(format!("clause class {k} out of range")));
                }
                let mask = parse_mask(bits, params.literals())?;
                classes[k].push(CompiledClause { mask, source, plan });
                polarities[k].push(pol);
            }
            Some(other) => return Err(Error::model(format!("unknown record {other:?}"))),
            None => {}
        }
    }
    let compiled = CompiledMulticlass { params, classes, polarities, stats, mode };
    compiled.validate()?;
    Ok(compiled)
}

/// Serialise a compiled CoTM artifact (per-clause `weights` rows are
/// the clause's weight *column*, in live-clause order).
pub fn compiled_cotm_to_string(c: &CompiledCotm) -> String {
    let mut s = String::new();
    s.push_str("tm-compiled v1 cotm\n");
    s.push_str(&params_line(&c.params));
    s.push('\n');
    let _ = writeln!(s, "mode {}", c.mode.name());
    s.push_str(&stats_line(&c.stats));
    s.push('\n');
    for (i, (cc, col)) in c.clauses.iter().zip(&c.weight_cols).enumerate() {
        let _ = writeln!(
            s,
            "clause {} {} {}",
            cc.source,
            cc.plan.name(),
            mask_bits(&cc.mask)
        );
        let ws: Vec<String> = col.iter().map(|w| w.to_string()).collect();
        let _ = writeln!(s, "weights {i} {}", ws.join(" "));
    }
    s
}

/// Parse a compiled CoTM artifact (validated before return).
pub fn compiled_cotm_from_str(text: &str) -> Result<CompiledCotm> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| Error::model("empty artifact file"))?;
    if header.trim() != "tm-compiled v1 cotm" {
        return Err(Error::model(format!("bad header {header:?}")));
    }
    let params = parse_params(
        lines.next().ok_or_else(|| Error::model("missing params line"))?,
    )?;
    let mode = parse_mode(lines.next().ok_or_else(|| Error::model("missing mode line"))?)?;
    let stats = parse_stats(lines.next().ok_or_else(|| Error::model("missing stats line"))?)?;
    let mut clauses = Vec::new();
    let mut weight_cols: Vec<Vec<i32>> = Vec::new();
    for line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("clause") => {
                let source: u32 = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad clause source id"))?;
                let plan = parse_plan(it.next().ok_or_else(|| Error::model("missing plan"))?)?;
                let bits = it.next().ok_or_else(|| Error::model("missing mask"))?;
                let mask = parse_mask(bits, params.literals())?;
                clauses.push(CompiledClause { mask, source, plan });
            }
            Some("weights") => {
                let i: usize = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad weight row idx"))?;
                if i != weight_cols.len() {
                    return Err(Error::model(format!("weights row {i} out of order")));
                }
                let col: Vec<i32> = it
                    .map(|t| t.parse().map_err(|_| Error::model("bad weight")))
                    .collect::<Result<_>>()?;
                weight_cols.push(col);
            }
            Some(other) => return Err(Error::model(format!("unknown record {other:?}"))),
            None => {}
        }
    }
    let compiled = CompiledCotm { params, clauses, weight_cols, stats, mode };
    compiled.validate()?;
    Ok(compiled)
}

/// Save a compiled multiclass artifact (`.tmc` by convention).
pub fn save_compiled_multiclass(c: &CompiledMulticlass, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path, compiled_multiclass_to_string(c))?;
    Ok(())
}

pub fn save_compiled_cotm(c: &CompiledCotm, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path, compiled_cotm_to_string(c))?;
    Ok(())
}

pub fn load_compiled_multiclass(path: impl AsRef<Path>) -> Result<CompiledMulticlass> {
    compiled_multiclass_from_str(&std::fs::read_to_string(path)?)
}

pub fn load_compiled_cotm(path: impl AsRef<Path>) -> Result<CompiledCotm> {
    compiled_cotm_from_str(&std::fs::read_to_string(path)?)
}

/// Save either model kind to a file.
pub fn save_multiclass(m: &MultiClassTmModel, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path, multiclass_to_string(m))?;
    Ok(())
}

pub fn save_cotm(m: &CoTmModel, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path, cotm_to_string(m))?;
    Ok(())
}

pub fn load_multiclass(path: impl AsRef<Path>) -> Result<MultiClassTmModel> {
    multiclass_from_str(&std::fs::read_to_string(path)?)
}

pub fn load_cotm(path: impl AsRef<Path>) -> Result<CoTmModel> {
    cotm_from_str(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::data;
    use crate::tm::{cotm_train::train_cotm, train::train_multiclass};

    fn small_params() -> TmParams {
        TmParams {
            features: 4,
            clauses: 4,
            classes: 2,
            ta_states: 16,
            threshold: 3,
            specificity: 3.0,
            max_weight: 5,
        }
    }

    #[test]
    fn multiclass_roundtrip_exact() {
        let d = data::xor_noise(100, 4, 0.0, 2);
        let m = train_multiclass(small_params(), &d, 5, 1).unwrap();
        let text = multiclass_to_string(&m);
        let back = multiclass_from_str(&text).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn cotm_roundtrip_exact() {
        let d = data::xor_noise(100, 4, 0.0, 2);
        let m = train_cotm(small_params(), &d, 5, 1).unwrap();
        let text = cotm_to_string(&m);
        let back = cotm_from_str(&text).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn rejects_wrong_header() {
        assert!(multiclass_from_str("tm-model v1 cotm\nparams features=1").is_err());
        assert!(cotm_from_str("garbage").is_err());
    }

    #[test]
    fn rejects_out_of_range_indices() {
        let m = crate::tm::MultiClassTmModel::zeroed(small_params());
        let mut text = multiclass_to_string(&m);
        text.push_str("clause 9 0 00000000\n");
        assert!(multiclass_from_str(&text).is_err());
    }

    #[test]
    fn rejects_bad_mask_width() {
        let m = crate::tm::CoTmModel::zeroed(small_params());
        let mut text = cotm_to_string(&m);
        text.push_str("clause 0 0101\n"); // 4 bits, needs 8
        assert!(cotm_from_str(&text).is_err());
    }

    #[test]
    fn trained_roundtrip_serves_bit_exact_across_engines() {
        // Train → serialize → deserialize → the parsed model must be
        // equal AND serve bit-exact class sums through every native
        // engine tier (scalar golden, bit-parallel, inverted-index,
        // compressed) — the end-to-end artifact path `tmtd train` +
        // `tmtd infer` exercise. (The other round-trip tests stop at
        // model equality; this one proves the parse feeds the engines.)
        use crate::tm::infer::{cotm_class_sums, multiclass_class_sums};
        use crate::tm::{
            BatchEngine, BitParallelCotm, BitParallelMulticlass, CompressedCotm,
            CompressedMulticlass, IndexedCotm, IndexedMulticlass,
        };
        let d = data::prototype_blobs(80, 9, 3, 0.1, 4);
        let p = TmParams {
            features: 9,
            clauses: 6,
            classes: 3,
            ta_states: 16,
            threshold: 3,
            specificity: 3.0,
            max_weight: 5,
        };
        let m = train_multiclass(p.clone(), &d, 4, 9).unwrap();
        let back = multiclass_from_str(&multiclass_to_string(&m)).unwrap();
        assert_eq!(m, back);
        let bp = BitParallelMulticlass::from_model(&back).unwrap();
        let ix = IndexedMulticlass::from_model(&back).unwrap();
        let cp = CompressedMulticlass::from_model(&back).unwrap();
        let cm = train_cotm(p, &d, 4, 11).unwrap();
        let cback = cotm_from_str(&cotm_to_string(&cm)).unwrap();
        assert_eq!(cm, cback);
        let cbp = BitParallelCotm::from_model(&cback).unwrap();
        let cix = IndexedCotm::from_model(&cback).unwrap();
        let ccp = CompressedCotm::from_model(&cback).unwrap();
        for x in d.features.iter().take(24) {
            let want = multiclass_class_sums(&m, x);
            assert_eq!(multiclass_class_sums(&back, x), want);
            assert_eq!(BatchEngine::class_sums(&bp, x), want);
            assert_eq!(BatchEngine::class_sums(&ix, x), want);
            assert_eq!(BatchEngine::class_sums(&cp, x), want);
            let cwant = cotm_class_sums(&cm, x);
            assert_eq!(cotm_class_sums(&cback, x), cwant);
            assert_eq!(BatchEngine::class_sums(&cbp, x), cwant);
            assert_eq!(BatchEngine::class_sums(&cix, x), cwant);
            assert_eq!(BatchEngine::class_sums(&ccp, x), cwant);
        }
    }

    #[test]
    fn compiled_roundtrip_exact() {
        // Train → compile (full mode, deterministic calibration) →
        // serialize → parse: the artifact must round-trip field-for-
        // field (mode, stats, clause order, plans, polarities/weights),
        // and the engine built from the parsed artifact must serve the
        // same sums as one built from the in-memory artifact.
        use crate::tm::compile::{CompileMode, ModelCompiler};
        use crate::tm::{BatchEngine, BitParallelCotm, BitParallelMulticlass};
        let d = data::xor_noise(100, 4, 0.0, 2);
        let compiler = ModelCompiler::new(CompileMode::Full)
            .with_synthetic_calibration(4, 16, 7);
        let m = train_multiclass(small_params(), &d, 5, 1).unwrap();
        let c = compiler.compile_multiclass(&m).unwrap();
        let back = compiled_multiclass_from_str(&compiled_multiclass_to_string(&c)).unwrap();
        assert_eq!(c, back);
        let cm = train_cotm(small_params(), &d, 5, 1).unwrap();
        let cc = compiler.compile_cotm(&cm).unwrap();
        let cback = compiled_cotm_from_str(&compiled_cotm_to_string(&cc)).unwrap();
        assert_eq!(cc, cback);
        let e = BitParallelMulticlass::from_compiled(&back).unwrap();
        let ce = BitParallelCotm::from_compiled(&cback).unwrap();
        for x in d.features.iter().take(16) {
            assert_eq!(
                BatchEngine::class_sums(&e, x),
                crate::tm::infer::multiclass_class_sums(&m, x)
            );
            assert_eq!(
                BatchEngine::class_sums(&ce, x),
                crate::tm::infer::cotm_class_sums(&cm, x)
            );
        }
    }

    #[test]
    fn compiled_parse_rejects_tampered_artifacts() {
        use crate::tm::compile::ModelCompiler;
        let d = data::xor_noise(60, 4, 0.0, 2);
        let m = train_multiclass(small_params(), &d, 3, 1).unwrap();
        let c = ModelCompiler::default().compile_multiclass(&m).unwrap();
        let text = compiled_multiclass_to_string(&c);
        // Wrong header kind.
        assert!(compiled_cotm_from_str(&text).is_err());
        // Unknown compile mode.
        assert!(compiled_multiclass_from_str(&text.replace("mode prune", "mode mystery"))
            .is_err());
        // Polarity out of {±1} fails artifact validation.
        let bad = text.replacen(" 1 skip", " 3 skip", 1);
        if bad != text {
            assert!(compiled_multiclass_from_str(&bad).is_err());
        }
        // Truncated stats histogram.
        assert!(compiled_multiclass_from_str(
            &text.replace("hist=0,", "hist=")
        )
        .is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("tmtd-serde-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.tm");
        let d = data::xor_noise(50, 4, 0.0, 3);
        let m = train_multiclass(small_params(), &d, 3, 7).unwrap();
        save_multiclass(&m, &path).unwrap();
        assert_eq!(load_multiclass(&path).unwrap(), m);
    }
}
