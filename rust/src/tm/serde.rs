//! Model (de)serialisation — a small line-oriented text format (no serde
//! crate offline). Stable across versions via an explicit header.
//!
//! ```text
//! tm-model v1 multiclass
//! params features=16 clauses=12 classes=3 ta_states=128 threshold=8 specificity=3 max_weight=7
//! clause 0 0 010010...            # class, clause index, 2F include bits
//! ...
//! ```
//!
//! CoTM adds `weights <class> w0 w1 ...` rows and omits the class index
//! on `clause` rows.

use std::fmt::Write as _;
use std::path::Path;

use super::model::{ClauseMask, CoTmModel, MultiClassTmModel, TmParams};
use crate::error::{Error, Result};

fn params_line(p: &TmParams) -> String {
    format!(
        "params features={} clauses={} classes={} ta_states={} threshold={} specificity={} max_weight={}",
        p.features, p.clauses, p.classes, p.ta_states, p.threshold, p.specificity, p.max_weight
    )
}

fn mask_bits(m: &ClauseMask) -> String {
    m.include.iter().map(|&b| if b { '1' } else { '0' }).collect()
}

fn parse_mask(bits: &str, literals: usize) -> Result<ClauseMask> {
    if bits.len() != literals {
        return Err(Error::model(format!(
            "clause width {} != 2F {}",
            bits.len(),
            literals
        )));
    }
    Ok(ClauseMask {
        include: bits
            .chars()
            .map(|c| match c {
                '1' => Ok(true),
                '0' => Ok(false),
                other => Err(Error::model(format!("bad mask char {other:?}"))),
            })
            .collect::<Result<Vec<bool>>>()?,
    })
}

fn parse_params(line: &str) -> Result<TmParams> {
    let mut p = TmParams {
        features: 0,
        clauses: 0,
        classes: 0,
        ta_states: 0,
        threshold: 0,
        specificity: 0.0,
        max_weight: 0,
    };
    for tok in line.split_whitespace().skip(1) {
        let (k, v) = tok
            .split_once('=')
            .ok_or_else(|| Error::model(format!("bad param token {tok:?}")))?;
        let fail = |_| Error::model(format!("bad value for {k}: {v:?}"));
        match k {
            "features" => p.features = v.parse().map_err(fail)?,
            "clauses" => p.clauses = v.parse().map_err(fail)?,
            "classes" => p.classes = v.parse().map_err(fail)?,
            "ta_states" => p.ta_states = v.parse().map_err(fail)?,
            "threshold" => p.threshold = v.parse().map_err(fail)?,
            "specificity" => {
                p.specificity = v.parse::<f64>().map_err(|_| Error::model("bad specificity"))?
            }
            "max_weight" => p.max_weight = v.parse().map_err(fail)?,
            _ => return Err(Error::model(format!("unknown param {k:?}"))),
        }
    }
    Ok(p)
}

/// Serialise a multi-class TM model.
pub fn multiclass_to_string(m: &MultiClassTmModel) -> String {
    let mut s = String::new();
    s.push_str("tm-model v1 multiclass\n");
    s.push_str(&params_line(&m.params));
    s.push('\n');
    for (ci, class) in m.clauses.iter().enumerate() {
        for (j, cl) in class.iter().enumerate() {
            let _ = writeln!(s, "clause {ci} {j} {}", mask_bits(cl));
        }
    }
    s
}

/// Parse a multi-class TM model.
pub fn multiclass_from_str(text: &str) -> Result<MultiClassTmModel> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| Error::model("empty model file"))?;
    if header.trim() != "tm-model v1 multiclass" {
        return Err(Error::model(format!("bad header {header:?}")));
    }
    let params = parse_params(
        lines
            .next()
            .ok_or_else(|| Error::model("missing params line"))?,
    )?;
    let mut model = MultiClassTmModel::zeroed(params);
    for line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("clause") => {
                let ci: usize = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad clause class idx"))?;
                let j: usize = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad clause idx"))?;
                let bits = it.next().ok_or_else(|| Error::model("missing mask"))?;
                if ci >= model.params.classes || j >= model.params.clauses {
                    return Err(Error::model(format!("clause [{ci}][{j}] out of range")));
                }
                model.clauses[ci][j] = parse_mask(bits, model.params.literals())?;
            }
            Some(other) => return Err(Error::model(format!("unknown record {other:?}"))),
            None => {}
        }
    }
    model.validate()?;
    Ok(model)
}

/// Serialise a CoTM model.
pub fn cotm_to_string(m: &CoTmModel) -> String {
    let mut s = String::new();
    s.push_str("tm-model v1 cotm\n");
    s.push_str(&params_line(&m.params));
    s.push('\n');
    for (j, cl) in m.clauses.iter().enumerate() {
        let _ = writeln!(s, "clause {j} {}", mask_bits(cl));
    }
    for (k, row) in m.weights.iter().enumerate() {
        let ws: Vec<String> = row.iter().map(|w| w.to_string()).collect();
        let _ = writeln!(s, "weights {k} {}", ws.join(" "));
    }
    s
}

/// Parse a CoTM model.
pub fn cotm_from_str(text: &str) -> Result<CoTmModel> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| Error::model("empty model file"))?;
    if header.trim() != "tm-model v1 cotm" {
        return Err(Error::model(format!("bad header {header:?}")));
    }
    let params = parse_params(
        lines
            .next()
            .ok_or_else(|| Error::model("missing params line"))?,
    )?;
    let mut model = CoTmModel::zeroed(params);
    for line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("clause") => {
                let j: usize = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad clause idx"))?;
                let bits = it.next().ok_or_else(|| Error::model("missing mask"))?;
                if j >= model.params.clauses {
                    return Err(Error::model(format!("clause {j} out of range")));
                }
                model.clauses[j] = parse_mask(bits, model.params.literals())?;
            }
            Some("weights") => {
                let k: usize = it
                    .next()
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| Error::model("bad weight class idx"))?;
                if k >= model.params.classes {
                    return Err(Error::model(format!("weights {k} out of range")));
                }
                let row: Vec<i32> = it
                    .map(|t| t.parse().map_err(|_| Error::model("bad weight")))
                    .collect::<Result<_>>()?;
                if row.len() != model.params.clauses {
                    return Err(Error::model("weight row width mismatch"));
                }
                model.weights[k] = row;
            }
            Some(other) => return Err(Error::model(format!("unknown record {other:?}"))),
            None => {}
        }
    }
    model.validate()?;
    Ok(model)
}

/// Save either model kind to a file.
pub fn save_multiclass(m: &MultiClassTmModel, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path, multiclass_to_string(m))?;
    Ok(())
}

pub fn save_cotm(m: &CoTmModel, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path, cotm_to_string(m))?;
    Ok(())
}

pub fn load_multiclass(path: impl AsRef<Path>) -> Result<MultiClassTmModel> {
    multiclass_from_str(&std::fs::read_to_string(path)?)
}

pub fn load_cotm(path: impl AsRef<Path>) -> Result<CoTmModel> {
    cotm_from_str(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::data;
    use crate::tm::{cotm_train::train_cotm, train::train_multiclass};

    fn small_params() -> TmParams {
        TmParams {
            features: 4,
            clauses: 4,
            classes: 2,
            ta_states: 16,
            threshold: 3,
            specificity: 3.0,
            max_weight: 5,
        }
    }

    #[test]
    fn multiclass_roundtrip_exact() {
        let d = data::xor_noise(100, 4, 0.0, 2);
        let m = train_multiclass(small_params(), &d, 5, 1).unwrap();
        let text = multiclass_to_string(&m);
        let back = multiclass_from_str(&text).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn cotm_roundtrip_exact() {
        let d = data::xor_noise(100, 4, 0.0, 2);
        let m = train_cotm(small_params(), &d, 5, 1).unwrap();
        let text = cotm_to_string(&m);
        let back = cotm_from_str(&text).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn rejects_wrong_header() {
        assert!(multiclass_from_str("tm-model v1 cotm\nparams features=1").is_err());
        assert!(cotm_from_str("garbage").is_err());
    }

    #[test]
    fn rejects_out_of_range_indices() {
        let m = crate::tm::MultiClassTmModel::zeroed(small_params());
        let mut text = multiclass_to_string(&m);
        text.push_str("clause 9 0 00000000\n");
        assert!(multiclass_from_str(&text).is_err());
    }

    #[test]
    fn rejects_bad_mask_width() {
        let m = crate::tm::CoTmModel::zeroed(small_params());
        let mut text = cotm_to_string(&m);
        text.push_str("clause 0 0101\n"); // 4 bits, needs 8
        assert!(cotm_from_str(&text).is_err());
    }

    #[test]
    fn trained_roundtrip_serves_bit_exact_across_engines() {
        // Train → serialize → deserialize → the parsed model must be
        // equal AND serve bit-exact class sums through every native
        // engine tier (scalar golden, bit-parallel, inverted-index,
        // compressed) — the end-to-end artifact path `tmtd train` +
        // `tmtd infer` exercise. (The other round-trip tests stop at
        // model equality; this one proves the parse feeds the engines.)
        use crate::tm::infer::{cotm_class_sums, multiclass_class_sums};
        use crate::tm::{
            BatchEngine, BitParallelCotm, BitParallelMulticlass, CompressedCotm,
            CompressedMulticlass, IndexedCotm, IndexedMulticlass,
        };
        let d = data::prototype_blobs(80, 9, 3, 0.1, 4);
        let p = TmParams {
            features: 9,
            clauses: 6,
            classes: 3,
            ta_states: 16,
            threshold: 3,
            specificity: 3.0,
            max_weight: 5,
        };
        let m = train_multiclass(p.clone(), &d, 4, 9).unwrap();
        let back = multiclass_from_str(&multiclass_to_string(&m)).unwrap();
        assert_eq!(m, back);
        let bp = BitParallelMulticlass::from_model(&back).unwrap();
        let ix = IndexedMulticlass::from_model(&back).unwrap();
        let cp = CompressedMulticlass::from_model(&back).unwrap();
        let cm = train_cotm(p, &d, 4, 11).unwrap();
        let cback = cotm_from_str(&cotm_to_string(&cm)).unwrap();
        assert_eq!(cm, cback);
        let cbp = BitParallelCotm::from_model(&cback).unwrap();
        let cix = IndexedCotm::from_model(&cback).unwrap();
        let ccp = CompressedCotm::from_model(&cback).unwrap();
        for x in d.features.iter().take(24) {
            let want = multiclass_class_sums(&m, x);
            assert_eq!(multiclass_class_sums(&back, x), want);
            assert_eq!(BatchEngine::class_sums(&bp, x), want);
            assert_eq!(BatchEngine::class_sums(&ix, x), want);
            assert_eq!(BatchEngine::class_sums(&cp, x), want);
            let cwant = cotm_class_sums(&cm, x);
            assert_eq!(cotm_class_sums(&cback, x), cwant);
            assert_eq!(BatchEngine::class_sums(&cbp, x), cwant);
            assert_eq!(BatchEngine::class_sums(&cix, x), cwant);
            assert_eq!(BatchEngine::class_sums(&ccp, x), cwant);
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("tmtd-serde-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.tm");
        let d = data::xor_noise(50, 4, 0.0, 3);
        let m = train_multiclass(small_params(), &d, 3, 7).unwrap();
        save_multiclass(&m, &path).unwrap();
        assert_eq!(load_multiclass(&path).unwrap(), m);
    }
}
