//! Software (bit-exact) TM inference — the L3-local golden reference.

use super::model::{make_literals, CoTmModel, MultiClassTmModel};

/// Multi-class TM class sums for one sample (Eq. 1).
pub fn multiclass_class_sums(model: &MultiClassTmModel, features: &[bool]) -> Vec<i32> {
    let lits = make_literals(features);
    model
        .clauses
        .iter()
        .map(|class| {
            class
                .iter()
                .enumerate()
                .map(|(j, cl)| {
                    let out = cl.evaluate(&lits) as i32;
                    if j % 2 == 0 {
                        out
                    } else {
                        -out
                    }
                })
                .sum()
        })
        .collect()
}

/// CoTM class sums for one sample (Eq. 2).
pub fn cotm_class_sums(model: &CoTmModel, features: &[bool]) -> Vec<i32> {
    let lits = make_literals(features);
    let clause_out: Vec<i32> = model
        .clauses
        .iter()
        .map(|cl| cl.evaluate(&lits) as i32)
        .collect();
    model
        .weights
        .iter()
        .map(|row| row.iter().zip(&clause_out).map(|(w, c)| w * c).sum())
        .collect()
}

/// CoTM clause outputs alone (used by the hybrid architecture whose
/// digital stage computes clauses and whose time-domain stage ranks sums).
pub fn cotm_clause_outputs(model: &CoTmModel, features: &[bool]) -> Vec<bool> {
    let lits = make_literals(features);
    model.clauses.iter().map(|cl| cl.evaluate(&lits)).collect()
}

/// Multi-class TM clause outputs, `[class][clause]`.
pub fn multiclass_clause_outputs(
    model: &MultiClassTmModel,
    features: &[bool],
) -> Vec<Vec<bool>> {
    let lits = make_literals(features);
    model
        .clauses
        .iter()
        .map(|class| class.iter().map(|cl| cl.evaluate(&lits)).collect())
        .collect()
}

/// argmax with lowest-index tie-break — matches the WTA grant rule (the
/// deterministic model tie) and `jnp.argmax`.
pub fn predict_argmax(sums: &[i32]) -> usize {
    let mut best = 0usize;
    for (i, &s) in sums.iter().enumerate().skip(1) {
        if s > sums[best] {
            best = i;
        }
    }
    best
}

/// Batch accuracy of a multi-class TM.
pub fn multiclass_accuracy(
    model: &MultiClassTmModel,
    xs: &[Vec<bool>],
    ys: &[usize],
) -> f64 {
    let correct = xs
        .iter()
        .zip(ys)
        .filter(|(x, &y)| predict_argmax(&multiclass_class_sums(model, x)) == y)
        .count();
    correct as f64 / xs.len().max(1) as f64
}

/// Batch accuracy of a CoTM.
pub fn cotm_accuracy(model: &CoTmModel, xs: &[Vec<bool>], ys: &[usize]) -> f64 {
    let correct = xs
        .iter()
        .zip(ys)
        .filter(|(x, &y)| predict_argmax(&cotm_class_sums(model, x)) == y)
        .count();
    correct as f64 / xs.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::model::{ClauseMask, TmParams};

    fn tiny_params() -> TmParams {
        TmParams {
            features: 2,
            clauses: 2,
            classes: 2,
            ..TmParams::iris_paper()
        }
    }

    /// The hand-worked example mirrored from python/tests/test_model.py —
    /// both layers must agree on it.
    #[test]
    fn hand_worked_multiclass_matches_python_oracle() {
        let mut m = crate::tm::MultiClassTmModel::zeroed(tiny_params());
        m.clauses[0][0].include[0] = true; // class0 clause0 (+): x0
        m.clauses[0][1].include[3] = true; // class0 clause1 (−): ¬x1
        m.clauses[1][0].include[1] = true; // class1 clause0 (+): ¬x0
        m.clauses[1][1].include[2] = true; // class1 clause1 (−): x1
        assert_eq!(multiclass_class_sums(&m, &[true, false]), vec![0, 0]);
        assert_eq!(multiclass_class_sums(&m, &[true, true]), vec![1, -1]);
        assert_eq!(predict_argmax(&multiclass_class_sums(&m, &[true, true])), 0);
    }

    #[test]
    fn hand_worked_cotm_matches_python_oracle() {
        let mut m = crate::tm::CoTmModel::zeroed(tiny_params());
        m.clauses[0].include[0] = true; // clause0: x0
        m.clauses[1].include[2] = true; // clause1: x1
        m.weights = vec![vec![3, -2], vec![-1, 4]];
        assert_eq!(cotm_class_sums(&m, &[true, true]), vec![1, 3]);
        assert_eq!(cotm_class_sums(&m, &[true, false]), vec![3, -1]);
        assert_eq!(cotm_class_sums(&m, &[false, false]), vec![0, 0]);
    }

    #[test]
    fn argmax_tie_breaks_low() {
        assert_eq!(predict_argmax(&[3, 3, 1]), 0);
        assert_eq!(predict_argmax(&[1, 3, 3]), 1);
        assert_eq!(predict_argmax(&[-5]), 0);
    }

    #[test]
    fn empty_model_predicts_class_zero() {
        let m = crate::tm::MultiClassTmModel::zeroed(tiny_params());
        assert_eq!(predict_argmax(&multiclass_class_sums(&m, &[true, true])), 0);
    }

    #[test]
    fn clause_mask_polarity_sign() {
        let p = tiny_params();
        let mut m = crate::tm::MultiClassTmModel::zeroed(p);
        // Odd clause fires -> negative contribution.
        m.clauses[0][1] = ClauseMask { include: vec![true, false, false, false] };
        assert_eq!(multiclass_class_sums(&m, &[true, false])[0], -1);
    }
}
