//! Datasets: the embedded Fisher Iris set (the paper's benchmark) and
//! synthetic generators for training/robustness studies.

use super::booleanize::Booleanizer;
use super::iris_data::{IRIS_FEATURES, IRIS_LABELS};
use crate::error::Result;
use crate::util::SplitMix64;

/// A booleanised classification dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub features: Vec<Vec<bool>>,
    pub labels: Vec<usize>,
    pub classes: usize,
    pub name: String,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.features.len()
    }

    pub fn is_empty(&self) -> bool {
        self.features.is_empty()
    }

    pub fn num_features(&self) -> usize {
        self.features.first().map_or(0, |f| f.len())
    }

    /// Deterministic stratified train/test split. `train_fraction` is
    /// clamped into `[0, 1]` (NaN behaves as 0): 0.0 puts every sample
    /// in the test set, 1.0 puts every sample in the train set.
    /// (Fractions > 1.0 used to slice out of bounds and panic; negative
    /// fractions silently saturated to 0 — both are now explicit
    /// clamps.)
    pub fn split(&self, train_fraction: f64, seed: u64) -> (Dataset, Dataset) {
        let fraction = if train_fraction.is_nan() {
            0.0
        } else {
            train_fraction.clamp(0.0, 1.0)
        };
        let mut rng = SplitMix64::new(seed);
        let mut train_idx = Vec::new();
        let mut test_idx = Vec::new();
        for class in 0..self.classes {
            let mut idx: Vec<usize> = (0..self.len())
                .filter(|&i| self.labels[i] == class)
                .collect();
            rng.shuffle(&mut idx);
            // Belt and braces: rounding can't exceed len once the
            // fraction is clamped, but the slice bound must never
            // depend on float subtleties.
            let n_train =
                (((idx.len() as f64) * fraction).round() as usize).min(idx.len());
            train_idx.extend_from_slice(&idx[..n_train]);
            test_idx.extend_from_slice(&idx[n_train..]);
        }
        rng.shuffle(&mut train_idx);
        rng.shuffle(&mut test_idx);
        (self.subset(&train_idx, "train"), self.subset(&test_idx, "test"))
    }

    fn subset(&self, idx: &[usize], suffix: &str) -> Dataset {
        Dataset {
            features: idx.iter().map(|&i| self.features[i].clone()).collect(),
            labels: idx.iter().map(|&i| self.labels[i]).collect(),
            classes: self.classes,
            name: format!("{}-{}", self.name, suffix),
        }
    }

    /// Features as a row-major f32 matrix (for the PJRT golden model).
    pub fn features_f32(&self) -> Vec<f32> {
        self.features
            .iter()
            .flat_map(|row| row.iter().map(|&b| if b { 1.0 } else { 0.0 }))
            .collect()
    }
}

/// The paper's benchmark: Iris booleanised to 16 features
/// (4 thermometer bits × 4 raw measurements), 3 classes.
pub fn iris() -> Result<Dataset> {
    let raw: Vec<Vec<f32>> = IRIS_FEATURES.iter().map(|r| r.to_vec()).collect();
    let booleanizer = Booleanizer::fit(&raw, 4)?;
    Ok(Dataset {
        features: booleanizer.encode_all(&raw)?,
        labels: IRIS_LABELS.iter().map(|&l| l as usize).collect(),
        classes: 3,
        name: "iris".into(),
    })
}

/// The fitted Iris booleanizer (needed to encode new raw samples when
/// serving).
pub fn iris_booleanizer() -> Result<Booleanizer> {
    let raw: Vec<Vec<f32>> = IRIS_FEATURES.iter().map(|r| r.to_vec()).collect();
    Booleanizer::fit(&raw, 4)
}

/// Noisy-XOR: label = x0 XOR x1 over `features` booleans (the rest are
/// distractors), with `noise` label-flip probability. The classic TM
/// sanity task.
pub fn xor_noise(n: usize, features: usize, noise: f64, seed: u64) -> Dataset {
    assert!(features >= 2);
    let mut rng = SplitMix64::new(seed);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let row: Vec<bool> = (0..features).map(|_| rng.next_bool()).collect();
        let mut label = (row[0] ^ row[1]) as usize;
        if rng.chance(noise) {
            label = 1 - label;
        }
        xs.push(row);
        ys.push(label);
    }
    Dataset { features: xs, labels: ys, classes: 2, name: "xor-noise".into() }
}

/// Prototype blobs: `classes` random boolean prototypes over `features`
/// bits; each sample is its class prototype with per-bit flip probability
/// `flip`. Controls class separation for scaling studies.
pub fn prototype_blobs(
    n: usize,
    features: usize,
    classes: usize,
    flip: f64,
    seed: u64,
) -> Dataset {
    let mut rng = SplitMix64::new(seed);
    let protos: Vec<Vec<bool>> = (0..classes)
        .map(|_| (0..features).map(|_| rng.next_bool()).collect())
        .collect();
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % classes;
        let row: Vec<bool> = protos[class]
            .iter()
            .map(|&b| if rng.chance(flip) { !b } else { b })
            .collect();
        xs.push(row);
        ys.push(class);
    }
    Dataset { features: xs, labels: ys, classes, name: "blobs".into() }
}

/// k-bit parity over the first `k` of `features` bits — the hard case for
/// clause-based learners; used by robustness tests.
pub fn parity(n: usize, features: usize, k: usize, seed: u64) -> Dataset {
    assert!(k <= features);
    let mut rng = SplitMix64::new(seed);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let row: Vec<bool> = (0..features).map(|_| rng.next_bool()).collect();
        let label = row[..k].iter().filter(|&&b| b).count() % 2;
        xs.push(row);
        ys.push(label);
    }
    Dataset { features: xs, labels: ys, classes: 2, name: "parity".into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iris_shape() {
        let d = iris().unwrap();
        assert_eq!(d.len(), 150);
        assert_eq!(d.num_features(), 16);
        assert_eq!(d.classes, 3);
        // Balanced classes.
        for c in 0..3 {
            assert_eq!(d.labels.iter().filter(|&&l| l == c).count(), 50);
        }
    }

    #[test]
    fn split_is_stratified_and_disjoint() {
        let d = iris().unwrap();
        let (tr, te) = d.split(0.8, 42);
        assert_eq!(tr.len() + te.len(), 150);
        for c in 0..3 {
            assert_eq!(tr.labels.iter().filter(|&&l| l == c).count(), 40);
            assert_eq!(te.labels.iter().filter(|&&l| l == c).count(), 10);
        }
    }

    #[test]
    fn split_clamps_out_of_range_fractions() {
        // Regression: 1.5 used to slice out of bounds (`&idx[..n_train]`
        // with n_train > len) and panic; -0.5 silently saturated.
        let d = iris().unwrap();
        let (tr, te) = d.split(1.5, 42);
        assert_eq!((tr.len(), te.len()), (150, 0));
        let (tr, te) = d.split(-0.5, 42);
        assert_eq!((tr.len(), te.len()), (0, 150));
        let (tr, te) = d.split(f64::NAN, 42);
        assert_eq!((tr.len(), te.len()), (0, 150));
    }

    #[test]
    fn split_boundary_fractions_are_exact() {
        let d = iris().unwrap();
        let (tr, te) = d.split(0.0, 7);
        assert_eq!((tr.len(), te.len()), (0, 150));
        let (tr, te) = d.split(1.0, 7);
        assert_eq!((tr.len(), te.len()), (150, 0));
        // Degenerate splits stay stratified datasets, not garbage.
        assert_eq!(te.len(), 0);
        assert_eq!(tr.classes, 3);
    }

    #[test]
    fn split_deterministic() {
        let d = iris().unwrap();
        let (a, _) = d.split(0.8, 7);
        let (b, _) = d.split(0.8, 7);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn xor_labels_consistent_at_zero_noise() {
        let d = xor_noise(200, 6, 0.0, 3);
        for (x, &y) in d.features.iter().zip(&d.labels) {
            assert_eq!((x[0] ^ x[1]) as usize, y);
        }
    }

    #[test]
    fn blobs_low_flip_are_separable() {
        let d = prototype_blobs(90, 12, 3, 0.02, 5);
        assert_eq!(d.classes, 3);
        assert_eq!(d.len(), 90);
    }

    #[test]
    fn features_f32_is_row_major() {
        let d = xor_noise(3, 4, 0.0, 1);
        let m = d.features_f32();
        assert_eq!(m.len(), 12);
        assert_eq!(m[5] == 1.0, d.features[1][1]);
    }
}
