//! Compressed-clause inference engines — the ETHEREAL serving tier.
//!
//! ETHEREAL (arXiv 2502.05640) observes that trained Tsetlin machines
//! are overwhelmingly *excludes*: only a few percent of the 2F literal
//! slots in a clause are included, so storing the dense include mask
//! wastes memory and evaluation time on slots that can never matter.
//! This module compresses each clause down to its **sorted
//! include-literal list** (CSR layout: one flat literal array plus
//! per-clause offsets) and evaluates by walking only that list,
//! **early-exiting on the first unsatisfied literal** — work is
//! proportional to what the clause actually checks, the representation
//! analogue of the paper's event-driven evaluation.
//!
//! Compared to the inverted-index tier ([`super::index`]) the sweep is
//! clause-major instead of literal-major: no counter scratch, no
//! restore pass, and the early exit means a clause that fails on its
//! first (hottest) literal costs one load. An optional
//! literal-frequency reorder ([`CompressedModel::reorder_by_frequency`],
//! applied by both engines at compile time) rewrites each clause's walk
//! order so globally *hot* literals cluster at the front — the order is
//! a speed decision only: clause firing is an AND over the same set, so
//! sums are invariant under any permutation of the walk (pinned by a
//! unit test below).
//!
//! Cost model: evaluating one sample costs at most one load per
//! *(clause, included literal)* pair — `density · C · 2F` — and in
//! practice far less because most clauses exit on their first literal.
//! That beats the dense packed sweep (`~C · ceil(2F/64)` word ops) well
//! above the indexed tier's crossover, so the three-way `auto-*`
//! selection ([`select_engine`]) serves: indexed below
//! `indexed_density_threshold`, compressed up to
//! [`PACKED_VS_COMPRESSED_DENSITY`] (`compressed_density_threshold` in
//! `ServeConfig`), packed above.
//!
//! Semantics are pinned to the scalar reference: an empty (all-exclude)
//! clause has an empty include list and **never fires** (the inference
//! convention), and a contradictory clause including both `x_i` and
//! `¬x_i` always early-exits on one of the pair. Bit-exactness is
//! enforced by `tests/engine_matrix.rs` across every engine family ×
//! SIMD level, and the algorithm is mirrored bit-for-bit by
//! `python/compressed.py` (shared golden vectors) so it validates on
//! toolchain-less CI images.

use super::compile::{CompiledCotm, CompiledMulticlass, ModelCompiler};
use super::fast_infer::{BatchEngine, BatchResult};
use super::index::prefer_indexed;
use super::infer::predict_argmax;
use super::model::{ClauseMask, CoTmModel, MultiClassTmModel, TmParams};
use crate::error::Result;

/// Default included-literal density below which the compressed engines
/// beat the packed engines (the upper edge of the three-way `auto-*`
/// crossover; see the module cost model and
/// `benches/compressed_vs_all.rs`). The indexed tier takes over below
/// `PACKED_VS_INDEXED_DENSITY`.
pub const PACKED_VS_COMPRESSED_DENSITY: f64 = 0.2;

/// Which engine family the `auto-*` backends should serve a model
/// through, given its included-literal density.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineChoice {
    /// Inverted-index counter sweep (`density <= indexed_threshold`).
    Indexed,
    /// Compressed include-list walk
    /// (`indexed_threshold < density <= compressed_threshold`).
    Compressed,
    /// Dense bit-parallel packed sweep (everything denser).
    Packed,
}

impl EngineChoice {
    /// Stable lowercase name (for reports and logs).
    pub fn name(&self) -> &'static str {
        match self {
            EngineChoice::Indexed => "indexed",
            EngineChoice::Compressed => "compressed",
            EngineChoice::Packed => "packed",
        }
    }
}

/// The three-way density-driven `auto-*` decision: indexed first (it
/// wins at extreme sparsity), then compressed, then packed. Pure and
/// total over every `(indexed_threshold, compressed_threshold)` pair —
/// including inverted or 0.0/1.0 edge pairs — so conformance tests can
/// assert the choice never changes outputs, only which engine computes
/// them.
pub fn select_engine(
    density: f64,
    indexed_threshold: f64,
    compressed_threshold: f64,
) -> EngineChoice {
    if prefer_indexed(density, indexed_threshold) {
        EngineChoice::Indexed
    } else if density <= compressed_threshold {
        EngineChoice::Compressed
    } else {
        EngineChoice::Packed
    }
}

/// Compressed clause store: per-clause sorted include-literal lists in
/// CSR layout (clause ids are the caller's flattened ordering, so the
/// multiclass engine's per-class grouping `id = class · C + j` is
/// preserved — each class's clauses are one contiguous id range).
#[derive(Debug, Clone)]
pub struct CompressedModel {
    /// `literals[offsets[c] as usize..offsets[c+1] as usize]` = include
    /// list of clause `c`, ascending by literal id after `build` (a
    /// frequency reorder may permute each list; set membership is what
    /// defines the clause).
    literals: Vec<u32>,
    /// Per-clause CSR offsets, length `num_clauses + 1`.
    offsets: Vec<u32>,
    /// Boolean feature width F (literal ids run over `0..2F`).
    features: usize,
}

impl CompressedModel {
    /// Compress clause masks over the 2F interleaved literals, in the
    /// order their ids should be assigned. Masks must all be width 2F
    /// (callers validate the model first).
    pub fn build<'a>(
        features: usize,
        masks: impl IntoIterator<Item = &'a ClauseMask>,
    ) -> CompressedModel {
        let mut literals = Vec::new();
        let mut offsets = vec![0u32];
        for mask in masks {
            debug_assert_eq!(mask.include.len(), 2 * features);
            for (lit, &inc) in mask.include.iter().enumerate() {
                if inc {
                    literals.push(lit as u32);
                }
            }
            offsets.push(literals.len() as u32);
        }
        CompressedModel { literals, offsets, features }
    }

    pub fn num_clauses(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn features(&self) -> usize {
        self.features
    }

    /// The include list of clause `c` (in walk order).
    pub fn included(&self, c: usize) -> &[u32] {
        &self.literals[self.offsets[c] as usize..self.offsets[c + 1] as usize]
    }

    /// Total stored literal ids (= included literals across all
    /// clauses) — the compressed footprint, vs `clauses · 2F` dense
    /// mask slots.
    pub fn postings(&self) -> usize {
        self.literals.len()
    }

    /// Clauses with a non-empty include list (all-exclude clauses never
    /// fire and do no work, so they don't belong in any density
    /// denominator).
    pub fn live_clauses(&self) -> usize {
        (0..self.num_clauses())
            .filter(|&c| self.offsets[c + 1] > self.offsets[c])
            .count()
    }

    /// Included-literal density of the compressed model, over **live**
    /// clauses only (see `index::included_density` for why dead clauses
    /// must not dilute the `auto-*` selection input).
    pub fn density(&self) -> f64 {
        let total = self.live_clauses() * 2 * self.features;
        if total == 0 {
            0.0
        } else {
            self.postings() as f64 / total as f64
        }
    }

    /// How many times each literal id appears across all clause lists —
    /// the "hotness" the frequency reorder clusters on.
    pub fn literal_frequencies(&self) -> Vec<u32> {
        let mut freq = vec![0u32; 2 * self.features];
        for &lit in &self.literals {
            freq[lit as usize] += 1;
        }
        freq
    }

    /// Reorder each clause's walk order so globally hot literals come
    /// first (descending frequency, ties by ascending literal id — the
    /// same deterministic key as `python/compressed.py`). A speed
    /// decision only: firing is an AND over the set, so outputs are
    /// invariant under any walk order.
    pub fn reorder_by_frequency(&mut self) {
        let freq = self.literal_frequencies();
        for c in 0..self.num_clauses() {
            let range = self.offsets[c] as usize..self.offsets[c + 1] as usize;
            self.literals[range]
                .sort_by_key(|&lit| (std::cmp::Reverse(freq[lit as usize]), lit));
        }
    }

    /// Does clause `c` fire on `sample`? Walks only the include list
    /// and early-exits on the first unsatisfied literal; an empty
    /// (all-exclude) clause never fires at inference.
    pub fn clause_fires(&self, c: usize, sample: &[bool]) -> bool {
        let list = self.included(c);
        if list.is_empty() {
            return false;
        }
        for &lit in list {
            // Interleaved literals: lit 2i is x_i, lit 2i+1 is ¬x_i.
            let i = (lit as usize) >> 1;
            let value = if lit & 1 == 0 { sample[i] } else { !sample[i] };
            if !value {
                return false; // early exit — the whole point.
            }
        }
        true
    }

    /// Append the ids of every firing clause to `fired` (cleared
    /// first) — the shared-scratch core both engines' batch paths
    /// reuse across samples.
    pub fn sweep(&self, sample: &[bool], fired: &mut Vec<u32>) {
        debug_assert_eq!(sample.len(), self.features);
        fired.clear();
        for c in 0..self.num_clauses() {
            if self.clause_fires(c, sample) {
                fired.push(c as u32);
            }
        }
    }
}

/// Compressed multi-class TM engine: one compressed store over the
/// flattened live clauses of the compiled artifact, each id carrying
/// its **explicit** `(class, polarity)` vote (the compile pass prunes
/// and reorders, so the old `id = class · C + j` decode no longer
/// holds; class groups remain contiguous id ranges by construction).
#[derive(Debug, Clone)]
pub struct CompressedMulticlass {
    pub params: TmParams,
    model: CompressedModel,
    /// Flat clause id → `(class, ±1 polarity)`.
    votes: Vec<(u32, i32)>,
}

impl CompressedMulticlass {
    /// Compile a validated model (default [`ModelCompiler`]: exact
    /// dead-clause pruning) into the compressed store, with the
    /// frequency reorder applied (hot literals first in each walk).
    pub fn from_model(model: &MultiClassTmModel) -> Result<CompressedMulticlass> {
        Self::from_compiled(&ModelCompiler::default().compile_multiclass(model)?)
    }

    /// Build from an already-compiled artifact — the shared pipeline
    /// entry point.
    pub fn from_compiled(compiled: &CompiledMulticlass) -> Result<CompressedMulticlass> {
        compiled.validate()?;
        let mut compressed = CompressedModel::build(
            compiled.params.features,
            compiled.classes.iter().flatten().map(|cc| &cc.mask),
        );
        compressed.reorder_by_frequency();
        let votes = compiled
            .classes
            .iter()
            .zip(&compiled.polarities)
            .enumerate()
            .flat_map(|(k, (class, pols))| {
                class.iter().zip(pols).map(move |(_, &pol)| (k as u32, pol))
            })
            .collect();
        Ok(CompressedMulticlass {
            params: compiled.params.clone(),
            model: compressed,
            votes,
        })
    }

    /// Included-literal density (the `auto-*` selection input).
    pub fn density(&self) -> f64 {
        self.model.density()
    }

    /// Stored literal ids (the compressed footprint).
    pub fn postings(&self) -> usize {
        self.model.postings()
    }

    fn sums_from_fired(&self, fired: &[u32]) -> Vec<i32> {
        let mut sums = vec![0i32; self.params.classes];
        for &id in fired {
            let (class, polarity) = self.votes[id as usize];
            sums[class as usize] += polarity;
        }
        sums
    }
}

impl BatchEngine for CompressedMulticlass {
    fn features(&self) -> usize {
        self.params.features
    }

    fn classes(&self) -> usize {
        self.params.classes
    }

    fn class_sums(&self, features: &[bool]) -> Vec<i32> {
        assert_eq!(
            features.len(),
            self.params.features,
            "feature width mismatch"
        );
        let mut fired = Vec::new();
        self.model.sweep(features, &mut fired);
        self.sums_from_fired(&fired)
    }

    fn infer_batch<R: AsRef<[bool]> + Sync>(&self, rows: &[R]) -> Vec<BatchResult> {
        // One fired-id scratch buffer for the whole batch.
        let mut fired = Vec::new();
        rows.iter()
            .map(|r| {
                let row = r.as_ref();
                assert_eq!(row.len(), self.params.features, "batch row width mismatch");
                self.model.sweep(row, &mut fired);
                let sums = self.sums_from_fired(&fired);
                let pred = predict_argmax(&sums);
                (sums, pred)
            })
            .collect()
    }
}

/// Compressed CoTM engine: one compressed store over the shared clause
/// pool plus the signed weight matrix, stored clause-major so a firing
/// clause adds its whole weight column (Eq. 2).
#[derive(Debug, Clone)]
pub struct CompressedCotm {
    pub params: TmParams,
    model: CompressedModel,
    /// `[clause][class]` weight columns (transposed from the model's
    /// `[class][clause]` for contiguous access per firing clause).
    weight_cols: Vec<Vec<i32>>,
}

impl CompressedCotm {
    /// Compile a validated model (default [`ModelCompiler`]: exact
    /// dead-clause pruning) into the compressed store, with the
    /// frequency reorder applied.
    pub fn from_model(model: &CoTmModel) -> Result<CompressedCotm> {
        Self::from_compiled(&ModelCompiler::default().compile_cotm(model)?)
    }

    /// Build from an already-compiled artifact: clause pool and weight
    /// columns arrive pruned and reordered in lockstep.
    pub fn from_compiled(compiled: &CompiledCotm) -> Result<CompressedCotm> {
        compiled.validate()?;
        let mut compressed = CompressedModel::build(
            compiled.params.features,
            compiled.clauses.iter().map(|cc| &cc.mask),
        );
        compressed.reorder_by_frequency();
        Ok(CompressedCotm {
            params: compiled.params.clone(),
            model: compressed,
            weight_cols: compiled.weight_cols.clone(),
        })
    }

    /// Included-literal density (the `auto-*` selection input).
    pub fn density(&self) -> f64 {
        self.model.density()
    }

    /// Stored literal ids (the compressed footprint).
    pub fn postings(&self) -> usize {
        self.model.postings()
    }

    fn sums_from_fired(&self, fired: &[u32]) -> Vec<i32> {
        let mut sums = vec![0i32; self.params.classes];
        for &id in fired {
            for (s, &w) in sums.iter_mut().zip(&self.weight_cols[id as usize]) {
                *s += w;
            }
        }
        sums
    }
}

impl BatchEngine for CompressedCotm {
    fn features(&self) -> usize {
        self.params.features
    }

    fn classes(&self) -> usize {
        self.params.classes
    }

    fn class_sums(&self, features: &[bool]) -> Vec<i32> {
        assert_eq!(
            features.len(),
            self.params.features,
            "feature width mismatch"
        );
        let mut fired = Vec::new();
        self.model.sweep(features, &mut fired);
        self.sums_from_fired(&fired)
    }

    fn infer_batch<R: AsRef<[bool]> + Sync>(&self, rows: &[R]) -> Vec<BatchResult> {
        let mut fired = Vec::new();
        rows.iter()
            .map(|r| {
                let row = r.as_ref();
                assert_eq!(row.len(), self.params.features, "batch row width mismatch");
                self.model.sweep(row, &mut fired);
                let sums = self.sums_from_fired(&fired);
                let pred = predict_argmax(&sums);
                (sums, pred)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::index::PACKED_VS_INDEXED_DENSITY;
    use crate::tm::infer::{cotm_class_sums, multiclass_class_sums};

    fn tiny_params() -> TmParams {
        TmParams {
            features: 2,
            clauses: 2,
            classes: 2,
            ..TmParams::iris_paper()
        }
    }

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn engines_are_send_and_sync() {
        // Same serving contract as the packed and indexed engines: one
        // shared instance across every coordinator thread.
        assert_send_sync::<CompressedMulticlass>();
        assert_send_sync::<CompressedCotm>();
    }

    /// Same hand-worked example as infer.rs / fast_infer.rs / index.rs /
    /// python/tests/test_model.py — every tier agrees on it.
    #[test]
    fn hand_worked_multiclass_matches_reference() {
        let mut m = MultiClassTmModel::zeroed(tiny_params());
        m.clauses[0][0].include[0] = true; // class0 clause0 (+): x0
        m.clauses[0][1].include[3] = true; // class0 clause1 (−): ¬x1
        m.clauses[1][0].include[1] = true; // class1 clause0 (+): ¬x0
        m.clauses[1][1].include[2] = true; // class1 clause1 (−): x1
        let e = CompressedMulticlass::from_model(&m).unwrap();
        for x in [[true, false], [true, true], [false, false], [false, true]] {
            assert_eq!(e.class_sums(&x), multiclass_class_sums(&m, &x), "{x:?}");
        }
        assert_eq!(e.class_sums(&[true, true]), vec![1, -1]);
        assert_eq!(e.predict(&[true, true]), 0);
    }

    #[test]
    fn hand_worked_cotm_matches_reference() {
        let mut m = CoTmModel::zeroed(tiny_params());
        m.clauses[0].include[0] = true; // clause0: x0
        m.clauses[1].include[2] = true; // clause1: x1
        m.weights = vec![vec![3, -2], vec![-1, 4]];
        let e = CompressedCotm::from_model(&m).unwrap();
        for x in [[true, true], [true, false], [false, false]] {
            assert_eq!(e.class_sums(&x), cotm_class_sums(&m, &x), "{x:?}");
        }
        assert_eq!(e.class_sums(&[true, true]), vec![1, 3]);
    }

    // ------------------------------------------------------------------
    // Cross-language golden vectors, shared with python/compressed.py
    // (python/tests/test_compressed.py asserts the identical sums and
    // the identical frequency-reordered walk lists): the models and
    // samples are the same closed-form formulas the invindex mirror
    // pins, so all four engine families golden-vector to one table.
    // ------------------------------------------------------------------

    /// F=9, C=4/class, K=3; include(k, j, l) = (3l + 5j + 7k) % 11 == 0.
    fn golden_multiclass() -> MultiClassTmModel {
        let p = TmParams { features: 9, clauses: 4, classes: 3, ..TmParams::iris_paper() };
        let mut m = MultiClassTmModel::zeroed(p);
        for (k, class) in m.clauses.iter_mut().enumerate() {
            for (j, clause) in class.iter_mut().enumerate() {
                for l in 0..18 {
                    clause.include[l] = (3 * l + 5 * j + 7 * k) % 11 == 0;
                }
            }
        }
        m
    }

    /// F=9, C=6, K=3; include(j, l) = (5l + 3j) % 7 == 0,
    /// weight(k, j) = (j + 2k) % 7 − 3.
    fn golden_cotm() -> CoTmModel {
        let p = TmParams { features: 9, clauses: 6, classes: 3, ..TmParams::iris_paper() };
        let mut m = CoTmModel::zeroed(p);
        for (j, clause) in m.clauses.iter_mut().enumerate() {
            for l in 0..18 {
                clause.include[l] = (5 * l + 3 * j) % 7 == 0;
            }
        }
        for (k, row) in m.weights.iter_mut().enumerate() {
            for (j, w) in row.iter_mut().enumerate() {
                *w = ((j + 2 * k) % 7) as i32 - 3;
            }
        }
        m
    }

    /// Sample s: feature i = (i² + 3is + 2s) % 7 < 3.
    fn golden_sample(s: usize) -> Vec<bool> {
        (0..9).map(|i| (i * i + 3 * i * s + 2 * s) % 7 < 3).collect()
    }

    #[test]
    fn golden_vectors_match_python_mirror() {
        let mc = CompressedMulticlass::from_model(&golden_multiclass()).unwrap();
        let co = CompressedCotm::from_model(&golden_cotm()).unwrap();
        let want_mc = [
            [1, 0, -1],
            [0, -1, 2],
            [0, -1, 0],
            [0, 0, 0],
            [-1, -1, 1],
            [0, 0, 0],
        ];
        let want_co = [
            [-2, 0, 2],
            [-6, 0, 6],
            [0, 2, -3],
            [3, 2, -6],
            [-3, -1, 1],
            [3, 2, -6],
        ];
        for s in 0..6 {
            let x = golden_sample(s);
            assert_eq!(mc.class_sums(&x), want_mc[s], "multiclass sample {s}");
            assert_eq!(co.class_sums(&x), want_co[s], "cotm sample {s}");
            // The golden vectors themselves match the scalar reference,
            // so every tier pins the same semantics.
            assert_eq!(
                multiclass_class_sums(&golden_multiclass(), &x),
                want_mc[s],
                "reference multiclass sample {s}"
            );
            assert_eq!(
                cotm_class_sums(&golden_cotm(), &x),
                want_co[s],
                "reference cotm sample {s}"
            );
        }
    }

    /// F=3; include lists (ascending): [0,4], [2,4], [4], [0,2,4,5] —
    /// literal frequencies 0:2, 2:2, 4:4, 5:1, so the reorder is a real
    /// permutation (shared with python/tests/test_compressed.py).
    fn reorder_masks() -> Vec<ClauseMask> {
        let lists: [&[usize]; 4] = [&[0, 4], &[2, 4], &[4], &[0, 2, 4, 5]];
        lists
            .iter()
            .map(|lits| {
                let mut mask = ClauseMask::empty(6);
                for &l in *lits {
                    mask.include[l] = true;
                }
                mask
            })
            .collect()
    }

    #[test]
    fn golden_frequency_reorder_matches_python_mirror() {
        // The deterministic reorder key (descending global frequency,
        // ties by ascending literal id) must agree across languages —
        // python/tests/test_compressed.py asserts these exact lists.
        let masks = reorder_masks();
        let mut c = CompressedModel::build(3, masks.iter());
        // Pre-reorder: ascending literal ids by construction.
        assert_eq!(c.included(3), &[0, 2, 4, 5]);
        assert_eq!(c.literal_frequencies(), vec![2, 0, 2, 0, 4, 1]);
        c.reorder_by_frequency();
        assert_eq!(c.included(0), &[4, 0]);
        assert_eq!(c.included(1), &[4, 2]);
        assert_eq!(c.included(2), &[4]);
        assert_eq!(c.included(3), &[4, 0, 2, 5]);
        // Reordering permutes each list in place: same set per clause.
        let mut back: Vec<u32> = c.included(3).to_vec();
        back.sort_unstable();
        assert_eq!(back, vec![0, 2, 4, 5]);
        // And both golden models reorder to themselves (uniform
        // in-clause frequencies), which the sums goldens rely on.
        let m = golden_cotm();
        let mut g = CompressedModel::build(9, m.clauses.iter());
        let before: Vec<Vec<u32>> =
            (0..g.num_clauses()).map(|cl| g.included(cl).to_vec()).collect();
        g.reorder_by_frequency();
        let after: Vec<Vec<u32>> =
            (0..g.num_clauses()).map(|cl| g.included(cl).to_vec()).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn walk_order_is_output_invariant() {
        // Sorted vs frequency-reordered walks are the same AND over the
        // same set — firing must be identical on every input. Uses the
        // reorder_masks model, where the reorder is a real permutation.
        let masks = reorder_masks();
        let sorted = CompressedModel::build(3, masks.iter());
        let mut hot = sorted.clone();
        hot.reorder_by_frequency();
        for bits in 0..8u32 {
            let x: Vec<bool> = (0..3).map(|i| (bits >> i) & 1 == 1).collect();
            for c in 0..sorted.num_clauses() {
                assert_eq!(
                    sorted.clause_fires(c, &x),
                    hot.clause_fires(c, &x),
                    "clause {c} input {bits:03b}"
                );
                // Both agree with the dense-mask reference.
                let lits = crate::tm::model::make_literals(&x);
                assert_eq!(sorted.clause_fires(c, &x), masks[c].evaluate(&lits));
            }
        }
    }

    #[test]
    fn from_model_rejects_invalid_models() {
        let odd = TmParams { clauses: 7, ..tiny_params() };
        assert!(CompressedMulticlass::from_model(&MultiClassTmModel::zeroed(odd)).is_err());
        let mut cm = CoTmModel::zeroed(tiny_params());
        cm.weights[0][0] = cm.params.max_weight + 1;
        assert!(CompressedCotm::from_model(&cm).is_err());
    }

    #[test]
    fn empty_clauses_never_fire() {
        // Zeroed model: all-exclude clauses compress to empty lists and
        // never fire — the inference convention.
        let e = CompressedCotm::from_model(&CoTmModel::zeroed(tiny_params())).unwrap();
        assert_eq!(e.class_sums(&[true, false]), vec![0, 0]);
        let out = e.infer_batch(&[vec![true, false], vec![false, true]]);
        assert_eq!(out, vec![(vec![0, 0], 0), (vec![0, 0], 0)]);
    }

    #[test]
    fn contradictory_clause_never_fires() {
        // A clause including both x0 and ¬x0 always early-exits on one
        // of the pair (exactly one is set per sample).
        let mut m = CoTmModel::zeroed(tiny_params());
        m.clauses[0].include[0] = true; // x0
        m.clauses[0].include[1] = true; // ¬x0
        m.weights = vec![vec![5, 0], vec![5, 0]];
        let e = CompressedCotm::from_model(&m).unwrap();
        for x in [[true, true], [false, false], [true, false]] {
            assert_eq!(e.class_sums(&x), vec![0, 0], "{x:?}");
            assert_eq!(e.class_sums(&x), cotm_class_sums(&m, &x));
        }
    }

    #[test]
    fn all_include_clause_fires_only_on_its_witness() {
        // A clause including exactly one literal per pair fires exactly
        // on the one sample that satisfies every pick — the longest
        // possible non-contradictory walk (no early exit on the
        // witness, first-literal exit elsewhere).
        let p = TmParams { features: 4, clauses: 2, classes: 2, ..TmParams::iris_paper() };
        let mut m = CoTmModel::zeroed(p);
        for i in 0..4 {
            // Include x_i for even i, ¬x_i for odd i.
            m.clauses[0].include[2 * i + (i % 2)] = true;
        }
        m.weights = vec![vec![2, 0], vec![-1, 0]];
        let e = CompressedCotm::from_model(&m).unwrap();
        let witness = [true, false, true, false];
        assert_eq!(e.class_sums(&witness), cotm_class_sums(&m, &witness));
        assert_eq!(e.class_sums(&witness), vec![2, -1]);
        for flip in 0..4 {
            let mut x = witness;
            x[flip] = !x[flip];
            assert_eq!(e.class_sums(&x), vec![0, 0], "flip {flip}");
        }
    }

    #[test]
    fn batched_agrees_with_single_sample_across_block_boundary() {
        // 130 samples: the default sharded path splits on 64-sample
        // blocks; compressed evaluation must be invariant to the split.
        let m = golden_multiclass();
        let e = CompressedMulticlass::from_model(&m).unwrap();
        let rows: Vec<Vec<bool>> = (0..130usize)
            .map(|s| (0..9).map(|i| (s >> (i % 7)) & 1 == 1).collect())
            .collect();
        let batched = e.infer_batch(&rows);
        assert_eq!(batched.len(), 130);
        for (s, (sums, pred)) in batched.iter().enumerate() {
            assert_eq!(sums, &e.class_sums(&rows[s]), "sample {s}");
            assert_eq!(*pred, predict_argmax(sums), "sample {s}");
        }
        assert_eq!(e.infer_batch_sharded(&rows, 4), batched);
    }

    #[test]
    fn empty_batch_is_empty() {
        let e = CompressedMulticlass::from_model(&golden_multiclass()).unwrap();
        assert!(e.infer_batch(&Vec::<Vec<bool>>::new()).is_empty());
    }

    #[test]
    fn density_and_postings_account_included_literals() {
        let m = golden_cotm();
        let e = CompressedCotm::from_model(&m).unwrap();
        let included: usize = m.clauses.iter().map(|c| c.included_count()).sum();
        assert_eq!(e.postings(), included);
        let want = included as f64 / (6.0 * 18.0);
        assert!((e.density() - want).abs() < 1e-12);
        // Degenerate inputs.
        assert_eq!(
            CompressedModel::build(0, std::iter::empty::<&ClauseMask>()).density(),
            0.0
        );
        let zeroed = CompressedCotm::from_model(&CoTmModel::zeroed(tiny_params())).unwrap();
        assert_eq!(zeroed.density(), 0.0);
        assert_eq!(zeroed.postings(), 0);
    }

    #[test]
    fn dead_clauses_do_not_flip_the_auto_choice() {
        // Regression (PR 8), compressed-side twin of the index.rs test:
        // 9 all-exclude clauses + 1 half-dense live clause. The stale
        // all-clauses denominator measured 5/(10·10) = 0.05 — exactly
        // the indexed threshold — so auto-* picked the indexed engine
        // for a model whose only working clause is 50% dense. Live
        // accounting measures 0.5 and picks packed.
        let mut masks = vec![ClauseMask::empty(10); 10];
        for l in [0, 2, 4, 6, 8] {
            masks[0].include[l] = true;
        }
        let c = CompressedModel::build(5, masks.iter());
        assert_eq!(c.num_clauses(), 10);
        assert_eq!(c.live_clauses(), 1);
        assert!((c.density() - 0.5).abs() < 1e-12);
        let stale = c.postings() as f64 / (c.num_clauses() * 10) as f64;
        assert_eq!(
            select_engine(stale, PACKED_VS_INDEXED_DENSITY, PACKED_VS_COMPRESSED_DENSITY),
            EngineChoice::Indexed
        );
        assert_eq!(
            select_engine(c.density(), PACKED_VS_INDEXED_DENSITY, PACKED_VS_COMPRESSED_DENSITY),
            EngineChoice::Packed
        );
    }

    #[test]
    fn compiled_artifact_with_pruned_reordered_clauses_stays_exact() {
        // Full compile of a model with dead clauses: the compressed
        // engine built from the artifact must match the scalar
        // reference on every input (explicit votes absorb the id
        // permutation; the frequency reorder stacks on top).
        use crate::tm::compile::{CompileMode, ModelCompiler};
        let p = TmParams { features: 3, clauses: 4, classes: 2, ..tiny_params() };
        let mut m = MultiClassTmModel::zeroed(p);
        m.clauses[0][0].include[1] = true; // (+) ¬x0
        m.clauses[0][2].include[2] = true;
        m.clauses[0][2].include[3] = true; // contradictory -> dead
        m.clauses[0][3].include[0] = true; // (−) x0
        m.clauses[1][1].include[4] = true; // (−) x2
        let calib: Vec<Vec<bool>> = (0..8u32)
            .map(|b| (0..3).map(|i| (b >> i) & 1 == 1).collect())
            .collect();
        let compiled = ModelCompiler::new(CompileMode::Full)
            .with_calibration(calib.clone())
            .compile_multiclass(&m)
            .unwrap();
        let e = CompressedMulticlass::from_compiled(&compiled).unwrap();
        for x in &calib {
            assert_eq!(e.class_sums(x), multiclass_class_sums(&m, x), "{x:?}");
        }
    }

    #[test]
    fn select_engine_is_a_pure_three_way_threshold() {
        let (it, ct) = (PACKED_VS_INDEXED_DENSITY, PACKED_VS_COMPRESSED_DENSITY);
        assert_eq!(select_engine(0.01, it, ct), EngineChoice::Indexed);
        assert_eq!(select_engine(it, it, ct), EngineChoice::Indexed);
        assert_eq!(select_engine(0.1, it, ct), EngineChoice::Compressed);
        assert_eq!(select_engine(ct, it, ct), EngineChoice::Compressed);
        assert_eq!(select_engine(0.5, it, ct), EngineChoice::Packed);
        // Edge pairs: 0.0/0.0 admits only all-empty models to indexed;
        // 1.0 on either knob swallows everything up to that tier.
        assert_eq!(select_engine(0.0, 0.0, 0.0), EngineChoice::Indexed);
        assert_eq!(select_engine(0.1, 0.0, 0.0), EngineChoice::Packed);
        assert_eq!(select_engine(0.1, 0.0, 1.0), EngineChoice::Compressed);
        assert_eq!(select_engine(1.0, 1.0, 0.0), EngineChoice::Indexed);
        assert_eq!(select_engine(0.9, 0.0, 0.9), EngineChoice::Compressed);
        // Inverted pairs stay total: indexed wins its range first.
        assert_eq!(select_engine(0.3, 0.5, 0.1), EngineChoice::Indexed);
        assert_eq!(select_engine(0.7, 0.5, 0.1), EngineChoice::Packed);
        assert_eq!(EngineChoice::Indexed.name(), "indexed");
        assert_eq!(EngineChoice::Compressed.name(), "compressed");
        assert_eq!(EngineChoice::Packed.name(), "packed");
    }
}
