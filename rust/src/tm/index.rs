//! Event-driven inverted-index inference engines — the sparse-model
//! serving tier.
//!
//! The paper's core idea is event-driven computation: work happens only
//! where an event occurs. [`super::bitpack`] applies that at word
//! granularity (zero include words are skipped); this module applies it
//! at **clause** granularity, the software analogue of the paper's
//! set-literal events: the literal→clause inverted index of *"Increasing
//! the Inference and Learning Speed of Tsetlin Machines with Clause
//! Indexing"* (arXiv 2004.03188).
//!
//! Per clause we keep a counter of *unsatisfied* included literals,
//! initialised to the clause's included-literal count. Evaluating a
//! sample walks only the sample's **set** literals (exactly F of the 2F
//! interleaved literals are set — one per `x_i`/`¬x_i` pair) and
//! decrements the counter of every clause whose include mask names that
//! literal. A clause **fires exactly when its counter reaches zero** —
//! the firing is itself the event; clauses no set literal touches are
//! never visited, let alone evaluated. After accumulating the fired
//! clauses into class sums, the same walk increments the counters back,
//! so the scratch state is restored in O(touched) instead of O(C).
//!
//! Cost model: the dense packed sweep costs ~`C · ceil(2F/64)` word ops
//! per sample regardless of sparsity; the indexed sweep costs one
//! counter op per *(set literal, including clause)* pair — about
//! `density · C · F` on uniform inputs. The crossover sits near
//! `density ≈ 1/32` ([`PACKED_VS_INDEXED_DENSITY`] holds the serving
//! default; `ServeConfig.indexed_density_threshold` overrides it), which
//! is exactly the compressed/sparse clause regime ETHEREAL
//! (arXiv 2502.05640) shows real TM deployments live in.
//!
//! Semantics are pinned to the scalar reference: an empty (all-exclude)
//! clause appears in no literal's clause list and its counter starts at
//! zero **but is never decremented**, so it never fires — matching the
//! "empty clause outputs 0 at inference" convention. A clause including
//! both `x_i` and `¬x_i` can never see its counter reach zero (only one
//! of the pair is ever set), which also matches the reference.
//!
//! Bit-exactness contract: class sums and argmax must equal
//! [`super::infer::multiclass_class_sums`] /
//! [`super::infer::cotm_class_sums`] and
//! [`super::infer::predict_argmax`] on every input — enforced by
//! `tests/bitparallel_equivalence.rs` alongside the packed engines, and
//! mirrored algorithm-for-algorithm by `python/invindex.py` (shared
//! golden vectors) so the counter sweep is validated even on
//! toolchain-less CI images.

use super::compile::{CompiledCotm, CompiledMulticlass, ModelCompiler};
use super::fast_infer::{BatchEngine, BatchResult};
use super::infer::predict_argmax;
use super::model::{ClauseMask, CoTmModel, MultiClassTmModel, TmParams};
use crate::error::Result;

/// Default included-literal density below which the indexed engines
/// beat the packed engines (the `auto-*` backend crossover; see the
/// module cost model and `benches/indexed_vs_bitpar.rs`).
pub const PACKED_VS_INDEXED_DENSITY: f64 = 0.05;

/// Should the `auto-*` backends serve this model through the indexed
/// engine? Pure decision function so conformance tests can assert the
/// choice never changes outputs — only which engine computes them.
pub fn prefer_indexed(density: f64, threshold: f64) -> bool {
    density <= threshold
}

/// Fraction of included literals across the **live** clause masks
/// (`included / (live clauses · 2F)`); 0.0 when no clause is live.
///
/// Dead (all-exclude) clauses do zero work in every engine, so counting
/// their zero contributions in the denominator used to drag measured
/// density toward 0 and flip `auto-*` crossovers for sparse trained
/// models — a model whose live clauses are dense would masquerade as
/// sparse. Live-clause accounting matches what the engines actually
/// execute (and `compile::CompileStats::density`, which additionally
/// excludes contradictory clauses the compile pass prunes).
pub fn included_density<'a>(masks: impl IntoIterator<Item = &'a ClauseMask>) -> f64 {
    let (mut included, mut total) = (0usize, 0usize);
    for m in masks {
        let count = m.included_count();
        if count > 0 {
            included += count;
            total += m.include.len();
        }
    }
    if total == 0 {
        0.0
    } else {
        included as f64 / total as f64
    }
}

/// Decrement the counters of one posting run, invoking `on_zero` for
/// every clause whose counter reaches zero — the firing event. Shared
/// batch kernel: the serving sweep below and the async trainer's
/// per-worker index (`tm/async_train.rs`) both decrement through it,
/// so the counter semantics ("fires at the instant the counter hits
/// zero, each counter decremented at most `required` times") live in
/// exactly one place. Operating on a whole contiguous run at a time
/// (instead of chasing one clause pointer per posting through nested
/// `Vec`s) is the SoA batching the ROADMAP's SIMD leftover (b) asked
/// for: the run is a flat `&[u32]`, so the loads stream.
#[inline]
pub(crate) fn decrement_run(run: &[u32], counts: &mut [u32], mut on_zero: impl FnMut(u32)) {
    for &c in run {
        let cnt = &mut counts[c as usize];
        *cnt -= 1;
        if *cnt == 0 {
            on_zero(c);
        }
    }
}

/// Undo [`decrement_run`] over the same run, restoring the reset state
/// in O(touched) — the event-driven undo half of the sweep.
#[inline]
pub(crate) fn restore_run(run: &[u32], counts: &mut [u32]) {
    for &c in run {
        counts[c as usize] += 1;
    }
}

/// Literal→clause inverted index plus per-clause unsatisfied-literal
/// reset counts, shared by both engine variants (clause ids are the
/// caller's flattened ordering).
///
/// Postings are stored CSR-style — one flat clause-id array grouped by
/// literal plus an offset table — rather than a `Vec<Vec<u32>>`: a
/// sweep touches F runs per sample, and with the flat layout each run
/// is a contiguous slice fed to the batch kernels above instead of F
/// separate heap allocations chased through a pointer each.
#[derive(Debug, Clone)]
pub struct InvertedIndex {
    /// CSR offsets: literal `lit`'s posting run is
    /// `posting_clauses[posting_offsets[lit]..posting_offsets[lit+1]]`.
    /// Length 2F + 1; last entry = total postings.
    posting_offsets: Vec<u32>,
    /// Flat clause ids, grouped by literal, ascending within each run
    /// (clause-major construction order).
    posting_clauses: Vec<u32>,
    /// Per-clause included-literal count — the counter reset value.
    required: Vec<u32>,
    /// Boolean feature width F.
    features: usize,
}

impl InvertedIndex {
    /// Build from clause masks over the 2F interleaved literals, in the
    /// order their ids should be assigned. Masks must all be width 2F
    /// (callers validate the model first). Two passes: count postings
    /// per literal (sizing the CSR runs exactly), then fill.
    pub fn build<'a>(
        features: usize,
        masks: impl IntoIterator<Item = &'a ClauseMask>,
    ) -> InvertedIndex {
        let literals = 2 * features;
        let masks: Vec<&ClauseMask> = masks.into_iter().collect();
        let mut required = Vec::with_capacity(masks.len());
        let mut run_lens = vec![0u32; literals];
        for mask in &masks {
            debug_assert_eq!(mask.include.len(), literals);
            required.push(mask.included_count() as u32);
            for (lit, &inc) in mask.include.iter().enumerate() {
                if inc {
                    run_lens[lit] += 1;
                }
            }
        }
        let mut posting_offsets = Vec::with_capacity(literals + 1);
        let mut total = 0u32;
        posting_offsets.push(0);
        for &n in &run_lens {
            total += n;
            posting_offsets.push(total);
        }
        // Fill cursors start at each run's offset and advance as the
        // clause-major walk appends, keeping runs ascending by id.
        let mut cursors: Vec<u32> = posting_offsets[..literals].to_vec();
        let mut posting_clauses = vec![0u32; total as usize];
        for (c, mask) in masks.iter().enumerate() {
            for (lit, &inc) in mask.include.iter().enumerate() {
                if inc {
                    posting_clauses[cursors[lit] as usize] = c as u32;
                    cursors[lit] += 1;
                }
            }
        }
        InvertedIndex { posting_offsets, posting_clauses, required, features }
    }

    /// Literal `lit`'s posting run (the CSR slice).
    #[inline]
    fn run(&self, lit: usize) -> &[u32] {
        let lo = self.posting_offsets[lit] as usize;
        let hi = self.posting_offsets[lit + 1] as usize;
        &self.posting_clauses[lo..hi]
    }

    pub fn num_clauses(&self) -> usize {
        self.required.len()
    }

    pub fn features(&self) -> usize {
        self.features
    }

    /// Total postings (= included literals across all clauses).
    pub fn postings(&self) -> usize {
        self.posting_clauses.len()
    }

    /// Clauses with at least one posting (all-exclude clauses appear in
    /// no literal list and never fire — they are dead weight in every
    /// accounting).
    pub fn live_clauses(&self) -> usize {
        self.required.iter().filter(|&&r| r > 0).count()
    }

    /// Included-literal density of the indexed model, over **live**
    /// clauses only (see [`included_density`] for why dead clauses must
    /// not dilute the denominator).
    pub fn density(&self) -> f64 {
        let total = self.live_clauses() * 2 * self.features;
        if total == 0 {
            0.0
        } else {
            self.postings() as f64 / total as f64
        }
    }

    /// A fresh counter buffer in the reset state (every clause at its
    /// included-literal count) — the scratch [`InvertedIndex::sweep`]
    /// needs. Allocate once per batch and reuse.
    pub fn fresh_counts(&self) -> Vec<u32> {
        self.required.clone()
    }

    /// The event-driven sweep for one sample: decrement the counter of
    /// every clause each **set** literal appears in, recording a clause
    /// id in `fired` at the instant its counter reaches zero, then walk
    /// the same postings again to restore `counts` to the reset state.
    ///
    /// `counts` must be in the reset state on entry (see
    /// [`InvertedIndex::fresh_counts`]) and is guaranteed to be back in
    /// it on return, so one buffer serves a whole batch. `fired` is
    /// cleared first; ids land in it in event (not id) order.
    pub fn sweep(&self, sample: &[bool], counts: &mut [u32], fired: &mut Vec<u32>) {
        debug_assert_eq!(sample.len(), self.features);
        debug_assert_eq!(counts.len(), self.required.len());
        fired.clear();
        for (i, &f) in sample.iter().enumerate() {
            // Interleaved literals: exactly one of (x_i, ¬x_i) is set.
            let lit = 2 * i + usize::from(!f);
            decrement_run(self.run(lit), counts, |c| fired.push(c));
        }
        // Event-driven undo: restore only the touched counters.
        for (i, &f) in sample.iter().enumerate() {
            let lit = 2 * i + usize::from(!f);
            restore_run(self.run(lit), counts);
        }
    }
}

/// Indexed multi-class TM engine: one inverted index over the
/// flattened live clauses of the compiled artifact, each id carrying
/// its **explicit** `(class, polarity)` vote. The old `id ↦ (id/C,
/// parity of id%C)` decode assumed the model's full clause grid; the
/// compile pass prunes and reorders, so votes are frozen per id at
/// build time instead.
#[derive(Debug, Clone)]
pub struct IndexedMulticlass {
    pub params: TmParams,
    index: InvertedIndex,
    /// Flat clause id → `(class, ±1 polarity)`.
    votes: Vec<(u32, i32)>,
}

impl IndexedMulticlass {
    /// Compile a validated model (default [`ModelCompiler`]: exact
    /// dead-clause pruning) into the inverted index.
    pub fn from_model(model: &MultiClassTmModel) -> Result<IndexedMulticlass> {
        Self::from_compiled(&ModelCompiler::default().compile_multiclass(model)?)
    }

    /// Build from an already-compiled artifact — the shared pipeline
    /// entry point.
    pub fn from_compiled(compiled: &CompiledMulticlass) -> Result<IndexedMulticlass> {
        compiled.validate()?;
        let index = InvertedIndex::build(
            compiled.params.features,
            compiled.classes.iter().flatten().map(|cc| &cc.mask),
        );
        let votes = compiled
            .classes
            .iter()
            .zip(&compiled.polarities)
            .enumerate()
            .flat_map(|(k, (class, pols))| {
                class.iter().zip(pols).map(move |(_, &pol)| (k as u32, pol))
            })
            .collect();
        Ok(IndexedMulticlass { params: compiled.params.clone(), index, votes })
    }

    /// Included-literal density (the `auto-*` selection input).
    pub fn density(&self) -> f64 {
        self.index.density()
    }

    fn sums_from_fired(&self, fired: &[u32]) -> Vec<i32> {
        let mut sums = vec![0i32; self.params.classes];
        for &id in fired {
            let (class, polarity) = self.votes[id as usize];
            sums[class as usize] += polarity;
        }
        sums
    }
}

impl BatchEngine for IndexedMulticlass {
    fn features(&self) -> usize {
        self.params.features
    }

    fn classes(&self) -> usize {
        self.params.classes
    }

    fn class_sums(&self, features: &[bool]) -> Vec<i32> {
        assert_eq!(
            features.len(),
            self.params.features,
            "feature width mismatch"
        );
        let mut counts = self.index.fresh_counts();
        let mut fired = Vec::new();
        self.index.sweep(features, &mut counts, &mut fired);
        self.sums_from_fired(&fired)
    }

    fn infer_batch<R: AsRef<[bool]> + Sync>(&self, rows: &[R]) -> Vec<BatchResult> {
        // One scratch counter buffer for the whole batch: sweep restores
        // it after every sample.
        let mut counts = self.index.fresh_counts();
        let mut fired = Vec::new();
        rows.iter()
            .map(|r| {
                let row = r.as_ref();
                assert_eq!(row.len(), self.params.features, "batch row width mismatch");
                self.index.sweep(row, &mut counts, &mut fired);
                let sums = self.sums_from_fired(&fired);
                let pred = predict_argmax(&sums);
                (sums, pred)
            })
            .collect()
    }
}

/// Indexed CoTM engine: one inverted index over the shared clause pool
/// plus the signed weight matrix, stored clause-major so a firing
/// clause adds its whole weight column (Eq. 2).
#[derive(Debug, Clone)]
pub struct IndexedCotm {
    pub params: TmParams,
    index: InvertedIndex,
    /// `[clause][class]` weight columns (transposed from the model's
    /// `[class][clause]` for contiguous access per firing clause).
    weight_cols: Vec<Vec<i32>>,
}

impl IndexedCotm {
    /// Compile a validated model (default [`ModelCompiler`]: exact
    /// dead-clause pruning) into the inverted index.
    pub fn from_model(model: &CoTmModel) -> Result<IndexedCotm> {
        Self::from_compiled(&ModelCompiler::default().compile_cotm(model)?)
    }

    /// Build from an already-compiled artifact: clause pool and weight
    /// columns arrive pruned and reordered in lockstep.
    pub fn from_compiled(compiled: &CompiledCotm) -> Result<IndexedCotm> {
        compiled.validate()?;
        let index = InvertedIndex::build(
            compiled.params.features,
            compiled.clauses.iter().map(|cc| &cc.mask),
        );
        Ok(IndexedCotm {
            params: compiled.params.clone(),
            index,
            weight_cols: compiled.weight_cols.clone(),
        })
    }

    /// Included-literal density (the `auto-*` selection input).
    pub fn density(&self) -> f64 {
        self.index.density()
    }

    fn sums_from_fired(&self, fired: &[u32]) -> Vec<i32> {
        let mut sums = vec![0i32; self.params.classes];
        for &id in fired {
            for (s, &w) in sums.iter_mut().zip(&self.weight_cols[id as usize]) {
                *s += w;
            }
        }
        sums
    }
}

impl BatchEngine for IndexedCotm {
    fn features(&self) -> usize {
        self.params.features
    }

    fn classes(&self) -> usize {
        self.params.classes
    }

    fn class_sums(&self, features: &[bool]) -> Vec<i32> {
        assert_eq!(
            features.len(),
            self.params.features,
            "feature width mismatch"
        );
        let mut counts = self.index.fresh_counts();
        let mut fired = Vec::new();
        self.index.sweep(features, &mut counts, &mut fired);
        self.sums_from_fired(&fired)
    }

    fn infer_batch<R: AsRef<[bool]> + Sync>(&self, rows: &[R]) -> Vec<BatchResult> {
        let mut counts = self.index.fresh_counts();
        let mut fired = Vec::new();
        rows.iter()
            .map(|r| {
                let row = r.as_ref();
                assert_eq!(row.len(), self.params.features, "batch row width mismatch");
                self.index.sweep(row, &mut counts, &mut fired);
                let sums = self.sums_from_fired(&fired);
                let pred = predict_argmax(&sums);
                (sums, pred)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::infer::{cotm_class_sums, multiclass_class_sums};

    fn tiny_params() -> TmParams {
        TmParams {
            features: 2,
            clauses: 2,
            classes: 2,
            ..TmParams::iris_paper()
        }
    }

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn engines_are_send_and_sync() {
        // Same serving contract as the packed engines: one shared
        // instance across every coordinator thread.
        assert_send_sync::<IndexedMulticlass>();
        assert_send_sync::<IndexedCotm>();
    }

    /// Same hand-worked example as infer.rs / fast_infer.rs /
    /// python/tests/test_model.py — every tier agrees on it.
    #[test]
    fn hand_worked_multiclass_matches_reference() {
        let mut m = MultiClassTmModel::zeroed(tiny_params());
        m.clauses[0][0].include[0] = true; // class0 clause0 (+): x0
        m.clauses[0][1].include[3] = true; // class0 clause1 (−): ¬x1
        m.clauses[1][0].include[1] = true; // class1 clause0 (+): ¬x0
        m.clauses[1][1].include[2] = true; // class1 clause1 (−): x1
        let e = IndexedMulticlass::from_model(&m).unwrap();
        for x in [[true, false], [true, true], [false, false], [false, true]] {
            assert_eq!(e.class_sums(&x), multiclass_class_sums(&m, &x), "{x:?}");
        }
        assert_eq!(e.class_sums(&[true, true]), vec![1, -1]);
        assert_eq!(e.predict(&[true, true]), 0);
    }

    #[test]
    fn hand_worked_cotm_matches_reference() {
        let mut m = CoTmModel::zeroed(tiny_params());
        m.clauses[0].include[0] = true; // clause0: x0
        m.clauses[1].include[2] = true; // clause1: x1
        m.weights = vec![vec![3, -2], vec![-1, 4]];
        let e = IndexedCotm::from_model(&m).unwrap();
        for x in [[true, true], [true, false], [false, false]] {
            assert_eq!(e.class_sums(&x), cotm_class_sums(&m, &x), "{x:?}");
        }
        assert_eq!(e.class_sums(&[true, true]), vec![1, 3]);
    }

    // ------------------------------------------------------------------
    // Cross-language golden vectors, shared with python/invindex.py
    // (python/tests/test_invindex.py asserts the identical sums): the
    // models and samples are defined by closed-form formulas so both
    // languages construct them independently, like the hash-ring mirror.
    // ------------------------------------------------------------------

    /// F=9, C=4/class, K=3; include(k, j, l) = (3l + 5j + 7k) % 11 == 0.
    fn golden_multiclass() -> MultiClassTmModel {
        let p = TmParams { features: 9, clauses: 4, classes: 3, ..TmParams::iris_paper() };
        let mut m = MultiClassTmModel::zeroed(p);
        for (k, class) in m.clauses.iter_mut().enumerate() {
            for (j, clause) in class.iter_mut().enumerate() {
                for l in 0..18 {
                    clause.include[l] = (3 * l + 5 * j + 7 * k) % 11 == 0;
                }
            }
        }
        m
    }

    /// F=9, C=6, K=3; include(j, l) = (5l + 3j) % 7 == 0,
    /// weight(k, j) = (j + 2k) % 7 − 3.
    fn golden_cotm() -> CoTmModel {
        let p = TmParams { features: 9, clauses: 6, classes: 3, ..TmParams::iris_paper() };
        let mut m = CoTmModel::zeroed(p);
        for (j, clause) in m.clauses.iter_mut().enumerate() {
            for l in 0..18 {
                clause.include[l] = (5 * l + 3 * j) % 7 == 0;
            }
        }
        for (k, row) in m.weights.iter_mut().enumerate() {
            for (j, w) in row.iter_mut().enumerate() {
                *w = ((j + 2 * k) % 7) as i32 - 3;
            }
        }
        m
    }

    /// Sample s: feature i = (i² + 3is + 2s) % 7 < 3.
    fn golden_sample(s: usize) -> Vec<bool> {
        (0..9).map(|i| (i * i + 3 * i * s + 2 * s) % 7 < 3).collect()
    }

    #[test]
    fn golden_vectors_match_python_mirror() {
        let mc = IndexedMulticlass::from_model(&golden_multiclass()).unwrap();
        let co = IndexedCotm::from_model(&golden_cotm()).unwrap();
        let want_mc = [
            [1, 0, -1],
            [0, -1, 2],
            [0, -1, 0],
            [0, 0, 0],
            [-1, -1, 1],
            [0, 0, 0],
        ];
        let want_co = [
            [-2, 0, 2],
            [-6, 0, 6],
            [0, 2, -3],
            [3, 2, -6],
            [-3, -1, 1],
            [3, 2, -6],
        ];
        for s in 0..6 {
            let x = golden_sample(s);
            assert_eq!(mc.class_sums(&x), want_mc[s], "multiclass sample {s}");
            assert_eq!(co.class_sums(&x), want_co[s], "cotm sample {s}");
            // And the golden vectors themselves match the scalar
            // reference, so all three tiers pin the same semantics.
            assert_eq!(
                multiclass_class_sums(&golden_multiclass(), &x),
                want_mc[s],
                "reference multiclass sample {s}"
            );
            assert_eq!(
                cotm_class_sums(&golden_cotm(), &x),
                want_co[s],
                "reference cotm sample {s}"
            );
        }
    }

    #[test]
    fn from_model_rejects_invalid_models() {
        let odd = TmParams { clauses: 7, ..tiny_params() };
        assert!(IndexedMulticlass::from_model(&MultiClassTmModel::zeroed(odd)).is_err());
        let mut cm = CoTmModel::zeroed(tiny_params());
        cm.weights[0][0] = cm.params.max_weight + 1;
        assert!(IndexedCotm::from_model(&cm).is_err());
    }

    #[test]
    fn empty_clauses_never_fire() {
        // Zeroed model: all-exclude clauses appear in no literal list,
        // their counters start at 0 and are never decremented.
        let e = IndexedCotm::from_model(&CoTmModel::zeroed(tiny_params())).unwrap();
        assert_eq!(e.class_sums(&[true, false]), vec![0, 0]);
        let out = e.infer_batch(&[vec![true, false], vec![false, true]]);
        assert_eq!(out, vec![(vec![0, 0], 0), (vec![0, 0], 0)]);
    }

    #[test]
    fn contradictory_clause_never_fires() {
        // A clause including both x0 and ¬x0 can never see its counter
        // reach zero (exactly one of the pair is set per sample).
        let mut m = CoTmModel::zeroed(tiny_params());
        m.clauses[0].include[0] = true; // x0
        m.clauses[0].include[1] = true; // ¬x0
        m.weights = vec![vec![5, 0], vec![5, 0]];
        let e = IndexedCotm::from_model(&m).unwrap();
        for x in [[true, true], [false, false], [true, false]] {
            assert_eq!(e.class_sums(&x), vec![0, 0], "{x:?}");
            assert_eq!(e.class_sums(&x), cotm_class_sums(&m, &x));
        }
    }

    #[test]
    fn sweep_restores_counters_and_batch_reuses_scratch() {
        let m = golden_multiclass();
        let e = IndexedMulticlass::from_model(&m).unwrap();
        let mut counts = e.index.fresh_counts();
        let baseline = counts.clone();
        let mut fired = Vec::new();
        for s in 0..6 {
            e.index.sweep(&golden_sample(s), &mut counts, &mut fired);
            assert_eq!(counts, baseline, "counters restored after sample {s}");
        }
        // Batched results equal per-sample results (same scratch reuse).
        let rows: Vec<Vec<bool>> = (0..6).map(golden_sample).collect();
        let out = e.infer_batch(&rows);
        for (s, (sums, pred)) in out.iter().enumerate() {
            assert_eq!(sums, &e.class_sums(&rows[s]), "sample {s}");
            assert_eq!(*pred, predict_argmax(sums));
        }
    }

    #[test]
    fn batched_agrees_with_single_sample_across_block_boundary() {
        // 130 samples: the default sharded path splits on 64-sample
        // blocks; indexed evaluation must be invariant to the split.
        let m = golden_multiclass();
        let e = IndexedMulticlass::from_model(&m).unwrap();
        let rows: Vec<Vec<bool>> = (0..130usize)
            .map(|s| (0..9).map(|i| (s >> (i % 7)) & 1 == 1).collect())
            .collect();
        let batched = e.infer_batch(&rows);
        assert_eq!(batched.len(), 130);
        for (s, (sums, pred)) in batched.iter().enumerate() {
            assert_eq!(sums, &e.class_sums(&rows[s]), "sample {s}");
            assert_eq!(*pred, predict_argmax(sums), "sample {s}");
        }
        assert_eq!(e.infer_batch_sharded(&rows, 4), batched);
    }

    #[test]
    fn empty_batch_is_empty() {
        let e = IndexedMulticlass::from_model(&golden_multiclass()).unwrap();
        assert!(e.infer_batch(&Vec::<Vec<bool>>::new()).is_empty());
    }

    #[test]
    fn density_and_postings_account_included_literals() {
        let m = golden_cotm();
        let e = IndexedCotm::from_model(&m).unwrap();
        let included: usize = m.clauses.iter().map(|c| c.included_count()).sum();
        assert_eq!(e.index.postings(), included);
        let want = included as f64 / (6.0 * 18.0);
        assert!((e.density() - want).abs() < 1e-12);
        assert!((included_density(m.clauses.iter()) - want).abs() < 1e-12);
        // Degenerate inputs.
        assert_eq!(included_density(std::iter::empty::<&ClauseMask>()), 0.0);
        let zeroed = IndexedCotm::from_model(&CoTmModel::zeroed(tiny_params())).unwrap();
        assert_eq!(zeroed.density(), 0.0);
    }

    #[test]
    fn csr_runs_group_postings_by_literal_in_clause_order() {
        // The CSR layout is an internal refactor of the old
        // Vec<Vec<u32>> postings: per-literal runs must contain exactly
        // the clauses including that literal, ascending by id, and the
        // offsets must tile the flat array exactly.
        let m = golden_cotm();
        let idx = InvertedIndex::build(9, m.clauses.iter());
        assert_eq!(*idx.posting_offsets.first().unwrap(), 0);
        assert_eq!(*idx.posting_offsets.last().unwrap() as usize, idx.postings());
        assert_eq!(idx.posting_offsets.len(), 2 * 9 + 1);
        for lit in 0..18 {
            let want: Vec<u32> = m
                .clauses
                .iter()
                .enumerate()
                .filter(|(_, cl)| cl.include[lit])
                .map(|(c, _)| c as u32)
                .collect();
            assert_eq!(idx.run(lit), want.as_slice(), "literal {lit}");
        }
        // The shared batch kernels invert each other on any run.
        let mut counts = idx.fresh_counts();
        let baseline = counts.clone();
        let mut zeros = Vec::new();
        for lit in 0..18 {
            decrement_run(idx.run(lit), &mut counts, |c| zeros.push(c));
            restore_run(idx.run(lit), &mut counts);
            assert_eq!(counts, baseline, "literal {lit}");
        }
    }

    #[test]
    fn dead_clauses_do_not_dilute_density_accounting() {
        // Regression (PR 8): 9 all-exclude clauses + 1 half-dense live
        // clause. The old denominator (all clauses) measured
        // 5/(10·10) = 0.05 — exactly the default threshold — so the
        // auto-* choice flipped to the indexed engine even though the
        // only clause that does any work is 50% dense. Live-clause
        // accounting measures 0.5 and keeps the packed engine.
        let features = 5;
        let mut masks = vec![ClauseMask::empty(10); 10];
        for l in [0, 2, 4, 6, 8] {
            masks[0].include[l] = true;
        }
        let idx = InvertedIndex::build(features, masks.iter());
        assert_eq!(idx.num_clauses(), 10);
        assert_eq!(idx.live_clauses(), 1);
        assert_eq!(idx.postings(), 5);
        assert!((idx.density() - 0.5).abs() < 1e-12);
        assert!((included_density(masks.iter()) - 0.5).abs() < 1e-12);
        assert!(!prefer_indexed(idx.density(), PACKED_VS_INDEXED_DENSITY));
        // The stale accounting would have chosen the other engine.
        let stale = idx.postings() as f64 / (idx.num_clauses() * 2 * features) as f64;
        assert!(prefer_indexed(stale, PACKED_VS_INDEXED_DENSITY));
    }

    #[test]
    fn compiled_artifact_with_pruned_reordered_clauses_stays_exact() {
        // Full compile of a model with dead clauses: the indexed engine
        // built from the artifact must match the scalar reference on
        // every input (explicit votes absorb the id permutation).
        use crate::tm::compile::{CompileMode, ModelCompiler};
        let p = TmParams { features: 3, clauses: 4, classes: 2, ..tiny_params() };
        let mut m = MultiClassTmModel::zeroed(p);
        m.clauses[0][0].include[1] = true; // (+) ¬x0
        m.clauses[0][2].include[2] = true;
        m.clauses[0][2].include[3] = true; // contradictory -> dead
        m.clauses[0][3].include[0] = true; // (−) x0
        m.clauses[1][1].include[4] = true; // (−) x2
        let calib: Vec<Vec<bool>> = (0..8u32)
            .map(|b| (0..3).map(|i| (b >> i) & 1 == 1).collect())
            .collect();
        let compiled = ModelCompiler::new(CompileMode::Full)
            .with_calibration(calib.clone())
            .compile_multiclass(&m)
            .unwrap();
        let e = IndexedMulticlass::from_compiled(&compiled).unwrap();
        for x in &calib {
            assert_eq!(e.class_sums(x), multiclass_class_sums(&m, x), "{x:?}");
        }
    }

    #[test]
    fn prefer_indexed_is_a_pure_threshold() {
        assert!(prefer_indexed(0.01, PACKED_VS_INDEXED_DENSITY));
        assert!(prefer_indexed(PACKED_VS_INDEXED_DENSITY, PACKED_VS_INDEXED_DENSITY));
        assert!(!prefer_indexed(0.5, PACKED_VS_INDEXED_DENSITY));
        // Threshold 0 still admits all-empty models (density exactly 0).
        assert!(prefer_indexed(0.0, 0.0));
        assert!(!prefer_indexed(0.1, 0.0));
        // Threshold 1 routes everything to the indexed engine.
        assert!(prefer_indexed(1.0, 1.0));
    }
}
