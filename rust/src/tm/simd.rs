//! SIMD multi-word evaluation tier: the `WordLanes` abstraction the
//! packed engines evaluate through.
//!
//! The packed representations in [`super::bitpack`] turned clause
//! evaluation into `u64` word ops, but the engines still consumed one
//! word per instruction. "Fast and Compact Tsetlin Machine Inference on
//! CPUs" (arXiv 2510.15653) measures 4–8× left on the table without
//! vector ILP at exactly this spot, and the massively-parallel layout
//! of arXiv 2009.04861 motivates the cache-blocked tiles
//! ([`super::bitpack::BitSlicedBatch`]) that make the lanes stream:
//! within a tile every literal's lane words are contiguous, so one
//! `WordLanes` op covers 4–8 sample blocks.
//!
//! # Lane widths
//!
//! * [`SimdLevel::Scalar`] — one `u64` per op with a branch per word:
//!   the historic (PR 1) evaluation walk, kept as the bench baseline
//!   and as the `simd = "scalar"` escape hatch.
//! * [`SimdLevel::Portable`] — 4×`u64` manually unrolled, pure safe
//!   Rust. Compiles everywhere, **remains the bit-exact reference** for
//!   the vector paths: the AVX variants are only ever allowed to be
//!   faster, never different (enforced by `tests/simd_dispatch.rs` and
//!   the `tmtd selfcheck` lane bars).
//! * [`SimdLevel::Neon`] — 2×`u64` via `core::arch::aarch64` intrinsics,
//!   `#[target_feature(enable = "neon")]`-gated, selected only when
//!   `is_aarch64_feature_detected!("neon")` says the host has it
//!   (aarch64 servers: Graviton, Ampere, Apple silicon).
//! * [`SimdLevel::Avx2`] — 4 lanes via `core::arch::x86_64` intrinsics,
//!   `#[target_feature(enable = "avx2")]`-gated, selected only when
//!   `is_x86_feature_detected!("avx2")` says the host has it.
//! * [`SimdLevel::Avx512`] — 8 lanes, additionally behind the
//!   **off-by-default `avx512` cargo feature** (the AVX-512 intrinsics
//!   need rustc ≥ 1.89; the default feature set keeps the crate
//!   building on older toolchains), and still runtime-detected.
//!
//! # Why the portable path stays the reference
//!
//! Every level computes the same two predicates —
//! `acc &= src` with an any-nonzero reduction, and
//! `any(include & !literals)` — over the same words, so all levels are
//! bit-identical by construction; the portable path is the one that
//! compiles on every target and therefore the one the conformance
//! suites diff the vector paths against. Dispatch
//! ([`WordLanes::detect`], [`SimdChoice`]) can only change *speed*.
//!
//! Compile the vector paths out entirely with
//! `--no-default-features` (drops the `simd` feature): dispatch then
//! resolves to the portable/scalar pair only, which is what
//! `scripts/verify.sh`'s portable-only build proves still stands alone.

// The one audited exception to the crate-wide `#![deny(unsafe_code)]`:
// `#[target_feature]` kernels plus the dispatch blocks that call them
// behind runtime feature detection. Lint rule R4
// (`python/analysis/rules/r4_unsafe_audit.py`) checks exactly that
// shape on every CI image, toolchain or not.
#![allow(unsafe_code)]

use crate::error::{Error, Result};

/// One evaluation lane width. Ordering is "preference at equal
/// availability": later variants are wider.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SimdLevel {
    /// One `u64` per op, branch per word — the PR 1 reference walk.
    Scalar,
    /// Portable 4×`u64` unrolled baseline (bit-exact reference for the
    /// vector paths; compiles on every target).
    Portable,
    /// NEON, 2×`u64` per 128-bit lane (aarch64, runtime-detected).
    /// Narrower than the portable unroll but ILP-dense on aarch64
    /// cores; ordered below AVX2 so x86 hosts never regress.
    Neon,
    /// AVX2, 4×`u64` per 256-bit lane (x86-64, runtime-detected).
    Avx2,
    /// AVX-512F, 8×`u64` per 512-bit lane (x86-64, runtime-detected,
    /// and compiled only with the `avx512` cargo feature).
    Avx512,
}

impl SimdLevel {
    pub const ALL: [SimdLevel; 5] = [
        SimdLevel::Scalar,
        SimdLevel::Portable,
        SimdLevel::Neon,
        SimdLevel::Avx2,
        SimdLevel::Avx512,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Portable => "portable",
            SimdLevel::Neon => "neon",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
        }
    }

    /// `u64` words consumed per unrolled step.
    pub fn lanes(self) -> usize {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Neon => 2,
            SimdLevel::Portable | SimdLevel::Avx2 => 4,
            SimdLevel::Avx512 => 8,
        }
    }

    /// Is this level usable on the running host (compiled in *and*
    /// detected)? Scalar and portable always are.
    pub fn is_available(self) -> bool {
        match self {
            SimdLevel::Scalar | SimdLevel::Portable => true,
            SimdLevel::Neon => neon_available(),
            SimdLevel::Avx2 => avx2_available(),
            SimdLevel::Avx512 => avx512_available(),
        }
    }

    /// Every level usable on the running host, narrowest first.
    pub fn available() -> Vec<SimdLevel> {
        SimdLevel::ALL.iter().copied().filter(|l| l.is_available()).collect()
    }

    /// The widest available level — what `simd = "auto"` resolves to.
    pub fn detect_best() -> SimdLevel {
        if avx512_available() {
            SimdLevel::Avx512
        } else if avx2_available() {
            SimdLevel::Avx2
        } else if neon_available() {
            SimdLevel::Neon
        } else {
            SimdLevel::Portable
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
fn avx2_available() -> bool {
    false
}

#[cfg(all(feature = "avx512", target_arch = "x86_64"))]
fn avx512_available() -> bool {
    is_x86_feature_detected!("avx512f")
}

#[cfg(not(all(feature = "avx512", target_arch = "x86_64")))]
fn avx512_available() -> bool {
    false
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(all(feature = "simd", target_arch = "aarch64")))]
fn neon_available() -> bool {
    false
}

/// The serve-config / CLI dispatch knob (`simd = "auto" | "scalar" |
/// "portable" | "neon" | "avx2" | "avx512"`). `Auto` picks the widest
/// detected level at engine-build time; a forced level errors cleanly at
/// build time when the host cannot run it (rather than faulting
/// mid-request).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdChoice {
    #[default]
    Auto,
    Forced(SimdLevel),
}

impl SimdChoice {
    pub fn parse(name: &str) -> Option<SimdChoice> {
        match name {
            "auto" => Some(SimdChoice::Auto),
            "scalar" | "single-word" => Some(SimdChoice::Forced(SimdLevel::Scalar)),
            "portable" | "unrolled" => Some(SimdChoice::Forced(SimdLevel::Portable)),
            "neon" => Some(SimdChoice::Forced(SimdLevel::Neon)),
            "avx2" => Some(SimdChoice::Forced(SimdLevel::Avx2)),
            "avx512" => Some(SimdChoice::Forced(SimdLevel::Avx512)),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SimdChoice::Auto => "auto",
            SimdChoice::Forced(l) => l.name(),
        }
    }

    /// Resolve to concrete lanes; errors when a forced level is not
    /// compiled in or not detected on this host.
    pub fn resolve(self) -> Result<WordLanes> {
        match self {
            SimdChoice::Auto => Ok(WordLanes::detect()),
            SimdChoice::Forced(level) => WordLanes::new(level),
        }
    }
}

/// A fixed lane width over `u64` word slices — the two predicates every
/// packed evaluation in the crate reduces to, dispatched once per slice
/// (not per word).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WordLanes {
    level: SimdLevel,
}

impl WordLanes {
    /// Lanes at an explicit level; errors when the level is unavailable
    /// on this host (not compiled in, or not detected).
    pub fn new(level: SimdLevel) -> Result<WordLanes> {
        if !level.is_available() {
            return Err(Error::config(format!(
                "simd level {:?} is not available on this host (available: {})",
                level.name(),
                SimdLevel::available()
                    .iter()
                    .map(|l| l.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )));
        }
        Ok(WordLanes { level })
    }

    /// The single-word reference walk.
    pub const fn scalar() -> WordLanes {
        WordLanes { level: SimdLevel::Scalar }
    }

    /// The portable 4×`u64` unrolled baseline — the bit-exact reference
    /// the vector paths are diffed against.
    pub const fn portable() -> WordLanes {
        WordLanes { level: SimdLevel::Portable }
    }

    /// The widest available level on this host.
    pub fn detect() -> WordLanes {
        WordLanes { level: SimdLevel::detect_best() }
    }

    pub fn level(self) -> SimdLevel {
        self.level
    }

    pub fn name(self) -> &'static str {
        self.level.name()
    }

    /// `acc[i] &= src[i]` over equal-length slices; returns whether any
    /// result word is non-zero (the tile evaluator's early-exit
    /// signal). All levels are bit-identical; only the op width
    /// differs.
    #[inline]
    pub fn and_assign_any(self, acc: &mut [u64], src: &[u64]) -> bool {
        // Hard assert, not debug: the vector kernels size their loops
        // from one slice and load from the other, so a mismatch in a
        // release build would read out of bounds (UB) from safe code.
        assert_eq!(acc.len(), src.len(), "lane slices must match");
        match self.level {
            SimdLevel::Scalar => and_any_scalar(acc, src),
            SimdLevel::Portable => and_any_portable(acc, src),
            #[cfg(all(feature = "simd", target_arch = "aarch64"))]
            // SAFETY: WordLanes::new / detect only construct this level
            // when is_aarch64_feature_detected!("neon") held.
            SimdLevel::Neon => unsafe { neon::and_any_neon(acc, src) },
            #[cfg(not(all(feature = "simd", target_arch = "aarch64")))]
            SimdLevel::Neon => and_any_portable(acc, src),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: WordLanes::new / detect only construct this level
            // when is_x86_feature_detected!("avx2") held.
            SimdLevel::Avx2 => unsafe { x86::and_any_avx2(acc, src) },
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            SimdLevel::Avx2 => and_any_portable(acc, src),
            #[cfg(all(feature = "avx512", target_arch = "x86_64"))]
            // SAFETY: constructed only when avx512f was detected.
            SimdLevel::Avx512 => unsafe { x86_512::and_any_avx512(acc, src) },
            #[cfg(not(all(feature = "avx512", target_arch = "x86_64")))]
            SimdLevel::Avx512 => and_any_portable(acc, src),
        }
    }

    /// Whether any word has `include & !literals != 0` — i.e. the
    /// clause constrains a literal the sample does not satisfy. This is
    /// the single-sample / training firing predicate: a clause fires
    /// under training semantics iff this is false.
    #[inline]
    pub fn violates(self, include: &[u64], literals: &[u64]) -> bool {
        // Hard assert for the same out-of-bounds reason as
        // and_assign_any.
        assert_eq!(include.len(), literals.len(), "lane slices must match");
        match self.level {
            SimdLevel::Scalar => violates_scalar(include, literals),
            SimdLevel::Portable => violates_portable(include, literals),
            #[cfg(all(feature = "simd", target_arch = "aarch64"))]
            // SAFETY: see and_assign_any.
            SimdLevel::Neon => unsafe { neon::violates_neon(include, literals) },
            #[cfg(not(all(feature = "simd", target_arch = "aarch64")))]
            SimdLevel::Neon => violates_portable(include, literals),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: see and_assign_any.
            SimdLevel::Avx2 => unsafe { x86::violates_avx2(include, literals) },
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            SimdLevel::Avx2 => violates_portable(include, literals),
            #[cfg(all(feature = "avx512", target_arch = "x86_64"))]
            // SAFETY: see and_assign_any.
            SimdLevel::Avx512 => unsafe { x86_512::violates_avx512(include, literals) },
            #[cfg(not(all(feature = "avx512", target_arch = "x86_64")))]
            SimdLevel::Avx512 => violates_portable(include, literals),
        }
    }
}

/// Process-wide default lanes: the widest detected level, resolved once
/// (one atomic load per call afterwards). This is what
/// `bitpack::eval_words_train` and freshly compiled engines use unless
/// a caller forces a level.
pub fn default_lanes() -> WordLanes {
    use std::sync::OnceLock;
    static DETECTED: OnceLock<WordLanes> = OnceLock::new();
    *DETECTED.get_or_init(WordLanes::detect)
}

// ---------------------------------------------------------------------
// Scalar (single-word) reference.
// ---------------------------------------------------------------------

fn and_any_scalar(acc: &mut [u64], src: &[u64]) -> bool {
    let mut any = 0u64;
    for (a, &s) in acc.iter_mut().zip(src) {
        *a &= s;
        any |= *a;
    }
    any != 0
}

fn violates_scalar(include: &[u64], literals: &[u64]) -> bool {
    include.iter().zip(literals).any(|(&inc, &lw)| inc & !lw != 0)
}

// ---------------------------------------------------------------------
// Portable 4×u64 unrolled baseline.
// ---------------------------------------------------------------------

fn and_any_portable(acc: &mut [u64], src: &[u64]) -> bool {
    let mut or0 = 0u64;
    let mut or1 = 0u64;
    let mut or2 = 0u64;
    let mut or3 = 0u64;
    let mut a4 = acc.chunks_exact_mut(4);
    let mut s4 = src.chunks_exact(4);
    for (a, s) in a4.by_ref().zip(s4.by_ref()) {
        a[0] &= s[0];
        or0 |= a[0];
        a[1] &= s[1];
        or1 |= a[1];
        a[2] &= s[2];
        or2 |= a[2];
        a[3] &= s[3];
        or3 |= a[3];
    }
    let mut tail = 0u64;
    for (a, &s) in a4.into_remainder().iter_mut().zip(s4.remainder()) {
        *a &= s;
        tail |= *a;
    }
    (or0 | or1 | or2 | or3 | tail) != 0
}

fn violates_portable(include: &[u64], literals: &[u64]) -> bool {
    let mut i4 = include.chunks_exact(4);
    let mut l4 = literals.chunks_exact(4);
    for (inc, lw) in i4.by_ref().zip(l4.by_ref()) {
        let v = (inc[0] & !lw[0])
            | (inc[1] & !lw[1])
            | (inc[2] & !lw[2])
            | (inc[3] & !lw[3]);
        if v != 0 {
            return true;
        }
    }
    i4.remainder()
        .iter()
        .zip(l4.remainder())
        .any(|(&inc, &lw)| inc & !lw != 0)
}

// ---------------------------------------------------------------------
// AVX2: 4×u64 per 256-bit op. Runtime-dispatched; never constructed
// unless detected.
// ---------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use core::arch::x86_64::{
        __m256i, _mm256_and_si256, _mm256_andnot_si256, _mm256_loadu_si256,
        _mm256_or_si256, _mm256_setzero_si256, _mm256_storeu_si256, _mm256_testz_si256,
    };

    /// # Safety
    /// Caller must guarantee the host supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn and_any_avx2(acc: &mut [u64], src: &[u64]) -> bool {
        let n = acc.len() / 4 * 4;
        let mut any = _mm256_setzero_si256();
        let mut i = 0;
        while i < n {
            let a = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let r = _mm256_and_si256(a, s);
            _mm256_storeu_si256(acc.as_mut_ptr().add(i) as *mut __m256i, r);
            any = _mm256_or_si256(any, r);
            i += 4;
        }
        let mut tail = 0u64;
        while i < acc.len() {
            acc[i] &= src[i];
            tail |= acc[i];
            i += 1;
        }
        _mm256_testz_si256(any, any) == 0 || tail != 0
    }

    /// # Safety
    /// Caller must guarantee the host supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn violates_avx2(include: &[u64], literals: &[u64]) -> bool {
        let n = include.len() / 4 * 4;
        let mut i = 0;
        while i < n {
            let inc = _mm256_loadu_si256(include.as_ptr().add(i) as *const __m256i);
            let lw = _mm256_loadu_si256(literals.as_ptr().add(i) as *const __m256i);
            // andnot(a, b) computes !a & b, so this is include & !lits.
            let v = _mm256_andnot_si256(lw, inc);
            if _mm256_testz_si256(v, v) == 0 {
                return true;
            }
            i += 4;
        }
        include[n..]
            .iter()
            .zip(&literals[n..])
            .any(|(&inc, &lw)| inc & !lw != 0)
    }
}

// ---------------------------------------------------------------------
// NEON: 2×u64 per 128-bit op (aarch64). Runtime-dispatched; never
// constructed unless detected.
// ---------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    use core::arch::aarch64::{
        uint64x2_t, vandq_u64, vbicq_u64, vdupq_n_u64, vgetq_lane_u64, vld1q_u64,
        vorrq_u64, vst1q_u64,
    };

    /// # Safety
    /// Caller must guarantee the host supports NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn and_any_neon(acc: &mut [u64], src: &[u64]) -> bool {
        let n = acc.len() / 2 * 2;
        let mut any: uint64x2_t = vdupq_n_u64(0);
        let mut i = 0;
        while i < n {
            let a = vld1q_u64(acc.as_ptr().add(i));
            let s = vld1q_u64(src.as_ptr().add(i));
            let r = vandq_u64(a, s);
            vst1q_u64(acc.as_mut_ptr().add(i), r);
            any = vorrq_u64(any, r);
            i += 2;
        }
        let mut tail = 0u64;
        while i < acc.len() {
            acc[i] &= src[i];
            tail |= acc[i];
            i += 1;
        }
        (vgetq_lane_u64::<0>(any) | vgetq_lane_u64::<1>(any) | tail) != 0
    }

    /// # Safety
    /// Caller must guarantee the host supports NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn violates_neon(include: &[u64], literals: &[u64]) -> bool {
        let n = include.len() / 2 * 2;
        let mut i = 0;
        while i < n {
            let inc = vld1q_u64(include.as_ptr().add(i));
            let lw = vld1q_u64(literals.as_ptr().add(i));
            // vbicq_u64(a, b) computes a & !b, so this is include & !lits.
            let v = vbicq_u64(inc, lw);
            if (vgetq_lane_u64::<0>(v) | vgetq_lane_u64::<1>(v)) != 0 {
                return true;
            }
            i += 2;
        }
        include[n..]
            .iter()
            .zip(&literals[n..])
            .any(|(&inc, &lw)| inc & !lw != 0)
    }
}

// ---------------------------------------------------------------------
// AVX-512F: 8×u64 per 512-bit op. Behind the off-by-default `avx512`
// cargo feature (the stabilized intrinsics need rustc >= 1.89).
// ---------------------------------------------------------------------

#[cfg(all(feature = "avx512", target_arch = "x86_64"))]
mod x86_512 {
    use core::arch::x86_64::{
        _mm512_and_epi64, _mm512_andnot_epi64, _mm512_loadu_epi64,
        _mm512_storeu_epi64, _mm512_test_epi64_mask,
    };

    /// # Safety
    /// Caller must guarantee the host supports AVX-512F.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn and_any_avx512(acc: &mut [u64], src: &[u64]) -> bool {
        let n = acc.len() / 8 * 8;
        let mut any: u8 = 0;
        let mut i = 0;
        while i < n {
            let a = _mm512_loadu_epi64(acc.as_ptr().add(i) as *const i64);
            let s = _mm512_loadu_epi64(src.as_ptr().add(i) as *const i64);
            let r = _mm512_and_epi64(a, s);
            _mm512_storeu_epi64(acc.as_mut_ptr().add(i) as *mut i64, r);
            any |= _mm512_test_epi64_mask(r, r);
            i += 8;
        }
        let mut tail = 0u64;
        while i < acc.len() {
            acc[i] &= src[i];
            tail |= acc[i];
            i += 1;
        }
        any != 0 || tail != 0
    }

    /// # Safety
    /// Caller must guarantee the host supports AVX-512F.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn violates_avx512(include: &[u64], literals: &[u64]) -> bool {
        let n = include.len() / 8 * 8;
        let mut i = 0;
        while i < n {
            let inc = _mm512_loadu_epi64(include.as_ptr().add(i) as *const i64);
            let lw = _mm512_loadu_epi64(literals.as_ptr().add(i) as *const i64);
            let v = _mm512_andnot_epi64(lw, inc);
            if _mm512_test_epi64_mask(v, v) != 0 {
                return true;
            }
            i += 8;
        }
        include[n..]
            .iter()
            .zip(&literals[n..])
            .any(|(&inc, &lw)| inc & !lw != 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;

    #[test]
    fn scalar_and_portable_are_always_available() {
        assert!(SimdLevel::Scalar.is_available());
        assert!(SimdLevel::Portable.is_available());
        let avail = SimdLevel::available();
        assert!(avail.contains(&SimdLevel::Scalar));
        assert!(avail.contains(&SimdLevel::Portable));
        // detect_best never picks an unavailable level, and never falls
        // below the portable baseline.
        let best = SimdLevel::detect_best();
        assert!(best.is_available());
        assert!(best >= SimdLevel::Portable);
        assert_eq!(default_lanes().level(), best);
    }

    #[test]
    fn new_rejects_unavailable_levels_only() {
        for level in SimdLevel::ALL {
            let lanes = WordLanes::new(level);
            assert_eq!(lanes.is_ok(), level.is_available(), "{}", level.name());
            if let Ok(l) = lanes {
                assert_eq!(l.level(), level);
            }
        }
    }

    #[test]
    fn choice_parse_roundtrip() {
        assert_eq!(SimdChoice::parse("auto"), Some(SimdChoice::Auto));
        assert_eq!(
            SimdChoice::parse("portable"),
            Some(SimdChoice::Forced(SimdLevel::Portable))
        );
        assert_eq!(
            SimdChoice::parse("unrolled"),
            Some(SimdChoice::Forced(SimdLevel::Portable))
        );
        assert_eq!(
            SimdChoice::parse("scalar"),
            Some(SimdChoice::Forced(SimdLevel::Scalar))
        );
        assert_eq!(SimdChoice::parse("neon"), Some(SimdChoice::Forced(SimdLevel::Neon)));
        assert_eq!(SimdChoice::parse("avx2"), Some(SimdChoice::Forced(SimdLevel::Avx2)));
        assert_eq!(
            SimdChoice::parse("avx512"),
            Some(SimdChoice::Forced(SimdLevel::Avx512))
        );
        assert_eq!(SimdChoice::parse("sve"), None);
        assert_eq!(SimdChoice::default(), SimdChoice::Auto);
        assert_eq!(SimdChoice::Auto.name(), "auto");
        assert_eq!(SimdChoice::Forced(SimdLevel::Avx2).name(), "avx2");
        // Auto and the always-available levels resolve everywhere.
        assert!(SimdChoice::Auto.resolve().is_ok());
        assert!(SimdChoice::Forced(SimdLevel::Scalar).resolve().is_ok());
        assert!(SimdChoice::Forced(SimdLevel::Portable).resolve().is_ok());
    }

    #[test]
    fn lane_widths_are_declared() {
        assert_eq!(SimdLevel::Scalar.lanes(), 1);
        assert_eq!(SimdLevel::Portable.lanes(), 4);
        assert_eq!(SimdLevel::Neon.lanes(), 2);
        assert_eq!(SimdLevel::Avx2.lanes(), 4);
        assert_eq!(SimdLevel::Avx512.lanes(), 8);
    }

    /// Oracle for and_assign_any.
    fn and_ref(acc: &mut [u64], src: &[u64]) -> bool {
        let mut any = false;
        for (a, &s) in acc.iter_mut().zip(src) {
            *a &= s;
            any |= *a != 0;
        }
        any
    }

    #[test]
    fn all_available_levels_match_the_word_by_word_oracle() {
        // Slice lengths straddle every unroll boundary (0..=17 covers
        // the 4-lane and 8-lane remainders); values include all-ones,
        // all-zeros and random words.
        prop("lane ops vs oracle", 200, |g| {
            let n = g.usize(0..18);
            let word = |g: &mut crate::testutil::Gen| match g.usize(0..4) {
                0 => 0u64,
                1 => !0u64,
                _ => g.u64(0..u64::MAX),
            };
            let acc: Vec<u64> = (0..n).map(|_| word(g)).collect();
            let src: Vec<u64> = (0..n).map(|_| word(g)).collect();
            let mut want = acc.clone();
            let want_any = and_ref(&mut want, &src);
            for level in SimdLevel::available() {
                let lanes = WordLanes::new(level).unwrap();
                let mut got = acc.clone();
                let got_any = lanes.and_assign_any(&mut got, &src);
                assert_eq!(got, want, "and_assign {} n={n}", level.name());
                assert_eq!(got_any, want_any, "any {} n={n}", level.name());

                let want_viol =
                    acc.iter().zip(&src).any(|(&a, &b)| a & !b != 0);
                assert_eq!(
                    lanes.violates(&acc, &src),
                    want_viol,
                    "violates {} n={n}",
                    level.name()
                );
            }
        });
    }

    #[test]
    fn empty_slices_are_vacuous() {
        for level in SimdLevel::available() {
            let lanes = WordLanes::new(level).unwrap();
            assert!(!lanes.and_assign_any(&mut [], &[]), "{}", level.name());
            assert!(!lanes.violates(&[], &[]), "{}", level.name());
        }
    }
}
