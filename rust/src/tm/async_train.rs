//! Asynchronous clause-parallel training — the throughput tier.
//!
//! The deterministic trainers ([`super::train`] / [`super::cotm_train`])
//! are the bit-exact bar: packed and reference engines produce identical
//! models per seed, pinned by golden vectors in two languages. They are
//! also single-threaded, and the ROADMAP names million-sample training
//! runs as the hard ceiling. This module is the throughput multiplier:
//! clause-level parallel training in the style of the massively-parallel
//! TM architecture (*"Massively Parallel and Asynchronous Tsetlin
//! Machine Architecture"*, arXiv 2009.04861), where clauses train
//! against **stale** class-sum votes and the accuracy cost is noise-level.
//!
//! # The snapshot contract
//!
//! * **Partitioning** — global clause slot `j` is owned by worker
//!   `j % threads`. Initial TA states are drawn from a single
//!   `SplitMix64(seed)` in exactly the reference trainer's order
//!   (class-major, clause order) *before* being moved into per-worker
//!   owned storage, so partitioning never perturbs initialisation. Each
//!   worker owns its clauses (and, for CoTM, their weight columns)
//!   outright — feedback is lock-free because nothing is shared, not
//!   because anything is cleverly synchronised. No `unsafe`, no slice
//!   splitting.
//! * **Stale votes** — the only shared state is one `AtomicI32` per
//!   class. A worker refreshes its partition's contribution once per
//!   (sample, touched class) by *differencing*:
//!   `votes[c].fetch_add(contrib - last[c], Relaxed)`, then reads the
//!   shared total with a `Relaxed` load for the update probability.
//!   Between refreshes, other partitions' entries are stale by design —
//!   that is the paper's asynchronism. All vote traffic is
//!   `Ordering::Relaxed`: each cell is an independent commutative
//!   counter, and no control flow depends on cross-cell ordering.
//!   `Acquire`/`Release` appear **only** at the partition join
//!   ([`join_votes`], after `thread::scope` has already synchronised),
//!   where the conservation law `votes[c] == Σ_w last_w[c]` proves no
//!   update was lost on a partition boundary. Lint rule r9 enforces
//!   this discipline mechanically.
//! * **RNG streams** — [`stream_seed`]`(seed, epoch, lane)` derives an
//!   independent SplitMix64 stream per (epoch, lane) in closed form
//!   (deliberately not `fork()`: any worker, in either language, can
//!   derive any stream with no draw-order coupling). Lane 0 is the
//!   shared sample-order shuffle, lane 1 the negative-class draw —
//!   every worker replays its own copy, so all workers agree on the two
//!   touched classes of each sample without communicating — and lanes
//!   2.. are the per-worker feedback streams.
//! * **Indexed feedback** — [`TrainerChoice::AsyncIndexed`] evaluates
//!   owned clauses through per-worker literal→clause postings with
//!   unsatisfied-literal counters (the [`super::index`] sweep, reusing
//!   its decrement kernel, but with training-time empty-clause-FIRES
//!   semantics), kept in sync incrementally after every feedback — an
//!   update pays O(touched literals), never O(model). Evaluation is
//!   exact, so `async-indexed` and `async` produce **bit-identical**
//!   models under the same schedule.
//!
//! # Two schedules, one step function
//!
//! The threaded epoch (`std::thread::scope` workers racing over the
//! shared votes) is deliberately nondeterministic and is validated by
//! the statistical accuracy-parity bar (`tmtd selfcheck`,
//! `tests/train_equivalence.rs`) plus concurrency-invariant fuzzing.
//! The deterministic epoch replays the *identical* per-(worker, sample)
//! step in sample-major round-robin order — bit-reproducible, mirrored
//! literal-for-literal by `python/asynctrain.py`, and pinned by shared
//! golden vectors (r5). At `threads == 1` the two schedules coincide,
//! so the deterministic contract pins the threaded code path too.
//! See `docs/TRAINING.md` for which bars apply to which tier.

use std::sync::atomic::{AtomicI32, Ordering};
use std::thread;

use super::bitpack::{pack_literals, WORD_BITS};
use super::data::Dataset;
use super::index::{decrement_run, restore_run};
use super::model::{make_literals, CoTmModel, MultiClassTmModel, TmParams};
use super::trainer_engine::{type_i, type_ii, ClauseState, TrainerEngine};
use crate::error::{Error, Result};
use crate::util::SplitMix64;

/// Which trainer `tmtd train` runs. The first two are the deterministic
/// bit-exact tiers (see [`TrainerEngine`]); the async tiers trade
/// bit-reproducibility under threading for core-count throughput.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TrainerChoice {
    /// Per-literal reference evaluation, single-threaded, bit-exact.
    Reference,
    /// Packed-word evaluation, single-threaded, bit-exact (default).
    #[default]
    Packed,
    /// Clause-parallel async trainer, packed evaluation.
    Async,
    /// Clause-parallel async trainer, indexed (sweep) evaluation.
    AsyncIndexed,
}

impl TrainerChoice {
    /// Parse a CLI/TOML name (`--trainer packed|reference|async|async-indexed`).
    pub fn parse(name: &str) -> Option<TrainerChoice> {
        match name {
            "reference" | "ref" => Some(TrainerChoice::Reference),
            "packed" => Some(TrainerChoice::Packed),
            "async" => Some(TrainerChoice::Async),
            "async-indexed" => Some(TrainerChoice::AsyncIndexed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TrainerChoice::Reference => "reference",
            TrainerChoice::Packed => "packed",
            TrainerChoice::Async => "async",
            TrainerChoice::AsyncIndexed => "async-indexed",
        }
    }

    /// The deterministic engine this choice maps to, when it is one of
    /// the bit-exact single-threaded tiers.
    pub fn engine(&self) -> Option<TrainerEngine> {
        match self {
            TrainerChoice::Reference => Some(TrainerEngine::Reference),
            TrainerChoice::Packed => Some(TrainerEngine::Packed),
            TrainerChoice::Async | TrainerChoice::AsyncIndexed => None,
        }
    }

    /// Is this one of the clause-parallel async tiers?
    pub fn is_async(&self) -> bool {
        matches!(self, TrainerChoice::Async | TrainerChoice::AsyncIndexed)
    }

    /// Does the async tier evaluate through the inverted index?
    pub fn indexed(&self) -> bool {
        matches!(self, TrainerChoice::AsyncIndexed)
    }
}

// ---------------------------------------------------------------------
// RNG stream derivation.
// ---------------------------------------------------------------------

/// Stream lane for the shared sample-order shuffle.
pub const LANE_ORDER: u64 = 0;
/// Stream lane for the negative-class draw (replayed by every worker).
pub const LANE_NEG: u64 = 1;
/// First per-worker feedback lane; worker `w` uses `LANE_WORKER0 + w`.
pub const LANE_WORKER0: u64 = 2;

/// Fixed odd mixing constants for the stream-seed closed form — part of
/// the cross-language contract (r5 probe "async stream seeds"):
/// changing either changes every async golden vector in both languages.
const STREAM_EPOCH_MIX: u64 = 0xA076_1D64_78BD_642F;
const STREAM_LANE_MIX: u64 = 0xE703_7ED1_A0B4_28DB;

/// Closed-form per-(epoch, lane) stream seed, mirrored by
/// `python/asynctrain.py::stream_seed`.
pub fn stream_seed(seed: u64, epoch: u64, lane: u64) -> u64 {
    let root = SplitMix64::new(seed).next_u64();
    let mix = root
        ^ epoch.wrapping_mul(STREAM_EPOCH_MIX)
        ^ lane.wrapping_mul(STREAM_LANE_MIX);
    SplitMix64::new(mix).next_u64()
}

// ---------------------------------------------------------------------
// Per-worker training index (the indexed feedback path).
// ---------------------------------------------------------------------

/// Literal→clause postings over one worker's *owned* clauses, with
/// persistent unsatisfied-literal counters — the [`super::index`] sweep
/// structure, sharing its decrement kernel, but with **training-time**
/// semantics (a clause with zero included literals FIRES, so it can
/// receive Type I feedback and grow) and incremental maintenance: after
/// every feedback the changed include bits are replayed into the
/// postings instead of rebuilding anything.
#[derive(Debug, Clone)]
struct TrainIndex {
    /// `postings[lit]` = local ids of owned clauses including `lit`.
    /// Mutable (unlike the CSR serving index): feedback edits it.
    postings: Vec<Vec<u32>>,
    /// Per-clause included-literal count — the counter reset value.
    required: Vec<u32>,
    /// Persistent counters, decremented during a sweep and restored
    /// afterwards; kept equal to `required` between sweeps.
    counts: Vec<u32>,
}

impl TrainIndex {
    fn build<'a>(states: impl Iterator<Item = &'a ClauseState>, literals: usize) -> TrainIndex {
        let mut postings = vec![Vec::new(); literals];
        let mut required = Vec::new();
        for (ci, cl) in states.enumerate() {
            let mut req = 0u32;
            for (w, &word) in cl.include_words().iter().enumerate() {
                let mut bits = word;
                while bits != 0 {
                    let l = w * WORD_BITS + bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    postings[l].push(ci as u32);
                    req += 1;
                }
            }
            required.push(req);
        }
        let counts = required.clone();
        TrainIndex { postings, required, counts }
    }

    /// One sweep: fired flags for every owned clause on this sample.
    /// A counter can never go below zero (a clause receives at most
    /// `required` decrements — one per included literal that is set).
    fn fired_flags(&mut self, lits: &[bool], flags: &mut Vec<bool>) {
        flags.clear();
        flags.extend(self.required.iter().map(|&r| r == 0));
        for (l, _) in lits.iter().enumerate().filter(|&(_, &on)| on) {
            decrement_run(&self.postings[l], &mut self.counts, |c| {
                flags[c as usize] = true;
            });
        }
        for (l, _) in lits.iter().enumerate().filter(|&(_, &on)| on) {
            restore_run(&self.postings[l], &mut self.counts);
        }
    }

    /// Replay one clause's include-mask change into the postings:
    /// O(changed bits), which Type I/II bound by O(touched literals).
    fn apply_diff(&mut self, ci: u32, old_words: &[u64], new_words: &[u64]) {
        for (w, (&ow, &nw)) in old_words.iter().zip(new_words).enumerate() {
            let mut diff = ow ^ nw;
            while diff != 0 {
                let b = diff.trailing_zeros() as usize;
                let l = w * WORD_BITS + b;
                diff &= diff - 1;
                if (nw >> b) & 1 == 1 {
                    self.postings[l].push(ci);
                    self.required[ci as usize] += 1;
                    self.counts[ci as usize] += 1;
                } else {
                    self.postings[l].retain(|&c| c != ci);
                    self.required[ci as usize] -= 1;
                    self.counts[ci as usize] -= 1;
                }
            }
        }
    }

    /// Incrementally-maintained index == a fresh build (posting order
    /// within a literal is immaterial to the sweep).
    fn coherent<'a>(&self, states: impl Iterator<Item = &'a ClauseState>) -> bool {
        let fresh = TrainIndex::build(states, self.postings.len());
        let sorted = |p: &Vec<u32>| {
            let mut s = p.clone();
            s.sort_unstable();
            s
        };
        self.postings.iter().map(sorted).eq(fresh.postings.iter().cloned())
            && self.required == fresh.required
            && self.counts == fresh.required
    }
}

// ---------------------------------------------------------------------
// Partitions and the shared per-(worker, sample) step.
// ---------------------------------------------------------------------

/// One clause moved into a worker's partition: its global (class, slot)
/// coordinates, training state, and — CoTM only — its per-class weight
/// column. The owning worker is the only reader and writer.
#[derive(Debug, Clone)]
struct OwnedClause {
    /// Class index (multi-class trainer; 0 for the shared CoTM pool).
    class: usize,
    /// Global clause slot within the class (polarity = slot parity).
    slot: usize,
    state: ClauseState,
    /// CoTM per-class weight column; empty for the multi-class trainer.
    weights: Vec<i32>,
}

/// One worker's owned clauses plus evaluation scratch.
#[derive(Debug, Clone)]
struct Partition {
    clauses: Vec<OwnedClause>,
    /// Indexed evaluation state, when the indexed engine is selected.
    index: Option<TrainIndex>,
    /// Scratch fired flags, one per owned clause.
    fired: Vec<bool>,
}

impl Partition {
    fn rebuild_index(&mut self, literals: usize) {
        self.index =
            Some(TrainIndex::build(self.clauses.iter().map(|oc| &oc.state), literals));
    }

    fn check(&self, n: u32) -> Result<()> {
        for oc in &self.clauses {
            oc.state.check(n)?;
        }
        if let Some(index) = &self.index {
            if !index.coherent(self.clauses.iter().map(|oc| &oc.state)) {
                return Err(Error::model(
                    "async trainer index diverged from clause states",
                ));
            }
        }
        Ok(())
    }
}

/// Per-worker per-epoch mutable state: the feedback stream, the
/// replayed negative-class stream, the last published contribution per
/// class, and reusable scratch.
struct WorkerCtx {
    rng: SplitMix64,
    neg_rng: SplitMix64,
    last: Vec<i32>,
    old_words: Vec<u64>,
}

impl WorkerCtx {
    fn new(seed: u64, epoch: u64, worker: usize, classes: usize) -> WorkerCtx {
        WorkerCtx {
            rng: SplitMix64::new(stream_seed(seed, epoch, LANE_WORKER0 + worker as u64)),
            neg_rng: SplitMix64::new(stream_seed(seed, epoch, LANE_NEG)),
            last: vec![0; classes],
            old_words: Vec::new(),
        }
    }
}

type StepFn = fn(&TmParams, &mut Partition, &mut WorkerCtx, &[AtomicI32], &[bool], &[u64], usize);

/// Publish a partition's fresh contribution for one class and read back
/// the (stale) global sum — the snapshot refresh. All Relaxed: each
/// cell is an independent commutative counter.
#[inline]
fn publish_and_read(votes: &[AtomicI32], last: &mut [i32], class: usize, contrib: i32) -> i32 {
    let prev = last[class];
    votes[class].fetch_add(contrib - prev, Ordering::Relaxed);
    last[class] = contrib;
    votes[class].load(Ordering::Relaxed)
}

/// The two classes a sample touches: its label (positive update) and a
/// uniformly-sampled other class (negative update). Every worker
/// replays the same lane-1 stream, so all agree without communicating.
#[inline]
fn sample_targets(classes: usize, y: usize, neg_rng: &mut SplitMix64) -> [Option<(usize, bool)>; 2] {
    let neg = if classes > 1 {
        let mut c = neg_rng.index(classes - 1);
        if c >= y {
            c += 1;
        }
        Some((c, false))
    } else {
        None
    };
    [Some((y, true)), neg]
}

/// One (worker, sample) step of the multi-class trainer. Multi-class
/// feedback only touches the positive class's clauses, which are
/// disjoint from the sampled negative class's — so the indexed sweep
/// runs once per sample and serves both class updates.
fn step_mc(
    p: &TmParams,
    part: &mut Partition,
    ctx: &mut WorkerCtx,
    votes: &[AtomicI32],
    lits: &[bool],
    words: &[u64],
    y: usize,
) {
    let (n, s, t) = (p.ta_states, p.specificity, p.threshold);
    let targets = sample_targets(p.classes, y, &mut ctx.neg_rng);
    if let Some(index) = part.index.as_mut() {
        index.fired_flags(lits, &mut part.fired);
    }
    for (class, positive) in targets.into_iter().flatten() {
        if part.index.is_none() {
            // Packed evaluation of this class's owned clauses only —
            // evaluation consumes no RNG, so engines stay in lockstep.
            part.fired.clear();
            part.fired.resize(part.clauses.len(), false);
            for (k, oc) in part.clauses.iter().enumerate() {
                if oc.class == class {
                    part.fired[k] = oc.state.fires_packed(words);
                }
            }
        }
        let mut contrib = 0i32;
        for (k, oc) in part.clauses.iter().enumerate() {
            if oc.class == class && part.fired[k] {
                contrib += if oc.slot % 2 == 0 { 1 } else { -1 };
            }
        }
        let sum = publish_and_read(votes, &mut ctx.last, class, contrib).clamp(-t, t);
        let p_update = if positive {
            (t - sum) as f64 / (2 * t) as f64
        } else {
            (t + sum) as f64 / (2 * t) as f64
        };
        let mut index = part.index.take();
        for (k, oc) in part.clauses.iter_mut().enumerate() {
            if oc.class != class {
                continue;
            }
            if !ctx.rng.chance(p_update) {
                continue;
            }
            let fired = part.fired[k];
            if index.is_some() {
                ctx.old_words.clear();
                ctx.old_words.extend_from_slice(oc.state.include_words());
            }
            let positive_clause = oc.slot % 2 == 0;
            let touched = if positive == positive_clause {
                type_i(&mut oc.state, lits, fired, n, s, &mut ctx.rng);
                true
            } else if fired {
                type_ii(&mut oc.state, lits, n);
                true
            } else {
                false
            };
            if touched {
                if let Some(idx) = index.as_mut() {
                    idx.apply_diff(k as u32, &ctx.old_words, oc.state.include_words());
                }
            }
        }
        part.index = index;
    }
}

/// One (worker, sample) step of the CoTM trainer. Every class update
/// touches *all* owned clauses, and the reference trainer re-evaluates
/// clause outputs per class update (the positive update's feedback
/// changes the shared pool before the negative update) — so evaluation
/// runs once per class update here, not once per sample.
fn step_co(
    p: &TmParams,
    part: &mut Partition,
    ctx: &mut WorkerCtx,
    votes: &[AtomicI32],
    lits: &[bool],
    words: &[u64],
    y: usize,
) {
    let (n, s, t) = (p.ta_states, p.specificity, p.threshold);
    let wmax = p.max_weight;
    let targets = sample_targets(p.classes, y, &mut ctx.neg_rng);
    for (class, positive) in targets.into_iter().flatten() {
        if let Some(index) = part.index.as_mut() {
            index.fired_flags(lits, &mut part.fired);
        } else {
            part.fired.clear();
            for oc in &part.clauses {
                part.fired.push(oc.state.fires_packed(words));
            }
        }
        let mut contrib = 0i32;
        for (k, oc) in part.clauses.iter().enumerate() {
            if part.fired[k] {
                contrib += oc.weights[class];
            }
        }
        let sum = publish_and_read(votes, &mut ctx.last, class, contrib).clamp(-t, t);
        let p_update = if positive {
            (t - sum) as f64 / (2 * t) as f64
        } else {
            (t + sum) as f64 / (2 * t) as f64
        };
        let mut index = part.index.take();
        for (k, oc) in part.clauses.iter_mut().enumerate() {
            if !ctx.rng.chance(p_update) {
                continue;
            }
            let fired = part.fired[k];
            let w = oc.weights[class]; // pre-update sign decides the role
            if index.is_some() {
                ctx.old_words.clear();
                ctx.old_words.extend_from_slice(oc.state.include_words());
            }
            let touched = if positive {
                if fired {
                    oc.weights[class] = (w + 1).min(wmax);
                    if w >= 0 {
                        type_i(&mut oc.state, lits, true, n, s, &mut ctx.rng);
                    } else {
                        type_ii(&mut oc.state, lits, n);
                    }
                    true
                } else if w >= 0 {
                    type_i(&mut oc.state, lits, false, n, s, &mut ctx.rng);
                    true
                } else {
                    false
                }
            } else if fired {
                oc.weights[class] = (w - 1).max(-wmax);
                if w > 0 {
                    type_ii(&mut oc.state, lits, n);
                } else {
                    type_i(&mut oc.state, lits, true, n, s, &mut ctx.rng);
                }
                true
            } else if w < 0 {
                type_i(&mut oc.state, lits, false, n, s, &mut ctx.rng);
                true
            } else {
                false
            };
            if touched {
                if let Some(idx) = index.as_mut() {
                    idx.apply_diff(k as u32, &ctx.old_words, oc.state.include_words());
                }
            }
        }
        part.index = index;
    }
}

/// Partition-join conservation check: after every worker has joined,
/// the shared accumulators must equal the sum of the workers' final
/// published contributions. A lost update on a partition boundary
/// (two workers clobbering one cell) shows up as an inequality here.
/// The `Acquire` loads pair with the `thread::scope` join that already
/// happened; all vote *traffic* is Relaxed (module snapshot contract).
fn join_votes(votes: &[AtomicI32], finals: &[Vec<i32>]) -> Result<()> {
    for (c, vote) in votes.iter().enumerate() {
        let got = vote.load(Ordering::Acquire);
        let want: i32 = finals.iter().map(|f| f[c]).sum();
        if got != want {
            return Err(Error::model(format!(
                "async trainer lost updates: class {c} votes {got} != joined {want}"
            )));
        }
    }
    Ok(())
}

/// Run one epoch over the partitions: threaded (`std::thread::scope`,
/// nondeterministic) or deterministic (sample-major round-robin replay
/// of the identical step sequence).
fn run_epoch(
    params: &TmParams,
    parts: &mut [Partition],
    seed: u64,
    epoch: u64,
    xs: &[Vec<bool>],
    ys: &[usize],
    deterministic: bool,
    step: StepFn,
) -> Result<()> {
    if xs.len() != ys.len() {
        return Err(Error::model("training features/labels length mismatch"));
    }
    let mut order: Vec<usize> = (0..xs.len()).collect();
    SplitMix64::new(stream_seed(seed, epoch, LANE_ORDER)).shuffle(&mut order);
    let lits_all: Vec<Vec<bool>> = xs.iter().map(|x| make_literals(x)).collect();
    let words_all: Vec<Vec<u64>> = xs.iter().map(|x| pack_literals(x)).collect();
    let votes: Vec<AtomicI32> = (0..params.classes).map(|_| AtomicI32::new(0)).collect();
    let finals: Vec<Vec<i32>> = if deterministic {
        let mut ctxs: Vec<WorkerCtx> = (0..parts.len())
            .map(|w| WorkerCtx::new(seed, epoch, w, params.classes))
            .collect();
        for &i in &order {
            for (w, part) in parts.iter_mut().enumerate() {
                step(params, part, &mut ctxs[w], &votes, &lits_all[i], &words_all[i], ys[i]);
            }
        }
        ctxs.into_iter().map(|c| c.last).collect()
    } else {
        let (order, lits_all, words_all, votes_ref) =
            (&order, &lits_all, &words_all, &votes);
        thread::scope(|scope| {
            let handles: Vec<_> = parts
                .iter_mut()
                .enumerate()
                .map(|(w, part)| {
                    scope.spawn(move || {
                        let mut ctx = WorkerCtx::new(seed, epoch, w, params.classes);
                        for &i in order {
                            step(params, part, &mut ctx, votes_ref, &lits_all[i], &words_all[i], ys[i]);
                        }
                        ctx.last
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .map_err(|_| Error::model("async trainer worker panicked"))
                })
                .collect::<Result<Vec<Vec<i32>>>>()
        })?
    };
    join_votes(&votes, &finals)
}

fn validate_async(params: &TmParams, threads: usize) -> Result<()> {
    params.validate()?;
    if threads == 0 {
        return Err(Error::config("async trainer needs at least 1 thread"));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// The trainers.
// ---------------------------------------------------------------------

/// Clause-parallel multi-class trainer (see the module contract).
pub struct AsyncMultiClassTrainer {
    params: TmParams,
    seed: u64,
    epochs_run: u64,
    parts: Vec<Partition>,
}

impl AsyncMultiClassTrainer {
    pub fn new(
        params: TmParams,
        seed: u64,
        threads: usize,
        indexed: bool,
    ) -> Result<AsyncMultiClassTrainer> {
        validate_async(&params, threads)?;
        if params.clauses % 2 != 0 {
            return Err(Error::model(format!(
                "multi-class TM needs an even clause count, got {}",
                params.clauses
            )));
        }
        let n = params.ta_states;
        let literals = params.literals();
        let mut rng = SplitMix64::new(seed);
        let mut parts: Vec<Partition> = (0..threads)
            .map(|_| Partition { clauses: Vec::new(), index: None, fired: Vec::new() })
            .collect();
        for class in 0..params.classes {
            for slot in 0..params.clauses {
                let state = ClauseState::init(literals, n, &mut rng);
                parts[slot % threads].clauses.push(OwnedClause {
                    class,
                    slot,
                    state,
                    weights: Vec::new(),
                });
            }
        }
        if indexed {
            for part in &mut parts {
                part.rebuild_index(literals);
            }
        }
        Ok(AsyncMultiClassTrainer { params, seed, epochs_run: 0, parts })
    }

    pub fn threads(&self) -> usize {
        self.parts.len()
    }

    /// One threaded (nondeterministic) epoch.
    pub fn epoch(&mut self, xs: &[Vec<bool>], ys: &[usize]) -> Result<()> {
        self.run(xs, ys, false)
    }

    /// One deterministic round-robin epoch (the mirrored contract).
    pub fn epoch_deterministic(&mut self, xs: &[Vec<bool>], ys: &[usize]) -> Result<()> {
        self.run(xs, ys, true)
    }

    fn run(&mut self, xs: &[Vec<bool>], ys: &[usize], deterministic: bool) -> Result<()> {
        run_epoch(
            &self.params,
            &mut self.parts,
            self.seed,
            self.epochs_run,
            xs,
            ys,
            deterministic,
            step_mc,
        )?;
        self.epochs_run += 1;
        Ok(())
    }

    /// Train with threaded epochs, check invariants, export.
    pub fn train(
        &mut self,
        xs: &[Vec<bool>],
        ys: &[usize],
        epochs: usize,
    ) -> Result<MultiClassTmModel> {
        for _ in 0..epochs {
            self.epoch(xs, ys)?;
        }
        self.check_invariants()?;
        Ok(self.export())
    }

    /// Train with deterministic epochs (golden-vector path).
    pub fn train_deterministic(
        &mut self,
        xs: &[Vec<bool>],
        ys: &[usize],
        epochs: usize,
    ) -> Result<MultiClassTmModel> {
        for _ in 0..epochs {
            self.epoch_deterministic(xs, ys)?;
        }
        self.check_invariants()?;
        Ok(self.export())
    }

    /// Scatter the owned clauses back into model (class, slot) order.
    pub fn export(&self) -> MultiClassTmModel {
        let n = self.params.ta_states;
        let mut model = MultiClassTmModel::zeroed(self.params.clone());
        for part in &self.parts {
            for oc in &part.clauses {
                model.clauses[oc.class][oc.slot] = oc.state.include_mask(n);
            }
        }
        model
    }

    /// TA bounds, incremental-mask coherence, and (indexed) index
    /// coherence across every partition.
    pub fn check_invariants(&self) -> Result<()> {
        for part in &self.parts {
            part.check(self.params.ta_states)?;
        }
        Ok(())
    }
}

/// Clause-parallel coalesced trainer. Weight column `j` travels with
/// clause `j`: the owning worker is the only writer of both.
pub struct AsyncCoTmTrainer {
    params: TmParams,
    seed: u64,
    epochs_run: u64,
    parts: Vec<Partition>,
}

impl AsyncCoTmTrainer {
    pub fn new(
        params: TmParams,
        seed: u64,
        threads: usize,
        indexed: bool,
    ) -> Result<AsyncCoTmTrainer> {
        validate_async(&params, threads)?;
        let n = params.ta_states;
        let literals = params.literals();
        let mut rng = SplitMix64::new(seed);
        let mut parts: Vec<Partition> = (0..threads)
            .map(|_| Partition { clauses: Vec::new(), index: None, fired: Vec::new() })
            .collect();
        for slot in 0..params.clauses {
            let state = ClauseState::init(literals, n, &mut rng);
            // Weights start at +/-1 alternating per class (symmetry
            // breaking), exactly the deterministic trainer's init.
            let weights = (0..params.classes)
                .map(|k| if (slot + k) % 2 == 0 { 1 } else { -1 })
                .collect();
            parts[slot % threads].clauses.push(OwnedClause { class: 0, slot, state, weights });
        }
        if indexed {
            for part in &mut parts {
                part.rebuild_index(literals);
            }
        }
        Ok(AsyncCoTmTrainer { params, seed, epochs_run: 0, parts })
    }

    pub fn threads(&self) -> usize {
        self.parts.len()
    }

    pub fn epoch(&mut self, xs: &[Vec<bool>], ys: &[usize]) -> Result<()> {
        self.run(xs, ys, false)
    }

    pub fn epoch_deterministic(&mut self, xs: &[Vec<bool>], ys: &[usize]) -> Result<()> {
        self.run(xs, ys, true)
    }

    fn run(&mut self, xs: &[Vec<bool>], ys: &[usize], deterministic: bool) -> Result<()> {
        run_epoch(
            &self.params,
            &mut self.parts,
            self.seed,
            self.epochs_run,
            xs,
            ys,
            deterministic,
            step_co,
        )?;
        self.epochs_run += 1;
        Ok(())
    }

    pub fn train(
        &mut self,
        xs: &[Vec<bool>],
        ys: &[usize],
        epochs: usize,
    ) -> Result<CoTmModel> {
        for _ in 0..epochs {
            self.epoch(xs, ys)?;
        }
        self.check_invariants()?;
        Ok(self.export())
    }

    pub fn train_deterministic(
        &mut self,
        xs: &[Vec<bool>],
        ys: &[usize],
        epochs: usize,
    ) -> Result<CoTmModel> {
        for _ in 0..epochs {
            self.epoch_deterministic(xs, ys)?;
        }
        self.check_invariants()?;
        Ok(self.export())
    }

    pub fn export(&self) -> CoTmModel {
        let n = self.params.ta_states;
        let mut model = CoTmModel::zeroed(self.params.clone());
        for part in &self.parts {
            for oc in &part.clauses {
                model.clauses[oc.slot] = oc.state.include_mask(n);
                for (k, &w) in oc.weights.iter().enumerate() {
                    model.weights[k][oc.slot] = w;
                }
            }
        }
        model
    }

    pub fn check_invariants(&self) -> Result<()> {
        for part in &self.parts {
            part.check(self.params.ta_states)?;
            for oc in &part.clauses {
                if let Some(&bad) =
                    oc.weights.iter().find(|w| w.abs() > self.params.max_weight)
                {
                    return Err(Error::model(format!(
                        "CoTM weight {bad} outside +/-{}",
                        self.params.max_weight
                    )));
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Dataset-level conveniences (CLI / selfcheck / bench entry points).
// ---------------------------------------------------------------------

/// Train a multi-class TM with the async tier (threaded epochs).
pub fn train_multiclass_async(
    params: TmParams,
    d: &Dataset,
    epochs: usize,
    seed: u64,
    threads: usize,
    indexed: bool,
) -> Result<MultiClassTmModel> {
    let mut tr = AsyncMultiClassTrainer::new(params, seed, threads, indexed)?;
    tr.train(&d.features, &d.labels, epochs)
}

/// Train a CoTM with the async tier (threaded epochs).
pub fn train_cotm_async(
    params: TmParams,
    d: &Dataset,
    epochs: usize,
    seed: u64,
    threads: usize,
    indexed: bool,
) -> Result<CoTmModel> {
    let mut tr = AsyncCoTmTrainer::new(params, seed, threads, indexed)?;
    tr.train(&d.features, &d.labels, epochs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;
    use crate::tm::model::ClauseMask;

    /// Closed-form dataset shared verbatim with the Python tests.
    fn synth(f: usize, n_samples: usize, classes: usize) -> Dataset {
        let features = (0..n_samples)
            .map(|s| (0..f).map(|i| (i * i + 3 * i * s + 2 * s) % 7 < 3).collect())
            .collect();
        let labels = (0..n_samples).map(|s| s % classes).collect();
        Dataset { features, labels, classes, name: "synth".into() }
    }

    fn mask_bits(m: &ClauseMask) -> String {
        m.include.iter().map(|&b| if b { '1' } else { '0' }).collect()
    }

    fn mc_params() -> TmParams {
        TmParams {
            features: 5,
            clauses: 4,
            classes: 2,
            ta_states: 8,
            threshold: 3,
            specificity: 3.0,
            max_weight: 7,
        }
    }

    fn co_params() -> TmParams {
        TmParams {
            features: 5,
            clauses: 5,
            classes: 3,
            ta_states: 8,
            threshold: 3,
            specificity: 3.0,
            max_weight: 3,
        }
    }

    #[test]
    fn trainer_choice_parse_names() {
        assert_eq!(TrainerChoice::parse("packed"), Some(TrainerChoice::Packed));
        assert_eq!(TrainerChoice::parse("reference"), Some(TrainerChoice::Reference));
        assert_eq!(TrainerChoice::parse("ref"), Some(TrainerChoice::Reference));
        assert_eq!(TrainerChoice::parse("async"), Some(TrainerChoice::Async));
        assert_eq!(
            TrainerChoice::parse("async-indexed"),
            Some(TrainerChoice::AsyncIndexed)
        );
        assert_eq!(TrainerChoice::parse("golden"), None);
        assert_eq!(TrainerChoice::default(), TrainerChoice::Packed);
        assert_eq!(TrainerChoice::Async.name(), "async");
        assert_eq!(TrainerChoice::AsyncIndexed.name(), "async-indexed");
        assert!(TrainerChoice::Async.is_async() && !TrainerChoice::Packed.is_async());
        assert!(TrainerChoice::AsyncIndexed.indexed() && !TrainerChoice::Async.indexed());
        assert_eq!(TrainerChoice::Packed.engine(), Some(TrainerEngine::Packed));
        assert_eq!(TrainerChoice::Reference.engine(), Some(TrainerEngine::Reference));
        assert_eq!(TrainerChoice::Async.engine(), None);
    }

    #[test]
    fn stream_seed_matches_python_mirror() {
        // Pinned identically in python/tests/test_asynctrain.py
        // (GOLDEN_STREAMS); the r5 probe compares the constants.
        let golden_streams = [
            0x57E1_FABA_6510_7204u64, // stream_seed(42, 0, 0)
            0x0778_2989_815C_29E4,    // stream_seed(42, 0, 1)
            0x98B3_AA39_0587_5FB8,    // stream_seed(42, 0, 2)
            0xE704_EB6B_C0A1_009A,    // stream_seed(42, 0, 3)
            0x5A0E_CCCE_1EDF_2C68,    // stream_seed(42, 1, 0)
            0x8C74_E472_FFA0_9510,    // stream_seed(42, 2, 5)
            0xBCBA_FD09_516C_DD67,    // stream_seed(7, 0, 2)
            0x4A03_5AA2_D920_6AF7,    // stream_seed(9, 3, 4)
        ];
        let triples =
            [(42, 0, 0), (42, 0, 1), (42, 0, 2), (42, 0, 3), (42, 1, 0), (42, 2, 5), (7, 0, 2), (9, 3, 4)];
        for ((seed, epoch, lane), want) in triples.into_iter().zip(golden_streams) {
            assert_eq!(
                stream_seed(seed, epoch, lane),
                want,
                "stream_seed({seed}, {epoch}, {lane})"
            );
        }
        // Distinct lanes/epochs give distinct streams on the goldens.
        let mut seen: Vec<u64> = golden_streams.to_vec();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), golden_streams.len());
    }

    #[test]
    fn async_multiclass_golden_matches_python_mirror() {
        // threads=2, deterministic schedule, 3 epochs, seed 42 —
        // generated by python/asynctrain.py, asserted identically in
        // python/tests/test_asynctrain.py (GOLDEN_ASYNC_MC_MASKS).
        let golden_async = [
            ["0010001001", "0000100001", "0000110000", "0100110000"], // class 0
            ["0000110000", "0110101010", "0000000000", "1001000001"], // class 1
        ];
        let d = synth(5, 12, 2);
        for indexed in [false, true] {
            let mut tr = AsyncMultiClassTrainer::new(mc_params(), 42, 2, indexed).unwrap();
            let m = tr.train_deterministic(&d.features, &d.labels, 3).unwrap();
            for (k, class) in m.clauses.iter().enumerate() {
                for (j, cl) in class.iter().enumerate() {
                    assert_eq!(
                        mask_bits(cl),
                        golden_async[k][j],
                        "indexed={indexed} class {k} clause {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn async_cotm_golden_matches_python_mirror() {
        // threads=2, deterministic schedule, 3 epochs, seed 43 —
        // shared with test_asynctrain.py (GOLDEN_ASYNC_CO_*).
        let golden_async_co = [
            "0000000001",
            "1000000100",
            "0000001100",
            "0000010010",
            "0100010100",
        ];
        let golden_async_co_weights = vec![
            vec![1, -2, 2, -1, 2],
            vec![0, 1, 0, 0, -1],
            vec![0, 0, 1, 0, 0],
        ];
        let d = synth(5, 12, 3);
        for indexed in [false, true] {
            let mut tr = AsyncCoTmTrainer::new(co_params(), 43, 2, indexed).unwrap();
            let m = tr.train_deterministic(&d.features, &d.labels, 3).unwrap();
            for (j, cl) in m.clauses.iter().enumerate() {
                assert_eq!(mask_bits(cl), golden_async_co[j], "indexed={indexed} clause {j}");
            }
            assert_eq!(m.weights, golden_async_co_weights, "indexed={indexed}");
        }
    }

    #[test]
    fn threads_one_threaded_equals_deterministic() {
        // With a single worker the threaded schedule degenerates to the
        // deterministic one — same step sequence, same model, bit for
        // bit. This pins the threaded code path to the mirrored contract.
        let d = synth(6, 14, 2);
        let p = TmParams { features: 6, ..mc_params() };
        let mut threaded = AsyncMultiClassTrainer::new(p.clone(), 11, 1, false).unwrap();
        let mut replay = AsyncMultiClassTrainer::new(p, 11, 1, false).unwrap();
        let a = threaded.train(&d.features, &d.labels, 3).unwrap();
        let b = replay.train_deterministic(&d.features, &d.labels, 3).unwrap();
        assert_eq!(a, b);
        let dc = synth(6, 14, 3);
        let pc = TmParams { features: 6, ..co_params() };
        let mut threaded = AsyncCoTmTrainer::new(pc.clone(), 11, 1, true).unwrap();
        let mut replay = AsyncCoTmTrainer::new(pc, 11, 1, true).unwrap();
        let a = threaded.train(&dc.features, &dc.labels, 3).unwrap();
        let b = replay.train_deterministic(&dc.features, &dc.labels, 3).unwrap();
        assert_eq!(a.clauses, b.clauses);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn indexed_equals_packed_under_deterministic_schedule() {
        // Evaluation is exact, so the two async engines are bit-identical
        // whenever the schedule is — across shapes and thread counts.
        prop("async indexed vs packed", 12, |g| {
            let f = g.usize(1..12);
            let clauses = 2 * g.usize(1..5);
            let classes = g.usize(1..4);
            let threads = g.usize(1..5);
            let seed = g.u64(0..1 << 40);
            let p = TmParams {
                features: f,
                clauses,
                classes,
                ta_states: 8,
                threshold: 3,
                specificity: 3.0,
                max_weight: 3,
            };
            let d = synth(f, 10, classes);
            let mut packed =
                AsyncMultiClassTrainer::new(p.clone(), seed, threads, false).unwrap();
            let mut indexed =
                AsyncMultiClassTrainer::new(p.clone(), seed, threads, true).unwrap();
            let a = packed.train_deterministic(&d.features, &d.labels, 2).unwrap();
            let b = indexed.train_deterministic(&d.features, &d.labels, 2).unwrap();
            assert_eq!(a, b, "multiclass f={f} threads={threads}");
            let mut packed = AsyncCoTmTrainer::new(p.clone(), seed, threads, false).unwrap();
            let mut indexed = AsyncCoTmTrainer::new(p, seed, threads, true).unwrap();
            let a = packed.train_deterministic(&d.features, &d.labels, 2).unwrap();
            let b = indexed.train_deterministic(&d.features, &d.labels, 2).unwrap();
            assert_eq!(a.clauses, b.clauses, "cotm f={f} threads={threads}");
            assert_eq!(a.weights, b.weights, "cotm f={f} threads={threads}");
        });
    }

    #[test]
    fn concurrency_invariants_hold_after_threaded_epochs() {
        // The real (racing) schedule: TA counters stay in bounds, the
        // incremental include masks equal a recompute after join, the
        // per-worker indexes stay coherent, and join_votes' conservation
        // law holds (a lost update fails the epoch itself).
        for threads in [2, 3, 8] {
            for indexed in [false, true] {
                let d = synth(7, 20, 3);
                let p = TmParams {
                    features: 7,
                    clauses: 8,
                    classes: 3,
                    ta_states: 16,
                    threshold: 4,
                    specificity: 3.0,
                    max_weight: 4,
                };
                let mut tr =
                    AsyncMultiClassTrainer::new(p.clone(), 99, threads, indexed).unwrap();
                tr.train(&d.features, &d.labels, 4).unwrap();
                tr.check_invariants().unwrap();
                let mut co = AsyncCoTmTrainer::new(p, 99, threads, indexed).unwrap();
                co.train(&d.features, &d.labels, 4).unwrap();
                co.check_invariants().unwrap();
            }
        }
    }

    #[test]
    fn more_threads_than_clauses_leaves_empty_partitions_working() {
        let d = synth(4, 8, 2);
        let p = TmParams { features: 4, clauses: 2, ..mc_params() };
        let mut tr = AsyncMultiClassTrainer::new(p, 3, 6, true).unwrap();
        assert_eq!(tr.threads(), 6);
        let m = tr.train(&d.features, &d.labels, 2).unwrap();
        m.validate().unwrap();
        tr.check_invariants().unwrap();
    }

    #[test]
    fn rejects_invalid_configurations() {
        assert!(AsyncMultiClassTrainer::new(mc_params(), 1, 0, false).is_err());
        let odd = TmParams { clauses: 3, ..mc_params() };
        assert!(AsyncMultiClassTrainer::new(odd, 1, 2, false).is_err());
        assert!(AsyncCoTmTrainer::new(co_params(), 1, 0, true).is_err());
        let mut tr = AsyncMultiClassTrainer::new(mc_params(), 1, 2, false).unwrap();
        let d = synth(5, 6, 2);
        assert!(tr.epoch(&d.features, &d.labels[..3]).is_err());
    }

    #[test]
    fn train_index_incremental_maintenance_matches_rebuild() {
        prop("train index diff coherence", 30, |g| {
            let f = g.usize(1..20);
            let n = 8u32;
            let mut rng = SplitMix64::new(g.u64(0..u64::MAX));
            let mut states: Vec<ClauseState> =
                (0..g.usize(1..6)).map(|_| ClauseState::init(2 * f, n, &mut rng)).collect();
            let mut index = TrainIndex::build(states.iter(), 2 * f);
            let mut flags = Vec::new();
            for _ in 0..40 {
                let x: Vec<bool> = (0..f).map(|_| g.bool()).collect();
                let lits = make_literals(&x);
                index.fired_flags(&lits, &mut flags);
                // Fired flags match direct training-time evaluation.
                for (ci, cl) in states.iter().enumerate() {
                    assert_eq!(flags[ci], cl.fires_reference(&lits, n), "clause {ci}");
                }
                // Random feedback, replayed into the index.
                let ci = g.usize(0..states.len());
                let old = states[ci].include_words().to_vec();
                if g.bool() {
                    type_i(&mut states[ci], &lits, g.bool(), n, 3.0, &mut rng);
                } else {
                    type_ii(&mut states[ci], &lits, n);
                }
                index.apply_diff(ci as u32, &old, states[ci].include_words());
                assert!(index.coherent(states.iter()));
            }
        });
    }
}
