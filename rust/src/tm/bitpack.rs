//! Word-packed representations for bit-parallel TM inference.
//!
//! A clause fires iff every *included* literal is 1 (`AND` over the
//! included literals). Packing the 2F interleaved literals and each
//! clause's include mask into `u64` words turns that reduction into
//! `include & !literals == 0` checked word-by-word — 64 literals per
//! instruction instead of a per-literal `bool` loop — the word-level
//! trick from "Fast and Compact Tsetlin Machine Inference on CPUs"
//! (arXiv 2510.15653).
//!
//! Two complementary layouts:
//!
//! * **Literal-major single sample** ([`pack_literals`] +
//!   [`PackedClause::evaluate`]): one sample's 2F literals as
//!   `ceil(2F/64)` words; each clause keeps a skip list of its non-zero
//!   include words so sparse clauses touch only the words they
//!   constrain (the clause-indexing idea of arXiv 2004.03188 applied at
//!   word granularity). Dense clauses instead sweep the whole span
//!   through [`super::simd::WordLanes`]
//!   ([`PackedClause::evaluate_with`]).
//! * **Tiled sample-major batch** ([`BitSlicedBatch`] +
//!   [`PackedClause::evaluate_tile`]): a bit-sliced transpose where bit
//!   `s % 64` of a literal's block word holds that literal's value for
//!   sample `s`, organised as **cache-blocked tiles** of
//!   [`TILE_BLOCKS`] sample blocks. Within a tile the layout is
//!   literal-major — literal `l`'s [`TILE_BLOCKS`] lane words are
//!   contiguous, so one [`super::simd::WordLanes`] op ANDs 4–8 blocks —
//!   and evaluation is **clause-major within a tile, samples-block-major
//!   across tiles**: every clause is evaluated against tile `t` before
//!   anyone touches tile `t+1`, keeping the working set at
//!   `2F × TILE_BLOCKS` words (cache-resident) however large the batch
//!   grows. This is the batch layout of the massively-parallel TM
//!   architecture (arXiv 2009.04861) adapted to CPU cache lines.
//!   [`PackedClause::evaluate_batch`] keeps the historic one-word-
//!   per-op walk over the same tiles as the single-word reference (and
//!   the `simd = "scalar"` serving path).
//!
//! Semantics are pinned to the scalar reference
//! ([`ClauseMask::evaluate`]): an **empty clause** (all-exclude mask —
//! which is also what a zero-feature clause degenerates to) outputs 0
//! at inference, even though the AND-of-nothing reading would be
//! "always include ⇒ always fire". The conformance suite
//! (`tests/bitparallel_equivalence.rs`) holds every path to bit-exact
//! agreement with the reference, so this convention is load-bearing.
//!
//! The tile geometry (stride, tile count, word indexing) is mirrored
//! bit-for-bit by `python/simdtile.py`; the golden vectors in the tests
//! below are asserted identically in `python/tests/test_simdtile.py`,
//! so toolchain-less CI still validates the layout math.

use super::model::ClauseMask;
use super::simd::{self, WordLanes};

/// Bits per packed word.
pub const WORD_BITS: usize = 64;

/// Sample blocks per cache tile of a [`BitSlicedBatch`]: 8 blocks =
/// 512 samples, and one tile's working set is `2F × 8` words (16 KiB
/// at F = 128) — sized so a whole tile stays cache-resident while every
/// clause walks it. 8 is also one AVX-512 op or two AVX2/portable
/// unrolled steps per literal.
pub const TILE_BLOCKS: usize = 8;

/// Number of `u64` words needed to hold `bits` bits.
pub fn words_for(bits: usize) -> usize {
    bits.div_ceil(WORD_BITS)
}

/// Minimum non-zero include words before a lane sweep can beat the
/// skip-list walk (below this, even a full-span clause fits in a
/// handful of scalar ops).
pub const LANE_SWEEP_MIN_NONZERO: usize = 8;

/// The skip-list-vs-lane-sweep rule, shared between the compile pass
/// (`super::compile::plan_for_mask`, which records the decision per
/// clause) and the packed fallback in [`PackedClause::from_mask`]:
/// sweep the whole span iff at least [`LANE_SWEEP_MIN_NONZERO`] include
/// words are non-zero *and* they cover at least half the span — either
/// way the predicate is identical, because skipped words are all-zero
/// and can never violate.
#[inline]
pub fn prefers_lane_sweep(nonzero_words: usize, words: usize) -> bool {
    nonzero_words >= LANE_SWEEP_MIN_NONZERO && 2 * nonzero_words >= words
}

/// Pack a bool slice into little-endian words: element `i` lands in bit
/// `i % 64` of word `i / 64`. Tail padding bits are zero.
pub fn pack_bools(bits: &[bool]) -> Vec<u64> {
    let mut words = vec![0u64; words_for(bits.len())];
    for (i, &b) in bits.iter().enumerate() {
        if b {
            words[i / WORD_BITS] |= 1u64 << (i % WORD_BITS);
        }
    }
    words
}

/// Pack one sample's interleaved literals (`lit[2i] = x_i`,
/// `lit[2i+1] = ¬x_i`) directly from the feature vector, skipping the
/// intermediate `Vec<bool>` that [`super::model::make_literals`] builds.
/// Exactly one of each literal pair is set, so tail padding (when
/// `2F % 64 != 0`) stays zero.
pub fn pack_literals(features: &[bool]) -> Vec<u64> {
    let mut words = vec![0u64; words_for(2 * features.len())];
    for (i, &f) in features.iter().enumerate() {
        let pos = 2 * i + usize::from(!f);
        words[pos / WORD_BITS] |= 1u64 << (pos % WORD_BITS);
    }
    words
}

/// Evaluate raw include words against packed literals with
/// **training-time semantics**: fires iff `include & !literals == 0`
/// in every word, so an all-zero include mask (empty clause) is
/// vacuously true and *fires*. This is deliberately the opposite of
/// [`PackedClause::evaluate`]'s inference convention — during training
/// an empty clause must fire to receive Type I feedback and grow. Used
/// by the trainer engine's incrementally-maintained masks
/// (`super::trainer_engine::ClauseState`).
///
/// Evaluates through the process-wide detected
/// [`WordLanes`](super::simd::WordLanes) — every lane width computes
/// the identical predicate (`tests/simd_dispatch.rs` diffs them), so
/// the trainer bit-identity contract is unaffected by dispatch.
#[inline]
pub fn eval_words_train(include: &[u64], literal_words: &[u64]) -> bool {
    eval_words_train_with(include, literal_words, simd::default_lanes())
}

/// [`eval_words_train`] at an explicit lane width (the forced-portable
/// parity suites pin every level to the same answer).
#[inline]
pub fn eval_words_train_with(
    include: &[u64],
    literal_words: &[u64],
    lanes: WordLanes,
) -> bool {
    debug_assert_eq!(include.len(), literal_words.len());
    !lanes.violates(include, literal_words)
}

/// One clause's include mask, packed for both evaluation layouts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedClause {
    /// Include mask over 2F literals, zero-padded to word width.
    pub include: Vec<u64>,
    /// Indices of non-zero `include` words (skip list): sparse clauses
    /// constrain few words, so only those are checked per sample.
    pub nonzero_words: Vec<u32>,
    /// Sorted indices of the included literals (for the batched path).
    pub literals: Vec<u32>,
    /// Single-sample execution plan: `true` = whole-span lane sweep,
    /// `false` = skip-list walk. Defaulted from [`prefers_lane_sweep`]
    /// by [`Self::from_mask`]; the compile pass overrides it per clause
    /// via [`Self::with_lane_sweep`].
    pub lane_sweep: bool,
}

impl PackedClause {
    /// Pack a [`ClauseMask`] (include mask over the 2F interleaved
    /// literals). The execution plan defaults to the shared
    /// [`prefers_lane_sweep`] rule on this mask's word density.
    pub fn from_mask(mask: &ClauseMask) -> PackedClause {
        let include = pack_bools(&mask.include);
        let nonzero_words: Vec<u32> = include
            .iter()
            .enumerate()
            .filter(|(_, &w)| w != 0)
            .map(|(i, _)| i as u32)
            .collect();
        let literals = mask
            .include
            .iter()
            .enumerate()
            .filter(|(_, &b)| b)
            .map(|(i, _)| i as u32)
            .collect();
        let lane_sweep = prefers_lane_sweep(nonzero_words.len(), include.len());
        PackedClause { include, nonzero_words, literals, lane_sweep }
    }

    /// Override the execution plan (the compile pass records one per
    /// clause; engines built `from_compiled` honor it here). Either
    /// plan computes the identical predicate.
    pub fn with_lane_sweep(mut self, lane_sweep: bool) -> PackedClause {
        self.lane_sweep = lane_sweep;
        self
    }

    /// Empty clause = all-exclude mask (fires never, matching the
    /// reference's inference convention).
    pub fn is_empty(&self) -> bool {
        self.nonzero_words.is_empty()
    }

    pub fn included_count(&self) -> usize {
        self.literals.len()
    }

    /// Evaluate against one packed literal vector ([`pack_literals`]):
    /// fires iff `include & !literals == 0` in every non-zero word.
    /// The single-word reference walk; [`PackedClause::evaluate_with`]
    /// is the lane-dispatched variant.
    pub fn evaluate(&self, literal_words: &[u64]) -> bool {
        if self.is_empty() {
            return false;
        }
        self.nonzero_words.iter().all(|&w| {
            let w = w as usize;
            self.include[w] & !literal_words[w] == 0
        })
    }

    /// Lane-dispatched single-sample evaluation, branching on the
    /// clause's recorded plan: sparse clauses keep the skip-list walk
    /// (they touch fewer words than any lane sweep would); dense ones
    /// sweep the whole span through `lanes` — identical answer either
    /// way, because the skipped words are all-zero and can never
    /// violate. The plan is decided once per clause ([`Self::from_mask`]
    /// default or the compile pass's override), not re-derived here.
    pub fn evaluate_with(&self, literal_words: &[u64], lanes: WordLanes) -> bool {
        if self.is_empty() {
            return false;
        }
        if self.lane_sweep {
            let words = self.include.len();
            !lanes.violates(&self.include, &literal_words[..words])
        } else {
            self.evaluate(literal_words)
        }
    }

    /// Evaluate 64 samples at once against one block of a
    /// [`BitSlicedBatch`]: returns a word with bit `s` = clause output
    /// for sample `blk*64 + s`. Padding sample bits come back 0 because
    /// their literal columns are all-zero (and empty clauses return 0
    /// outright). One `u64` per op with a branch per word — the
    /// single-word reference the SIMD tile path is diffed against.
    pub fn evaluate_batch(&self, batch: &BitSlicedBatch, blk: usize) -> u64 {
        if self.is_empty() {
            return 0;
        }
        let mut acc = !0u64;
        for &l in &self.literals {
            acc &= batch.lit_word(blk, l as usize);
            if acc == 0 {
                break;
            }
        }
        acc & batch.valid_mask(blk)
    }

    /// Evaluate one whole tile (up to [`TILE_BLOCKS`] × 64 samples) in
    /// lane-width steps: `out[j]` gets the clause-output word of the
    /// tile's block `j`. The accumulator starts all-ones, ANDs each
    /// included literal's contiguous lane words, and exits as soon as
    /// every lane goes dead. `out.len()` must be
    /// [`BitSlicedBatch::tile_blocks`]`(tile)`.
    pub fn evaluate_tile(
        &self,
        batch: &BitSlicedBatch,
        tile: usize,
        lanes: WordLanes,
        out: &mut [u64],
    ) {
        let tb = batch.tile_blocks(tile);
        debug_assert_eq!(out.len(), tb, "tile output width mismatch");
        if self.is_empty() {
            out.fill(0);
            return;
        }
        out.fill(!0u64);
        for &l in &self.literals {
            if !lanes.and_assign_any(out, batch.lit_lane(tile, l as usize)) {
                return; // every lane dead — out is all zeros already
            }
        }
        // Padding bits of the batch's final partial block are already 0
        // (each AND above used zero-padded columns); the mask keeps the
        // invariant explicit and free.
        let last = tile * batch.tile_stride() + tb - 1;
        if last + 1 == batch.blocks {
            out[tb - 1] &= batch.valid_mask(last);
        }
    }
}

/// A batch of samples in tiled bit-sliced (sample-major) layout.
///
/// Samples are split into 64-wide *blocks* (bit `s % 64` of a block
/// word) and blocks into tiles of [`TILE_BLOCKS`]; within tile `t`, the
/// lane words of literal `l` for the tile's blocks are contiguous:
///
/// ```text
/// word(blk, l) = data[(blk / stride) * 2F * stride   // tile base
///                     + l * stride                   // literal lane
///                     + blk % stride]                // block in tile
/// ```
///
/// where `stride = min(blocks, TILE_BLOCKS)` (small batches don't pad
/// out to a full tile). Mirrored bit-for-bit by `python/simdtile.py`.
#[derive(Debug, Clone)]
pub struct BitSlicedBatch {
    /// `tiles * 2F * stride` words, tile-major, literal-major within a
    /// tile. Words past the last block of the final tile stay zero.
    data: Vec<u64>,
    /// Boolean input features per sample (F).
    pub features: usize,
    /// Samples in the batch.
    pub samples: usize,
    /// `ceil(samples / 64)` sample blocks across the whole batch.
    pub blocks: usize,
    /// Blocks per tile (`min(blocks, TILE_BLOCKS)`).
    stride: usize,
}

impl BitSlicedBatch {
    /// Transpose `rows` (each a length-F feature vector) into tiled
    /// bit-sliced literal lanes. Panics if a row width differs from
    /// `features` (callers validate widths at the serving boundary).
    pub fn pack<R: AsRef<[bool]>>(rows: &[R], features: usize) -> BitSlicedBatch {
        let samples = rows.len();
        let blocks = words_for(samples.max(1));
        let stride = blocks.min(TILE_BLOCKS);
        let tiles = blocks.div_ceil(stride);
        let lits = 2 * features;
        let mut data = vec![0u64; tiles * lits * stride];
        for (s, row) in rows.iter().enumerate() {
            let row = row.as_ref();
            assert_eq!(row.len(), features, "batch row width mismatch");
            let blk = s / WORD_BITS;
            let bit = 1u64 << (s % WORD_BITS);
            let base = (blk / stride) * lits * stride + blk % stride;
            for (i, &f) in row.iter().enumerate() {
                let lit = 2 * i + usize::from(!f);
                data[base + lit * stride] |= bit;
            }
        }
        BitSlicedBatch { data, features, samples, blocks, stride }
    }

    /// Blocks per tile (the lane width the tile evaluator walks).
    #[inline]
    pub fn tile_stride(&self) -> usize {
        self.stride
    }

    /// Number of tiles.
    #[inline]
    pub fn tiles(&self) -> usize {
        self.blocks.div_ceil(self.stride)
    }

    /// Blocks actually present in tile `t` (`stride` except a shorter
    /// final tile).
    #[inline]
    pub fn tile_blocks(&self, t: usize) -> usize {
        self.stride.min(self.blocks - t * self.stride)
    }

    /// The contiguous lane words of literal `l` in tile `t`
    /// ([`Self::tile_blocks`]`(t)` words).
    #[inline]
    pub fn lit_lane(&self, t: usize, l: usize) -> &[u64] {
        let base = (t * 2 * self.features + l) * self.stride;
        &self.data[base..base + self.tile_blocks(t)]
    }

    /// One literal's word for one global block index.
    #[inline]
    pub fn lit_word(&self, blk: usize, l: usize) -> u64 {
        let t = blk / self.stride;
        self.data[(t * 2 * self.features + l) * self.stride + blk % self.stride]
    }

    /// Raw tiled words (the Python mirror fingerprints these).
    pub fn raw_words(&self) -> &[u64] {
        &self.data
    }

    /// Mask of valid sample bits in block `blk` (all-ones except the
    /// final partial block).
    #[inline]
    pub fn valid_mask(&self, blk: usize) -> u64 {
        let used = self.samples - blk * WORD_BITS;
        if used >= WORD_BITS {
            !0
        } else {
            (1u64 << used) - 1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::model::make_literals;
    use crate::tm::simd::SimdLevel;

    fn mask(include: Vec<bool>) -> ClauseMask {
        ClauseMask { include }
    }

    #[test]
    fn words_for_boundaries() {
        assert_eq!(words_for(0), 0);
        assert_eq!(words_for(1), 1);
        assert_eq!(words_for(64), 1);
        assert_eq!(words_for(65), 2);
        assert_eq!(words_for(128), 2);
        assert_eq!(words_for(129), 3);
    }

    #[test]
    fn pack_literals_matches_pack_bools_of_make_literals() {
        // The direct packing must agree with the two-step reference
        // packing, including at the 64-literal (= 32-feature) boundary.
        for f in [1usize, 2, 31, 32, 33, 64, 65] {
            let feats: Vec<bool> = (0..f).map(|i| i % 3 == 0).collect();
            assert_eq!(
                pack_literals(&feats),
                pack_bools(&make_literals(&feats)),
                "features={f}"
            );
        }
    }

    #[test]
    fn features_64_and_65_boundary_packing() {
        // F=32 -> exactly one word of literals; F=33 -> 65 literals + a
        // tail word whose padding must be zero.
        let f32_feats = vec![true; 32];
        let w = pack_literals(&f32_feats);
        assert_eq!(w.len(), 1);
        // Every even bit set (x_i = 1), every odd bit clear (¬x_i = 0).
        assert_eq!(w[0], 0x5555_5555_5555_5555);

        let f33_feats = vec![true; 33];
        let w = pack_literals(&f33_feats);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0], 0x5555_5555_5555_5555);
        assert_eq!(w[1], 0b01, "only literal 64 (= x_32) set; padding zero");
    }

    #[test]
    fn training_eval_fires_empty_clauses_unlike_inference() {
        // The two conventions, side by side, on the same words: the
        // inference path (PackedClause) returns 0 for an all-exclude
        // clause; the training path (eval_words_train) fires it —
        // at every available lane width.
        let lits = pack_literals(&[true, false, true]);
        let empty = vec![0u64; lits.len()];
        assert!(eval_words_train(&empty, &lits));
        assert!(!PackedClause::from_mask(&mask(vec![false; 6])).evaluate(&lits));
        // Non-empty masks agree with the inference predicate.
        for inc_lit in 0..6usize {
            let mut inc = vec![false; 6];
            inc[inc_lit] = true;
            let pc = PackedClause::from_mask(&mask(inc));
            for level in SimdLevel::available() {
                let lanes = WordLanes::new(level).unwrap();
                assert_eq!(
                    eval_words_train_with(&pc.include, &lits, lanes),
                    pc.evaluate(&lits),
                    "literal {inc_lit} level {}",
                    level.name()
                );
            }
        }
    }

    #[test]
    fn all_exclude_mask_never_fires() {
        // Empty clause (all-exclude) outputs 0 at inference, exactly as
        // the scalar reference does — not the "AND of nothing is true"
        // reading. A zero-feature clause is the same degenerate case.
        let pc = PackedClause::from_mask(&mask(vec![false; 10]));
        assert!(pc.is_empty());
        assert!(!pc.evaluate(&pack_literals(&[true; 5])));
        assert!(!mask(vec![false; 10]).evaluate(&make_literals(&[true; 5])));

        let zero_feature = PackedClause::from_mask(&mask(Vec::new()));
        assert!(zero_feature.is_empty());
        assert!(!zero_feature.evaluate(&[]));
    }

    #[test]
    fn skip_list_only_names_nonzero_words() {
        // 2F = 192 literals, includes only in the last word.
        let mut inc = vec![false; 192];
        inc[130] = true;
        inc[191] = true;
        let pc = PackedClause::from_mask(&mask(inc));
        assert_eq!(pc.include.len(), 3);
        assert_eq!(pc.nonzero_words, vec![2]);
        assert_eq!(pc.literals, vec![130, 191]);
        assert_eq!(pc.included_count(), 2);
    }

    #[test]
    fn packed_evaluate_matches_scalar_on_word_boundary_literals() {
        // Clause includes literal 63 and literal 64 — straddles the
        // first/second word boundary; catches shift/index off-by-ones.
        let f = 40; // 80 literals, 2 words
        let mut inc = vec![false; 2 * f];
        inc[63] = true; // ¬x_31
        inc[64] = true; // x_32
        let m = mask(inc);
        let pc = PackedClause::from_mask(&m);
        for (x31, x32) in [(false, true), (true, true), (false, false)] {
            let mut feats = vec![false; f];
            feats[31] = x31;
            feats[32] = x32;
            assert_eq!(
                pc.evaluate(&pack_literals(&feats)),
                m.evaluate(&make_literals(&feats)),
                "x31={x31} x32={x32}"
            );
            assert_eq!(pc.evaluate(&pack_literals(&feats)), !x31 && x32);
        }
    }

    #[test]
    fn evaluate_with_agrees_with_skip_walk_at_every_density() {
        // Sparse clauses route through the skip list, dense ones through
        // the lane sweep — the answers must be identical at every lane
        // width, including the dense threshold boundary.
        use crate::testutil::prop;
        prop("evaluate_with vs skip walk", 120, |g| {
            let f = g.usize(1..200);
            let density = if g.chance(0.3) { 0.9 } else { g.f64_unit() * 0.5 };
            let inc: Vec<bool> = (0..2 * f).map(|_| g.chance(density)).collect();
            let pc = PackedClause::from_mask(&mask(inc));
            let x = g.bools(f);
            let lw = pack_literals(&x);
            let want = pc.evaluate(&lw);
            for level in SimdLevel::available() {
                let lanes = WordLanes::new(level).unwrap();
                assert_eq!(
                    pc.evaluate_with(&lw, lanes),
                    want,
                    "f={f} level {}",
                    level.name()
                );
            }
        });
    }

    #[test]
    fn lane_sweep_rule_boundaries_and_override_are_exact() {
        // The shared rule: >= 8 non-zero words AND covering >= half the
        // span. Pinned here and consumed by compile::plan_for_mask.
        assert!(!prefers_lane_sweep(7, 14));
        assert!(prefers_lane_sweep(8, 16));
        assert!(!prefers_lane_sweep(8, 17));
        assert!(prefers_lane_sweep(16, 16));
        assert!(!prefers_lane_sweep(0, 0));
        // from_mask records the rule's verdict on the packed mask.
        let dense: Vec<bool> = (0..1024).map(|l| l % 64 == 0).collect();
        assert!(PackedClause::from_mask(&mask(dense.clone())).lane_sweep);
        let sparse: Vec<bool> = (0..1024).map(|l| l % 256 == 0).collect();
        assert!(!PackedClause::from_mask(&mask(sparse)).lane_sweep);
        // Forcing either plan never changes the predicate.
        use crate::testutil::prop;
        prop("plan override is output-invariant", 60, |g| {
            let f = g.usize(1..150);
            let inc: Vec<bool> = (0..2 * f).map(|_| g.chance(g.f64_unit())).collect();
            let pc = PackedClause::from_mask(&mask(inc));
            let lw = pack_literals(&g.bools(f));
            let want = pc.evaluate(&lw);
            for forced in [false, true] {
                let forced_pc = pc.clone().with_lane_sweep(forced);
                for level in SimdLevel::available() {
                    let lanes = WordLanes::new(level).unwrap();
                    assert_eq!(forced_pc.evaluate_with(&lw, lanes), want, "f={f}");
                }
            }
        });
    }

    #[test]
    fn single_sample_and_batched_agree() {
        // 5 features, 3 clauses, 67 samples (crosses the 64-sample block
        // boundary): bit `s` of each batch word must equal the
        // single-sample result — via both the single-word walk and the
        // tile path at every available lane width.
        let f = 5;
        let masks = [
            mask((0..2 * f).map(|i| i % 4 == 0).collect()),
            mask(vec![false; 2 * f]), // empty
            mask((0..2 * f).map(|i| i == 3).collect()),
        ];
        let samples: Vec<Vec<bool>> = (0..67u32)
            .map(|s| (0..f).map(|i| (s >> (i % 7)) & 1 == 1).collect())
            .collect();
        let rows: Vec<&[bool]> = samples.iter().map(|r| r.as_slice()).collect();
        let batch = BitSlicedBatch::pack(&rows, f);
        assert_eq!(batch.blocks, 2);
        assert_eq!(batch.tile_stride(), 2);
        assert_eq!(batch.tiles(), 1);
        assert_eq!(batch.tile_blocks(0), 2);
        assert_eq!(batch.valid_mask(0), !0);
        assert_eq!(batch.valid_mask(1), 0b111);
        for m in &masks {
            let pc = PackedClause::from_mask(m);
            let mut tile_out = vec![0u64; 2];
            for level in SimdLevel::available() {
                let lanes = WordLanes::new(level).unwrap();
                pc.evaluate_tile(&batch, 0, lanes, &mut tile_out);
                for (s, sample) in samples.iter().enumerate() {
                    let single = pc.evaluate(&pack_literals(sample));
                    let word = pc.evaluate_batch(&batch, s / WORD_BITS);
                    let batched = (word >> (s % WORD_BITS)) & 1 == 1;
                    let tiled =
                        (tile_out[s / WORD_BITS] >> (s % WORD_BITS)) & 1 == 1;
                    assert_eq!(single, batched, "sample {s}");
                    assert_eq!(single, tiled, "sample {s} level {}", level.name());
                    assert_eq!(single, m.evaluate(&make_literals(sample)), "sample {s}");
                }
            }
        }
    }

    #[test]
    fn tile_geometry_spans_multiple_tiles() {
        // 600 samples -> 10 blocks -> stride 8, 2 tiles (8 + 2 blocks);
        // the word of any (blk, literal) must equal the untiled
        // transpose, wherever the tile boundary falls.
        let f = 5;
        let rows: Vec<Vec<bool>> = (0..600u32)
            .map(|s| (0..f).map(|i| (s.wrapping_mul(2654435761) >> i) & 1 == 1).collect())
            .collect();
        let batch = BitSlicedBatch::pack(&rows, f);
        assert_eq!(batch.blocks, 10);
        assert_eq!(batch.tile_stride(), 8);
        assert_eq!(batch.tiles(), 2);
        assert_eq!(batch.tile_blocks(0), 8);
        assert_eq!(batch.tile_blocks(1), 2);
        // lit_lane is the contiguous view of lit_word over the tile.
        for t in 0..batch.tiles() {
            for l in 0..2 * f {
                let lane = batch.lit_lane(t, l);
                assert_eq!(lane.len(), batch.tile_blocks(t));
                for (j, &w) in lane.iter().enumerate() {
                    assert_eq!(w, batch.lit_word(t * 8 + j, l), "t={t} l={l} j={j}");
                }
            }
        }
        // Every bit equals the per-sample literal value.
        for (s, row) in rows.iter().enumerate() {
            for (i, &fv) in row.iter().enumerate() {
                let lit = 2 * i + usize::from(!fv);
                let w = batch.lit_word(s / WORD_BITS, lit);
                assert_eq!((w >> (s % WORD_BITS)) & 1, 1, "s={s} i={i}");
            }
        }
    }

    // -----------------------------------------------------------------
    // Cross-language golden vectors, asserted identically in
    // python/tests/test_simdtile.py (the mirror generated them). If
    // either language's tile layout drifts, both suites fail.
    // Scheme: F=3, 200 samples, feature i of sample s =
    // (i*i + 3*i*s + 2*s) % 7 < 3 (the packedtrain/invindex formula);
    // clause includes literal l iff (3*l) % 5 == 0.
    // -----------------------------------------------------------------

    fn golden_rows() -> Vec<Vec<bool>> {
        (0..200usize)
            .map(|s| (0..3).map(|i| (i * i + 3 * i * s + 2 * s) % 7 < 3).collect())
            .collect()
    }

    /// FNV-1a/64 over the tiled words' little-endian bytes (local copy;
    /// the shared constant lives in coordinator::shard for routing).
    fn fnv1a64_words(words: &[u64]) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &w in words {
            for b in w.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }

    #[test]
    fn tiled_layout_golden_vectors_match_python_mirror() {
        let rows = golden_rows();
        let batch = BitSlicedBatch::pack(&rows, 3);
        assert_eq!(batch.blocks, 4);
        assert_eq!(batch.tile_stride(), 4);
        assert_eq!(batch.tiles(), 1);
        assert_eq!(batch.raw_words().len(), 24);
        // Pinned by python/tests/test_simdtile.py::test_golden_vectors.
        assert_eq!(fnv1a64_words(batch.raw_words()), 0x6c6e_8c1e_a843_9d9e);
        assert_eq!(batch.lit_word(0, 0), 0x9326_4c99_3264_c993);
        assert_eq!(batch.lit_word(1, 1), 0x366c_d9b3_66cd_9b36);
        assert_eq!(batch.lit_word(3, 4), 0x0000_0000_0000_0087);
        assert_eq!(batch.valid_mask(3), 0x0000_0000_0000_00ff);

        let inc: Vec<bool> = (0..6).map(|l| (3 * l) % 5 == 0).collect();
        let pc = PackedClause::from_mask(&mask(inc));
        assert_eq!(pc.literals, vec![0, 5]);
        let mut out = vec![0u64; 4];
        for level in SimdLevel::available() {
            pc.evaluate_tile(&batch, 0, WordLanes::new(level).unwrap(), &mut out);
            // Pinned by the Python mirror as well; every lane width
            // must land on the same words.
            assert_eq!(
                out,
                vec![
                    0x8306_0c18_3060_c183,
                    0xc183_060c_1830_60c1,
                    0x60c1_8306_0c18_3060,
                    0x0000_0000_0000_0030,
                ],
                "level {}",
                level.name()
            );
        }
    }

    #[test]
    fn batch_padding_bits_are_zero() {
        // An always-firing clause (includes a literal every sample has
        // set) must still leave padding bits clear in the tail block.
        let f = 2;
        let samples = vec![vec![true, false]; 3];
        let rows: Vec<&[bool]> = samples.iter().map(|r| r.as_slice()).collect();
        let batch = BitSlicedBatch::pack(&rows, f);
        let mut inc = vec![false; 4];
        inc[0] = true; // x_0, set in every sample
        let pc = PackedClause::from_mask(&mask(inc));
        assert_eq!(pc.evaluate_batch(&batch, 0), 0b111);
        let mut out = vec![0u64; 1];
        pc.evaluate_tile(&batch, 0, WordLanes::portable(), &mut out);
        assert_eq!(out[0], 0b111);
    }
}
