//! Word-packed representations for bit-parallel TM inference.
//!
//! A clause fires iff every *included* literal is 1 (`AND` over the
//! included literals). Packing the 2F interleaved literals and each
//! clause's include mask into `u64` words turns that reduction into
//! `include & !literals == 0` checked word-by-word — 64 literals per
//! instruction instead of a per-literal `bool` loop — the word-level
//! trick from "Fast and Compact Tsetlin Machine Inference on CPUs"
//! (arXiv 2510.15653).
//!
//! Two complementary layouts:
//!
//! * **Literal-major single sample** ([`pack_literals`] +
//!   [`PackedClause::evaluate`]): one sample's 2F literals as
//!   `ceil(2F/64)` words; each clause keeps a skip list of its non-zero
//!   include words so sparse clauses touch only the words they
//!   constrain (the clause-indexing idea of arXiv 2004.03188 applied at
//!   word granularity).
//! * **Sample-major batch** ([`BitSlicedBatch`] +
//!   [`PackedClause::evaluate_batch`]): a bit-sliced transpose where
//!   word `column[l][blk]` holds literal `l` of samples
//!   `blk*64 .. blk*64+63`, one sample per bit. A clause then ANDs one
//!   column per included literal and produces 64 clause outputs per
//!   word — the batched path the serving coordinator flushes through.
//!
//! Semantics are pinned to the scalar reference
//! ([`ClauseMask::evaluate`]): an **empty clause** (all-exclude mask —
//! which is also what a zero-feature clause degenerates to) outputs 0
//! at inference, even though the AND-of-nothing reading would be
//! "always include ⇒ always fire". The conformance suite
//! (`tests/bitparallel_equivalence.rs`) holds every path to bit-exact
//! agreement with the reference, so this convention is load-bearing.

use super::model::ClauseMask;

/// Bits per packed word.
pub const WORD_BITS: usize = 64;

/// Number of `u64` words needed to hold `bits` bits.
pub fn words_for(bits: usize) -> usize {
    bits.div_ceil(WORD_BITS)
}

/// Pack a bool slice into little-endian words: element `i` lands in bit
/// `i % 64` of word `i / 64`. Tail padding bits are zero.
pub fn pack_bools(bits: &[bool]) -> Vec<u64> {
    let mut words = vec![0u64; words_for(bits.len())];
    for (i, &b) in bits.iter().enumerate() {
        if b {
            words[i / WORD_BITS] |= 1u64 << (i % WORD_BITS);
        }
    }
    words
}

/// Pack one sample's interleaved literals (`lit[2i] = x_i`,
/// `lit[2i+1] = ¬x_i`) directly from the feature vector, skipping the
/// intermediate `Vec<bool>` that [`super::model::make_literals`] builds.
/// Exactly one of each literal pair is set, so tail padding (when
/// `2F % 64 != 0`) stays zero.
pub fn pack_literals(features: &[bool]) -> Vec<u64> {
    let mut words = vec![0u64; words_for(2 * features.len())];
    for (i, &f) in features.iter().enumerate() {
        let pos = 2 * i + usize::from(!f);
        words[pos / WORD_BITS] |= 1u64 << (pos % WORD_BITS);
    }
    words
}

/// Evaluate raw include words against packed literals with
/// **training-time semantics**: fires iff `include & !literals == 0`
/// in every word, so an all-zero include mask (empty clause) is
/// vacuously true and *fires*. This is deliberately the opposite of
/// [`PackedClause::evaluate`]'s inference convention — during training
/// an empty clause must fire to receive Type I feedback and grow. Used
/// by the trainer engine's incrementally-maintained masks
/// (`super::trainer_engine::ClauseState`).
#[inline]
pub fn eval_words_train(include: &[u64], literal_words: &[u64]) -> bool {
    include
        .iter()
        .zip(literal_words)
        .all(|(&inc, &lw)| inc & !lw == 0)
}

/// One clause's include mask, packed for both evaluation layouts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedClause {
    /// Include mask over 2F literals, zero-padded to word width.
    pub include: Vec<u64>,
    /// Indices of non-zero `include` words (skip list): sparse clauses
    /// constrain few words, so only those are checked per sample.
    pub nonzero_words: Vec<u32>,
    /// Sorted indices of the included literals (for the batched path).
    pub literals: Vec<u32>,
}

impl PackedClause {
    /// Pack a [`ClauseMask`] (include mask over the 2F interleaved
    /// literals).
    pub fn from_mask(mask: &ClauseMask) -> PackedClause {
        let include = pack_bools(&mask.include);
        let nonzero_words = include
            .iter()
            .enumerate()
            .filter(|(_, &w)| w != 0)
            .map(|(i, _)| i as u32)
            .collect();
        let literals = mask
            .include
            .iter()
            .enumerate()
            .filter(|(_, &b)| b)
            .map(|(i, _)| i as u32)
            .collect();
        PackedClause { include, nonzero_words, literals }
    }

    /// Empty clause = all-exclude mask (fires never, matching the
    /// reference's inference convention).
    pub fn is_empty(&self) -> bool {
        self.nonzero_words.is_empty()
    }

    pub fn included_count(&self) -> usize {
        self.literals.len()
    }

    /// Evaluate against one packed literal vector ([`pack_literals`]):
    /// fires iff `include & !literals == 0` in every non-zero word.
    pub fn evaluate(&self, literal_words: &[u64]) -> bool {
        if self.is_empty() {
            return false;
        }
        self.nonzero_words.iter().all(|&w| {
            let w = w as usize;
            self.include[w] & !literal_words[w] == 0
        })
    }

    /// Evaluate 64 samples at once against one block of a
    /// [`BitSlicedBatch`]: returns a word with bit `s` = clause output
    /// for sample `blk*64 + s`. Padding sample bits come back 0 because
    /// their literal columns are all-zero (and empty clauses return 0
    /// outright).
    pub fn evaluate_batch(&self, batch: &BitSlicedBatch, blk: usize) -> u64 {
        if self.is_empty() {
            return 0;
        }
        let mut acc = !0u64;
        for &l in &self.literals {
            acc &= batch.column(l as usize)[blk];
            if acc == 0 {
                break;
            }
        }
        acc & batch.valid_mask(blk)
    }
}

/// A batch of samples in bit-sliced (sample-major) layout: for each of
/// the 2F literals, `blocks` words whose bit `s` is that literal's value
/// for sample `blk*64 + s`.
#[derive(Debug, Clone)]
pub struct BitSlicedBatch {
    /// `2F * blocks` words, literal-major (`column(l)` is contiguous).
    columns: Vec<u64>,
    /// Boolean input features per sample (F).
    pub features: usize,
    /// Samples in the batch.
    pub samples: usize,
    /// `ceil(samples / 64)` words per literal column.
    pub blocks: usize,
}

impl BitSlicedBatch {
    /// Transpose `rows` (each a length-F feature vector) into bit-sliced
    /// literal columns. Panics if a row width differs from `features`
    /// (callers validate widths at the serving boundary).
    pub fn pack<R: AsRef<[bool]>>(rows: &[R], features: usize) -> BitSlicedBatch {
        let samples = rows.len();
        let blocks = words_for(samples.max(1));
        let mut columns = vec![0u64; 2 * features * blocks];
        for (s, row) in rows.iter().enumerate() {
            let row = row.as_ref();
            assert_eq!(row.len(), features, "batch row width mismatch");
            let (blk, bit) = (s / WORD_BITS, 1u64 << (s % WORD_BITS));
            for (i, &f) in row.iter().enumerate() {
                let lit = 2 * i + usize::from(!f);
                columns[lit * blocks + blk] |= bit;
            }
        }
        BitSlicedBatch { columns, features, samples, blocks }
    }

    /// The packed column of literal `l` (`blocks` words).
    #[inline]
    pub fn column(&self, l: usize) -> &[u64] {
        &self.columns[l * self.blocks..(l + 1) * self.blocks]
    }

    /// Mask of valid sample bits in block `blk` (all-ones except the
    /// final partial block).
    #[inline]
    pub fn valid_mask(&self, blk: usize) -> u64 {
        let used = self.samples - blk * WORD_BITS;
        if used >= WORD_BITS {
            !0
        } else {
            (1u64 << used) - 1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::model::make_literals;

    fn mask(include: Vec<bool>) -> ClauseMask {
        ClauseMask { include }
    }

    #[test]
    fn words_for_boundaries() {
        assert_eq!(words_for(0), 0);
        assert_eq!(words_for(1), 1);
        assert_eq!(words_for(64), 1);
        assert_eq!(words_for(65), 2);
        assert_eq!(words_for(128), 2);
        assert_eq!(words_for(129), 3);
    }

    #[test]
    fn pack_literals_matches_pack_bools_of_make_literals() {
        // The direct packing must agree with the two-step reference
        // packing, including at the 64-literal (= 32-feature) boundary.
        for f in [1usize, 2, 31, 32, 33, 64, 65] {
            let feats: Vec<bool> = (0..f).map(|i| i % 3 == 0).collect();
            assert_eq!(
                pack_literals(&feats),
                pack_bools(&make_literals(&feats)),
                "features={f}"
            );
        }
    }

    #[test]
    fn features_64_and_65_boundary_packing() {
        // F=32 -> exactly one word of literals; F=33 -> 65 literals + a
        // tail word whose padding must be zero.
        let f32_feats = vec![true; 32];
        let w = pack_literals(&f32_feats);
        assert_eq!(w.len(), 1);
        // Every even bit set (x_i = 1), every odd bit clear (¬x_i = 0).
        assert_eq!(w[0], 0x5555_5555_5555_5555);

        let f33_feats = vec![true; 33];
        let w = pack_literals(&f33_feats);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0], 0x5555_5555_5555_5555);
        assert_eq!(w[1], 0b01, "only literal 64 (= x_32) set; padding zero");
    }

    #[test]
    fn training_eval_fires_empty_clauses_unlike_inference() {
        // The two conventions, side by side, on the same words: the
        // inference path (PackedClause) returns 0 for an all-exclude
        // clause; the training path (eval_words_train) fires it.
        let lits = pack_literals(&[true, false, true]);
        let empty = vec![0u64; lits.len()];
        assert!(eval_words_train(&empty, &lits));
        assert!(!PackedClause::from_mask(&mask(vec![false; 6])).evaluate(&lits));
        // Non-empty masks agree with the inference predicate.
        for inc_lit in 0..6usize {
            let mut inc = vec![false; 6];
            inc[inc_lit] = true;
            let pc = PackedClause::from_mask(&mask(inc));
            assert_eq!(
                eval_words_train(&pc.include, &lits),
                pc.evaluate(&lits),
                "literal {inc_lit}"
            );
        }
    }

    #[test]
    fn all_exclude_mask_never_fires() {
        // Empty clause (all-exclude) outputs 0 at inference, exactly as
        // the scalar reference does — not the "AND of nothing is true"
        // reading. A zero-feature clause is the same degenerate case.
        let pc = PackedClause::from_mask(&mask(vec![false; 10]));
        assert!(pc.is_empty());
        assert!(!pc.evaluate(&pack_literals(&[true; 5])));
        assert!(!mask(vec![false; 10]).evaluate(&make_literals(&[true; 5])));

        let zero_feature = PackedClause::from_mask(&mask(Vec::new()));
        assert!(zero_feature.is_empty());
        assert!(!zero_feature.evaluate(&[]));
    }

    #[test]
    fn skip_list_only_names_nonzero_words() {
        // 2F = 192 literals, includes only in the last word.
        let mut inc = vec![false; 192];
        inc[130] = true;
        inc[191] = true;
        let pc = PackedClause::from_mask(&mask(inc));
        assert_eq!(pc.include.len(), 3);
        assert_eq!(pc.nonzero_words, vec![2]);
        assert_eq!(pc.literals, vec![130, 191]);
        assert_eq!(pc.included_count(), 2);
    }

    #[test]
    fn packed_evaluate_matches_scalar_on_word_boundary_literals() {
        // Clause includes literal 63 and literal 64 — straddles the
        // first/second word boundary; catches shift/index off-by-ones.
        let f = 40; // 80 literals, 2 words
        let mut inc = vec![false; 2 * f];
        inc[63] = true; // ¬x_31
        inc[64] = true; // x_32
        let m = mask(inc);
        let pc = PackedClause::from_mask(&m);
        for (x31, x32) in [(false, true), (true, true), (false, false)] {
            let mut feats = vec![false; f];
            feats[31] = x31;
            feats[32] = x32;
            assert_eq!(
                pc.evaluate(&pack_literals(&feats)),
                m.evaluate(&make_literals(&feats)),
                "x31={x31} x32={x32}"
            );
            assert_eq!(pc.evaluate(&pack_literals(&feats)), !x31 && x32);
        }
    }

    #[test]
    fn single_sample_and_batched_agree() {
        // 5 features, 3 clauses, 67 samples (crosses the 64-sample block
        // boundary): bit `s` of each batch word must equal the
        // single-sample result.
        let f = 5;
        let masks = [
            mask((0..2 * f).map(|i| i % 4 == 0).collect()),
            mask(vec![false; 2 * f]), // empty
            mask((0..2 * f).map(|i| i == 3).collect()),
        ];
        let samples: Vec<Vec<bool>> = (0..67u32)
            .map(|s| (0..f).map(|i| (s >> (i % 7)) & 1 == 1).collect())
            .collect();
        let rows: Vec<&[bool]> = samples.iter().map(|r| r.as_slice()).collect();
        let batch = BitSlicedBatch::pack(&rows, f);
        assert_eq!(batch.blocks, 2);
        assert_eq!(batch.valid_mask(0), !0);
        assert_eq!(batch.valid_mask(1), 0b111);
        for m in &masks {
            let pc = PackedClause::from_mask(m);
            for (s, sample) in samples.iter().enumerate() {
                let single = pc.evaluate(&pack_literals(sample));
                let word = pc.evaluate_batch(&batch, s / WORD_BITS);
                let batched = (word >> (s % WORD_BITS)) & 1 == 1;
                assert_eq!(single, batched, "sample {s}");
                assert_eq!(single, m.evaluate(&make_literals(sample)), "sample {s}");
            }
        }
    }

    #[test]
    fn batch_padding_bits_are_zero() {
        // An always-firing clause (includes a literal every sample has
        // set) must still leave padding bits clear in the tail block.
        let f = 2;
        let samples = vec![vec![true, false]; 3];
        let rows: Vec<&[bool]> = samples.iter().map(|r| r.as_slice()).collect();
        let batch = BitSlicedBatch::pack(&rows, f);
        let mut inc = vec![false; 4];
        inc[0] = true; // x_0, set in every sample
        let pc = PackedClause::from_mask(&mask(inc));
        assert_eq!(pc.evaluate_batch(&batch, 0), 0b111);
    }
}
