//! Bit-parallel inference engines — the production CPU serving path.
//!
//! [`BitParallelMulticlass`] and [`BitParallelCotm`] precompile a
//! trained model into the packed clause plans of [`super::bitpack`]:
//! clause evaluation becomes word-wide `AND`/compare instead of
//! per-literal `bool` loops, and batched requests are evaluated through
//! the cache-blocked tile layout — clause-major within a tile,
//! samples-block-major across tiles — in [`super::simd::WordLanes`]
//! steps (4×`u64` portable-unrolled, AVX2, or AVX-512 lanes behind
//! runtime dispatch; `SimdLevel::Scalar` keeps the historic
//! one-word-per-op walk as the reference and bench baseline). Both
//! engines are plain owned data — `Send + Sync` — so one shared
//! instance serves every coordinator thread, unlike the `Rc`-coded
//! hardware models that must be rebuilt per worker.
//!
//! The lane width is a *speed* decision only: every level computes the
//! identical predicates, so sums and argmax are invariant under
//! dispatch (enforced by `tests/simd_dispatch.rs` on top of the
//! reference conformance below).
//!
//! Bit-exactness contract (§III-A): class sums and argmax must equal
//! [`super::infer::multiclass_class_sums`] /
//! [`super::infer::cotm_class_sums`] and
//! [`super::infer::predict_argmax`] on every input — enforced by
//! `tests/bitparallel_equivalence.rs`.

use super::bitpack::{pack_literals, words_for, BitSlicedBatch, PackedClause, WORD_BITS};
use super::compile::{CompiledCotm, CompiledMulticlass, ModelCompiler};
use super::infer::predict_argmax;
use super::model::{CoTmModel, MultiClassTmModel, TmParams};
use super::simd::{self, SimdLevel, WordLanes};
use crate::error::Result;

/// Per-sample result of a batched evaluation: `(class_sums, argmax)`.
pub type BatchResult = (Vec<i32>, usize);

/// Common surface of the bit-parallel engines, plus a provided
/// scoped-thread sharding of large batches (the engines are `Sync`, so
/// shards share `&self` with zero copying).
pub trait BatchEngine: Sync {
    /// Boolean feature width F the engine was compiled for.
    fn features(&self) -> usize;

    /// Number of classes K.
    fn classes(&self) -> usize;

    /// Class sums for a single sample (must be length-F).
    fn class_sums(&self, features: &[bool]) -> Vec<i32>;

    /// Evaluate a batch of samples via the bit-sliced layout.
    fn infer_batch<R: AsRef<[bool]> + Sync>(&self, rows: &[R]) -> Vec<BatchResult>;

    /// Single-sample prediction (lowest-index tie-break, matching
    /// [`predict_argmax`]).
    fn predict(&self, features: &[bool]) -> usize {
        predict_argmax(&self.class_sums(features))
    }

    /// Shard a large batch across up to `max_threads` scoped threads.
    /// Order-preserving; falls back to single-threaded evaluation for
    /// small batches where transpose + spawn overhead dominates.
    fn infer_batch_sharded<R: AsRef<[bool]> + Sync>(
        &self,
        rows: &[R],
        max_threads: usize,
    ) -> Vec<BatchResult> {
        let n = rows.len();
        if max_threads <= 1 || n < 2 * WORD_BITS {
            return self.infer_batch(rows);
        }
        // One shard per whole 64-sample block, at most `max_threads`.
        let shards = max_threads.min(n.div_ceil(WORD_BITS));
        let chunk = n.div_ceil(shards).div_ceil(WORD_BITS) * WORD_BITS;
        std::thread::scope(|s| {
            let handles: Vec<_> = rows
                .chunks(chunk)
                .map(|c| s.spawn(move || self.infer_batch(c)))
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("batch shard panicked"))
                .collect()
        })
    }
}

/// Walk every set clause-output bit of `plans` over a tiled batch and
/// hand `(payload, sample_index)` to `apply` — the shared scatter core
/// of both engines' batch paths.
///
/// Non-scalar lanes stream **clause-major within a tile**: every plan
/// is evaluated against tile `t` (whose `2F × stride` words are
/// cache-resident) before tile `t+1` is touched, and each plan's
/// literal lanes are contiguous `stride`-word runs. `Scalar` keeps the
/// historic per-block single-word walk — the reference the lane paths
/// are diffed against, and the `simd = "scalar"` serving path.
fn scatter_clause_words<P: Copy>(
    batch: &BitSlicedBatch,
    lanes: WordLanes,
    plans: &[(&PackedClause, P)],
    mut apply: impl FnMut(P, usize),
) {
    if lanes.level() == SimdLevel::Scalar {
        for &(pc, payload) in plans {
            for blk in 0..batch.blocks {
                let mut word = pc.evaluate_batch(batch, blk);
                while word != 0 {
                    let s = blk * WORD_BITS + word.trailing_zeros() as usize;
                    apply(payload, s);
                    word &= word - 1;
                }
            }
        }
        return;
    }
    let stride = batch.tile_stride();
    let mut out = vec![0u64; stride];
    for t in 0..batch.tiles() {
        let tb = batch.tile_blocks(t);
        let o = &mut out[..tb];
        for &(pc, payload) in plans {
            pc.evaluate_tile(batch, t, lanes, o);
            for (j, &w) in o.iter().enumerate() {
                let mut word = w;
                let base = (t * stride + j) * WORD_BITS;
                while word != 0 {
                    apply(payload, base + word.trailing_zeros() as usize);
                    word &= word - 1;
                }
            }
        }
    }
}

/// Bit-parallel multi-class TM engine: per class, packed clause plans
/// each carrying its **explicit** vote polarity from the compiled
/// artifact (Eq. 1's parity rule frozen at compile time — the pass may
/// have pruned or reordered clauses, so position parity is meaningless
/// here).
#[derive(Debug, Clone)]
pub struct BitParallelMulticlass {
    pub params: TmParams,
    /// `[class][clause]` packed plans with their ±1 vote polarity.
    clauses: Vec<Vec<(PackedClause, i32)>>,
    /// Lane width every evaluation dispatches through.
    lanes: WordLanes,
}

impl BitParallelMulticlass {
    /// Compile a validated model (default [`ModelCompiler`]: exact
    /// dead-clause pruning) into packed clause plans, evaluating
    /// through the widest detected lane width
    /// ([`simd::default_lanes`]); override with [`Self::with_lanes`].
    pub fn from_model(model: &MultiClassTmModel) -> Result<BitParallelMulticlass> {
        Self::from_compiled(&ModelCompiler::default().compile_multiclass(model)?)
    }

    /// Build from an already-compiled artifact — the shared pipeline
    /// entry point (`coordinator/server.rs` compiles once and builds
    /// every engine family from the same artifact).
    pub fn from_compiled(compiled: &CompiledMulticlass) -> Result<BitParallelMulticlass> {
        compiled.validate()?;
        let clauses = compiled
            .classes
            .iter()
            .zip(&compiled.polarities)
            .map(|(class, pols)| {
                class
                    .iter()
                    .zip(pols)
                    .map(|(cc, &pol)| (cc.packed(), pol))
                    .collect()
            })
            .collect();
        Ok(BitParallelMulticlass {
            params: compiled.params.clone(),
            clauses,
            lanes: simd::default_lanes(),
        })
    }

    /// The same engine at an explicit lane width (a speed decision
    /// only: sums are invariant under dispatch).
    pub fn with_lanes(mut self, lanes: WordLanes) -> BitParallelMulticlass {
        self.lanes = lanes;
        self
    }

    /// Words per packed literal vector (`ceil(2F/64)`).
    pub fn literal_words(&self) -> usize {
        words_for(2 * self.params.features)
    }

    /// Class sums from an already-packed literal vector
    /// ([`pack_literals`]) — lets callers amortise packing across the
    /// K·C clause evaluations.
    pub fn class_sums_packed(&self, literal_words: &[u64]) -> Vec<i32> {
        debug_assert_eq!(literal_words.len(), self.literal_words());
        self.clauses
            .iter()
            .map(|class| {
                let mut sum = 0i32;
                for (pc, polarity) in class {
                    if pc.evaluate_with(literal_words, self.lanes) {
                        sum += polarity;
                    }
                }
                sum
            })
            .collect()
    }
}

impl BatchEngine for BitParallelMulticlass {
    fn features(&self) -> usize {
        self.params.features
    }

    fn classes(&self) -> usize {
        self.params.classes
    }

    fn class_sums(&self, features: &[bool]) -> Vec<i32> {
        assert_eq!(
            features.len(),
            self.params.features,
            "feature width mismatch"
        );
        self.class_sums_packed(&pack_literals(features))
    }

    fn infer_batch<R: AsRef<[bool]> + Sync>(&self, rows: &[R]) -> Vec<BatchResult> {
        let batch = BitSlicedBatch::pack(rows, self.params.features);
        let (n, k) = (batch.samples, self.params.classes);
        // Plans carry (class, polarity); clause-major within each tile.
        let plans: Vec<(&PackedClause, (usize, i32))> = self
            .clauses
            .iter()
            .enumerate()
            .flat_map(|(ci, class)| {
                class.iter().map(move |(pc, pol)| (pc, (ci, *pol)))
            })
            .collect();
        // Sample-major accumulator: sums[s*k + class].
        let mut sums = vec![0i32; n * k];
        scatter_clause_words(&batch, self.lanes, &plans, |(ci, polarity), s| {
            sums[s * k + ci] += polarity;
        });
        collect_rows(&sums, n, k)
    }
}

/// Bit-parallel CoTM engine: one shared packed clause pool plus the
/// signed weight matrix, stored clause-major so a firing clause adds its
/// whole weight column (Eq. 2).
#[derive(Debug, Clone)]
pub struct BitParallelCotm {
    pub params: TmParams,
    clauses: Vec<PackedClause>,
    /// `[clause][class]` weight columns (transposed from the model's
    /// `[class][clause]` for contiguous access per firing clause).
    weight_cols: Vec<Vec<i32>>,
    /// Lane width every evaluation dispatches through.
    lanes: WordLanes,
}

impl BitParallelCotm {
    /// Compile a validated model (default [`ModelCompiler`]: exact
    /// dead-clause pruning) into packed clause plans (widest detected
    /// lanes; override with [`Self::with_lanes`]).
    pub fn from_model(model: &CoTmModel) -> Result<BitParallelCotm> {
        Self::from_compiled(&ModelCompiler::default().compile_cotm(model)?)
    }

    /// Build from an already-compiled artifact: the clause pool and its
    /// weight columns arrive pruned and reordered in lockstep.
    pub fn from_compiled(compiled: &CompiledCotm) -> Result<BitParallelCotm> {
        compiled.validate()?;
        Ok(BitParallelCotm {
            params: compiled.params.clone(),
            clauses: compiled.clauses.iter().map(|cc| cc.packed()).collect(),
            weight_cols: compiled.weight_cols.clone(),
            lanes: simd::default_lanes(),
        })
    }

    /// The same engine at an explicit lane width.
    pub fn with_lanes(mut self, lanes: WordLanes) -> BitParallelCotm {
        self.lanes = lanes;
        self
    }

    /// Words per packed literal vector (`ceil(2F/64)`).
    pub fn literal_words(&self) -> usize {
        words_for(2 * self.params.features)
    }

    /// Class sums from an already-packed literal vector.
    pub fn class_sums_packed(&self, literal_words: &[u64]) -> Vec<i32> {
        debug_assert_eq!(literal_words.len(), self.literal_words());
        let mut sums = vec![0i32; self.params.classes];
        for (pc, wcol) in self.clauses.iter().zip(&self.weight_cols) {
            if pc.evaluate_with(literal_words, self.lanes) {
                for (s, &w) in sums.iter_mut().zip(wcol) {
                    *s += w;
                }
            }
        }
        sums
    }
}

impl BatchEngine for BitParallelCotm {
    fn features(&self) -> usize {
        self.params.features
    }

    fn classes(&self) -> usize {
        self.params.classes
    }

    fn class_sums(&self, features: &[bool]) -> Vec<i32> {
        assert_eq!(
            features.len(),
            self.params.features,
            "feature width mismatch"
        );
        self.class_sums_packed(&pack_literals(features))
    }

    fn infer_batch<R: AsRef<[bool]> + Sync>(&self, rows: &[R]) -> Vec<BatchResult> {
        let batch = BitSlicedBatch::pack(rows, self.params.features);
        let (n, k) = (batch.samples, self.params.classes);
        let plans: Vec<(&PackedClause, usize)> =
            self.clauses.iter().enumerate().map(|(j, pc)| (pc, j)).collect();
        let mut sums = vec![0i32; n * k];
        scatter_clause_words(&batch, self.lanes, &plans, |j, s| {
            let row = &mut sums[s * k..(s + 1) * k];
            for (acc, &w) in row.iter_mut().zip(&self.weight_cols[j]) {
                *acc += w;
            }
        });
        collect_rows(&sums, n, k)
    }
}

/// Split a sample-major accumulator into per-sample `(sums, argmax)`.
fn collect_rows(sums: &[i32], n: usize, k: usize) -> Vec<BatchResult> {
    (0..n)
        .map(|s| {
            let row = sums[s * k..(s + 1) * k].to_vec();
            let pred = predict_argmax(&row);
            (row, pred)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::infer::{cotm_class_sums, multiclass_class_sums};
    use crate::tm::model::ClauseMask;

    fn tiny_params() -> TmParams {
        TmParams {
            features: 2,
            clauses: 2,
            classes: 2,
            ..TmParams::iris_paper()
        }
    }

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn engines_are_send_and_sync() {
        // The whole point of this backend: shareable across the
        // coordinator's threads without per-worker rebuilds.
        assert_send_sync::<BitParallelMulticlass>();
        assert_send_sync::<BitParallelCotm>();
    }

    /// Same hand-worked example as infer.rs / python/tests/test_model.py.
    #[test]
    fn hand_worked_multiclass_matches_reference() {
        let mut m = MultiClassTmModel::zeroed(tiny_params());
        m.clauses[0][0].include[0] = true; // class0 clause0 (+): x0
        m.clauses[0][1].include[3] = true; // class0 clause1 (−): ¬x1
        m.clauses[1][0].include[1] = true; // class1 clause0 (+): ¬x0
        m.clauses[1][1].include[2] = true; // class1 clause1 (−): x1
        let e = BitParallelMulticlass::from_model(&m).unwrap();
        for x in [[true, false], [true, true], [false, false], [false, true]] {
            assert_eq!(e.class_sums(&x), multiclass_class_sums(&m, &x), "{x:?}");
        }
        assert_eq!(e.class_sums(&[true, true]), vec![1, -1]);
        assert_eq!(e.predict(&[true, true]), 0);
    }

    #[test]
    fn hand_worked_cotm_matches_reference() {
        let mut m = CoTmModel::zeroed(tiny_params());
        m.clauses[0].include[0] = true; // clause0: x0
        m.clauses[1].include[2] = true; // clause1: x1
        m.weights = vec![vec![3, -2], vec![-1, 4]];
        let e = BitParallelCotm::from_model(&m).unwrap();
        for x in [[true, true], [true, false], [false, false]] {
            assert_eq!(e.class_sums(&x), cotm_class_sums(&m, &x), "{x:?}");
        }
        assert_eq!(e.class_sums(&[true, true]), vec![1, 3]);
    }

    #[test]
    fn from_model_rejects_invalid_models() {
        let odd = TmParams { clauses: 7, ..tiny_params() };
        assert!(BitParallelMulticlass::from_model(&MultiClassTmModel::zeroed(odd)).is_err());
        let mut cm = CoTmModel::zeroed(tiny_params());
        cm.weights[0][0] = cm.params.max_weight + 1;
        assert!(BitParallelCotm::from_model(&cm).is_err());
    }

    #[test]
    fn batched_agrees_with_single_sample_across_block_boundary() {
        // 130 samples = 2 full 64-sample blocks + a 2-sample tail.
        let p = TmParams { features: 5, clauses: 4, classes: 3, ..tiny_params() };
        let mut m = MultiClassTmModel::zeroed(p.clone());
        for (ci, class) in m.clauses.iter_mut().enumerate() {
            for (j, cl) in class.iter_mut().enumerate() {
                *cl = ClauseMask {
                    include: (0..10).map(|l| (l + ci + j) % 3 == 0).collect(),
                };
            }
        }
        let e = BitParallelMulticlass::from_model(&m).unwrap();
        let rows: Vec<Vec<bool>> = (0..130u32)
            .map(|s| (0..5).map(|i| (s >> i) & 1 == 1).collect())
            .collect();
        let batched = e.infer_batch(&rows);
        assert_eq!(batched.len(), 130);
        for (s, (sums, pred)) in batched.iter().enumerate() {
            assert_eq!(sums, &e.class_sums(&rows[s]), "sample {s}");
            assert_eq!(*pred, predict_argmax(sums), "sample {s}");
        }
        // Sharded evaluation is a pure reordering of the same work.
        assert_eq!(e.infer_batch_sharded(&rows, 4), batched);
    }

    #[test]
    fn every_available_lane_width_produces_identical_batches() {
        // The dispatch choice is a speed decision only: forced scalar,
        // portable, and any detected vector level must produce the
        // same batch output word for word (the full random-model sweep
        // lives in tests/simd_dispatch.rs).
        let p = TmParams { features: 9, clauses: 6, classes: 3, ..tiny_params() };
        let mut m = MultiClassTmModel::zeroed(p.clone());
        for (ci, class) in m.clauses.iter_mut().enumerate() {
            for (j, cl) in class.iter_mut().enumerate() {
                *cl = ClauseMask {
                    include: (0..18).map(|l| (l + 2 * ci + j) % 5 == 0).collect(),
                };
            }
        }
        let rows: Vec<Vec<bool>> = (0..200u32)
            .map(|s| (0..9).map(|i| (s.wrapping_mul(7 + i)) & 2 == 2).collect())
            .collect();
        let base = BitParallelMulticlass::from_model(&m)
            .unwrap()
            .with_lanes(WordLanes::portable());
        let want = base.infer_batch(&rows);
        for level in SimdLevel::available() {
            let e = base.clone().with_lanes(WordLanes::new(level).unwrap());
            assert_eq!(e.infer_batch(&rows), want, "level {}", level.name());
            for x in rows.iter().take(5) {
                assert_eq!(e.class_sums(x), base.class_sums(x), "level {}", level.name());
            }
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let e = BitParallelMulticlass::from_model(&MultiClassTmModel::zeroed(tiny_params()))
            .unwrap();
        assert!(e.infer_batch(&Vec::<Vec<bool>>::new()).is_empty());
        let scalar = e.with_lanes(WordLanes::scalar());
        assert!(scalar.infer_batch(&Vec::<Vec<bool>>::new()).is_empty());
    }

    #[test]
    fn compiled_artifacts_serve_bit_identical_sums() {
        // Full compile (prune + reorder) of models with dead clauses:
        // the engine built from the compiled artifact must match the
        // scalar reference on every input — explicit polarity / weight
        // columns absorb the id permutation.
        use crate::tm::compile::{CompileMode, ModelCompiler};
        let p = TmParams { features: 3, clauses: 4, classes: 2, ..tiny_params() };
        let mut m = MultiClassTmModel::zeroed(p.clone());
        m.clauses[0][0].include[1] = true; // class0 c0 (+): ¬x0
        m.clauses[0][2].include[2] = true; // class0 c2 (+): x1
        m.clauses[0][2].include[3] = true; // ... and ¬x1 -> contradictory
        m.clauses[0][3].include[0] = true; // class0 c3 (−): x0
        m.clauses[1][1].include[4] = true; // class1 c1 (−): x2
        let calib: Vec<Vec<bool>> = (0..8u32)
            .map(|b| (0..3).map(|i| (b >> i) & 1 == 1).collect())
            .collect();
        let compiler = ModelCompiler::new(CompileMode::Full).with_calibration(calib.clone());
        let e = BitParallelMulticlass::from_compiled(
            &compiler.compile_multiclass(&m).unwrap(),
        )
        .unwrap();
        for x in &calib {
            assert_eq!(e.class_sums(x), multiclass_class_sums(&m, x), "{x:?}");
        }
        assert_eq!(e.infer_batch(&calib).len(), 8);

        let mut cm = CoTmModel::zeroed(p);
        cm.clauses[0].include[5] = true; // ¬x2
        cm.clauses[2].include[0] = true; // x0
        cm.clauses[3].include[2] = true;
        cm.clauses[3].include[3] = true; // contradictory
        cm.weights = vec![vec![2, -1, 3, 5], vec![-2, 1, -3, 5]];
        let ce = BitParallelCotm::from_compiled(&compiler.compile_cotm(&cm).unwrap()).unwrap();
        for x in &calib {
            assert_eq!(ce.class_sums(x), cotm_class_sums(&cm, x), "{x:?}");
        }
    }

    #[test]
    fn all_empty_clauses_give_zero_sums() {
        // Zeroed model: every clause is all-exclude -> sums all zero,
        // argmax 0, in both single and batched paths.
        let e = BitParallelCotm::from_model(&CoTmModel::zeroed(tiny_params())).unwrap();
        assert_eq!(e.class_sums(&[true, false]), vec![0, 0]);
        let out = e.infer_batch(&[vec![true, false], vec![false, true]]);
        assert_eq!(out, vec![(vec![0, 0], 0), (vec![0, 0], 0)]);
    }
}
