//! Load-time model compilation — the shared pass between trained
//! models and every serving engine.
//!
//! "Fast and Compact Tsetlin Machine Inference on CPUs Using
//! Instruction-Level Optimization" (arXiv 2510.15653) gets its large
//! CPU wins by specializing inference to the *trained* model rather
//! than the engine: clauses that can never fire are deleted outright,
//! the survivors are reordered by observed fire probability, and the
//! evaluation strategy is chosen per clause instead of per engine. This
//! module is that pass for the serving stack: [`ModelCompiler`] turns a
//! validated [`MultiClassTmModel`] / [`CoTmModel`] into a
//! [`CompiledMulticlass`] / [`CompiledCotm`] artifact that every engine
//! family builds from (`from_compiled` in `fast_infer` / `index` /
//! `compressed`), so representation decisions are made **once per
//! model** instead of re-derived per engine.
//!
//! The pass has four products:
//!
//! 1. **Dead-clause elimination.** An *all-exclude* clause never fires
//!    at inference (the pinned convention of every engine), and a
//!    *contradictory* clause — one including both `x_i` and `¬x_i` —
//!    can never see all its literals satisfied because exactly one of
//!    each interleaved pair is set per sample. Both contribute exactly
//!    0 to every class sum, so pruning them is **exact**: served sums
//!    and argmax are bit-identical (`tests/engine_matrix.rs` is the
//!    bar).
//! 2. **Fire-probability clause reordering** ([`CompileMode::Full`])
//!    from an optional calibration batch: clauses are sorted by
//!    descending fire count with a **deterministic tie-break by
//!    ascending source clause id**, so early-exit paths (the compressed
//!    first-miss walk, the WTA-style resolve-early serving goal) do
//!    their likely work first. Order is a speed decision only — sums
//!    are invariant under any clause permutation because the compiled
//!    artifact carries each clause's vote explicitly (see below).
//! 3. **A per-clause execution plan** ([`ClausePlan`]): skip-list walk
//!    for sparse clauses, whole-span lane sweep for dense ones, decided
//!    from the clause's include-word density at compile time. This
//!    replaces the per-engine heuristic that used to live inline in
//!    `bitpack::PackedClause::evaluate_with` — the rule is the same
//!    ([`super::bitpack::prefers_lane_sweep`]), but it is now decided
//!    once, recorded in the artifact, and honored by the packed engine.
//! 4. **Compile-time model stats** ([`CompileStats`]): post-prune
//!    density over *live* clauses, postings count, and a clause-length
//!    histogram. `coordinator/server.rs` feeds the density straight
//!    into [`super::compressed::select_engine`] for the `auto-*`
//!    resolution instead of rebuilding an engine to measure it.
//!
//! The multiclass engines used to derive vote polarity from clause
//! index parity (`j % 2`) and the CoTM engines indexed the weight
//! matrix by clause id — both break the moment pruning or reordering
//! permutes ids. The compiled artifact therefore carries **explicit
//! per-clause polarity** (multiclass) and **explicit per-clause weight
//! columns** (CoTM), keyed by position, with the original id kept as
//! [`CompiledClause::source`] for provenance and the reorder tie-break.
//!
//! Mirrored bit-for-bit by `python/modelcompile.py` (shared golden
//! vectors in the tests below and `python/tests/test_modelcompile.py`),
//! so the prune/reorder/plan logic is validated on toolchain-less CI
//! images. The serializable form lives in [`super::serde`]
//! (`tm-compiled v1`).

use super::bitpack::{pack_bools, prefers_lane_sweep, words_for};
use super::model::{make_literals, ClauseMask, CoTmModel, MultiClassTmModel, TmParams};
use crate::error::{Error, Result};
use crate::util::SplitMix64;

/// Buckets in the compile-time clause-length histogram: bucket
/// `min(len * 8 / 2F, 7)` counts live clauses by include-list length.
pub const HIST_BUCKETS: usize = 8;

/// How much of the compile pass runs (the `compile` ServeConfig knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CompileMode {
    /// No pruning, no reordering: every clause is emitted in model
    /// order. Plans and stats are still computed (both are free and
    /// output-invariant).
    Off,
    /// Dead-clause elimination only — exact, so this is the default.
    #[default]
    Prune,
    /// Prune plus fire-probability reordering from the calibration
    /// batch (no calibration ⇒ prune order is kept).
    Full,
}

impl CompileMode {
    /// Stable lowercase name (TOML / CLI / artifact header).
    pub fn name(&self) -> &'static str {
        match self {
            CompileMode::Off => "off",
            CompileMode::Prune => "prune",
            CompileMode::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Option<CompileMode> {
        match s {
            "off" => Some(CompileMode::Off),
            "prune" => Some(CompileMode::Prune),
            "full" => Some(CompileMode::Full),
            _ => None,
        }
    }
}

/// Per-clause execution plan for the packed engine, decided at compile
/// time from include-word density (replacing the inline per-engine
/// heuristic). Either plan computes the identical predicate — skipped
/// words are all-zero and can never violate — so the choice is a speed
/// decision only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClausePlan {
    /// Walk only the clause's non-zero include words.
    SkipList,
    /// Sweep the whole literal span in SIMD lane steps.
    LaneSweep,
}

impl ClausePlan {
    pub fn name(&self) -> &'static str {
        match self {
            ClausePlan::SkipList => "skip",
            ClausePlan::LaneSweep => "sweep",
        }
    }

    pub fn parse(s: &str) -> Option<ClausePlan> {
        match s {
            "skip" => Some(ClausePlan::SkipList),
            "sweep" => Some(ClausePlan::LaneSweep),
            _ => None,
        }
    }
}

/// Why the compile pass considers a clause dead (it can never fire at
/// inference, so removing it is exact).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeadReason {
    /// All-exclude mask: outputs 0 at inference by the pinned
    /// convention of every engine.
    AllExclude,
    /// Includes both `x_i` and `¬x_i` for some feature: exactly one of
    /// each interleaved pair is set per sample, so the AND can never be
    /// satisfied.
    Contradictory,
}

/// Is this clause dead at inference? All-exclude takes precedence in
/// the report (an empty mask is trivially non-contradictory).
pub fn dead_reason(mask: &ClauseMask) -> Option<DeadReason> {
    if mask.is_empty() {
        return Some(DeadReason::AllExclude);
    }
    let contradictory = mask
        .include
        .chunks(2)
        .any(|pair| pair.len() == 2 && pair[0] && pair[1]);
    if contradictory {
        Some(DeadReason::Contradictory)
    } else {
        None
    }
}

/// The compile-time plan decision for one clause: lane sweep iff the
/// packed include mask is dense enough in words
/// ([`super::bitpack::prefers_lane_sweep`] — the same rule the packed
/// engine used to apply inline per evaluation).
pub fn plan_for_mask(mask: &ClauseMask) -> ClausePlan {
    let words = words_for(mask.include.len());
    let nonzero = pack_bools(&mask.include).iter().filter(|&&w| w != 0).count();
    if prefers_lane_sweep(nonzero, words) {
        ClausePlan::LaneSweep
    } else {
        ClausePlan::SkipList
    }
}

/// One live clause of a compiled artifact, in execution order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompiledClause {
    /// Include mask over the 2F interleaved literals.
    pub mask: ClauseMask,
    /// Original clause id (within its class for multiclass, within the
    /// shared pool for CoTM) — provenance, and the reorder tie-break.
    pub source: u32,
    /// Execution plan for the packed engine.
    pub plan: ClausePlan,
}

impl CompiledClause {
    /// Pack for the bit-parallel engine, carrying this clause's
    /// compile-time plan instead of the pack-time default.
    pub fn packed(&self) -> super::bitpack::PackedClause {
        super::bitpack::PackedClause::from_mask(&self.mask)
            .with_lane_sweep(self.plan == ClausePlan::LaneSweep)
    }
}

/// Compile-time model stats, computed over the model's **live**
/// clauses (the dead ones contribute zero useful work, so counting
/// them in the denominator skews the `auto-*` crossover — the density
/// accounting bug this pass fixed).
#[derive(Debug, Clone, PartialEq)]
pub struct CompileStats {
    /// Clauses in the source model (K·C multiclass, C CoTM).
    pub total_clauses: usize,
    /// Clauses that can fire (total − dead).
    pub live_clauses: usize,
    /// Dead by all-exclude mask.
    pub dead_all_exclude: usize,
    /// Dead by contradictory include pair.
    pub dead_contradictory: usize,
    /// Included literals across live clauses.
    pub postings: usize,
    /// `postings / (live_clauses · 2F)`; 0.0 when no clause is live.
    /// This is the `auto-*` selection input.
    pub density: f64,
    /// Live clauses whose plan is [`ClausePlan::LaneSweep`].
    pub lane_sweep_clauses: usize,
    /// Live clauses whose plan is [`ClausePlan::SkipList`].
    pub skip_list_clauses: usize,
    /// Live-clause include-list lengths, bucketed as
    /// `min(len · HIST_BUCKETS / 2F, HIST_BUCKETS − 1)`.
    pub length_histogram: [usize; HIST_BUCKETS],
}

impl CompileStats {
    /// Stats over a model's clause masks (an intrinsic property of the
    /// model — the same whatever [`CompileMode`] ran).
    pub fn from_masks<'a>(
        literals: usize,
        masks: impl IntoIterator<Item = &'a ClauseMask>,
    ) -> CompileStats {
        let mut s = CompileStats {
            total_clauses: 0,
            live_clauses: 0,
            dead_all_exclude: 0,
            dead_contradictory: 0,
            postings: 0,
            density: 0.0,
            lane_sweep_clauses: 0,
            skip_list_clauses: 0,
            length_histogram: [0; HIST_BUCKETS],
        };
        for mask in masks {
            s.total_clauses += 1;
            match dead_reason(mask) {
                Some(DeadReason::AllExclude) => s.dead_all_exclude += 1,
                Some(DeadReason::Contradictory) => s.dead_contradictory += 1,
                None => {
                    s.live_clauses += 1;
                    let len = mask.included_count();
                    s.postings += len;
                    match plan_for_mask(mask) {
                        ClausePlan::LaneSweep => s.lane_sweep_clauses += 1,
                        ClausePlan::SkipList => s.skip_list_clauses += 1,
                    }
                    let bucket = if literals == 0 {
                        0
                    } else {
                        (len * HIST_BUCKETS / literals).min(HIST_BUCKETS - 1)
                    };
                    s.length_histogram[bucket] += 1;
                }
            }
        }
        if s.live_clauses > 0 && literals > 0 {
            s.density = s.postings as f64 / (s.live_clauses * literals) as f64;
        }
        s
    }
}

/// Compiled multi-class TM artifact: per class, live clauses in
/// execution order with **explicit** vote polarity (the source-index
/// parity rule of Eq. 1, frozen before pruning/reordering permuted
/// ids).
#[derive(Debug, Clone, PartialEq)]
pub struct CompiledMulticlass {
    pub params: TmParams,
    /// `[class]` → live clauses, in execution order.
    pub classes: Vec<Vec<CompiledClause>>,
    /// `[class]` → per live clause, +1/−1 vote polarity (parallel to
    /// `classes`).
    pub polarities: Vec<Vec<i32>>,
    pub stats: CompileStats,
    pub mode: CompileMode,
}

impl CompiledMulticlass {
    /// Structural validation — the artifact boundary check `from_compiled`
    /// constructors and the serde loader rely on.
    pub fn validate(&self) -> Result<()> {
        self.params.validate()?;
        if self.classes.len() != self.params.classes
            || self.polarities.len() != self.params.classes
        {
            return Err(Error::model("compiled class count mismatch"));
        }
        for (k, (class, pols)) in self.classes.iter().zip(&self.polarities).enumerate() {
            if class.len() != pols.len() {
                return Err(Error::model(format!("polarity count mismatch in class {k}")));
            }
            if class.len() > self.params.clauses {
                return Err(Error::model(format!("class {k} has more clauses than the model")));
            }
            for (cc, &pol) in class.iter().zip(pols) {
                if cc.mask.include.len() != self.params.literals() {
                    return Err(Error::model(format!("literal width mismatch in class {k}")));
                }
                if cc.source as usize >= self.params.clauses {
                    return Err(Error::model(format!("source id out of range in class {k}")));
                }
                if pol != 1 && pol != -1 {
                    return Err(Error::model(format!("polarity must be ±1 in class {k}")));
                }
            }
        }
        Ok(())
    }
}

/// Compiled CoTM artifact: the shared live clause pool in execution
/// order plus **explicit** per-clause weight columns (pruned and
/// permuted in lockstep with the clauses).
#[derive(Debug, Clone, PartialEq)]
pub struct CompiledCotm {
    pub params: TmParams,
    /// Live clauses, in execution order.
    pub clauses: Vec<CompiledClause>,
    /// `[live clause][class]` signed weight columns (transposed from
    /// the model's `[class][clause]` rows).
    pub weight_cols: Vec<Vec<i32>>,
    pub stats: CompileStats,
    pub mode: CompileMode,
}

impl CompiledCotm {
    /// Structural validation (see [`CompiledMulticlass::validate`]).
    pub fn validate(&self) -> Result<()> {
        self.params.validate()?;
        if self.weight_cols.len() != self.clauses.len() {
            return Err(Error::model("compiled weight column count mismatch"));
        }
        if self.clauses.len() > self.params.clauses {
            return Err(Error::model("compiled artifact has more clauses than the model"));
        }
        for (cc, col) in self.clauses.iter().zip(&self.weight_cols) {
            if cc.mask.include.len() != self.params.literals() {
                return Err(Error::model("compiled literal width mismatch"));
            }
            if cc.source as usize >= self.params.clauses {
                return Err(Error::model("compiled source id out of range"));
            }
            if col.len() != self.params.classes {
                return Err(Error::model("compiled weight column width mismatch"));
            }
            if col.iter().any(|w| w.abs() > self.params.max_weight) {
                return Err(Error::model("compiled weight exceeds max_weight"));
            }
        }
        Ok(())
    }
}

/// The model→artifact compiler. Construct with a [`CompileMode`], add
/// a calibration batch for [`CompileMode::Full`] reordering, then
/// [`Self::compile_multiclass`] / [`Self::compile_cotm`].
#[derive(Debug, Clone, Default)]
pub struct ModelCompiler {
    mode: CompileMode,
    calibration: Option<Vec<Vec<bool>>>,
}

impl ModelCompiler {
    pub fn new(mode: CompileMode) -> ModelCompiler {
        ModelCompiler { mode, calibration: None }
    }

    pub fn mode(&self) -> CompileMode {
        self.mode
    }

    /// Reorder clauses by fire probability over `rows` (each a
    /// length-F feature vector; widths are checked at compile time).
    pub fn with_calibration(mut self, rows: Vec<Vec<bool>>) -> ModelCompiler {
        self.calibration = Some(rows);
        self
    }

    /// A deterministic synthetic calibration batch (SplitMix64-seeded
    /// uniform features) — what the server uses for `compile = "full"`
    /// when no real traffic sample is available. Reordering is
    /// output-invariant, so a unrepresentative batch can only cost
    /// speed, never correctness.
    pub fn with_synthetic_calibration(
        self,
        features: usize,
        samples: usize,
        seed: u64,
    ) -> ModelCompiler {
        let mut rng = SplitMix64::new(seed);
        let rows = (0..samples)
            .map(|_| (0..features).map(|_| rng.next_bool()).collect())
            .collect();
        self.with_calibration(rows)
    }

    fn check_calibration(&self, features: usize) -> Result<()> {
        if let Some(rows) = &self.calibration {
            for (i, row) in rows.iter().enumerate() {
                if row.len() != features {
                    return Err(Error::model(format!(
                        "calibration row {i} width {} != F={features}",
                        row.len()
                    )));
                }
            }
        }
        Ok(())
    }

    /// Fire count of each emitted clause over the calibration batch
    /// (None when there is no batch — order is then left unchanged).
    fn fire_counts(&self, clauses: &[CompiledClause]) -> Option<Vec<u32>> {
        let rows = self.calibration.as_ref()?;
        let lits: Vec<Vec<bool>> = rows.iter().map(|r| make_literals(r)).collect();
        Some(
            clauses
                .iter()
                .map(|cc| lits.iter().filter(|l| cc.mask.evaluate(l)).count() as u32)
                .collect(),
        )
    }

    /// Sort `clauses` (and any parallel payload, via the returned
    /// permutation applied by the caller) by descending fire count,
    /// ties broken by ascending source id — fully deterministic.
    fn reorder(&self, clauses: &mut Vec<CompiledClause>) -> Option<Vec<usize>> {
        if self.mode != CompileMode::Full {
            return None;
        }
        let fires = self.fire_counts(clauses)?;
        let mut order: Vec<usize> = (0..clauses.len()).collect();
        order.sort_by_key(|&i| (std::cmp::Reverse(fires[i]), clauses[i].source));
        let reordered: Vec<CompiledClause> =
            order.iter().map(|&i| clauses[i].clone()).collect();
        *clauses = reordered;
        Some(order)
    }

    /// Emit the live clauses of one mask list in model order ([`
    /// CompileMode::Off`] keeps dead clauses too — it exists to serve
    /// the legacy pipeline byte-for-byte).
    fn emit(&self, masks: &[ClauseMask]) -> Vec<CompiledClause> {
        masks
            .iter()
            .enumerate()
            .filter(|(_, m)| self.mode == CompileMode::Off || dead_reason(m).is_none())
            .map(|(j, m)| CompiledClause {
                mask: m.clone(),
                source: j as u32,
                plan: plan_for_mask(m),
            })
            .collect()
    }

    pub fn compile_multiclass(&self, model: &MultiClassTmModel) -> Result<CompiledMulticlass> {
        model.validate()?;
        self.check_calibration(model.params.features)?;
        let mut classes = Vec::with_capacity(model.params.classes);
        let mut polarities = Vec::with_capacity(model.params.classes);
        for class in &model.clauses {
            let mut emitted = self.emit(class);
            self.reorder(&mut emitted);
            // Polarity is the *source* index parity (Eq. 1), frozen
            // into the artifact so pruning/reordering cannot skew sums.
            let pols = emitted
                .iter()
                .map(|cc| if cc.source % 2 == 0 { 1 } else { -1 })
                .collect();
            classes.push(emitted);
            polarities.push(pols);
        }
        let stats = CompileStats::from_masks(
            model.params.literals(),
            model.clauses.iter().flatten(),
        );
        Ok(CompiledMulticlass {
            params: model.params.clone(),
            classes,
            polarities,
            stats,
            mode: self.mode,
        })
    }

    pub fn compile_cotm(&self, model: &CoTmModel) -> Result<CompiledCotm> {
        model.validate()?;
        self.check_calibration(model.params.features)?;
        let mut clauses = self.emit(&model.clauses);
        self.reorder(&mut clauses);
        // Weight columns follow their clause through prune + reorder.
        let weight_cols = clauses
            .iter()
            .map(|cc| {
                model
                    .weights
                    .iter()
                    .map(|row| row[cc.source as usize])
                    .collect()
            })
            .collect();
        let stats = CompileStats::from_masks(model.params.literals(), model.clauses.iter());
        Ok(CompiledCotm {
            params: model.params.clone(),
            clauses,
            weight_cols,
            stats,
            mode: self.mode,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::infer::{cotm_class_sums, multiclass_class_sums};

    fn mask_of(literals: usize, lits: &[usize]) -> ClauseMask {
        let mut m = ClauseMask::empty(literals);
        for &l in lits {
            m.include[l] = true;
        }
        m
    }

    // ------------------------------------------------------------------
    // Cross-language golden vectors, shared with python/modelcompile.py
    // (python/tests/test_modelcompile.py asserts the identical prune
    // counts, stats, plans and reordered source orders). The golden
    // models and calibration samples are the same closed-form formulas
    // the invindex/compressed mirrors pin.
    // ------------------------------------------------------------------

    /// F=9, C=4/class, K=3; include(k, j, l) = (3l + 5j + 7k) % 11 == 0.
    fn golden_multiclass() -> MultiClassTmModel {
        let p = TmParams { features: 9, clauses: 4, classes: 3, ..TmParams::iris_paper() };
        let mut m = MultiClassTmModel::zeroed(p);
        for (k, class) in m.clauses.iter_mut().enumerate() {
            for (j, clause) in class.iter_mut().enumerate() {
                for l in 0..18 {
                    clause.include[l] = (3 * l + 5 * j + 7 * k) % 11 == 0;
                }
            }
        }
        m
    }

    /// F=9, C=6, K=3; include(j, l) = (5l + 3j) % 7 == 0,
    /// weight(k, j) = (j + 2k) % 7 − 3.
    fn golden_cotm() -> CoTmModel {
        let p = TmParams { features: 9, clauses: 6, classes: 3, ..TmParams::iris_paper() };
        let mut m = CoTmModel::zeroed(p);
        for (j, clause) in m.clauses.iter_mut().enumerate() {
            for l in 0..18 {
                clause.include[l] = (5 * l + 3 * j) % 7 == 0;
            }
        }
        for (k, row) in m.weights.iter_mut().enumerate() {
            for (j, w) in row.iter_mut().enumerate() {
                *w = ((j + 2 * k) % 7) as i32 - 3;
            }
        }
        m
    }

    /// Sample s: feature i = (i² + 3is + 2s) % 7 < 3.
    fn golden_sample(s: usize) -> Vec<bool> {
        (0..9).map(|i| (i * i + 3 * i * s + 2 * s) % 7 < 3).collect()
    }

    fn golden_calibration() -> Vec<Vec<bool>> {
        (0..6).map(golden_sample).collect()
    }

    /// The hand-worked dead-clause model (multiclass): F=3, K=2, C=4.
    /// class 0: {1,4}, all-exclude, {2,3} (contradictory x1/¬x1), {0}.
    /// class 1: {0,1} (contradictory x0/¬x0), {5}, {0,2}, all-exclude.
    fn dead_multiclass() -> MultiClassTmModel {
        let p = TmParams { features: 3, clauses: 4, classes: 2, ..TmParams::iris_paper() };
        let mut m = MultiClassTmModel::zeroed(p);
        m.clauses[0][0] = mask_of(6, &[1, 4]);
        m.clauses[0][2] = mask_of(6, &[2, 3]);
        m.clauses[0][3] = mask_of(6, &[0]);
        m.clauses[1][0] = mask_of(6, &[0, 1]);
        m.clauses[1][1] = mask_of(6, &[5]);
        m.clauses[1][2] = mask_of(6, &[0, 2]);
        m
    }

    /// The hand-worked dead-clause model (CoTM): F=3, C=5, K=2.
    /// Clauses {4}, all-exclude, {0,4}, {2,3} (contradictory), {1}.
    fn dead_cotm() -> CoTmModel {
        let p = TmParams { features: 3, clauses: 5, classes: 2, ..TmParams::iris_paper() };
        let mut m = CoTmModel::zeroed(p);
        m.clauses[0] = mask_of(6, &[4]);
        m.clauses[2] = mask_of(6, &[0, 4]);
        m.clauses[3] = mask_of(6, &[2, 3]);
        m.clauses[4] = mask_of(6, &[1]);
        m.weights = vec![vec![1, 3, -1, 5, 0], vec![-2, 3, 2, 5, 1]];
        m
    }

    /// All 8 feature combinations of F=3 — the hand-worked calibration.
    fn all_combos() -> Vec<Vec<bool>> {
        (0..8u32)
            .map(|bits| (0..3).map(|i| (bits >> i) & 1 == 1).collect())
            .collect()
    }

    #[test]
    fn dead_reason_classifies_the_three_kinds() {
        assert_eq!(dead_reason(&ClauseMask::empty(6)), Some(DeadReason::AllExclude));
        assert_eq!(
            dead_reason(&mask_of(6, &[2, 3])),
            Some(DeadReason::Contradictory)
        );
        assert_eq!(dead_reason(&mask_of(6, &[0, 2])), None);
        // A pair split across features is not a contradiction.
        assert_eq!(dead_reason(&mask_of(6, &[1, 2])), None);
        // Zero-width masks are the all-exclude degenerate case.
        assert_eq!(dead_reason(&ClauseMask::empty(0)), Some(DeadReason::AllExclude));
    }

    #[test]
    fn plan_rule_matches_the_packed_heuristic_boundaries() {
        // Shared with python/tests/test_modelcompile.py: the rule is
        // lane-sweep iff nonzero_words >= 8 and 2·nonzero >= words.
        // 1 word, sparse -> skip.
        assert_eq!(plan_for_mask(&mask_of(6, &[0])), ClausePlan::SkipList);
        // 16 words, one include per word -> sweep (16 >= 8, 32 >= 16).
        let dense: Vec<usize> = (0..1024).step_by(64).collect();
        assert_eq!(plan_for_mask(&mask_of(1024, &dense)), ClausePlan::LaneSweep);
        // 16 words, every other word -> boundary sweep (8 >= 8, 16 >= 16).
        let half: Vec<usize> = (0..1024).step_by(128).collect();
        assert_eq!(plan_for_mask(&mask_of(1024, &half)), ClausePlan::LaneSweep);
        // 16 words, every 4th word -> skip (4 < 8).
        let quarter: Vec<usize> = (0..1024).step_by(256).collect();
        assert_eq!(plan_for_mask(&mask_of(1024, &quarter)), ClausePlan::SkipList);
        // 14 words, 7 nonzero -> skip (7 < 8 even though 14 >= 14).
        let seven: Vec<usize> = (0..896).step_by(128).collect();
        assert_eq!(plan_for_mask(&mask_of(896, &seven)), ClausePlan::SkipList);
        // 10 words, all nonzero -> sweep.
        let ten: Vec<usize> = (0..640).step_by(64).collect();
        assert_eq!(plan_for_mask(&mask_of(640, &ten)), ClausePlan::LaneSweep);
    }

    #[test]
    fn mode_and_plan_names_roundtrip() {
        for mode in [CompileMode::Off, CompileMode::Prune, CompileMode::Full] {
            assert_eq!(CompileMode::parse(mode.name()), Some(mode));
        }
        assert_eq!(CompileMode::parse("bogus"), None);
        assert_eq!(CompileMode::default(), CompileMode::Prune);
        for plan in [ClausePlan::SkipList, ClausePlan::LaneSweep] {
            assert_eq!(ClausePlan::parse(plan.name()), Some(plan));
        }
        assert_eq!(ClausePlan::parse("bogus"), None);
    }

    #[test]
    fn dead_multiclass_prunes_exactly_and_keeps_explicit_polarity() {
        let m = dead_multiclass();
        let c = ModelCompiler::new(CompileMode::Prune).compile_multiclass(&m).unwrap();
        c.validate().unwrap();
        // Pinned by the Python mirror: stats of the hand-worked model.
        assert_eq!(c.stats.total_clauses, 8);
        assert_eq!(c.stats.dead_all_exclude, 2);
        assert_eq!(c.stats.dead_contradictory, 2);
        assert_eq!(c.stats.live_clauses, 4);
        assert_eq!(c.stats.postings, 6);
        assert!((c.stats.density - 0.25).abs() < 1e-12);
        assert_eq!(c.stats.length_histogram, [0, 2, 2, 0, 0, 0, 0, 0]);
        assert_eq!(c.stats.skip_list_clauses, 4);
        assert_eq!(c.stats.lane_sweep_clauses, 0);
        // Live clauses in source order, polarity from source parity.
        let srcs: Vec<Vec<u32>> = c
            .classes
            .iter()
            .map(|cl| cl.iter().map(|cc| cc.source).collect())
            .collect();
        assert_eq!(srcs, vec![vec![0, 3], vec![1, 2]]);
        assert_eq!(c.polarities, vec![vec![1, -1], vec![-1, 1]]);
    }

    #[test]
    fn full_reorder_is_deterministic_and_pinned() {
        // Hand-worked fire counts over all 8 F=3 combos:
        // class 0: {1,4} fires 2, {0} fires 4 -> order [3, 0].
        // class 1: {5} fires 4, {0,2} fires 2 -> order [1, 2].
        let m = dead_multiclass();
        let c = ModelCompiler::new(CompileMode::Full)
            .with_calibration(all_combos())
            .compile_multiclass(&m)
            .unwrap();
        let srcs: Vec<Vec<u32>> = c
            .classes
            .iter()
            .map(|cl| cl.iter().map(|cc| cc.source).collect())
            .collect();
        assert_eq!(srcs, vec![vec![3, 0], vec![1, 2]]);
        assert_eq!(c.polarities, vec![vec![-1, 1], vec![-1, 1]]);

        // CoTM: fires {4}:4, {0,4}:2, {1}:4 -> order [0, 4, 2], weight
        // columns permuted in lockstep.
        let co = ModelCompiler::new(CompileMode::Full)
            .with_calibration(all_combos())
            .compile_cotm(&dead_cotm())
            .unwrap();
        co.validate().unwrap();
        let srcs: Vec<u32> = co.clauses.iter().map(|cc| cc.source).collect();
        assert_eq!(srcs, vec![0, 4, 2]);
        assert_eq!(co.weight_cols, vec![vec![1, -2], vec![0, 1], vec![-1, 2]]);
        assert_eq!(co.stats.total_clauses, 5);
        assert_eq!(co.stats.dead_all_exclude, 1);
        assert_eq!(co.stats.dead_contradictory, 1);
        assert_eq!(co.stats.live_clauses, 3);
        assert_eq!(co.stats.postings, 4);
        assert!((co.stats.density - 4.0 / 18.0).abs() < 1e-12);
        assert_eq!(co.stats.length_histogram, [0, 2, 1, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn golden_models_compile_to_pinned_stats_and_orders() {
        // Shared with python/tests/test_modelcompile.py — both
        // languages derive these from the closed-form golden formulas.
        let mc = ModelCompiler::new(CompileMode::Full)
            .with_calibration(golden_calibration())
            .compile_multiclass(&golden_multiclass())
            .unwrap();
        assert_eq!(mc.stats.total_clauses, 12);
        assert_eq!(mc.stats.live_clauses, 12);
        assert_eq!(mc.stats.postings, 21);
        assert!((mc.stats.density - 21.0 / (12.0 * 18.0)).abs() < 1e-12);
        assert_eq!(mc.stats.length_histogram, [12, 0, 0, 0, 0, 0, 0, 0]);
        let srcs: Vec<Vec<u32>> = mc
            .classes
            .iter()
            .map(|cl| cl.iter().map(|cc| cc.source).collect())
            .collect();
        assert_eq!(srcs, vec![vec![1, 2, 0, 3], vec![1, 0, 3, 2], vec![0, 2, 3, 1]]);

        let co = ModelCompiler::new(CompileMode::Full)
            .with_calibration(golden_calibration())
            .compile_cotm(&golden_cotm())
            .unwrap();
        assert_eq!(co.stats.postings, 15);
        assert!((co.stats.density - 15.0 / (6.0 * 18.0)).abs() < 1e-12);
        assert_eq!(co.stats.length_histogram, [3, 3, 0, 0, 0, 0, 0, 0]);
        let srcs: Vec<u32> = co.clauses.iter().map(|cc| cc.source).collect();
        assert_eq!(srcs, vec![3, 0, 1, 4, 5, 2]);
    }

    #[test]
    fn off_mode_emits_every_clause_in_model_order() {
        let m = dead_multiclass();
        let c = ModelCompiler::new(CompileMode::Off).compile_multiclass(&m).unwrap();
        for (k, class) in c.classes.iter().enumerate() {
            assert_eq!(class.len(), 4, "class {k}");
            let srcs: Vec<u32> = class.iter().map(|cc| cc.source).collect();
            assert_eq!(srcs, vec![0, 1, 2, 3]);
        }
        // Stats are mode-independent (a property of the model).
        let pruned = ModelCompiler::new(CompileMode::Prune).compile_multiclass(&m).unwrap();
        assert_eq!(c.stats, pruned.stats);
    }

    #[test]
    fn full_without_calibration_keeps_prune_order() {
        let m = dead_cotm();
        let full = ModelCompiler::new(CompileMode::Full).compile_cotm(&m).unwrap();
        let pruned = ModelCompiler::new(CompileMode::Prune).compile_cotm(&m).unwrap();
        assert_eq!(full.clauses, pruned.clauses);
        assert_eq!(full.weight_cols, pruned.weight_cols);
    }

    #[test]
    fn compiled_sums_are_bit_identical_via_direct_walk() {
        // Walk the compiled artifacts directly (mask evaluate + explicit
        // polarity/weights) and diff against the scalar reference on
        // every F=3 input — prune and reorder must be exact.
        let mc_model = dead_multiclass();
        let co_model = dead_cotm();
        for mode in [CompileMode::Off, CompileMode::Prune, CompileMode::Full] {
            let compiler = ModelCompiler::new(mode).with_calibration(all_combos());
            let mc = compiler.compile_multiclass(&mc_model).unwrap();
            let co = compiler.compile_cotm(&co_model).unwrap();
            for x in all_combos() {
                let lits = make_literals(&x);
                let sums: Vec<i32> = mc
                    .classes
                    .iter()
                    .zip(&mc.polarities)
                    .map(|(class, pols)| {
                        class
                            .iter()
                            .zip(pols)
                            .filter(|(cc, _)| cc.mask.evaluate(&lits))
                            .map(|(_, &p)| p)
                            .sum()
                    })
                    .collect();
                assert_eq!(sums, multiclass_class_sums(&mc_model, &x), "{mode:?} {x:?}");
                let mut co_sums = vec![0i32; 2];
                for (cc, col) in co.clauses.iter().zip(&co.weight_cols) {
                    if cc.mask.evaluate(&lits) {
                        for (s, &w) in co_sums.iter_mut().zip(col) {
                            *s += w;
                        }
                    }
                }
                assert_eq!(co_sums, cotm_class_sums(&co_model, &x), "{mode:?} {x:?}");
            }
        }
    }

    #[test]
    fn all_dead_model_compiles_to_zero_live_clauses() {
        let p = TmParams { features: 3, clauses: 2, classes: 2, ..TmParams::iris_paper() };
        let mut m = MultiClassTmModel::zeroed(p.clone());
        m.clauses[1][0] = mask_of(6, &[0, 1]); // contradictory
        let c = ModelCompiler::new(CompileMode::Prune).compile_multiclass(&m).unwrap();
        assert_eq!(c.stats.live_clauses, 0);
        assert_eq!(c.stats.density, 0.0);
        assert!(c.classes.iter().all(|cl| cl.is_empty()));
        c.validate().unwrap();

        let co = ModelCompiler::new(CompileMode::Full)
            .compile_cotm(&CoTmModel::zeroed(p))
            .unwrap();
        assert!(co.clauses.is_empty());
        assert_eq!(co.stats.density, 0.0);
    }

    #[test]
    fn synthetic_calibration_is_deterministic() {
        let a = ModelCompiler::new(CompileMode::Full)
            .with_synthetic_calibration(9, 16, 42)
            .compile_cotm(&golden_cotm())
            .unwrap();
        let b = ModelCompiler::new(CompileMode::Full)
            .with_synthetic_calibration(9, 16, 42)
            .compile_cotm(&golden_cotm())
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn compile_rejects_invalid_inputs() {
        let odd = TmParams { features: 2, clauses: 7, classes: 2, ..TmParams::iris_paper() };
        assert!(ModelCompiler::default()
            .compile_multiclass(&MultiClassTmModel::zeroed(odd))
            .is_err());
        // Calibration width mismatch is a compile error.
        assert!(ModelCompiler::new(CompileMode::Full)
            .with_calibration(vec![vec![true; 4]])
            .compile_cotm(&dead_cotm())
            .is_err());
        // Artifact validation catches a tampered polarity.
        let mut c = ModelCompiler::default().compile_multiclass(&dead_multiclass()).unwrap();
        c.polarities[0][0] = 2;
        assert!(c.validate().is_err());
    }
}
