"""L2: JAX functional models of multi-class TM and CoTM inference.

These are the "golden models" of the paper's §III-A functional
verification: the same classification function the hardware computes
(Eq. 1 for multi-class TM, Eq. 2 for CoTM), written in JAX on top of the
L1 Pallas kernels, AOT-lowered once by ``aot.py`` and executed from the
rust coordinator via PJRT.  Python never runs on the request path.

Shapes (fixed at lowering time, one artifact per model variant):
    features: f32 (B, F)   in {0,1}
    include:  f32 (K, C, 2F) for multi-class, (C, 2F) for CoTM
    weights:  f32 (K, C)     CoTM only (signed integers stored as f32)
Returns f32 (B, K) class sums; argmax/WTA happens downstream in rust,
matching the paper where argmax is the WTA arbiter, a separate block.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.clause_eval import clause_eval, make_literals_kernel
from .kernels.class_sum import class_sum_multiclass, class_sum_weighted


def multiclass_tm_infer(features: jnp.ndarray, include: jnp.ndarray):
    """Multi-class TM forward (Eq. 1): -> class sums f32 (B, K)."""
    k, c, twof = include.shape
    lits = make_literals_kernel(features)
    clauses = clause_eval(lits, include.reshape(k * c, twof))
    return (class_sum_multiclass(clauses, num_classes=k),)


def cotm_infer(features: jnp.ndarray, include: jnp.ndarray, weights: jnp.ndarray):
    """CoTM forward (Eq. 2): -> class sums f32 (B, K)."""
    lits = make_literals_kernel(features)
    clauses = clause_eval(lits, include)
    return (class_sum_weighted(clauses, weights),)


def clause_only(features: jnp.ndarray, include: jnp.ndarray):
    """Clause-evaluation stage alone: -> clause outputs f32 (B, NC).

    Exported as its own artifact so the rust *hybrid* path can run literal
    generation + clause evaluation functionally while simulating the
    time-domain classification stage event-by-event (the paper's split:
    "literal generation and clause output are carried out in the digital
    domain; the class sum and argmax functions are converted to the time
    domain").
    """
    lits = make_literals_kernel(features)
    return (clause_eval(lits, include),)
