"""L1 Pallas kernel: Tsetlin-machine clause evaluation.

The compute hot-spot of TM inference (Algorithm 2 of the paper) is the
conjunction of included literals for every clause:

    clause_j(X) = AND_l ( literal_l OR NOT include_{j,l} )

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper realises
this as per-clause AND planes in 65 nm CMOS; on TPU the same computation is
a masked max-reduction tiled for VMEM.  BlockSpec tiles the *clause*
dimension so each grid step holds one (CLAUSE_TILE × 2F) include block and
the full (B × 2F) literal panel resident in VMEM — the analogue of the
paper's clause-parallel logic planes.  The batch panel is re-used across
all clause tiles (it is the smaller operand), so HBM traffic is
    2F·(B + NC) + B·NC   elements per call, the streaming lower bound.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated analytically in DESIGN.md §9.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Clause-dimension tile.  128 matches the TPU lane width so the
# max-reduction vectorises across the full VPU register; the Iris model
# (NC = 36) pads to a single tile.
CLAUSE_TILE = 128


def _clause_kernel(lit_ref, inc_ref, out_ref):
    """One grid step: literals (B, 2F) × include-tile (TC, 2F) -> (B, TC).

    violated[b, j] = max_l include[j, l] * (1 - lit[b, l])
    out[b, j]      = (1 - violated) * nonempty[j]
    """
    lit = lit_ref[...]  # (B, 2F)
    inc = inc_ref[...]  # (TC, 2F)
    # (B, 1, 2F) against (1, TC, 2F) — broadcast, then reduce over literals.
    violated = jnp.max(inc[None, :, :] * (1.0 - lit[:, None, :]), axis=-1)
    nonempty = (jnp.sum(inc, axis=-1) > 0.0).astype(lit.dtype)  # (TC,)
    out_ref[...] = (1.0 - violated) * nonempty[None, :]


@functools.partial(jax.jit, static_argnames=("clause_tile",))
def clause_eval(
    literals: jnp.ndarray,
    include: jnp.ndarray,
    *,
    clause_tile: int = CLAUSE_TILE,
) -> jnp.ndarray:
    """Evaluate all clauses: literals (B, 2F), include (NC, 2F) -> (B, NC).

    Pads NC up to a multiple of ``clause_tile`` (padded clauses have empty
    include masks, so they evaluate to 0 and are sliced away).
    """
    b, twof = literals.shape
    nc = include.shape[0]
    tiles = pl.cdiv(nc, clause_tile)
    padded = tiles * clause_tile
    if padded != nc:
        include = jnp.pad(include, ((0, padded - nc), (0, 0)))

    out = pl.pallas_call(
        _clause_kernel,
        grid=(tiles,),
        in_specs=[
            # Literal panel: full block, re-read each step (resident in VMEM).
            pl.BlockSpec((b, twof), lambda i: (0, 0)),
            # Include tile: marches along the clause dimension.
            pl.BlockSpec((clause_tile, twof), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, clause_tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, padded), literals.dtype),
        interpret=True,
    )(literals, include)
    return out[:, :nc]


def make_literals_kernel(features: jnp.ndarray) -> jnp.ndarray:
    """(B, F) -> (B, 2F) interleaved literals, as a tiny Pallas kernel.

    Literal generation is a pure wiring stage in the paper's hardware
    (Algorithm 2 lines 8–11); here it is a stack+reshape in VMEM.
    """
    b, f = features.shape

    def _kernel(x_ref, o_ref):
        x = x_ref[...]
        lits = jnp.stack([x, 1.0 - x], axis=-1).reshape(x.shape[0], 2 * x.shape[1])
        o_ref[...] = lits

    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b, 2 * f), features.dtype),
        interpret=True,
    )(features)
