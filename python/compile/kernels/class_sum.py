"""L1 Pallas kernel: class-sum accumulation (Eq. 1 / Eq. 2 of the paper).

Two variants:

* ``class_sum_weighted`` — CoTM (Eq. 2): clause outputs (B, C) contracted
  against a signed weight matrix (K, C).  This is the binary-MAC the paper
  moves into the time domain; on TPU it is an MXU-shaped matmul
  (B × C)·(C × K) in f32.
* ``class_sum_multiclass`` — vanilla multi-class TM (Eq. 1): alternating
  ±1 polarity inside each class group, expressed as the same contraction
  with a constant ±1 weight layout so both variants share one kernel body.

Keeping the contraction in a single Pallas kernel (rather than composing
jnp ops) mirrors the paper's single delay-accumulation module: one fused
pass over the clause outputs, no intermediate (B, K, C) tensor in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(cl_ref, w_ref, out_ref):
    """(B, C) · (C, K) -> (B, K), accumulated in f32 on the MXU."""
    out_ref[...] = jnp.dot(
        cl_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@jax.jit
def class_sum_weighted(clauses: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """CoTM class sums: clauses (B, C) × weights (K, C) -> sums (B, K)."""
    b, c = clauses.shape
    k = weights.shape[0]
    return pl.pallas_call(
        _matvec_kernel,
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,
    )(clauses, weights.T.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("num_classes",))
def class_sum_multiclass(clauses: jnp.ndarray, *, num_classes: int) -> jnp.ndarray:
    """Multi-class TM class sums via the shared contraction kernel.

    clauses: (B, K*C) grouped per class; polarity alternates +,−,+,− within
    each class group (Eq. 1).  Builds the equivalent block-diagonal ±1
    weight matrix (K, K*C) once (it is constant-folded by XLA) and reuses
    the weighted kernel.
    """
    b, total = clauses.shape
    per_class = total // num_classes
    polarity = jnp.where(jnp.arange(per_class) % 2 == 0, 1.0, -1.0)  # (C,)
    eye = jnp.eye(num_classes, dtype=jnp.float32)  # (K, K)
    weights = (eye[:, :, None] * polarity[None, None, :]).reshape(
        num_classes, total
    )  # (K, K*C) block-diagonal ±1
    return class_sum_weighted(clauses, weights)
