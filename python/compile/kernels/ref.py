"""Pure-jnp oracle for the Pallas kernels and the full TM models.

This module is the single source of functional truth for the whole stack:

* the Pallas kernels in ``clause_eval.py`` / ``class_sum.py`` are asserted
  against these functions by ``python/tests/``;
* the rust event-driven hardware architectures are asserted against the
  AOT-compiled L2 model, which itself is asserted against this oracle —
  mirroring the paper's claim that *"all logically equivalent TM
  implementations achieve identical inference accuracy"* (§III-A).

Conventions
-----------
* ``features``: float32 (B, F) with values in {0.0, 1.0}.
* ``include``:  float32 (..., 2F) in {0.0, 1.0}; literal order is
  ``[x0, ¬x0, x1, ¬x1, ...]`` — *interleaved*, matching Algorithm 2 of the
  paper (``literal[2i] = feature[i]; literal[2i+1] = ¬feature[i]``).
* Empty clauses (no includes) output **0 during inference** — the standard
  TM inference convention (they output 1 only during training).
"""

from __future__ import annotations

import jax.numpy as jnp


def make_literals(features: jnp.ndarray) -> jnp.ndarray:
    """(B, F) {0,1} features -> (B, 2F) interleaved literals.

    literal[:, 2i] = x_i ; literal[:, 2i+1] = NOT x_i  (Algorithm 2).
    """
    b, f = features.shape
    lits = jnp.stack([features, 1.0 - features], axis=-1)  # (B, F, 2)
    return lits.reshape(b, 2 * f)


def clause_outputs(literals: jnp.ndarray, include: jnp.ndarray) -> jnp.ndarray:
    """Evaluate conjunctive clauses.

    literals: (B, 2F); include: (NC, 2F)  ->  (B, NC) in {0,1}.

    A clause fires iff every *included* literal is 1:
        out = NOT OR_l( include_l AND NOT literal_l )   AND   (clause non-empty)
    """
    violated = jnp.max(
        include[None, :, :] * (1.0 - literals[:, None, :]), axis=-1
    )  # (B, NC): 1 if any included literal is 0
    nonempty = (jnp.sum(include, axis=-1) > 0).astype(literals.dtype)  # (NC,)
    return (1.0 - violated) * nonempty[None, :]


def class_sums_multiclass(clauses: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    """Multi-class TM class sums (Eq. 1).

    clauses: (B, K*C) grouped per class; within a class clause j has
    polarity + for even j and − for odd j.  Returns (B, K) float32.
    """
    b, total = clauses.shape
    per_class = total // num_classes
    grouped = clauses.reshape(b, num_classes, per_class)
    polarity = jnp.where(jnp.arange(per_class) % 2 == 0, 1.0, -1.0)
    return jnp.sum(grouped * polarity[None, None, :], axis=-1)


def class_sums_cotm(clauses: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """CoTM class sums (Eq. 2): clauses (B, C) · weights (K, C) -> (B, K)."""
    return clauses @ weights.T


def multiclass_tm_infer(features: jnp.ndarray, include: jnp.ndarray) -> jnp.ndarray:
    """Full multi-class TM forward: returns class sums (B, K).

    include: (K, C, 2F) — per-class clause include masks, clause j polarity
    alternates (+,−,+,−,...) inside each class, per Eq. 1.
    """
    k, c, twof = include.shape
    lits = make_literals(features)
    flat = include.reshape(k * c, twof)
    cl = clause_outputs(lits, flat)  # (B, K*C)
    return class_sums_multiclass(cl, k)


def cotm_infer(
    features: jnp.ndarray, include: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """Full CoTM forward: include (C, 2F), weights (K, C) -> sums (B, K)."""
    lits = make_literals(features)
    cl = clause_outputs(lits, include)  # (B, C)
    return class_sums_cotm(cl, weights)


def predict(class_sums: jnp.ndarray) -> jnp.ndarray:
    """argmax with lowest-index tie-break (matches the rust WTA grant rule)."""
    return jnp.argmax(class_sums, axis=-1)
