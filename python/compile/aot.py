"""AOT lowering: JAX (L2+L1) -> HLO *text* artifacts for the rust runtime.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Lowering path: jax.jit(fn).lower(...) -> stablehlo -> XlaComputation
(``return_tuple=True``) -> ``as_hlo_text()``.  The rust side unwraps the
1-tuple with ``to_tuple1()``.

Run once at build time (``make artifacts``); the rust binary is
self-contained afterwards.  A ``manifest.json`` sidecar records every
artifact's entry shapes so the rust runtime can validate inputs without
parsing HLO.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            [--features 16 --clauses 12 --classes 3 --batches 1,16,64]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> HLO text via stablehlo round-trip."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_artifacts(out_dir: str, features: int, clauses: int, classes: int,
                    batches: list[int]) -> dict:
    """Lower every model variant; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {
        "version": 1,
        "literal_order": "interleaved",  # [x0, !x0, x1, !x1, ...]
        "features": features,
        "clauses": clauses,
        "classes": classes,
        "artifacts": {},
    }
    twof = 2 * features

    def emit(name: str, fn, args, arg_shapes, out_shape):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": arg_shapes,
            "out": out_shape,
        }
        print(f"  {name}.hlo.txt  ({len(text)} chars)")

    for b in batches:
        emit(
            f"multiclass_tm_b{b}",
            model.multiclass_tm_infer,
            (f32(b, features), f32(classes, clauses, twof)),
            [[b, features], [classes, clauses, twof]],
            [b, classes],
        )
        emit(
            f"cotm_b{b}",
            model.cotm_infer,
            (f32(b, features), f32(clauses, twof), f32(classes, clauses)),
            [[b, features], [clauses, twof], [classes, clauses]],
            [b, classes],
        )
        emit(
            f"clause_only_b{b}",
            model.clause_only,
            (f32(b, features), f32(clauses, twof)),
            [[b, features], [clauses, twof]],
            [b, clauses],
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--features", type=int, default=16,
                    help="booleanised input features F (paper Iris: 16)")
    ap.add_argument("--clauses", type=int, default=12,
                    help="clauses per class (TM) / shared clauses (CoTM)")
    ap.add_argument("--classes", type=int, default=3)
    ap.add_argument("--batches", default="1,16,64",
                    help="comma-separated batch sizes to lower")
    args = ap.parse_args()
    batches = [int(x) for x in args.batches.split(",")]
    print(f"lowering artifacts -> {args.out_dir} "
          f"(F={args.features} C={args.clauses} K={args.classes} B={batches})")
    lower_artifacts(args.out_dir, args.features, args.clauses, args.classes,
                    batches)


if __name__ == "__main__":
    main()
